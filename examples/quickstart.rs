//! Quickstart: approximate one self-attention call with Skeinformer.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a realistic (Q, K, V) triple, runs exact attention and
//! Algorithm 1 side by side, and prints the approximation error and
//! speedup — the 30-second version of the paper's whole story.  If AOT
//! artifacts are present it also runs the Pallas-kernel version through
//! PJRT to show the L1/L3 layers producing the same numbers.

use skeinformer::attention::{AttentionMethod, Skeinformer, Standard, VMean};
use skeinformer::rng::Rng;
use skeinformer::synth_qkv::{generate, QkvConfig};
use skeinformer::tensor::{spectral_norm, spectral_norm_diff};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n = 2048;
    let p = 64;
    let d = 256;
    println!("Skeinformer quickstart: n={n}, head dim p={p}, sketch size d={d}\n");

    // 1. realistic inputs (pretrained-embedding statistics)
    let mut rng = Rng::new(7);
    let (q, k, v) = generate(&QkvConfig::pretrained(n, p), &mut rng);

    // 2. exact attention (the O(n²) baseline)
    let t0 = Instant::now();
    let exact = Standard::exact(&q, &k, &v, None);
    let t_exact = t0.elapsed();
    let base = spectral_norm(&exact);
    println!("standard attention:   {:>8.1} ms", t_exact.as_secs_f64() * 1e3);

    // 3. Skeinformer (Algorithm 1) — O(n log n)
    let skein = Skeinformer::new(d);
    let t0 = Instant::now();
    let approx = skein.compute(&q, &k, &v, None, &mut Rng::new(1));
    let t_skein = t0.elapsed();
    let err = spectral_norm_diff(&approx, &exact) / base;
    println!(
        "skeinformer:          {:>8.1} ms   rel spectral error {err:.4}   speedup {:.1}x",
        t_skein.as_secs_f64() * 1e3,
        t_exact.as_secs_f64() / t_skein.as_secs_f64()
    );

    // 4. the rank-one baseline, for calibration
    let vm = VMean.compute(&q, &k, &v, None, &mut Rng::new(0));
    println!(
        "v-mean (rank-1):      {:>8} —   rel spectral error {:.4}",
        "-",
        spectral_norm_diff(&vm, &exact) / base
    );

    // 5. the same kernel through the AOT/PJRT path, if built
    let manifest = std::path::Path::new("artifacts/attn_manifest.json");
    if manifest.exists() {
        println!("\nrunning the Pallas-kernel artifact through PJRT ...");
        run_artifact()?;
    } else {
        println!("\n(artifacts/ not built — run `make artifacts` to also exercise the\n AOT Pallas-kernel path through PJRT)");
    }
    Ok(())
}

/// Load artifacts/attn_skeinformer.hlo.txt (the L1 Pallas kernel lowered by
/// jax) and artifacts/attn_standard.hlo.txt, run both on the same inputs.
fn run_artifact() -> anyhow::Result<()> {
    use skeinformer::json;
    use skeinformer::runtime::{literal_f32, scalar_i32, Runtime};

    let man = json::parse(&std::fs::read_to_string("artifacts/attn_manifest.json")?)?;
    let n = man.req_usize("n")?;
    let p = man.req_usize("p")?;
    let rt = Runtime::cpu()?;
    let skein_exe = rt.load_hlo(std::path::Path::new("artifacts/attn_skeinformer.hlo.txt"))?;
    let std_exe = rt.load_hlo(std::path::Path::new("artifacts/attn_standard.hlo.txt"))?;

    let mut rng = Rng::new(11);
    let (q, k, v) = generate(&QkvConfig::pretrained(n, p), &mut rng);
    let inputs = [
        literal_f32(q.data(), &[n, p])?,
        literal_f32(k.data(), &[n, p])?,
        literal_f32(v.data(), &[n, p])?,
        scalar_i32(0),
    ];
    let t0 = Instant::now();
    let skein_out = skein_exe.run(&inputs)?;
    let t_skein = t0.elapsed();
    let t0 = Instant::now();
    let std_out = std_exe.run(&inputs)?;
    let t_std = t0.elapsed();

    let skein_m = skeinformer::tensor::Matrix::from_vec(n, p, skein_out[0].to_vec::<f32>()?);
    let std_m = skeinformer::tensor::Matrix::from_vec(n, p, std_out[0].to_vec::<f32>()?);
    let rel = spectral_norm_diff(&skein_m, &std_m) / spectral_norm(&std_m);
    println!(
        "pallas skeinformer kernel: {:>7.1} ms | exact kernel: {:>7.1} ms | rel error {rel:.4}",
        t_skein.as_secs_f64() * 1e3,
        t_std.as_secs_f64() * 1e3
    );
    Ok(())
}
