//! End-to-end driver (deliverable validation run): train the paper's
//! experimental transformer on the synthetic ListOps task through all
//! three layers — rust coordinator → AOT XLA train-step (jax-lowered,
//! Pallas-validated attention math) → PJRT CPU — for a few hundred steps,
//! logging the loss curve, then evaluate and compare Skeinformer against
//! the exact-attention baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example lra_train
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use skeinformer::config::ExperimentConfig;
use skeinformer::runtime::Runtime;
use skeinformer::train::run_experiment;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/skeinformer_manifest.json").exists() {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first");
    }
    let steps: usize = std::env::args()
        .skip_while(|a| a != "--steps")
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let rt = Runtime::cpu()?;
    let mut results = Vec::new();
    for method in ["skeinformer", "standard_nodrop"] {
        let mut cfg = ExperimentConfig::default();
        cfg.method = method.into();
        cfg.task = "listops".into();
        cfg.train.max_steps = steps;
        cfg.train.eval_every = 20;
        cfg.train.patience = 8;
        cfg.train.eval_examples = 256;

        eprintln!("=== training {method} on listops for ≤{steps} steps ===");
        let outcome = run_experiment(&rt, &cfg)?;
        for p in outcome.history.points() {
            println!(
                "{method} step {:>4}  t={:>6.1}s  train_loss={:.4}  val_loss={:.4}  val_acc={:.4}",
                p.step, p.seconds, p.train_loss, p.val_loss, p.val_accuracy
            );
        }
        println!(
            "{method}: {} steps, best val acc {:.4}, {:.1}s total ({:.1} ms/step)\n",
            outcome.steps, outcome.best_accuracy, outcome.seconds, outcome.ms_per_step
        );
        results.push(outcome);
    }

    // summary: the loss must actually go down, and both methods must beat
    // chance (10 classes ⇒ 0.1) — this is the end-to-end validation gate.
    let (header, rows) = skeinformer::report::figure2_csv(&results);
    skeinformer::bench_util::write_csv("reports/lra_train_e2e.csv", &header, &rows)?;
    println!("loss curves -> reports/lra_train_e2e.csv");
    for o in &results {
        let first = o.history.points().first().map(|p| p.val_loss).unwrap_or(f64::NAN);
        let last_best = o.history.best_val_loss().unwrap_or(f64::NAN);
        println!(
            "{}: val loss {:.3} -> {:.3}, best acc {:.3} (chance 0.10)",
            o.method, first, last_best, o.best_accuracy
        );
        anyhow::ensure!(last_best < first, "{} loss did not decrease", o.method);
        anyhow::ensure!(o.best_accuracy > 0.12, "{} did not beat chance", o.method);
    }
    println!("E2E validation PASSED: all three layers compose and learn.");
    Ok(())
}
