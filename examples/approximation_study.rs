//! Approximation study (the Figure-1 workflow as a library example):
//! sweep sketch sizes for a chosen set of methods on realistic inputs and
//! print a compact loss-vs-d table with standard errors.
//!
//! ```bash
//! cargo run --release --example approximation_study -- --n 1024 --trials 8
//! ```

use skeinformer::attention::{registry, Standard};
use skeinformer::cli::Args;
use skeinformer::metrics::RunningStats;
use skeinformer::rng::Rng;
use skeinformer::synth_qkv::{generate, QkvConfig};
use skeinformer::tensor::{spectral_norm, spectral_norm_diff};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let n = args.get_usize("n", 1024)?;
    let p = args.get_usize("p", 64)?;
    let trials = args.get_usize("trials", 6)? as u64;

    let mut rng = Rng::new(2024);
    let (q, k, v) = generate(&QkvConfig::pretrained(n, p), &mut rng);
    let exact = Standard::exact(&q, &k, &v, None);
    let base = spectral_norm(&exact);

    let focus = ["vmean", "skeinformer", "skein_no_norm", "informer", "linformer",
                 "linformer_jlt", "nystromformer"];
    println!("relative spectral-norm loss ‖BV−R‖₂/‖BV‖₂  (n={n}, {trials} trials)\n");
    print!("{:<18}", "method \\ d");
    let ds = [16usize, 32, 64, 128, 256];
    for d in ds {
        print!("{d:>12}");
    }
    println!();
    for name in focus {
        print!("{name:<18}");
        for d in ds {
            if d > n {
                print!("{:>12}", "-");
                continue;
            }
            let method = registry(d).into_iter().find(|m| m.name() == name).unwrap();
            let mut stats = RunningStats::new();
            for t in 0..trials {
                let out = method.compute(&q, &k, &v, None, &mut Rng::new(10 + t));
                stats.push((spectral_norm_diff(&out, &exact) / base) as f64);
            }
            print!("{:>12}", format!("{:.3}±{:.3}", stats.mean(), stats.std_err()));
        }
        println!();
    }
    println!(
        "\nreading guide: V-Mean is flat (rank-one, no d); Skeinformer should\n\
         drop fastest with d; the unreduced JLT beats the reduced Linformer;\n\
         disabling adaptive row normalization (skein_no_norm) hurts — the\n\
         qualitative shape of the paper's Figure 1."
    );
    Ok(())
}
