//! Serving example: batched inference under an open-loop arrival process,
//! reporting latency percentiles and throughput at several offered loads —
//! the systems-side payoff of an O(n log n) attention: more sequences per
//! second per device.
//!
//! Two engines:
//!
//! * `--engine cpu` (default) — the pure-rust [`BatchedAttention`] path:
//!   clients submit `Arc<[f32]>` Q/K/V slabs of shape
//!   `[heads, seq, head_dim]`, the server wraps them into a `B × H` grid
//!   without copying and fans heads out across the persistent worker
//!   pool.  Works offline, no artifacts needed.  `--pool-size N` sizes
//!   the pool.
//! * `--engine pjrt` — the AOT artifact path (token sequences through the
//!   compiled forward graph); requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --example serving -- --method skeinformer --batch 8 --heads 4
//! ```
//!
//! [`BatchedAttention`]: skeinformer::attention::BatchedAttention

use skeinformer::cli::Args;
use skeinformer::coordinator::attention_server::{
    self, AttentionServerConfig, HeadsRequest, ServeError,
};
use skeinformer::metrics::Percentiles;
use skeinformer::rng::Rng;
use std::sync::mpsc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let pool_size = args.get_usize("pool-size", 0)?;
    if pool_size > 0 {
        skeinformer::pool::set_pool_size(pool_size);
    }
    match args.get_or("engine", "cpu") {
        "cpu" => run_cpu(&args),
        "pjrt" => run_pjrt(&args),
        other => anyhow::bail!("unknown engine {other:?} — expected cpu or pjrt"),
    }
}

/// Drain (receiver, submit-time) pairs concurrently with the submission
/// loop, so recorded latency is submit→reply, not submit→end-of-run.
/// (Replies come back in submission order — the batcher is FIFO — so an
/// in-order blocking drain observes each reply as soon as it is ready.)
fn spawn_latency_collector<T: Send + 'static>(
    check: impl Fn(&T) -> bool + Send + 'static,
) -> (
    mpsc::Sender<(mpsc::Receiver<T>, Instant)>,
    std::thread::JoinHandle<anyhow::Result<Percentiles>>,
) {
    let (pipe_tx, pipe_rx) = mpsc::channel::<(mpsc::Receiver<T>, Instant)>();
    let join = std::thread::spawn(move || {
        let mut latency = Percentiles::default();
        for (rx, sent) in pipe_rx {
            let out = rx.recv()?;
            anyhow::ensure!(check(&out), "bad reply payload");
            latency.push(sent.elapsed().as_secs_f64() * 1e3);
        }
        Ok(latency)
    });
    (pipe_tx, join)
}

fn run_cpu(args: &Args) -> anyhow::Result<()> {
    let cfg = AttentionServerConfig::from_args(args)?;
    let total = args.get_usize("requests", 96)?;
    println!(
        "batched attention service: method={} B<={} H={} n={} p={} d={}",
        cfg.method, cfg.max_batch, cfg.heads, cfg.seq, cfg.head_dim, cfg.d
    );

    for rate_per_s in [50.0f64, 200.0] {
        let handle = attention_server::start(cfg.clone())?;
        let mut rng = Rng::new(123);
        let gap = Duration::from_secs_f64(1.0 / rate_per_s);
        let (pipe, collector) = spawn_latency_collector(|out: &Result<Vec<f32>, ServeError>| {
            matches!(out, Ok(o) if o.iter().all(|x| x.is_finite()))
        });
        let t0 = Instant::now();
        for i in 0..total {
            // absolute-deadline pacing: payload generation time must not
            // erode the offered rate
            let target = t0 + gap.mul_f64(i as f64);
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
            let req = HeadsRequest::random(cfg.request_elems(), &mut rng);
            let _ = pipe.send((handle.submit(req).into_inner(), Instant::now()));
        }
        drop(pipe);
        let collected = collector
            .join()
            .map_err(|_| anyhow::anyhow!("latency collector panicked"))?;
        let wall = t0.elapsed().as_secs_f64();
        let mut latency = match collected {
            Ok(l) => l,
            // reply channels closed early: the serve thread bailed —
            // surface its own error if it has one
            Err(e) => {
                return match handle.shutdown() {
                    Ok(_) => Err(e),
                    Err(server_err) => Err(server_err),
                };
            }
        };
        let stats = handle.shutdown()?;
        println!(
            "offered {rate_per_s:>6.0} seq/s | served {:>4} in {wall:>6.2}s ({:>6.1} seq/s) | \
             batches {:>3} (occ {:.2}, {:.1} ms/batch) | \
             latency p50 {:>7.1} ms  p95 {:>7.1} ms  p99 {:>7.1} ms",
            stats.requests,
            stats.requests as f64 / wall,
            stats.batches,
            stats.mean_occupancy,
            stats.mean_batch_ms,
            latency.percentile(50.0),
            latency.percentile(95.0),
            latency.percentile(99.0),
        );
    }
    Ok(())
}

fn run_pjrt(args: &Args) -> anyhow::Result<()> {
    use skeinformer::config::ExperimentConfig;
    use skeinformer::coordinator::server;
    use skeinformer::data;

    if !std::path::Path::new("artifacts/skeinformer_manifest.json").exists() {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first (or use --engine cpu)");
    }
    let mut cfg = ExperimentConfig::default();
    cfg.method = args.get_or("method", "skeinformer").to_string();
    cfg.task = args.get_or("task", "text").to_string();
    let total = args.get_usize("requests", 96)?;
    let max_wait = Duration::from_millis(args.get_u64("max-wait-ms", 8)?);

    let task = data::by_name(&cfg.task, cfg.model.seq_len).unwrap();
    println!(
        "batched inference service: method={} task={} (batch capacity from artifact)",
        cfg.method, cfg.task
    );

    for rate_per_s in [50.0f64, 200.0] {
        let handle = server::start(cfg.clone(), max_wait);
        let mut rng = Rng::new(123);
        let gap = Duration::from_secs_f64(1.0 / rate_per_s);
        let (pipe, collector) =
            spawn_latency_collector(|logits: &Vec<f32>| logits.iter().all(|x| x.is_finite()));
        let t0 = Instant::now();
        for i in 0..total {
            let target = t0 + gap.mul_f64(i as f64);
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
            let ex = task.sample(&mut rng);
            let _ = pipe.send((handle.submit(ex.tokens), Instant::now()));
        }
        drop(pipe);
        let collected = collector
            .join()
            .map_err(|_| anyhow::anyhow!("latency collector panicked"))?;
        let wall = t0.elapsed().as_secs_f64();
        let mut latency = match collected {
            Ok(l) => l,
            // reply channels closed early: surface the serve thread's own
            // error (e.g. "PJRT unavailable" in offline stub builds)
            Err(e) => {
                return match handle.shutdown() {
                    Ok(_) => Err(e),
                    Err(server_err) => Err(server_err),
                };
            }
        };
        let stats = handle.shutdown()?;
        println!(
            "offered {rate_per_s:>6.0} req/s | served {:>4} in {wall:>6.2}s ({:>6.1} req/s) | \
             batches {:>3} (occ {:.2}) | latency p50 {:>7.1} ms  p95 {:>7.1} ms  p99 {:>7.1} ms",
            stats.requests,
            stats.requests as f64 / wall,
            stats.batches,
            stats.mean_occupancy,
            latency.percentile(50.0),
            latency.percentile(95.0),
            latency.percentile(99.0),
        );
    }
    Ok(())
}
