//! Serving example: the L3 batched-inference service under an open-loop
//! arrival process, reporting latency percentiles and throughput at
//! several offered loads — the systems-side payoff of an O(n log n)
//! attention: more sequences per second per device.
//!
//! ```bash
//! make artifacts && cargo run --release --example serving -- --method skeinformer
//! ```

use skeinformer::cli::Args;
use skeinformer::config::ExperimentConfig;
use skeinformer::coordinator::server;
use skeinformer::data;
use skeinformer::metrics::Percentiles;
use skeinformer::rng::Rng;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/skeinformer_manifest.json").exists() {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first");
    }
    let args = Args::parse(std::env::args().skip(1))?;
    let mut cfg = ExperimentConfig::default();
    cfg.method = args.get_or("method", "skeinformer").to_string();
    cfg.task = args.get_or("task", "text").to_string();
    let total = args.get_usize("requests", 96)?;
    let max_wait = Duration::from_millis(args.get_u64("max-wait-ms", 8)?);

    let task = data::by_name(&cfg.task, cfg.model.seq_len).unwrap();
    println!(
        "batched inference service: method={} task={} (batch capacity from artifact)",
        cfg.method, cfg.task
    );

    for rate_per_s in [50.0f64, 200.0] {
        let handle = server::start(cfg.clone(), max_wait);
        let mut rng = Rng::new(123);
        let mut latency = Percentiles::default();
        let gap = Duration::from_secs_f64(1.0 / rate_per_s);
        let t0 = Instant::now();
        let mut inflight = Vec::new();
        for i in 0..total {
            let ex = task.sample(&mut rng);
            inflight.push((handle.submit(ex.tokens), Instant::now()));
            if i + 1 < total {
                std::thread::sleep(gap);
            }
        }
        for (rx, sent) in inflight {
            let logits = rx.recv()?;
            anyhow::ensure!(logits.iter().all(|x| x.is_finite()));
            latency.push(sent.elapsed().as_secs_f64() * 1e3);
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = handle.shutdown()?;
        println!(
            "offered {rate_per_s:>6.0} req/s | served {:>4} in {wall:>6.2}s ({:>6.1} req/s) | \
             batches {:>3} (occ {:.2}) | latency p50 {:>7.1} ms  p95 {:>7.1} ms  p99 {:>7.1} ms",
            stats.requests,
            stats.requests as f64 / wall,
            stats.batches,
            stats.mean_occupancy,
            latency.percentile(50.0),
            latency.percentile(95.0),
            latency.percentile(99.0),
        );
    }
    Ok(())
}
