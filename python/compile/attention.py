"""Layer-2 attention variants (pure jnp) used inside the training graph.

Every method from the paper's Table 1 is implemented as a drop-in,
single-head function ``(q, k, v, key, mask) -> (n, p)`` so the transformer
in ``model.py`` can swap them via config.  The training graph uses these
jnp forms (differentiable end-to-end); the Pallas kernels in ``kernels/``
implement the same math for the inference/serving hot path and are tested
against ``kernels/ref.py``, which these functions also match (see
``tests/test_attention.py``).

Method registry (paper Table 1 rows → names here):
  standard            Vaswani et al. 2017 (optional attention dropout)
  standard_nodrop     · w/o dropout
  vmean               (1/n) 1 1^T V rank-one baseline
  skeinformer         Algorithm 1 (column sampling + adaptive row norm + PSR)
  skein_uniform       · w/ uniform sampling        (ablation)
  skein_no_norm       · w/o row normalization      (ablation)
  skein_simple_norm   · w/ simple row normalization(ablation)
  skein_no_psr        · w/o pilot sampling reutil. (ablation)
  informer            Zhou et al. 2020 (top-u queries by sparsity measure)
  informer_mask       · w/ padding mask (section 4.4)
  linformer           Wang et al. 2020 (reduced JL form, random projections)
  linformer_jlt       · w/ unreduced JLT: D^{-1} A S S^T V
  performer           Choromanski et al. 2020 (FAVOR+ positive features)
  nystromformer       Xiong et al. 2021 (segment-mean landmarks)
  bigbird             Zaheer et al. 2020 (window+global+random, masked dense)
  reformer            Kitaev et al. 2020 (single-round LSH bucketing)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import ref

_EPS = 1e-30


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _masked_softmax(scores, mask):
    """Row softmax with optional (n,) 0/1 key mask."""
    if mask is not None:
        scores = jnp.where(mask[None, :] > 0, scores, -1e30)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores)
    return e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), _EPS)


def _gumbel_topk_without_replacement(key, log_probs, d):
    g = -jnp.log(-jnp.log(jax.random.uniform(key, log_probs.shape, minval=1e-20, maxval=1.0)))
    # argsort instead of lax.top_k (old-XLA HLO-text compatibility).
    # stop_gradient: selection indices carry no gradient (and grad-of-sort
    # is unsupported by the pinned jax/xla_extension pairing).
    return jnp.argsort(jax.lax.stop_gradient(-(log_probs + g)))[:d]


def _valid_count(mask, n, dtype):
    if mask is None:
        return jnp.asarray(n, dtype)
    return jnp.maximum(jnp.sum(mask.astype(dtype)), 1.0)


# ---------------------------------------------------------------------------
# exact baselines
# ---------------------------------------------------------------------------

def standard(q, k, v, key=None, mask=None, *, dropout: float = 0.0):
    """Exact softmax attention, optional attention-prob dropout."""
    p = q.shape[-1]
    scores = q @ k.T / jnp.sqrt(jnp.asarray(p, q.dtype))
    probs = _masked_softmax(scores, mask)
    if dropout > 0.0 and key is not None:
        keep = jax.random.bernoulli(key, 1.0 - dropout, probs.shape)
        probs = probs * keep / (1.0 - dropout)
    return probs @ v


def standard_nodrop(q, k, v, key=None, mask=None):
    return standard(q, k, v, None, mask, dropout=0.0)


def vmean(q, k, v, key=None, mask=None):
    return ref.vmean_attention(v, mask)


# ---------------------------------------------------------------------------
# Skeinformer (Algorithm 1) + ablations
# ---------------------------------------------------------------------------

def skeinformer(
    q,
    k,
    v,
    key,
    mask=None,
    *,
    d: int = 64,
    uniform_sampling: bool = False,
    row_norm: str = "adaptive",  # "adaptive" | "simple" | "none"
    psr: bool = True,
):
    """Algorithm 1 with ablation switches (Table 1's four ablation rows).

    row_norm="adaptive": geometric-mean fill (Eq. 6), the paper's method.
    row_norm="simple":   normalize by the selected-column sum only, i.e. the
                         row normalization Informer implements.
    row_norm="none":     no normalization — raw sketched product
                         A^{J'} V_{J'} / d rescaled by inverse probabilities
                         (the plain AMM estimator of Prop. 1).
    """
    n, p = q.shape
    d = min(d, n)
    key_pilot, key_col = jax.random.split(key)

    m = _valid_count(mask, n, q.dtype)
    if mask is not None:
        logits = jnp.where(mask > 0, 0.0, -1e30)
        pilot_idx = jax.random.categorical(key_pilot, logits, shape=(d,))
    else:
        pilot_idx = jax.random.randint(key_pilot, (d,), 0, n)

    bj = ref.pilot_scores(q, k, pilot_idx, mask)  # (d, n)

    if uniform_sampling:
        if mask is not None:
            w = mask.astype(q.dtype)
        else:
            w = jnp.ones((n,), q.dtype)
        probs = w / jnp.sum(w)
    else:
        probs = ref.pilot_probabilities(bj, v, mask)

    sel_idx = _gumbel_topk_without_replacement(key_col, jnp.log(jnp.maximum(probs, _EPS)), d)

    k_sel = k[sel_idx]
    v_sel = v[sel_idx]
    a_sel = ref.sampled_exp_scores(q, k_sel)
    if mask is not None:
        a_sel = a_sel * mask[sel_idx][None, :]

    if row_norm == "adaptive":
        if mask is not None:
            v_total = jnp.sum(v * mask[:, None], axis=0)
        else:
            v_total = jnp.sum(v, axis=0)
        v_unsel_sum = v_total - jnp.sum(v_sel, axis=0)
        r = ref.skeinformer_assemble(a_sel, v_sel, v_unsel_sum, m - d)
    elif row_norm == "simple":
        row_sum = jnp.maximum(jnp.sum(a_sel, axis=1), _EPS)
        r = (a_sel @ v_sel) / row_sum[:, None]
    elif row_norm == "none":
        # Unbiased AMM estimator: B S S^T V with S from Definition 3.1,
        # realised as a probability-weighted sum over the sampled columns.
        inv_dp = 1.0 / jnp.maximum(d * probs[sel_idx], _EPS)
        # Rows of B are softmax rows; approximate them with the exp scores
        # normalized by the *estimated* full row sum from the pilot columns.
        est_row_sum = jnp.maximum(jnp.sum(a_sel * inv_dp[None, :], axis=1), _EPS)
        r = ((a_sel * inv_dp[None, :]) @ v_sel) / est_row_sum[:, None]
    else:
        raise ValueError(f"unknown row_norm {row_norm!r}")

    if psr:
        r = r.at[pilot_idx].set(bj @ v)  # line 12
    return r


skein_uniform = functools.partial(skeinformer, uniform_sampling=True)
skein_no_norm = functools.partial(skeinformer, row_norm="none")
skein_simple_norm = functools.partial(skeinformer, row_norm="simple")
skein_no_psr = functools.partial(skeinformer, psr=False)


# ---------------------------------------------------------------------------
# Informer (Zhou et al. 2020)
# ---------------------------------------------------------------------------

def informer(q, k, v, key, mask=None, *, d: int = 64, use_mask: bool = False):
    """ProbSparse self-attention: only the top-u queries (by the sparsity
    measurement M_i, estimated from sampled keys) attend exactly; the
    remaining rows fall back to the mean of V (Informer's row fill).

    ``use_mask=True`` is the paper's section-4.4 padding-aware variant.
    """
    n, p = q.shape
    u = min(d, n)
    key_s, _ = jax.random.split(key)
    m_valid = mask if use_mask else None

    # Sample O(log n)-scaled key subset to estimate M_i = max - mean proxy
    # (the standard Informer implementation uses max-minus-mean of sampled
    # scores as a cheap surrogate for the KL sparsity measurement).
    n_sample = min(u, n)
    if m_valid is not None:
        logits = jnp.where(m_valid > 0, 0.0, -1e30)
        samp = jax.random.categorical(key_s, logits, shape=(n_sample,))
    else:
        samp = jax.random.randint(key_s, (n_sample,), 0, n)
    k_samp = k[samp]  # (s, p)
    scores_samp = q @ k_samp.T / jnp.sqrt(jnp.asarray(p, q.dtype))  # (n, s)
    if m_valid is not None:
        col_ok = m_valid[samp]
        scores_samp = jnp.where(col_ok[None, :] > 0, scores_samp, -1e30)
    sparsity = jnp.max(scores_samp, axis=1) - jnp.mean(scores_samp, axis=1)
    if m_valid is not None:
        sparsity = jnp.where(m_valid > 0, sparsity, -1e30)

    top_idx = jnp.argsort(jax.lax.stop_gradient(-sparsity))[:u]  # argsort, not lax.top_k (old XLA)
    q_top = q[top_idx]
    scores = q_top @ k.T / jnp.sqrt(jnp.asarray(p, q.dtype))  # (u, n)
    probs = _masked_softmax(scores, m_valid)
    exact = probs @ v  # (u, p)

    # Row fill: mean of V (non-causal Informer uses cumulative/global mean).
    mean_v = ref.vmean_attention(v, m_valid)
    out = mean_v.at[top_idx].set(exact)
    return out


informer_mask = functools.partial(informer, use_mask=True)


# ---------------------------------------------------------------------------
# Linformer (Wang et al. 2020)
# ---------------------------------------------------------------------------

def linformer(q, k, v, key, mask=None, *, d: int = 64):
    """Reduced JL form: softmax(Q (S^T K)^T / sqrt(p)) (S^T V).

    S is a fresh (n, d) Gaussian sketch (E = F = S^T / sqrt(d)); the
    published Linformer learns E, F, but the paper analyses exactly this
    random-JL drop-in, which is what we reproduce.
    """
    n, p = q.shape
    s = jax.random.normal(key, (n, d), q.dtype) / jnp.sqrt(jnp.asarray(d, q.dtype))
    if mask is not None:
        s = s * mask[:, None]
    k_proj = s.T @ k  # (d, p)
    v_proj = s.T @ v  # (d, p)
    scores = q @ k_proj.T / jnp.sqrt(jnp.asarray(p, q.dtype))
    probs = _masked_softmax(scores, None)
    return probs @ v_proj


def linformer_jlt(q, k, v, key, mask=None, *, d: int = 64):
    """Unreduced JLT: D^{-1} A S S^T V — the true sketching form Linformer
    deviates from (computes the full attention, then sketches V)."""
    n, p = q.shape
    scores = q @ k.T / jnp.sqrt(jnp.asarray(p, q.dtype))
    b = _masked_softmax(scores, mask)  # (n, n) = D^{-1} A
    s = jax.random.normal(key, (n, d), q.dtype) / jnp.sqrt(jnp.asarray(d, q.dtype))
    if mask is not None:
        s = s * mask[:, None]
    return (b @ s) @ (s.T @ v)


# ---------------------------------------------------------------------------
# Performer (Choromanski et al. 2020)
# ---------------------------------------------------------------------------

def performer(q, k, v, key, mask=None, *, d: int = 64):
    """FAVOR+ with positive softmax features:
    phi(x) = exp(W x - ||x||^2 / 2) / sqrt(m)."""
    n, p = q.shape
    scale = 1.0 / jnp.sqrt(jnp.sqrt(jnp.asarray(p, q.dtype)))
    qs = q * scale
    ks = k * scale
    w = jax.random.normal(key, (d, p), q.dtype)  # unstructured ORF omitted

    def phi(x):
        proj = x @ w.T  # (n, d)
        sq = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
        # subtract max for stability (standard FAVOR+ stabilisation)
        z = proj - sq
        z = z - jnp.max(z)
        return jnp.exp(z) / jnp.sqrt(jnp.asarray(d, x.dtype))

    qp = phi(qs)  # (n, d)
    kp = phi(ks)  # (n, d)
    if mask is not None:
        kp = kp * mask[:, None]
    kv = kp.T @ v  # (d, p)
    normal = kp.T @ jnp.ones((n,), q.dtype)  # (d,)
    out = qp @ kv
    denom = jnp.maximum(qp @ normal, _EPS)
    return out / denom[:, None]


# ---------------------------------------------------------------------------
# Nystromformer (Xiong et al. 2021)
# ---------------------------------------------------------------------------

def _newton_pinv(a, iters: int = 6):
    """Iterative Moore-Penrose pseudo-inverse (the Nystromformer trick)."""
    z = a.T / (jnp.max(jnp.sum(jnp.abs(a), axis=0)) * jnp.max(jnp.sum(jnp.abs(a), axis=1)) + _EPS)
    ident = jnp.eye(a.shape[0], dtype=a.dtype)

    def body(z, _):
        az = a @ z
        z = 0.25 * z @ (13.0 * ident - az @ (15.0 * ident - az @ (7.0 * ident - az)))
        return z, None

    z, _ = jax.lax.scan(body, z, None, length=iters)
    return z


def nystromformer(q, k, v, key=None, mask=None, *, d: int = 64):
    """Nyström approximation with segment-mean landmarks."""
    n, p = q.shape
    m_land = min(d, n)
    seg = n // m_land
    scale = 1.0 / jnp.sqrt(jnp.asarray(p, q.dtype))
    q_land = jnp.mean(q[: seg * m_land].reshape(m_land, seg, p), axis=1)
    k_land = jnp.mean(k[: seg * m_land].reshape(m_land, seg, p), axis=1)

    f1 = _masked_softmax(q @ k_land.T * scale, None)  # (n, m)
    a2 = _masked_softmax(q_land @ k_land.T * scale, None)  # (m, m)
    f3 = _masked_softmax(q_land @ k.T * scale, mask)  # (m, n)
    return f1 @ (_newton_pinv(a2) @ (f3 @ v))


# ---------------------------------------------------------------------------
# BigBird (Zaheer et al. 2020) — masked-dense form
# ---------------------------------------------------------------------------

def bigbird(
    q, k, v, key, mask=None, *, window: int = 3, n_global: int = 2, n_random: int = 3, block: int = 16
):
    """Random + window + global attention, realised as a sparse 0/1 pattern
    applied to the dense score matrix.  At training length (n=128) the
    masked-dense form is exact and simplest; the rust implementation uses
    the block-sparse gather for the large-n benchmarks.
    """
    n, p = q.shape
    nb = max(n // block, 1)
    bi = jnp.arange(nb)
    # window pattern over blocks
    diff = jnp.abs(bi[:, None] - bi[None, :])
    pat = diff <= (window // 2)
    # global: first n_global blocks attend/are attended everywhere
    g = bi < n_global
    pat = pat | g[:, None] | g[None, :]
    # random blocks per row (fixed by key — BigBird's random pattern)
    rnd = jax.random.randint(key, (nb, n_random), 0, nb)
    pat = pat | jnp.any(bi[None, None, :] == rnd[:, :, None], axis=1)
    # expand block pattern to token level
    tok_pat = jnp.repeat(jnp.repeat(pat, block, axis=0), block, axis=1)[:n, :n]

    scores = q @ k.T / jnp.sqrt(jnp.asarray(p, q.dtype))
    scores = jnp.where(tok_pat, scores, -1e30)
    probs = _masked_softmax(scores, mask)
    return probs @ v


# ---------------------------------------------------------------------------
# Reformer (Kitaev et al. 2020) — simplified single-round LSH
# ---------------------------------------------------------------------------

def reformer(q, k, v, key, mask=None, *, n_buckets: int = 8, chunk: int = 32):
    """Single-round LSH attention with shared QK (Reformer ties Q=K).

    Tokens are bucketed by random-rotation argmax, sorted by bucket, and
    attend within fixed-size chunks plus the previous chunk — the standard
    simplification of Reformer's scheme.
    """
    n, p = q.shape
    qk = q  # Reformer shares QK; we take Q as the shared projection.
    rot = jax.random.normal(key, (p, n_buckets // 2), q.dtype)
    proj = qk @ rot  # (n, nb/2)
    buckets = jnp.argmax(jnp.concatenate([proj, -proj], axis=-1), axis=-1)  # (n,)
    order = jnp.argsort(buckets * (n + 1) + jnp.arange(n))  # stable by position
    inv_order = jnp.argsort(order)

    qs = qk[order].reshape(n // chunk, chunk, p)
    vs = v[order].reshape(n // chunk, chunk, p)
    bs = buckets[order].reshape(n // chunk, chunk)
    ms = None if mask is None else mask[order].reshape(n // chunk, chunk)

    # each chunk attends to itself and the previous chunk
    k_prev = jnp.roll(qs, 1, axis=0)
    v_prev = jnp.roll(vs, 1, axis=0)
    b_prev = jnp.roll(bs, 1, axis=0)
    k_cat = jnp.concatenate([qs, k_prev], axis=1)  # (nc, 2c, p)
    v_cat = jnp.concatenate([vs, v_prev], axis=1)
    b_cat = jnp.concatenate([bs, b_prev], axis=1)  # (nc, 2c)

    scale = 1.0 / jnp.sqrt(jnp.asarray(p, q.dtype))
    scores = jnp.einsum("ncp,nmp->ncm", qs, k_cat) * scale
    same_bucket = bs[:, :, None] == b_cat[:, None, :]
    scores = jnp.where(same_bucket, scores, -1e30)
    if ms is not None:
        m_prev = jnp.roll(ms, 1, axis=0)
        m_cat = jnp.concatenate([ms, m_prev], axis=1)
        scores = jnp.where(m_cat[:, None, :] > 0, scores, -1e30)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores)
    probs = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), _EPS)
    out = jnp.einsum("ncm,nmp->ncp", probs, v_cat).reshape(n, p)
    return out[inv_order]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

METHODS = {
    "standard": functools.partial(standard, dropout=0.1),
    "standard_nodrop": standard_nodrop,
    "vmean": vmean,
    "skeinformer": skeinformer,
    "skein_uniform": skein_uniform,
    "skein_no_norm": skein_no_norm,
    "skein_simple_norm": skein_simple_norm,
    "skein_no_psr": skein_no_psr,
    "informer": informer,
    "informer_mask": informer_mask,
    "linformer": linformer,
    "linformer_jlt": linformer_jlt,
    "performer": performer,
    "nystromformer": nystromformer,
    "bigbird": bigbird,
    "reformer": reformer,
}


def get_method(name: str):
    try:
        return METHODS[name]
    except KeyError:
        raise KeyError(f"unknown attention method {name!r}; known: {sorted(METHODS)}") from None
