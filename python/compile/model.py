"""Layer-2 model: a 2-layer transformer encoder classifier in plain jax.

This mirrors the paper's experimental model exactly (section 6.2): 2 layers,
64 embedding dims, 128 FFN dims, 2 attention heads, mean pooling, with the
self-attention module swapped per method via ``attention.METHODS``.

Everything needed for training — forward, softmax cross-entropy, and a
hand-written Adam (lr 1e-4, the paper's optimizer) — lives here so the whole
train step lowers to a single HLO module with **no Python on the request
path**.  Parameters travel as a flat, name-sorted list of arrays; the same
ordering is recorded in the AOT manifest consumed by the rust runtime.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 64
    seq_len: int = 128
    embed: int = 64
    heads: int = 2
    layers: int = 2
    ffn: int = 128
    classes: int = 10
    method: str = "skeinformer"
    # feature budget d: the paper uses 256 at n∈[1k,4k]; we scale it with n
    # to keep d/n comparable (256/1024 -> 32/128 ... default 64 = n/2).
    features: int = 64
    batch: int = 32
    lr: float = 1e-4

    @property
    def head_dim(self) -> int:
        assert self.embed % self.heads == 0
        return self.embed // self.heads


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    """Glorot-ish init; returns a flat {name: array} dict."""
    params: Dict[str, jnp.ndarray] = {}
    k_iter = iter(jax.random.split(key, 6 + 12 * cfg.layers))

    def dense(name, shape, scale=None):
        if scale is None:
            scale = 1.0 / jnp.sqrt(shape[0])
        params[name] = jax.random.normal(next(k_iter), shape, jnp.float32) * scale

    dense("embed/tok", (cfg.vocab, cfg.embed), 0.02)
    dense("embed/pos", (cfg.seq_len, cfg.embed), 0.02)
    for layer in range(cfg.layers):
        pre = f"layer{layer}"
        for nm in ("wq", "wk", "wv", "wo"):
            dense(f"{pre}/attn/{nm}", (cfg.embed, cfg.embed))
        params[f"{pre}/ln1/g"] = jnp.ones((cfg.embed,), jnp.float32)
        params[f"{pre}/ln1/b"] = jnp.zeros((cfg.embed,), jnp.float32)
        params[f"{pre}/ln2/g"] = jnp.ones((cfg.embed,), jnp.float32)
        params[f"{pre}/ln2/b"] = jnp.zeros((cfg.embed,), jnp.float32)
        dense(f"{pre}/ffn/w1", (cfg.embed, cfg.ffn))
        params[f"{pre}/ffn/b1"] = jnp.zeros((cfg.ffn,), jnp.float32)
        dense(f"{pre}/ffn/w2", (cfg.ffn, cfg.embed))
        params[f"{pre}/ffn/b2"] = jnp.zeros((cfg.embed,), jnp.float32)
    params["head/lnf/g"] = jnp.ones((cfg.embed,), jnp.float32)
    params["head/lnf/b"] = jnp.zeros((cfg.embed,), jnp.float32)
    dense("head/cls/w", (cfg.embed, cfg.classes))
    params["head/cls/b"] = jnp.zeros((cfg.classes,), jnp.float32)
    return params


def param_order(params: Dict[str, jnp.ndarray]) -> List[str]:
    """The canonical flatten order shared with the rust manifest."""
    return sorted(params)


def flatten(params: Dict[str, jnp.ndarray]) -> List[jnp.ndarray]:
    return [params[k] for k in param_order(params)]


def unflatten(names: List[str], arrays) -> Dict[str, jnp.ndarray]:
    return dict(zip(names, arrays))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _multihead(cfg: ModelConfig, attn_fn, x, mask, key, wq, wk, wv, wo):
    """x: (n, e).  Splits heads, applies attn_fn per head, merges."""
    n = x.shape[0]
    h, hd = cfg.heads, cfg.head_dim
    q = (x @ wq).reshape(n, h, hd).transpose(1, 0, 2)  # (h, n, hd)
    k = (x @ wk).reshape(n, h, hd).transpose(1, 0, 2)
    v = (x @ wv).reshape(n, h, hd).transpose(1, 0, 2)
    keys = jax.random.split(key, h)
    out = jax.vmap(lambda qq, kk, vv, kk2: attn_fn(qq, kk, vv, kk2, mask))(q, k, v, keys)
    out = out.transpose(1, 0, 2).reshape(n, cfg.embed)
    return out @ wo


def forward(cfg: ModelConfig, params: Dict[str, jnp.ndarray], tokens, mask, key):
    """tokens: (B, n) int32, mask: (B, n) f32 → logits (B, classes).

    The PRNG key drives the attention's sampling (and dropout for the
    standard method); it is folded per example so every sequence in the
    batch sees an independent sketch — matching how the paper's stochastic
    approximations behave under batching.
    """
    method = attention.get_method(cfg.method)

    def attn_fn(q, k, v, kk, m):
        if cfg.method in ("standard", "standard_nodrop", "vmean"):
            return method(q, k, v, kk, m)
        if cfg.method in ("bigbird", "reformer"):
            return method(q, k, v, kk, m)
        return method(q, k, v, kk, m, d=cfg.features)

    def encode_one(tok, m, kk):
        x = params["embed/tok"][tok] + params["embed/pos"]
        x = x * m[:, None]
        for layer in range(cfg.layers):
            pre = f"layer{layer}"
            kk, k_attn = jax.random.split(kk)
            h = _layer_norm(x, params[f"{pre}/ln1/g"], params[f"{pre}/ln1/b"])
            h = _multihead(
                cfg, attn_fn, h, m, k_attn,
                params[f"{pre}/attn/wq"], params[f"{pre}/attn/wk"],
                params[f"{pre}/attn/wv"], params[f"{pre}/attn/wo"],
            )
            x = x + h
            h = _layer_norm(x, params[f"{pre}/ln2/g"], params[f"{pre}/ln2/b"])
            h = jax.nn.gelu(h @ params[f"{pre}/ffn/w1"] + params[f"{pre}/ffn/b1"])
            h = h @ params[f"{pre}/ffn/w2"] + params[f"{pre}/ffn/b2"]
            x = x + h
        x = _layer_norm(x, params["head/lnf/g"], params["head/lnf/b"])
        # mean pooling over valid positions (the paper's pooling)
        denom = jnp.maximum(jnp.sum(m), 1.0)
        pooled = jnp.sum(x * m[:, None], axis=0) / denom
        return pooled @ params["head/cls/w"] + params["head/cls/b"]

    batch = tokens.shape[0]
    keys = jax.random.split(key, batch)
    return jax.vmap(encode_one)(tokens, mask, keys)


# ---------------------------------------------------------------------------
# loss / metrics / adam
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params, tokens, mask, labels, key):
    logits = forward(cfg, params, tokens, mask, key)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).squeeze(-1)
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, acc


def adam_update(cfg: ModelConfig, p, g, m, v, step, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1 ** step)
    vhat = v / (1 - b2 ** step)
    p = p - cfg.lr * mhat / (jnp.sqrt(vhat) + eps)
    return p, m, v


def make_train_step(cfg: ModelConfig, names: List[str]):
    """Returns train_step(flat_params, flat_m, flat_v, step, tokens, mask,
    labels, seed) -> (flat_params', flat_m', flat_v', loss, acc).

    ``step`` is a float32 scalar (Adam bias correction), ``seed`` an int32
    scalar expanded to a PRNG key inside the graph, so the rust coordinator
    only ever feeds plain scalars.
    """

    def train_step(flat_params, flat_m, flat_v, step, tokens, mask, labels, seed):
        params = unflatten(names, flat_params)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), jnp.asarray(step, jnp.int32))
        (loss, acc), grads = jax.value_and_grad(
            lambda pr: loss_fn(cfg, pr, tokens, mask, labels, key), has_aux=True
        )(params)
        new_p, new_m, new_v = [], [], []
        for name, p0, m0, v0 in zip(names, flat_params, flat_m, flat_v):
            p1, m1, v1 = adam_update(cfg, p0, grads[name], m0, v0, step)
            new_p.append(p1)
            new_m.append(m1)
            new_v.append(v1)
        return tuple(new_p + new_m + new_v + [loss, acc])

    return train_step


def make_forward(cfg: ModelConfig, names: List[str]):
    """Returns eval_fn(flat_params, tokens, mask, seed) -> (logits,)."""

    def eval_step(flat_params, tokens, mask, seed):
        params = unflatten(names, flat_params)
        key = jax.random.PRNGKey(seed)
        return (forward(cfg, params, tokens, mask, key),)

    return eval_step
