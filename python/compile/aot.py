"""AOT compile path: lower the L2 graphs to HLO *text* + a JSON manifest.

Run once by ``make artifacts``; python never touches the request path.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per method this emits into ``artifacts/``:

  <method>_train.hlo.txt   train_step: (params, m, v, step, tokens, mask,
                           labels, seed) -> (params', m', v', loss, acc)
  <method>_fwd.hlo.txt     forward:    (params, tokens, mask, seed) -> logits
  <method>_manifest.json   input/output layout + config + init-params blob info
  <method>_params.bin      initial parameters, f32 LE, manifest order

plus two raw-attention artifacts used by the quickstart/serving examples:

  attn_skeinformer.hlo.txt  the L1 Pallas kernel path (q,k,v,seed) -> R
  attn_standard.hlo.txt     the exact-attention Pallas kernel
  attn_manifest.json
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_lib
from .kernels.skeinformer import skeinformer_attention_kernelized
from .kernels.standard import standard_attention_kernel

DEFAULT_METHODS = [
    "standard",
    "standard_nodrop",
    "vmean",
    "skeinformer",
    "skein_uniform",
    "skein_no_norm",
    "skein_simple_norm",
    "skein_no_psr",
    "informer",
    "informer_mask",
    "linformer",
    "linformer_jlt",
    "performer",
    "nystromformer",
    "bigbird",
    "reformer",
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(arr) -> dict:
    return {"shape": list(arr.shape), "dtype": str(arr.dtype)}


def write_params_bin(path: str, flat_params) -> int:
    """Concatenated f32 little-endian arrays in manifest order."""
    total = 0
    with open(path, "wb") as f:
        for arr in flat_params:
            data = jax.device_get(arr).astype("<f4").tobytes()
            f.write(data)
            total += arr.size
    return total


def build_method(method: str, out_dir: str, cfg_overrides: dict) -> None:
    cfg = model_lib.ModelConfig(method=method, **cfg_overrides)
    key = jax.random.PRNGKey(42)
    params = model_lib.init_params(cfg, key)
    names = model_lib.param_order(params)
    flat = model_lib.flatten(params)
    zeros = [jnp.zeros_like(p) for p in flat]

    b, n = cfg.batch, cfg.seq_len
    tokens = jnp.zeros((b, n), jnp.int32)
    mask = jnp.ones((b, n), jnp.float32)
    labels = jnp.zeros((b,), jnp.int32)
    step = jnp.asarray(1.0, jnp.float32)
    seed = jnp.asarray(0, jnp.int32)

    train_step = model_lib.make_train_step(cfg, names)
    # keep_unused=True: methods without stochastic ops would otherwise have
    # their `seed` (etc.) parameter pruned from the entry signature, breaking
    # the fixed input contract the rust runtime feeds.
    lowered_train = jax.jit(train_step, keep_unused=True).lower(
        flat, zeros, zeros, step, tokens, mask, labels, seed)
    train_path = os.path.join(out_dir, f"{method}_train.hlo.txt")
    with open(train_path, "w") as f:
        f.write(to_hlo_text(lowered_train))

    fwd = model_lib.make_forward(cfg, names)
    lowered_fwd = jax.jit(fwd, keep_unused=True).lower(flat, tokens, mask, seed)
    fwd_path = os.path.join(out_dir, f"{method}_fwd.hlo.txt")
    with open(fwd_path, "w") as f:
        f.write(to_hlo_text(lowered_fwd))

    params_bin = os.path.join(out_dir, f"{method}_params.bin")
    total = write_params_bin(params_bin, flat)

    manifest = {
        "method": method,
        "config": {
            "vocab": cfg.vocab,
            "seq_len": cfg.seq_len,
            "embed": cfg.embed,
            "heads": cfg.heads,
            "layers": cfg.layers,
            "ffn": cfg.ffn,
            "classes": cfg.classes,
            "features": cfg.features,
            "batch": cfg.batch,
            "lr": cfg.lr,
        },
        "params": [{"name": nm, **_spec(params[nm])} for nm in names],
        "params_bin": {"file": os.path.basename(params_bin), "f32_count": total},
        "train": {
            "file": os.path.basename(train_path),
            # input order: params*N, m*N, v*N, step, tokens, mask, labels, seed
            "inputs": (
                [{"role": "param", "name": nm, **_spec(params[nm])} for nm in names]
                + [{"role": "adam_m", "name": nm, **_spec(params[nm])} for nm in names]
                + [{"role": "adam_v", "name": nm, **_spec(params[nm])} for nm in names]
                + [
                    {"role": "step", "shape": [], "dtype": "float32"},
                    {"role": "tokens", "shape": [b, n], "dtype": "int32"},
                    {"role": "mask", "shape": [b, n], "dtype": "float32"},
                    {"role": "labels", "shape": [b], "dtype": "int32"},
                    {"role": "seed", "shape": [], "dtype": "int32"},
                ]
            ),
            # output order: params'*N, m'*N, v'*N, loss, acc
            "outputs": {"n_params": len(names), "extra": ["loss", "acc"]},
        },
        "forward": {
            "file": os.path.basename(fwd_path),
            "inputs": (
                [{"role": "param", "name": nm, **_spec(params[nm])} for nm in names]
                + [
                    {"role": "tokens", "shape": [b, n], "dtype": "int32"},
                    {"role": "mask", "shape": [b, n], "dtype": "float32"},
                    {"role": "seed", "shape": [], "dtype": "int32"},
                ]
            ),
            "outputs": {"logits": [b, cfg.classes]},
        },
    }
    with open(os.path.join(out_dir, f"{method}_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] {method}: train={os.path.getsize(train_path)//1024}KiB "
          f"fwd={os.path.getsize(fwd_path)//1024}KiB params={total} f32")


def build_attention_kernels(out_dir: str, n: int = 1024, p: int = 64, d: int = 128) -> None:
    """Raw L1 attention artifacts for the quickstart / serving examples."""
    spec = jax.ShapeDtypeStruct((n, p), jnp.float32)

    def skein(q, k, v, seed):
        key = jax.random.PRNGKey(seed)
        # block_n=256/block_d=32: perf-pass result (EXPERIMENTS.md §Perf L1)
        # — fewer interpret-mode grid steps, same numerics.
        return (skeinformer_attention_kernelized(q, k, v, key, d=d, block_n=n, block_d=d),)

    def std(q, k, v, seed):
        del seed
        return (standard_attention_kernel(q, k, v),)

    seed_spec = jax.ShapeDtypeStruct((), jnp.int32)
    for name, fn in (("attn_skeinformer", skein), ("attn_standard", std)):
        lowered = jax.jit(fn, keep_unused=True).lower(spec, spec, spec, seed_spec)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        print(f"[aot] {name}: {os.path.getsize(path)//1024}KiB")
    with open(os.path.join(out_dir, "attn_manifest.json"), "w") as f:
        json.dump(
            {
                "n": n, "p": p, "d": d,
                "inputs": [
                    {"role": "q", "shape": [n, p], "dtype": "float32"},
                    {"role": "k", "shape": [n, p], "dtype": "float32"},
                    {"role": "v", "shape": [n, p], "dtype": "float32"},
                    {"role": "seed", "shape": [], "dtype": "int32"},
                ],
                "files": {"skeinformer": "attn_skeinformer.hlo.txt",
                          "standard": "attn_standard.hlo.txt"},
            },
            f,
            indent=1,
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--methods", default=",".join(DEFAULT_METHODS),
                    help="comma-separated method list, or 'core' for a fast subset")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--features", type=int, default=64)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--vocab", type=int, default=64)
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()

    if args.methods == "core":
        methods = ["standard", "skeinformer", "linformer", "informer"]
    else:
        methods = [m.strip() for m in args.methods.split(",") if m.strip()]

    os.makedirs(args.out, exist_ok=True)
    overrides = {
        "batch": args.batch,
        "seq_len": args.seq_len,
        "features": args.features,
        "classes": args.classes,
        "vocab": args.vocab,
    }
    for method in methods:
        build_method(method, args.out, overrides)
    if not args.skip_kernels:
        build_attention_kernels(args.out)
    print("[aot] done", file=sys.stderr)


if __name__ == "__main__":
    main()
