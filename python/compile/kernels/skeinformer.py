"""Layer-1 Pallas kernels for Skeinformer (Algorithm 1 hot spots).

Two kernels implement the compute-bound parts of Algorithm 1:

* :func:`pilot_scores` — line 3: ``B_J = softmax(Q_J K^T / sqrt(p))``,
  tiled over pilot rows; each grid step owns a ``(block_d, n)`` strip so
  the row softmax is computed locally and numerically stably.
* :func:`sampled_attention` — lines 7-11 fused: for a block of query rows
  it computes the exp-scores against the ``d`` sampled keys, the partial
  product ``R_{J'}``, the row-sum estimate with geometric-mean fill
  (adaptive row normalization, Eq. 6) and the final normalized output in
  one pass, so the ``(n, d)`` score strip never round-trips to HBM.

TPU adaptation (see DESIGN.md §7): the sampled ``K_{J'}, V_{J'}`` blocks
(d×p) are small enough to persist in VMEM across the whole grid, while the
query rows stream through in MXU-shaped ``(block_n, p)`` tiles.  On CPU the
kernels run with ``interpret=True`` — the only mode the CPU PJRT client can
execute — and the same code lowers to Mosaic for a real TPU target.

The index sampling itself (lines 1, 4-6) is O(n log d) control work, not
MXU work, and deliberately stays in jnp (see ``ref.skeinformer_attention``
and ``attention.py``), mirroring how the paper keeps the sampler on the
host side of the GPU kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pilot_scores", "sampled_attention", "skeinformer_attention_kernelized"]

# Interpret mode is mandatory on CPU PJRT (real-TPU lowering emits a Mosaic
# custom-call the CPU plugin cannot run).  Kept as a module switch so a TPU
# build can flip it off without touching call sites.
INTERPRET = True


def _pilot_kernel(qj_ref, k_ref, scale_ref, bj_ref):
    """One (block_d, n) strip of B_J = softmax(Q_J K^T * scale)."""
    qj = qj_ref[...].astype(jnp.float32)  # (block_d, p)
    k = k_ref[...].astype(jnp.float32)  # (n, p)
    scale = scale_ref[0]
    scores = jax.lax.dot_general(
        qj, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    scores = scores * scale
    scores = scores - jnp.max(scores, axis=1, keepdims=True)
    e = jnp.exp(scores)
    bj = e / jnp.sum(e, axis=1, keepdims=True)
    bj_ref[...] = bj.astype(bj_ref.dtype)


def pilot_scores(qj, k, *, block_d: int = 8):
    """B_J = softmax(Q_J K^T / sqrt(p)) as a Pallas kernel.

    qj : (d, p) pilot query rows, k : (n, p).  Returns (d, n) float32.
    """
    d, p = qj.shape
    n = k.shape[0]
    block_d = min(block_d, d)
    if d % block_d != 0:
        raise ValueError(f"pilot size {d} not divisible by block_d {block_d}")
    scale = jnp.full((1,), 1.0 / jnp.sqrt(p), jnp.float32)
    return pl.pallas_call(
        _pilot_kernel,
        grid=(d // block_d,),
        in_specs=[
            pl.BlockSpec((block_d, p), lambda i: (i, 0)),
            pl.BlockSpec((n, p), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_d, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, n), jnp.float32),
        interpret=INTERPRET,
    )(qj, k, scale)


def _sampled_kernel(q_ref, ksel_ref, vsel_ref, vuns_ref, nuns_ref, scale_ref, r_ref):
    """Fused lines 7-11 for one (block_n, p) strip of query rows.

    a   = exp(q @ K_sel^T * scale)                     (block_n, d)
    g   = exp(mean(log a, axis=1))                     geometric-mean fill
    dhat= sum(a, 1) + n_unsel * g                      Eq. (6)
    r   = (a @ V_sel + g * v_unsel_sum) / dhat         line 11
    """
    q = q_ref[...].astype(jnp.float32)  # (block_n, p)
    ksel = ksel_ref[...].astype(jnp.float32)  # (d, p)
    vsel = vsel_ref[...].astype(jnp.float32)  # (d, p)
    vuns = vuns_ref[...].astype(jnp.float32)  # (1, p)
    n_unsel = nuns_ref[0]
    scale = scale_ref[0]

    logits = jax.lax.dot_general(
        q, ksel, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    logits = jnp.clip(logits * scale, -30.0, 30.0)  # (block_n, d); clip = overflow guard (matches ref)
    a = jnp.exp(logits)
    # log a == logits, so the geometric mean needs no log() call: one fewer
    # transcendental per element than the naive exp(mean(log(exp(l)))).
    g = jnp.exp(jnp.mean(logits, axis=1))  # (block_n,)
    row_sum = jnp.sum(a, axis=1) + n_unsel * g
    r_sel = jax.lax.dot_general(
        a, vsel, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    r = (r_sel + g[:, None] * vuns) / row_sum[:, None]
    r_ref[...] = r.astype(r_ref.dtype)


def sampled_attention(q, k_sel, v_sel, v_unsel_sum, n_unsel, *, block_n: int = 128):
    """Fused column-sampled attention with adaptive row normalization.

    q           : (n, p) queries
    k_sel, v_sel: (d, p) importance-sampled key/value rows
    v_unsel_sum : (p,)   1^T V over the un-selected rows
    n_unsel     : scalar (float) count of un-selected rows

    Returns (n, p) float32 — R of line 11 (pilot reutilization, line 12, is
    a cheap scatter applied by the caller).
    """
    n, p = q.shape
    d = k_sel.shape[0]
    block_n = min(block_n, n)
    if n % block_n != 0:
        raise ValueError(f"sequence length {n} not divisible by block_n {block_n}")
    vuns = jnp.asarray(v_unsel_sum, jnp.float32).reshape(1, p)
    nuns = jnp.asarray(n_unsel, jnp.float32).reshape(1)
    scale = jnp.full((1,), 1.0 / jnp.sqrt(p), jnp.float32)
    return pl.pallas_call(
        _sampled_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((d, p), lambda i: (0, 0)),
            pl.BlockSpec((d, p), lambda i: (0, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.float32),
        interpret=INTERPRET,
    )(q, k_sel, v_sel, vuns, nuns, scale)


@functools.partial(jax.jit, static_argnames=("d", "block_n", "block_d"))
def skeinformer_attention_kernelized(q, k, v, key, *, d: int, block_n: int = 128, block_d: int = 8):
    """Full Algorithm 1 with the two Pallas kernels on the hot path.

    Equivalent to ``ref.skeinformer_attention`` (same sampling trick and
    PRNG layout) but with lines 3 and 7-11 executed by the fused kernels.
    """
    n = q.shape[0]
    key_pilot, key_col = jax.random.split(key)
    pilot_idx = jax.random.randint(key_pilot, (d,), 0, n)

    bj = pilot_scores(q[pilot_idx], k, block_d=block_d)  # (d, n)

    col_norm = jnp.sqrt(jnp.sum(bj * bj, axis=0))
    v_norm = jnp.sqrt(jnp.sum(v * v, axis=-1))
    w = col_norm * v_norm
    probs = w / jnp.maximum(jnp.sum(w), 1e-30)

    gumbel = -jnp.log(-jnp.log(jax.random.uniform(key_col, (n,), minval=1e-20, maxval=1.0)))
    # argsort instead of lax.top_k: the topk HLO op postdates xla_extension
    # 0.5.1's parser; sort round-trips through HLO text cleanly.
    sel_idx = jnp.argsort(jax.lax.stop_gradient(-(jnp.log(jnp.maximum(probs, 1e-30)) + gumbel)))[:d]

    k_sel = k[sel_idx]
    v_sel = v[sel_idx]
    v_unsel_sum = jnp.sum(v, axis=0) - jnp.sum(v_sel, axis=0)

    r = sampled_attention(q, k_sel, v_sel, v_unsel_sum, float(n - d), block_n=block_n)
    # Line 12: pilot sampling reutilization.
    r = r.at[pilot_idx].set(bj @ v)
    return r
