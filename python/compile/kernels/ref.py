"""Pure-jnp reference oracles for the attention kernels.

Everything in this file is the *ground truth* the Pallas kernels (and the
rust implementations, transitively, through golden files) are validated
against.  It mirrors Algorithm 1 of the paper step by step, with no fusion
or tiling tricks, so each line can be read against the paper text.

Shapes follow the paper's notation: Q, K, V are (n, p); the sketch size is
``d`` (the paper's sub-sample size); ``J`` is the pilot index set and ``J'``
the importance-sampled column set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "standard_attention",
    "pilot_scores",
    "pilot_probabilities",
    "sampled_exp_scores",
    "skeinformer_assemble",
    "skeinformer_attention",
    "vmean_attention",
]


def standard_attention(q, k, v, mask=None):
    """Exact softmax attention: softmax(QK^T/sqrt(p)) V.

    ``mask`` is an optional (n,) 0/1 float vector of valid (un-padded) key
    positions; masked keys receive -inf score before the softmax, matching
    the usual padding-mask convention.
    """
    p = q.shape[-1]
    scores = q @ k.T / jnp.sqrt(jnp.asarray(p, q.dtype))
    if mask is not None:
        scores = jnp.where(mask[None, :] > 0, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return probs @ v


def pilot_scores(q, k, pilot_idx, mask=None):
    """Line 3 of Algorithm 1: B_J = softmax(Q_J K^T / sqrt(p)).

    Returns the (d, n) row-stochastic pilot score matrix.  With a padding
    mask, padded *columns* are zeroed after the softmax (section 4.4: the
    columns belonging to the padded part are set to all zero so their
    sampling probability vanishes).
    """
    p = q.shape[-1]
    qj = q[pilot_idx]  # (d, p)
    scores = qj @ k.T / jnp.sqrt(jnp.asarray(p, q.dtype))
    if mask is not None:
        scores = jnp.where(mask[None, :] > 0, scores, -jnp.inf)
    bj = jax.nn.softmax(scores, axis=-1)
    if mask is not None:
        bj = bj * mask[None, :]
    return bj


def pilot_probabilities(bj, v, mask=None):
    """Equation (5): p̂_i ∝ (Σ_k b_{j_k i}^2)^{1/2} · ||V_(i)||."""
    col_norm = jnp.sqrt(jnp.sum(bj * bj, axis=0))  # (n,)
    v_norm = jnp.sqrt(jnp.sum(v * v, axis=-1))  # (n,)
    w = col_norm * v_norm
    if mask is not None:
        w = w * mask
    total = jnp.sum(w)
    # Guard against a fully-degenerate pilot (all-zero weights).
    return jnp.where(total > 0, w / jnp.maximum(total, 1e-30), 1.0 / w.shape[0])


def sampled_exp_scores(q, k_sel, mask_sel=None):
    """Line 7 of Algorithm 1: A^{J'} = exp(Q K_{J'}^T / sqrt(p)).

    ``mask_sel`` optionally zeroes out columns whose sampled index was
    padding (defensive; the sampler never selects padded columns when the
    probabilities are masked).
    """
    p = q.shape[-1]
    # clip logits to ±30 before exp: f32 overflow guard (exp(30)·n ≈ 1e15
    # stays finite); the pallas kernel applies the identical clip.
    logits = jnp.clip(q @ k_sel.T / jnp.sqrt(jnp.asarray(p, q.dtype)), -30.0, 30.0)
    a = jnp.exp(logits)
    if mask_sel is not None:
        a = a * mask_sel[None, :]
    return a


def skeinformer_assemble(a_sel, v_sel, v_unsel_sum, n_unsel):
    """Lines 8-11 of Algorithm 1 (adaptive row normalization).

    a_sel      : (n, d)  exp scores for the selected columns
    v_sel      : (d, p)  selected value rows
    v_unsel_sum: (p,)    1^T V over the *un-selected* rows (line 10's v)
    n_unsel    : scalar  number of un-selected rows (n - d, or mask-aware)

    Returns the intermediate output R (n, p) of line 11.
    """
    r_sel = a_sel @ v_sel  # (n, p), line 7's R_{J'}
    # Line 8: g_i = geometric mean of the selected exp-scores in row i.
    # Computed in log space for stability; a_sel > 0 by construction.
    log_a = jnp.log(jnp.maximum(a_sel, 1e-30))
    g = jnp.exp(jnp.mean(log_a, axis=1))  # (n,)
    # Line 9: d_i = Σ_k a_{i j'_k} + (n - d) g_i
    row_sum = jnp.sum(a_sel, axis=1) + n_unsel * g
    # Line 11: R = diag(d)^{-1} (R_{J'} + g v^T)
    r = (r_sel + g[:, None] * v_unsel_sum[None, :]) / row_sum[:, None]
    return r


def skeinformer_attention(q, k, v, d, key, mask=None):
    """Full Algorithm 1 in plain jnp (the oracle for the fused kernel).

    d    : sub-sample size (pilot size == column-sample size, as in the paper)
    key  : jax PRNG key driving both sampling stages
    mask : optional (n,) 0/1 float padding mask

    Sampling without replacement (line 5) is realised with the Gumbel
    top-k trick, which is exactly sampling-without-replacement for the
    categorical distribution given by the probabilities.
    """
    n = q.shape[0]
    key_pilot, key_col = jax.random.split(key)

    if mask is not None:
        m = jnp.maximum(jnp.sum(mask), 1.0)
        # Pilot sampling restricted to the un-padded range (section 4.4).
        logits = jnp.where(mask > 0, 0.0, -jnp.inf)
        pilot_idx = jax.random.categorical(key_pilot, logits, shape=(d,))
    else:
        m = jnp.asarray(n, q.dtype)
        pilot_idx = jax.random.randint(key_pilot, (d,), 0, n)

    bj = pilot_scores(q, k, pilot_idx, mask)  # (d, n)
    probs = pilot_probabilities(bj, v, mask)  # (n,)

    # Gumbel top-k == weighted sampling without replacement (line 5).
    gumbel = -jnp.log(-jnp.log(jax.random.uniform(key_col, (n,), minval=1e-20, maxval=1.0)))
    # argsort instead of lax.top_k (see kernels/skeinformer.py note)
    sel_idx = jnp.argsort(jax.lax.stop_gradient(-(jnp.log(jnp.maximum(probs, 1e-30)) + gumbel)))[:d]

    k_sel = k[sel_idx]
    v_sel = v[sel_idx]
    a_sel = sampled_exp_scores(q, k_sel)

    # Line 10: v = V_{(J')^C}^T 1 — total value mass minus the selected rows.
    if mask is not None:
        v_total = jnp.sum(v * mask[:, None], axis=0)
    else:
        v_total = jnp.sum(v, axis=0)
    v_unsel_sum = v_total - jnp.sum(v_sel, axis=0)
    n_unsel = m - d

    r = skeinformer_assemble(a_sel, v_sel, v_unsel_sum, n_unsel)

    # Line 12: pilot sampling reutilization — pilot rows get the exact output.
    exact_rows = bj @ v  # (d, p)
    r = r.at[pilot_idx].set(exact_rows)
    return r


def vmean_attention(v, mask=None):
    """The rank-one "V-Mean" baseline: (1/n) 1 1^T V."""
    if mask is not None:
        m = jnp.maximum(jnp.sum(mask), 1.0)
        mean = jnp.sum(v * mask[:, None], axis=0) / m
    else:
        mean = jnp.mean(v, axis=0)
    return jnp.broadcast_to(mean[None, :], v.shape)
