"""Layer-1 Pallas kernel for the exact (standard) softmax attention baseline.

Query rows stream through in ``(block_n, p)`` MXU tiles; each grid step
computes its full score strip against K, takes a numerically-stable softmax
and multiplies into V.  This is the O(n²) baseline every approximation in
the paper is measured against, so it is kept deliberately simple — the
``(block_n, n)`` strip is the quadratic object the paper's Figure 1 and
Table 5 count.

On a real TPU the K/V operands would be streamed block-wise with a running
(max, sum) rescale (flash-attention style) to bound VMEM at large n; under
``interpret=True`` the whole K/V is a single VMEM block, which is exact and
adequate for the CPU correctness path (n ≤ 4096 → K,V ≤ 1 MiB each).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["standard_attention_kernel"]

INTERPRET = True


def _std_kernel(q_ref, k_ref, v_ref, scale_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)  # (block_n, p)
    k = k_ref[...].astype(jnp.float32)  # (n, p)
    v = v_ref[...].astype(jnp.float32)  # (n, p)
    scale = scale_ref[0]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    scores = scores * scale
    scores = scores - jnp.max(scores, axis=1, keepdims=True)
    e = jnp.exp(scores)
    probs = e / jnp.sum(e, axis=1, keepdims=True)
    o = jax.lax.dot_general(
        probs, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = o.astype(o_ref.dtype)


def standard_attention_kernel(q, k, v, *, block_n: int = 128):
    """Exact softmax(QK^T/sqrt(p))V with row-block tiling."""
    n, p = q.shape
    block_n = min(block_n, n)
    if n % block_n != 0:
        raise ValueError(f"sequence length {n} not divisible by block_n {block_n}")
    scale = jnp.full((1,), 1.0 / jnp.sqrt(p), jnp.float32)
    return pl.pallas_call(
        _std_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((n, p), lambda i: (0, 0)),
            pl.BlockSpec((n, p), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.float32),
        interpret=INTERPRET,
    )(q, k, v, scale)
