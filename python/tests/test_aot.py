"""AOT pipeline tests: HLO text artifacts + manifests are rust-loadable.

These run the real lowering for one small method config into a tmp dir and
validate the manifest contract the rust runtime (rust/src/runtime/) relies
on: input ordering, parameter blob layout, and HLO text format.
"""

import json
import os
import struct

import jax
import pytest

from compile import aot, model as model_lib

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build_method("vmean", out, dict(batch=4, seq_len=32, features=16, classes=4, vocab=16))
    return out


def test_hlo_text_format(built):
    text = open(os.path.join(built, "vmean_train.hlo.txt")).read()
    assert text.startswith("HloModule"), "rust HloModuleProto::from_text_file needs HLO text"
    assert "ENTRY" in text


def test_manifest_input_ordering(built):
    man = json.load(open(os.path.join(built, "vmean_manifest.json")))
    n_params = len(man["params"])
    inputs = man["train"]["inputs"]
    assert [i["role"] for i in inputs[:n_params]] == ["param"] * n_params
    assert [i["role"] for i in inputs[n_params:2 * n_params]] == ["adam_m"] * n_params
    assert [i["role"] for i in inputs[2 * n_params:3 * n_params]] == ["adam_v"] * n_params
    tail = [i["role"] for i in inputs[3 * n_params:]]
    assert tail == ["step", "tokens", "mask", "labels", "seed"]
    # names sorted == canonical flatten order
    names = [p["name"] for p in man["params"]]
    assert names == sorted(names)


def test_params_bin_layout(built):
    man = json.load(open(os.path.join(built, "vmean_manifest.json")))
    path = os.path.join(built, man["params_bin"]["file"])
    expect = man["params_bin"]["f32_count"]
    assert os.path.getsize(path) == expect * 4
    total = sum(
        int(np_prod(p["shape"])) for p in man["params"]
    )
    assert total == expect
    # first value is finite f32 (embedding init)
    with open(path, "rb") as f:
        (x,) = struct.unpack("<f", f.read(4))
    assert x == x  # not NaN


def np_prod(shape):
    out = 1
    for s in shape:
        out *= s
    return out


def test_forward_manifest(built):
    man = json.load(open(os.path.join(built, "vmean_manifest.json")))
    fwd = man["forward"]
    assert fwd["outputs"]["logits"] == [4, 4]
    roles = [i["role"] for i in fwd["inputs"]]
    assert roles[-3:] == ["tokens", "mask", "seed"]


def test_attention_kernel_artifacts(tmp_path):
    out = str(tmp_path)
    aot.build_attention_kernels(out, n=128, p=16, d=32)
    man = json.load(open(os.path.join(out, "attn_manifest.json")))
    assert man["n"] == 128
    for f in man["files"].values():
        text = open(os.path.join(out, f)).read()
        assert text.startswith("HloModule")
