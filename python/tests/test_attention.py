"""L2 attention-variant tests: every Table-1 method behaves like attention.

Checks per method: shape/finiteness, padding-mask invariance (padded key
content must not leak into valid outputs), determinism given a key, and the
paper's qualitative approximation ordering on peaked inputs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import attention
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

N, P, D = 128, 16, 32


def qkv(seed=0, scale=1.0, n=N, p=P):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    return (
        jax.random.normal(kq, (n, p)) * scale,
        jax.random.normal(kk, (n, p)) * scale,
        jax.random.normal(kv, (n, p)),
    )


def run(name, q, k, v, seed=0, mask=None):
    fn = attention.get_method(name)
    key = jax.random.PRNGKey(seed)
    if name in ("standard", "standard_nodrop", "vmean", "bigbird", "reformer"):
        return fn(q, k, v, key, mask)
    return fn(q, k, v, key, mask, d=D)


ALL = sorted(attention.METHODS)


@pytest.mark.parametrize("name", ALL)
def test_output_shape_and_finite(name):
    q, k, v = qkv(1)
    out = run(name, q, k, v)
    assert out.shape == v.shape
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("name", ALL)
def test_deterministic_given_key(name):
    q, k, v = qkv(2)
    a = run(name, q, k, v, seed=7)
    b = run(name, q, k, v, seed=7)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize(
    "name",
    # methods with a first-class padding-mask path
    ["standard", "standard_nodrop", "vmean", "skeinformer", "skein_uniform",
     "skein_simple_norm", "skein_no_psr", "informer_mask", "linformer_jlt",
     "performer", "bigbird"],
)
def test_padding_content_invariance(name):
    """Corrupting padded K/V rows must not change valid-row outputs (within
    sampling noise: the key is fixed, so the draw is identical)."""
    q, k, v = qkv(3)
    valid = 96
    mask = jnp.concatenate([jnp.ones(valid), jnp.zeros(N - valid)])
    out1 = run(name, q, k, v, seed=5, mask=mask)
    k2 = k.at[valid:].set(1e3)
    v2 = v.at[valid:].set(-1e3)
    out2 = run(name, q, k2, v2, seed=5, mask=mask)
    np.testing.assert_allclose(
        np.asarray(out1[:valid]), np.asarray(out2[:valid]), rtol=1e-4, atol=1e-4
    )


def test_standard_matches_oracle():
    q, k, v = qkv(4)
    np.testing.assert_allclose(
        run("standard_nodrop", q, k, v), ref.standard_attention(q, k, v),
        rtol=1e-5, atol=1e-5,
    )


def test_standard_dropout_is_stochastic_but_unbiased_scale():
    q, k, v = qkv(5)
    fn = attention.get_method("standard")
    a = fn(q, k, v, jax.random.PRNGKey(0), None)
    b = fn(q, k, v, jax.random.PRNGKey(1), None)
    assert float(jnp.max(jnp.abs(a - b))) > 1e-6  # different dropout masks


def test_vmean_is_rank_one():
    q, k, v = qkv(6)
    out = run("vmean", q, k, v)
    # all rows identical
    assert float(jnp.max(jnp.abs(out - out[0][None, :]))) < 1e-6


def test_skeinformer_matches_ref_oracle():
    """attention.skeinformer (default flags) == kernels.ref.skeinformer_attention."""
    q, k, v = qkv(7)
    key = jax.random.PRNGKey(3)
    got = attention.skeinformer(q, k, v, key, None, d=D)
    want = ref.skeinformer_attention(q, k, v, D, key)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_approximation_ordering_on_peaked_attention():
    """Paper Fig. 1 qualitative shape: skeinformer < vmean error, and the
    adaptive row-norm ablation hurts (no_norm worse than full method)."""
    q, k, v = qkv(8, scale=2.0)
    exact = ref.standard_attention(q, k, v)

    def mean_err(name, trials=8):
        errs = []
        for s in range(trials):
            out = run(name, q, k, v, seed=s)
            errs.append(float(jnp.linalg.norm(out - exact, 2)))
        return np.mean(errs)

    e_skein = mean_err("skeinformer")
    e_vmean = mean_err("vmean")
    e_nonorm = mean_err("skein_no_norm")
    assert e_skein < e_vmean
    assert e_skein < e_nonorm


def test_psr_rows_are_exact():
    """Pilot-reutilized rows must equal the exact attention rows."""
    q, k, v = qkv(9)
    key = jax.random.PRNGKey(11)
    out = attention.skeinformer(q, k, v, key, None, d=D)
    exact = ref.standard_attention(q, k, v)
    # recover the pilot indices the same way the implementation draws them
    key_pilot, _ = jax.random.split(key)
    pilot_idx = jax.random.randint(key_pilot, (D,), 0, N)
    np.testing.assert_allclose(
        np.asarray(out[pilot_idx]), np.asarray(exact[pilot_idx]), rtol=1e-4, atol=1e-5
    )


def test_informer_exact_rows_subset():
    """Informer: selected top-u rows are exact; the rest are the V mean."""
    q, k, v = qkv(10, scale=2.0)
    out = run("informer", q, k, v, seed=1)
    exact = ref.standard_attention(q, k, v)
    vm = jnp.mean(v, axis=0)
    row_err = jnp.linalg.norm(out - exact, axis=1)
    is_mean = jnp.linalg.norm(out - vm[None, :], axis=1) < 1e-5
    # every row is either (nearly) exact or exactly the mean fill
    assert bool(jnp.all((row_err < 1e-3) | is_mean))
    # and at least one of each kind exists
    assert int(jnp.sum(is_mean)) > 0
    assert int(jnp.sum(~is_mean)) > 0


def test_linformer_jlt_better_than_reduced_on_average():
    """The paper's point: the unreduced JLT stays closer to the true output."""
    q, k, v = qkv(12, scale=2.0)
    exact = ref.standard_attention(q, k, v)

    def mean_err(name, trials=16):
        return np.mean([
            float(jnp.linalg.norm(run(name, q, k, v, seed=s) - exact, 2))
            for s in range(trials)
        ])

    assert mean_err("linformer_jlt") < mean_err("linformer")


def test_performer_kernel_positivity():
    """FAVOR+ outputs are convex combos of V rows -> bounded by V range."""
    q, k, v = qkv(13)
    out = run("performer", q, k, v)
    assert float(jnp.max(out)) <= float(jnp.max(v)) + 1e-4
    assert float(jnp.min(out)) >= float(jnp.min(v)) - 1e-4


def test_nystromformer_exact_when_landmarks_equal_n():
    """With one landmark per token, Nystrom should be near-exact."""
    q, k, v = qkv(14, n=32)
    fn = attention.get_method("nystromformer")
    out = fn(q, k, v, jax.random.PRNGKey(0), None, d=32)
    exact = ref.standard_attention(q, k, v)
    np.testing.assert_allclose(out, exact, rtol=5e-2, atol=5e-2)


def test_bigbird_respects_pattern():
    """A token outside window/global/random blocks contributes nothing."""
    q, k, v = qkv(15)
    out1 = run("bigbird", q, k, v, seed=3)
    assert out1.shape == v.shape
    # global property: first block tokens attend everywhere -> their rows
    # differ from a pure-window model when distant V changes.
    v2 = v.at[N - 1].set(v[N - 1] + 100.0)
    out2 = run("bigbird", q, k, v2, seed=3)
    assert float(jnp.max(jnp.abs(out2[0] - out1[0]))) > 1e-3


def test_reformer_permutation_consistency():
    """Bucket-sorted attention must return rows to original positions:
    applying the same permutation to inputs permutes outputs identically."""
    q, k, v = qkv(16)
    out = run("reformer", q, k, v, seed=2)
    assert out.shape == v.shape
    assert bool(jnp.all(jnp.isfinite(out)))
