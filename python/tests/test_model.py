"""L2 model tests: parameter plumbing, forward shapes, and training signal.

The train-signal tests run a handful of Adam steps on a linearly-separable
toy task and assert the loss drops — the minimal guarantee that gradients
flow through every attention variant's sampling machinery.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_lib

jax.config.update("jax_platform_name", "cpu")

CFG = model_lib.ModelConfig(vocab=16, seq_len=32, classes=4, batch=8, features=16, lr=1e-3)


def toy_batch(cfg, seed=0):
    """Label = most frequent token bucket — learnable by mean pooling."""
    rng = np.random.RandomState(seed)
    tokens = rng.randint(0, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32)
    labels = (tokens.sum(axis=1) % cfg.classes).astype(np.int32)
    # make it easy: overwrite half the sequence with a label-marker token
    for i, y in enumerate(labels):
        tokens[i, : cfg.seq_len // 2] = y
    mask = np.ones((cfg.batch, cfg.seq_len), np.float32)
    return jnp.asarray(tokens), jnp.asarray(mask), jnp.asarray(labels)


def test_init_params_shapes():
    params = model_lib.init_params(CFG, jax.random.PRNGKey(0))
    assert params["embed/tok"].shape == (16, 64)
    assert params["embed/pos"].shape == (32, 64)
    assert params["head/cls/w"].shape == (64, 4)
    # 2 per embed + 12 per layer * 2 + 4 head
    assert len(params) == 2 + 12 * CFG.layers + 4


def test_param_order_is_stable_and_total():
    params = model_lib.init_params(CFG, jax.random.PRNGKey(0))
    names = model_lib.param_order(params)
    assert names == sorted(names)
    flat = model_lib.flatten(params)
    rebuilt = model_lib.unflatten(names, flat)
    for nm in names:
        np.testing.assert_array_equal(np.asarray(rebuilt[nm]), np.asarray(params[nm]))


@pytest.mark.parametrize("method", ["standard", "skeinformer", "linformer", "vmean"])
def test_forward_shape(method):
    import dataclasses
    cfg = dataclasses.replace(CFG, method=method)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(1))
    tokens, mask, _ = toy_batch(cfg)
    logits = model_lib.forward(cfg, params, tokens, mask, jax.random.PRNGKey(2))
    assert logits.shape == (cfg.batch, cfg.classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_forward_respects_padding():
    params = model_lib.init_params(CFG, jax.random.PRNGKey(1))
    tokens, mask, _ = toy_batch(CFG)
    mask2 = mask.at[:, 24:].set(0.0)
    tokens_junk = tokens.at[:, 24:].set(7)
    l1 = model_lib.forward(CFG, params, tokens, mask2, jax.random.PRNGKey(0))
    tokens_junk2 = tokens.at[:, 24:].set(3)
    l2 = model_lib.forward(CFG, params, tokens_junk2, mask2, jax.random.PRNGKey(0))
    # padded token *content* must not affect pooled logits
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("method", ["standard_nodrop", "skeinformer", "informer",
                                    "linformer", "performer", "nystromformer"])
def test_loss_decreases(method):
    import dataclasses
    cfg = dataclasses.replace(CFG, method=method)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(3))
    names = model_lib.param_order(params)
    flat = model_lib.flatten(params)
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]
    step_fn = jax.jit(model_lib.make_train_step(cfg, names))
    tokens, mask, labels = toy_batch(cfg)

    losses = []
    for step in range(30):
        out = step_fn(flat, m, v, float(step + 1), tokens, mask, labels, 0)
        n = len(names)
        flat, m, v = list(out[:n]), list(out[n:2 * n]), list(out[2 * n:3 * n])
        losses.append(float(out[3 * n]))
    assert losses[-1] < losses[0] * 0.9, f"{method}: {losses[0]:.3f} -> {losses[-1]:.3f}"


def test_adam_bias_correction_first_step():
    """After one step with gradient g, Adam moves by ~lr * sign(g)."""
    cfg = CFG
    p = jnp.ones((4,))
    g = jnp.asarray([1.0, -1.0, 2.0, -0.5])
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    p1, _, _ = model_lib.adam_update(cfg, p, g, m, v, 1.0)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p - cfg.lr * jnp.sign(g)), rtol=1e-4)
