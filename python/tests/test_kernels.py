"""L1 correctness: Pallas kernels vs the pure-jnp oracle in kernels/ref.py.

hypothesis sweeps shapes/dtypes; every property is an exact-math identity
(same sampling keys on both sides), so tolerances only absorb float
reassociation from tiling.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.skeinformer import (
    pilot_scores,
    sampled_attention,
    skeinformer_attention_kernelized,
)
from compile.kernels.standard import standard_attention_kernel

jax.config.update("jax_platform_name", "cpu")


def make_qkv(seed, n, p, dtype=jnp.float32, scale=1.0):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (n, p), dtype) * scale
    k = jax.random.normal(kk, (n, p), dtype) * scale
    v = jax.random.normal(kv, (n, p), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# standard kernel
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([64, 128, 256, 384]),
    p=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**16),
    scale=st.sampled_from([0.3, 1.0, 3.0]),
)
def test_standard_kernel_matches_ref(n, p, seed, scale):
    q, k, v = make_qkv(seed, n, p, scale=scale)
    got = standard_attention_kernel(q, k, v, block_n=64)
    want = ref.standard_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_standard_kernel_bf16_inputs():
    q, k, v = make_qkv(7, 128, 32, dtype=jnp.bfloat16)
    got = standard_attention_kernel(q, k, v)
    want = ref.standard_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                                  v.astype(jnp.float32))
    # bf16 inputs, f32 accumulate: tolerance is the bf16 mantissa.
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


def test_standard_kernel_rejects_ragged():
    q, k, v = make_qkv(0, 100, 16)
    with pytest.raises(ValueError):
        standard_attention_kernel(q, k, v, block_n=64)


def test_standard_kernel_rows_convex():
    """Each output row is a convex combination of V rows -> bounded by V."""
    q, k, v = make_qkv(3, 128, 16)
    out = standard_attention_kernel(q, k, v)
    assert float(jnp.max(out)) <= float(jnp.max(v)) + 1e-5
    assert float(jnp.min(out)) >= float(jnp.min(v)) - 1e-5


# ---------------------------------------------------------------------------
# pilot scores kernel
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([64, 128, 256]),
    p=st.sampled_from([16, 32]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_pilot_scores_matches_ref(n, p, d, seed):
    q, k, _ = make_qkv(seed, n, p)
    idx = jax.random.randint(jax.random.PRNGKey(seed + 1), (d,), 0, n)
    got = pilot_scores(q[idx], k, block_d=8)
    want = ref.pilot_scores(q, k, idx)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_pilot_scores_row_stochastic():
    q, k, _ = make_qkv(11, 256, 32)
    idx = jnp.arange(16)
    bj = pilot_scores(q[idx], k)
    np.testing.assert_allclose(jnp.sum(bj, axis=1), jnp.ones(16), rtol=1e-5)
    assert float(jnp.min(bj)) >= 0.0


# ---------------------------------------------------------------------------
# fused sampled-attention kernel
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([128, 256]),
    p=st.sampled_from([16, 32, 64]),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**16),
)
def test_sampled_attention_matches_assemble(n, p, d, seed):
    q, k, v = make_qkv(seed, n, p)
    idx = jax.random.permutation(jax.random.PRNGKey(seed + 2), n)[:d]
    k_sel, v_sel = k[idx], v[idx]
    v_unsel_sum = jnp.sum(v, axis=0) - jnp.sum(v_sel, axis=0)
    got = sampled_attention(q, k_sel, v_sel, v_unsel_sum, float(n - d), block_n=64)
    a_sel = ref.sampled_exp_scores(q, k_sel)
    want = ref.skeinformer_assemble(a_sel, v_sel, v_unsel_sum, float(n - d))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_sampled_attention_block_invariance():
    """Tiling must not change the numbers (pure data parallel over rows)."""
    q, k, v = make_qkv(5, 256, 32)
    idx = jnp.arange(32)
    vu = jnp.sum(v[32:], axis=0)
    a = sampled_attention(q, k[idx], v[idx], vu, 224.0, block_n=32)
    b = sampled_attention(q, k[idx], v[idx], vu, 224.0, block_n=256)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# end-to-end kernelized Algorithm 1
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_kernelized_skeinformer_matches_ref(seed):
    n, p, d = 256, 32, 64
    q, k, v = make_qkv(seed, n, p)
    key = jax.random.PRNGKey(seed + 3)
    got = skeinformer_attention_kernelized(q, k, v, key, d=d, block_n=64)
    want = ref.skeinformer_attention(q, k, v, d, key)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_kernelized_approximates_exact_attention():
    """Approximation quality: with d = n/4 on peaked attention, skeinformer
    must beat the rank-one V-Mean baseline (the paper's sanity ablation)."""
    n, p, d = 256, 32, 64
    q, k, v = make_qkv(21, n, p, scale=2.0)  # sharper attention rows
    exact = ref.standard_attention(q, k, v)
    errs = []
    for s in range(8):
        r = skeinformer_attention_kernelized(q, k, v, jax.random.PRNGKey(s), d=d)
        errs.append(float(jnp.linalg.norm(r - exact, 2)))
    vmean_err = float(jnp.linalg.norm(ref.vmean_attention(v) - exact, 2))
    assert np.mean(errs) < vmean_err


def test_kernelized_deterministic_given_key():
    n, p, d = 128, 16, 32
    q, k, v = make_qkv(2, n, p)
    key = jax.random.PRNGKey(9)
    a = skeinformer_attention_kernelized(q, k, v, key, d=d)
    b = skeinformer_attention_kernelized(q, k, v, key, d=d)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
