# Convenience targets the module docs reference.
#
# `make artifacts` needs a python environment with jax installed (the L2
# lowering path); everything else is pure rust and works offline.

.PHONY: artifacts build test test-doc bench stream-bench cache-bench prefill-bench tier-bench net-bench shard-bench shard-smoke obs-bench kernel-bench fmt clippy doc

artifacts:
	python3 python/compile/aot.py --out artifacts

build:
	cargo build --release

test:
	cargo test -q

# rustdoc runnable examples (the v2 attention API docs are executable)
test-doc:
	cargo test --doc

bench:
	cargo bench --bench batched_throughput

# streaming decode probe: session append-one-token vs full recompute
stream-bench:
	cargo bench --bench streaming_decode

# paged KV cache probe: tok/s + resident KV bytes, shared vs disjoint
# prefixes, window in {512, 2048, inf} (also runs the prefill suite)
cache-bench:
	cargo bench --bench kv_cache

# chunked-prefill ingest sweep (chunk in {1, block, 4xblock}) +
# batch-slab dedupe hit-rate probe only
prefill-bench:
	cargo bench --bench kv_cache -- --prefill

# tier-ladder sweep only: f32-only vs f16 vs int8 vs f16+int8 vs spill
# at one capacity, alternating shared/disjoint streams
tier-bench:
	cargo bench --bench kv_cache -- --tiers

# TCP serving front end: req/s and per-step occupancy, socket vs
# in-process, 1 vs 4 client connections
net-bench:
	cargo bench --bench serving_net

# shard coordinator sweep: req/s and per-shard occupancy through a
# coordinator over {1, 2, 4} engine shards -> reports/sharding.csv
shard-bench:
	cargo bench --bench sharding

# telemetry overhead probe: req/s with telemetry off / on / on+live
# trace+scrape consumer -> reports/telemetry.csv
obs-bench:
	cargo bench --bench telemetry_overhead

# per-kernel GFLOP/s sweep across every supported ISA (scalar / sse2 /
# avx2) plus the seed's 4-way scalar dot as the legacy baseline
# -> reports/kernels.csv
kernel-bench:
	cargo bench --features simd --bench kernels

# quick cluster smoke for CI: two engine shards + a coordinator on
# loopback, driven by the stock client (one-shots and a decode stream);
# shard 0 exposes /metrics, validated with `skein scrape`
shard-smoke: build
	target/release/skein serve --listen 127.0.0.1:7971 --shard-of 2 --shard-index 0 \
	  --metrics-addr 127.0.0.1:7981 --serve-secs 25 & \
	target/release/skein serve --listen 127.0.0.1:7972 --shard-of 2 --shard-index 1 --serve-secs 25 & \
	sleep 1; \
	target/release/skein coordinator --shards 127.0.0.1:7971,127.0.0.1:7972 \
	  --listen 127.0.0.1:7970 --serve-secs 20 & \
	sleep 1; \
	target/release/skein client --addr 127.0.0.1:7970 --requests 32 --window 8 && \
	target/release/skein client --addr 127.0.0.1:7970 --stream --tokens 32 && \
	target/release/skein scrape --addr 127.0.0.1:7981; \
	wait

fmt:
	cargo fmt --check

clippy:
	cargo clippy -- -D warnings

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
