# Convenience targets the module docs reference.
#
# `make artifacts` needs a python environment with jax installed (the L2
# lowering path); everything else is pure rust and works offline.

.PHONY: artifacts build test bench fmt clippy doc

artifacts:
	python3 python/compile/aot.py --out artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench batched_throughput

fmt:
	cargo fmt --check

clippy:
	cargo clippy -- -D warnings

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
