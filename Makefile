# Convenience targets the module docs reference.
#
# `make artifacts` needs a python environment with jax installed (the L2
# lowering path); everything else is pure rust and works offline.

.PHONY: artifacts build test test-doc bench stream-bench cache-bench prefill-bench tier-bench net-bench fmt clippy doc

artifacts:
	python3 python/compile/aot.py --out artifacts

build:
	cargo build --release

test:
	cargo test -q

# rustdoc runnable examples (the v2 attention API docs are executable)
test-doc:
	cargo test --doc

bench:
	cargo bench --bench batched_throughput

# streaming decode probe: session append-one-token vs full recompute
stream-bench:
	cargo bench --bench streaming_decode

# paged KV cache probe: tok/s + resident KV bytes, shared vs disjoint
# prefixes, window in {512, 2048, inf} (also runs the prefill suite)
cache-bench:
	cargo bench --bench kv_cache

# chunked-prefill ingest sweep (chunk in {1, block, 4xblock}) +
# batch-slab dedupe hit-rate probe only
prefill-bench:
	cargo bench --bench kv_cache -- --prefill

# tier-ladder sweep only: f32-only vs f16 vs int8 vs f16+int8 vs spill
# at one capacity, alternating shared/disjoint streams
tier-bench:
	cargo bench --bench kv_cache -- --tiers

# TCP serving front end: req/s and per-step occupancy, socket vs
# in-process, 1 vs 4 client connections
net-bench:
	cargo bench --bench serving_net

fmt:
	cargo fmt --check

clippy:
	cargo clippy -- -D warnings

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
