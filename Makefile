# Convenience targets the module docs reference.
#
# `make artifacts` needs a python environment with jax installed (the L2
# lowering path); everything else is pure rust and works offline.

.PHONY: artifacts build test test-doc bench stream-bench cache-bench fmt clippy doc

artifacts:
	python3 python/compile/aot.py --out artifacts

build:
	cargo build --release

test:
	cargo test -q

# rustdoc runnable examples (the v2 attention API docs are executable)
test-doc:
	cargo test --doc

bench:
	cargo bench --bench batched_throughput

# streaming decode probe: session append-one-token vs full recompute
stream-bench:
	cargo bench --bench streaming_decode

# paged KV cache probe: tok/s + resident KV bytes, shared vs disjoint
# prefixes, window in {512, 2048, inf}
cache-bench:
	cargo bench --bench kv_cache

fmt:
	cargo fmt --check

clippy:
	cargo clippy -- -D warnings

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
