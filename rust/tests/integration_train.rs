//! Integration tests over the training stack: TrainSession stepping the
//! real AOT artifacts, the experiment runner, and the inference server.
//! All skip gracefully when artifacts are missing.

use skeinformer::config::ExperimentConfig;
use skeinformer::data::Batcher;
use skeinformer::rng::Rng;
use skeinformer::runtime::Runtime;
use skeinformer::train::{run_experiment, TrainSession};
use std::path::Path;

fn ready() -> bool {
    Path::new("artifacts/skeinformer_manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !ready() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn train_step_reduces_loss_on_fixed_batch() {
    require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let cfg = ExperimentConfig::default();
    let mut session = TrainSession::load(&rt, &cfg).unwrap();
    let task = skeinformer::data::by_name("listops", session.seq_len()).unwrap();
    let batcher = Batcher::new(task.as_ref(), session.batch(), session.seq_len());
    let batch = batcher.next_batch(&mut Rng::new(1));

    // repeatedly stepping on the same batch must drive its loss down
    let (first_loss, _) = session.step(&batch).unwrap();
    let mut last = first_loss;
    for _ in 0..15 {
        let (l, _) = session.step(&batch).unwrap();
        last = l;
    }
    assert!(
        last < first_loss * 0.9,
        "loss did not decrease on fixed batch: {first_loss} -> {last}"
    );
}

#[test]
fn forward_is_deterministic_and_shaped() {
    require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let cfg = ExperimentConfig::default();
    let session = TrainSession::load(&rt, &cfg).unwrap();
    let task = skeinformer::data::by_name("text", session.seq_len()).unwrap();
    let batcher = Batcher::new(task.as_ref(), session.batch(), session.seq_len());
    let batch = batcher.next_batch(&mut Rng::new(2));
    let a = session.forward(&batch).unwrap();
    let b = session.forward(&batch).unwrap();
    assert_eq!(a.len(), session.batch() * session.classes());
    assert_eq!(a, b, "forward not deterministic given fixed seed");
    assert!(a.iter().all(|x| x.is_finite()));
}

#[test]
fn evaluate_reports_sane_metrics() {
    require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let cfg = ExperimentConfig::default();
    let session = TrainSession::load(&rt, &cfg).unwrap();
    let task = skeinformer::data::by_name("listops", session.seq_len()).unwrap();
    let batcher = Batcher::new(task.as_ref(), session.batch(), session.seq_len());
    let mut rng = Rng::new(3);
    let batches: Vec<_> = (0..3).map(|_| batcher.next_batch(&mut rng)).collect();
    let (loss, acc) = session.evaluate(&batches).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));
    // untrained model ≈ chance on a 10-class task
    assert!(acc < 0.5, "untrained accuracy suspiciously high: {acc}");
}

#[test]
fn run_experiment_end_to_end_short() {
    require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let mut cfg = ExperimentConfig::default();
    cfg.method = "skeinformer".into();
    cfg.task = "text".into();
    cfg.train.max_steps = 30;
    cfg.train.eval_every = 10;
    cfg.train.patience = 10;
    cfg.train.eval_examples = 64;
    let outcome = run_experiment(&rt, &cfg).unwrap();
    assert_eq!(outcome.method, "skeinformer");
    assert!(outcome.steps > 0 && outcome.steps <= 30);
    assert!(!outcome.history.is_empty());
    assert!(outcome.ms_per_step > 0.0);
    // history is monotone in step and time
    let pts = outcome.history.points();
    for w in pts.windows(2) {
        assert!(w[1].step > w[0].step);
        assert!(w[1].seconds >= w[0].seconds);
    }
}

#[test]
fn early_stopping_respects_patience() {
    require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let mut cfg = ExperimentConfig::default();
    // vmean on pathfinder barely learns -> early stop path gets exercised
    cfg.method = "vmean".into();
    cfg.task = "pathfinder".into();
    cfg.train.max_steps = 200;
    cfg.train.eval_every = 5;
    cfg.train.patience = 2;
    cfg.train.eval_examples = 32;
    let outcome = run_experiment(&rt, &cfg).unwrap();
    assert!(
        outcome.steps < 200,
        "expected early stop, ran all {} steps",
        outcome.steps
    );
}

#[test]
fn inference_server_round_trip() {
    require_artifacts!();
    let cfg = ExperimentConfig::default();
    let task = skeinformer::data::by_name("listops", cfg.model.seq_len).unwrap();
    let handle =
        skeinformer::coordinator::server::start(cfg.clone(), std::time::Duration::from_millis(3));
    let mut rng = Rng::new(5);
    let mut rxs = Vec::new();
    for _ in 0..40 {
        let ex = skeinformer::data::Task::sample(task.as_ref(), &mut rng);
        rxs.push(handle.submit(ex.tokens));
    }
    for rx in rxs {
        let logits = rx.recv().expect("reply");
        assert_eq!(logits.len(), cfg.model.classes);
        assert!(logits.iter().all(|x| x.is_finite()));
    }
    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.requests, 40);
    assert!(stats.batches >= 2, "batching never formed multiple batches");
    assert!(stats.mean_occupancy > 0.0);
}

#[test]
fn seed_changes_training_trajectory_but_not_contract() {
    require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let mut cfg = ExperimentConfig::default();
    cfg.train.max_steps = 6;
    cfg.train.eval_every = 3;
    cfg.train.eval_examples = 32;
    let o1 = run_experiment(&rt, &cfg).unwrap();
    cfg.train.seed = 777;
    let o2 = run_experiment(&rt, &cfg).unwrap();
    // different seeds -> different data stream -> different losses
    let l1 = o1.history.points().last().unwrap().val_loss;
    let l2 = o2.history.points().last().unwrap().val_loss;
    assert!((l1 - l2).abs() > 1e-9, "seeds produced identical trajectories");
}

#[test]
fn checkpoint_roundtrip_through_session() {
    require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let cfg = ExperimentConfig::default();
    let mut session = TrainSession::load(&rt, &cfg).unwrap();
    let task = skeinformer::data::by_name("listops", session.seq_len()).unwrap();
    let batcher = Batcher::new(task.as_ref(), session.batch(), session.seq_len());
    let mut rng = Rng::new(9);
    for _ in 0..3 {
        let b = batcher.next_batch(&mut rng);
        session.step(&b).unwrap();
    }
    let ck = session.snapshot();
    let dir = std::env::temp_dir().join("skein_session_ckpt");
    let prefix = dir.join("run");
    ck.save(&prefix).unwrap();
    let loaded = skeinformer::train::Checkpoint::load(&prefix).unwrap();

    // restoring into a fresh session reproduces the same forward outputs
    let mut fresh = TrainSession::load(&rt, &cfg).unwrap();
    let probe = batcher.next_batch(&mut rng);
    let before = fresh.forward(&probe).unwrap();
    fresh.restore(&loaded).unwrap();
    let after = fresh.forward(&probe).unwrap();
    let trained = session.forward(&probe).unwrap();
    assert_ne!(before, after, "restore had no effect");
    assert_eq!(after, trained, "restored state differs from source session");
    assert_eq!(fresh.steps_taken(), 3);
    let _ = std::fs::remove_dir_all(dir);
}
