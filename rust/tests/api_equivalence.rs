//! Attention API v2 equivalence suite.
//!
//! Pins the two contracts the redesign promises:
//!
//! 1. **`compute_into` ≡ legacy `compute`** — for every registry method
//!    and every mask class, the zero-allocation path produces bitwise the
//!    bytes the allocating path produces at the same seed, including into
//!    dirty reused outputs and with a shared long-lived scratch.
//! 2. **Sessions ≡ full recompute** — a session fed one token at a time
//!    matches a from-scratch computation over the same K/V: bitwise for
//!    the exact incremental sessions (standard / vmean / linformer), and
//!    bitwise-at-the-epoch-seed for the recompute sessions of
//!    approximating methods (re-pilot stride 1 → the epoch seed is
//!    `session_seed(seed, n)`).

use skeinformer::attention::{
    self, session_epoch, session_seed, AttentionMethod, AttnInputs, AttnScratch, Linformer,
    SessionSpec, Standard, VMean,
};
use skeinformer::rng::Rng;
use skeinformer::tensor::Matrix;

const N: usize = 48;
const P: usize = 8;
const D: usize = 16;

fn toy(seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let mut mk = || {
        let mut m = Matrix::zeros(N, P);
        rng.fill_normal(m.data_mut());
        m
    };
    (mk(), mk(), mk())
}

/// The mask classes every contract is checked under: unmasked, padded
/// tail, and a sparse interior mask.
fn mask_classes() -> Vec<Option<Vec<f32>>> {
    let padded: Vec<f32> = (0..N).map(|i| if i < N - 12 { 1.0 } else { 0.0 }).collect();
    let sparse: Vec<f32> = (0..N).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
    vec![None, Some(padded), Some(sparse)]
}

#[test]
fn compute_into_is_bitwise_identical_to_compute_for_every_method_and_mask() {
    let (q, k, v) = toy(1);
    // one scratch shared across every method and mask class: buffer reuse
    // must never leak state between calls
    let mut scratch = AttnScratch::new();
    for mask in mask_classes() {
        let mask = mask.as_deref();
        for method in attention::registry(D) {
            for seed in [0u64, 7, 991] {
                let legacy = method.compute(&q, &k, &v, mask, &mut Rng::new(seed));
                let mut out = Matrix::full(N, P, f32::NAN); // dirty reuse
                method.compute_into(
                    &AttnInputs::new(&q, &k, &v).with_mask(mask).with_seed(seed),
                    &mut out,
                    &mut scratch,
                );
                assert_eq!(
                    out.max_abs_diff(&legacy),
                    0.0,
                    "{} diverged (seed {seed}, mask {:?})",
                    method.name(),
                    mask.map(|m| m.iter().filter(|x| **x == 0.0).count())
                );
            }
        }
    }
}

#[test]
fn repeated_compute_into_with_one_scratch_is_stable() {
    // the same call through the same scratch twice in a row must not be
    // perturbed by recycled buffer contents
    let (q, k, v) = toy(2);
    let mut scratch = AttnScratch::new();
    for method in attention::registry(D) {
        let inputs = AttnInputs::new(&q, &k, &v).with_seed(4);
        let mut a = Matrix::zeros(N, P);
        method.compute_into(&inputs, &mut a, &mut scratch);
        let mut b = Matrix::full(N, P, -3.25);
        method.compute_into(&inputs, &mut b, &mut scratch);
        assert_eq!(a.max_abs_diff(&b), 0.0, "{} unstable under scratch reuse", method.name());
    }
}

#[test]
fn session_one_token_at_a_time_matches_full_recompute_for_every_method() {
    // stride 1: every append re-pilots, so querying with the full square
    // Q equals a from-scratch compute at the derived epoch seed — exactly
    // (diff 0.0) for every registry method.  Exact incremental sessions
    // are additionally pinned against their *base*-seed recompute below.
    let (q, k, v) = toy(3);
    let base_seed = 21u64;
    for method in attention::registry(D) {
        let mut session = method.begin_session(
            SessionSpec::new(P).with_seed(base_seed).with_repilot_stride(1),
        );
        for i in 0..N {
            session.append(k.row(i), v.row(i));
        }
        assert_eq!(session.len(), N, "{}", method.name());
        let got = session.query(&q);
        let want = match method.name() {
            // exact incremental sessions: seed-independent (vmean) or
            // tied to the base seed's sketch stream (linformer)
            "vmean" => method.compute(&q, &k, &v, None, &mut Rng::new(0)),
            "linformer" => method.compute(&q, &k, &v, None, &mut Rng::new(base_seed)),
            _ => {
                let epoch = session_epoch(N, 1);
                method.compute(&q, &k, &v, None, &mut Rng::new(session_seed(base_seed, epoch)))
            }
        };
        assert_eq!(
            got.max_abs_diff(&want),
            0.0,
            "{} session deviates from full recompute",
            method.name()
        );
    }
}

#[test]
fn exact_sessions_decode_token_by_token() {
    // the decode loop proper: one query row per appended token, checked
    // against the growing-prefix recompute
    let (q, k, v) = toy(4);
    let mut scratch = AttnScratch::new();

    // standard: exact streaming softmax
    let mut std_sess = Standard.begin_session(SessionSpec::new(P));
    // vmean: running mean
    let mut vm_sess = VMean.begin_session(SessionSpec::new(P));
    // linformer: incremental sketch projections
    let lin = Linformer::new(6);
    let mut lin_sess = lin.begin_session(SessionSpec::new(P).with_seed(17));

    for t in 0..N {
        std_sess.append(k.row(t), v.row(t));
        vm_sess.append(k.row(t), v.row(t));
        lin_sess.append(k.row(t), v.row(t));
        if t % 7 != 3 {
            continue; // query a few prefixes, not all (keeps the test fast)
        }
        let prefix: Vec<usize> = (0..=t).collect();
        let kp = k.gather_rows(&prefix);
        let vp = v.gather_rows(&prefix);
        let qt = Matrix::from_vec(1, P, q.row(t).to_vec());
        let mut out = Matrix::zeros(1, P);

        std_sess.query_into(&qt, &mut out, &mut scratch);
        let want = Standard::exact(&qt, &kp, &vp, None);
        assert_eq!(out.max_abs_diff(&want), 0.0, "standard decode at t={t}");

        vm_sess.query_into(&qt, &mut out, &mut scratch);
        let want = VMean.compute(&qt, &kp, &vp, None, &mut Rng::new(0));
        assert_eq!(out.max_abs_diff(&want), 0.0, "vmean decode at t={t}");

        lin_sess.query_into(&qt, &mut out, &mut scratch);
        let want = lin.compute(&qt, &kp, &vp, None, &mut Rng::new(17));
        assert_eq!(out.max_abs_diff(&want), 0.0, "linformer decode at t={t}");
    }
}

#[test]
fn repilot_stride_freezes_randomness_within_an_epoch() {
    let (q, k, v) = toy(5);
    let skein = attention::by_name("skeinformer", D).unwrap();
    // stride >= n: appending all n tokens stays in epoch 1 territory only
    // after n/stride rolls over — pick stride so two lengths share an epoch
    let spec = SessionSpec::new(P).with_seed(9).with_repilot_stride(N);
    let mut session = skein.begin_session(spec);
    for i in 0..N / 2 {
        session.append(k.row(i), v.row(i));
    }
    // both queries happen at the same length -> same epoch -> same bytes
    let a = session.query(&q.gather_rows(&(0..N / 2).collect::<Vec<_>>()));
    let b = session.query(&q.gather_rows(&(0..N / 2).collect::<Vec<_>>()));
    assert_eq!(a.max_abs_diff(&b), 0.0, "same-epoch queries must reproduce");
}

#[test]
fn cross_shape_decode_works_for_capable_methods_and_panics_for_square_only() {
    let (q, k, v) = toy(6);
    let q_dec = q.gather_rows(&[N - 2, N - 1]); // 2 decode queries
    for method in attention::registry(D) {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            method.compute(&q_dec, &k, &v, None, &mut Rng::new(1))
        }));
        if method.supports_cross_shape() {
            let out = result.unwrap_or_else(|_| {
                panic!("{} claims cross-shape support but panicked", method.name())
            });
            assert_eq!(out.shape(), (2, P), "{}", method.name());
            assert!(out.all_finite(), "{}", method.name());
        } else {
            assert!(
                result.is_err(),
                "{} must reject cross-shape inputs loudly",
                method.name()
            );
        }
    }
}

#[test]
fn batched_engine_matches_legacy_per_head_compute() {
    // the engine now routes through compute_into + pool scratch; outputs
    // must still be bitwise the documented per-head derivation
    use skeinformer::attention::{BatchedAttention, HeadSpec};
    use skeinformer::tensor::BatchTensor;
    let spec = HeadSpec::new(2, 3, 24, P);
    let mk = |salt: u64| {
        let mut t = BatchTensor::zeros(spec.batch, spec.heads, spec.seq, spec.head_dim);
        Rng::new(50 + salt).fill_normal(t.data_mut());
        t
    };
    let (q, k, v) = (mk(0), mk(1), mk(2));
    let seed = 13u64;
    for method in attention::registry(D) {
        let out = BatchedAttention::new().run(method.as_ref(), &q, &k, &v, None, seed);
        for b in 0..spec.batch {
            for h in 0..spec.heads {
                let mut rng = Rng::new(seed ^ spec.head_index(b, h));
                let want = method.compute(
                    &q.head_matrix(b, h),
                    &k.head_matrix(b, h),
                    &v.head_matrix(b, h),
                    None,
                    &mut rng,
                );
                assert_eq!(
                    out.head_matrix(b, h).max_abs_diff(&want),
                    0.0,
                    "{} head ({b},{h})",
                    method.name()
                );
            }
        }
    }
}
