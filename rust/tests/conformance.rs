//! Cross-method conformance suite: every method in `attention::registry`
//! must honor the shared contract — output shape/finiteness across
//! non-square-friendly sizes, masked-out rows contributing zero weight,
//! and seed determinism (including bitwise worker-count invariance) under
//! the batched multi-head path.
//!
//! Methods declare their masking contract by membership in one of the
//! three lists below; a registry method missing from all of them fails the
//! coverage test, so new methods must pick a class explicitly.

use skeinformer::attention::{registry, BatchedAttention, HeadSpec};
use skeinformer::pool;
use skeinformer::rng::Rng;
use skeinformer::tensor::{BatchTensor, Matrix};

/// Methods whose output over valid rows is invariant to the *content* of
/// masked K and V rows (the §4.4 contract).
const MASK_KV_INVARIANT: &[&str] = &[
    "standard",
    "vmean",
    "skeinformer",
    "skein_uniform",
    "skein_no_norm",
    "skein_simple_norm",
    "skein_no_psr",
    "informer_mask",
    "linformer",
    "linformer_jlt",
    "performer",
    "bigbird",
    "reformer",
];

/// Methods invariant to masked V content only (landmark construction mixes
/// raw K rows before masking).
const MASK_V_INVARIANT: &[&str] = &["nystromformer"];

/// Methods that ignore the padding mask by design (the paper's point about
/// the published Informer; its `informer_mask` variant is the fix).
const MASK_OBLIVIOUS: &[&str] = &["informer"];

fn random_matrix(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    rng.fill_normal(m.data_mut());
    m
}

fn qkv(n: usize, p: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    (
        random_matrix(n, p, &mut rng),
        random_matrix(n, p, &mut rng),
        random_matrix(n, p, &mut rng),
    )
}

fn random_batch(spec: HeadSpec, seed: u64) -> (BatchTensor, BatchTensor, BatchTensor) {
    let mut rng = Rng::new(seed);
    let mut mk = || {
        let mut t = spec.zeros();
        rng.fill_normal(t.data_mut());
        t
    };
    (mk(), mk(), mk())
}

#[test]
fn every_registry_method_declares_a_mask_class() {
    for m in registry(16) {
        let name = m.name();
        let classes = [MASK_KV_INVARIANT, MASK_V_INVARIANT, MASK_OBLIVIOUS];
        let hits: usize = classes.iter().filter(|c| c.contains(&name)).count();
        assert_eq!(hits, 1, "{name} must appear in exactly one mask class (got {hits})");
    }
}

#[test]
fn shape_and_finiteness_across_sizes_and_budgets() {
    // n covers the required {32, 64, 128}; p includes non-power-of-two,
    // non-square-friendly head dims; d includes a non-power-of-two budget.
    for &n in &[32usize, 64, 128] {
        for &p in &[8usize, 12, 20] {
            let (q, k, v) = qkv(n, p, 1000 + (n * 31 + p) as u64);
            for &d in &[12usize, 24] {
                for m in registry(d) {
                    let out = m.compute(&q, &k, &v, None, &mut Rng::new(7));
                    assert_eq!(
                        out.shape(),
                        (n, p),
                        "{} wrong shape at n={n} p={p} d={d}",
                        m.name()
                    );
                    assert!(
                        out.all_finite(),
                        "{} produced non-finite values at n={n} p={p} d={d}",
                        m.name()
                    );
                }
            }
        }
    }
}

#[test]
fn masked_out_rows_contribute_zero_weight() {
    let n = 48;
    let p = 8;
    let valid = 32;
    let mask: Vec<f32> = (0..n).map(|i| if i < valid { 1.0 } else { 0.0 }).collect();
    let (q, k, v) = qkv(n, p, 21);

    // corrupted copies: masked rows replaced with huge values
    let corrupt = |m: &Matrix| {
        let mut c = m.clone();
        for i in valid..n {
            for j in 0..p {
                c.set(i, j, if (i + j) % 2 == 0 { 1e3 } else { -1e3 });
            }
        }
        c
    };
    let (k_bad, v_bad) = (corrupt(&k), corrupt(&v));

    for m in registry(16) {
        let name = m.name();
        if MASK_OBLIVIOUS.contains(&name) {
            continue;
        }
        let kv = MASK_KV_INVARIANT.contains(&name);
        let (k2, v2) = if kv { (&k_bad, &v_bad) } else { (&k, &v_bad) };
        let base = m.compute(&q, &k, &v, Some(&mask), &mut Rng::new(33));
        let after = m.compute(&q, k2, v2, Some(&mask), &mut Rng::new(33));
        for i in 0..valid {
            for j in 0..p {
                assert!(
                    (base.get(i, j) - after.get(i, j)).abs() < 1e-2,
                    "{name}: masked content leaked into valid row {i} \
                     ({} vs {})",
                    base.get(i, j),
                    after.get(i, j)
                );
            }
        }
    }
}

#[test]
fn batched_path_is_seed_deterministic_for_every_method() {
    let spec = HeadSpec::new(2, 2, 32, 8);
    let (q, k, v) = random_batch(spec, 5);
    let engine = BatchedAttention::new();
    for m in registry(16) {
        let a = engine.run(m.as_ref(), &q, &k, &v, None, 99);
        let b = engine.run(m.as_ref(), &q, &k, &v, None, 99);
        assert_eq!(
            a.max_abs_diff(&b),
            0.0,
            "{} not deterministic under the batched path",
            m.name()
        );
        assert!(a.all_finite(), "{} non-finite batched output", m.name());
    }
}

#[test]
fn batched_worker_count_invariance() {
    // The acceptance-criterion methods plus the exact baseline and a
    // random-feature method: worker counts 1 and worker_count() must agree
    // bitwise for the same seed.
    let spec = HeadSpec::new(3, 4, 48, 8);
    let (q, k, v) = random_batch(spec, 11);
    let masks = Matrix::from_fn(spec.batch, spec.seq, |b, i| {
        if b == 2 && i >= 40 {
            0.0
        } else {
            1.0
        }
    });
    for name in ["skeinformer", "informer", "linformer", "standard", "performer"] {
        let m = skeinformer::attention::by_name(name, 16).expect("registry method");
        let one = BatchedAttention::new()
            .with_workers(1)
            .run(m.as_ref(), &q, &k, &v, Some(&masks), 7);
        let many = BatchedAttention::new()
            .with_workers(pool::worker_count())
            .run(m.as_ref(), &q, &k, &v, Some(&masks), 7);
        assert_eq!(
            one.max_abs_diff(&many),
            0.0,
            "{name}: workers=1 vs workers={} diverged",
            pool::worker_count()
        );
    }
}

#[test]
fn batched_heads_follow_the_documented_rng_rule() {
    // head (b, h) must equal a single-head call with
    // Rng::new(seed ^ (b * heads + h)) — the engine's contract.
    let spec = HeadSpec::new(2, 3, 32, 8);
    let (q, k, v) = random_batch(spec, 17);
    let seed = 1234u64;
    let engine = BatchedAttention::new();
    for name in ["skeinformer", "linformer", "informer"] {
        let m = skeinformer::attention::by_name(name, 12).expect("registry method");
        let out = engine.run(m.as_ref(), &q, &k, &v, None, seed);
        for b in 0..spec.batch {
            for h in 0..spec.heads {
                let mut rng = Rng::new(seed ^ spec.head_index(b, h));
                let want = m.compute(
                    &q.head_matrix(b, h),
                    &k.head_matrix(b, h),
                    &v.head_matrix(b, h),
                    None,
                    &mut rng,
                );
                assert_eq!(
                    out.head_matrix(b, h).max_abs_diff(&want),
                    0.0,
                    "{name}: head ({b},{h}) deviates from the derivation rule"
                );
            }
        }
    }
}
