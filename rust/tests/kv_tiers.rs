//! Tier-ladder conformance: quantised cold blocks, the content-addressed
//! spill store, and the failure modes between them.
//!
//! * Codec properties: f16 round-trips exactly-representable values
//!   bitwise and bounds everything else by `2^-11` relative error; int8
//!   error is ≤ half the per-payload power-of-two scale (asserted through
//!   the conservative `absmax/127` bound); decode→re-ingest→decode is a
//!   fixed point (idempotence, observed end to end).
//! * Demotion under pressure serves *bounded-error* bytes through the
//!   same `gather_head_into` seam hot blocks use — and with headroom (or
//!   tiers off) the cache stays bitwise identical to the pre-tier one.
//! * Spilled blocks rehydrate bitwise: the archive is written from exact
//!   f32 bytes at first demotion, re-verified by digest on every read.
//! * Fault injection: a truncated file, a flipped byte, and a missing
//!   file under a live manifest entry each degrade to a clean miss
//!   (`spill_corrupt` bumped, block re-ingested) — never a panic, never
//!   silent wrong bytes.
//! * Warm restart: a fresh cache over a spilled directory replays the
//!   whole prefix with zero index allocations, and steady-state replays
//!   stop touching the heap (`fresh_allocs` flat).
//! * Cross-process sharing: two caches over one store directory serve
//!   bitwise-identical gathers from the same archived blocks.

use skeinformer::kvcache::{
    f16_bits_to_f32, f32_to_f16_bits, tempdir, KvCache, KvCacheConfig, StreamChain, TierLadder,
};
use skeinformer::rng::Rng;
use skeinformer::tensor::Matrix;
use std::ops::Range;

/// token_elems: 1 head × head_dim 2.
const TE: usize = 2;
/// Tokens per block.
const BS: usize = 2;

/// Deterministic per-token rows, deliberately *not* f16- or int8-exact.
fn krow(t: usize) -> [f32; TE] {
    let x = t as f32 * 0.37 + 0.123;
    [x, -x * 1.9]
}

fn vrow(t: usize) -> [f32; TE] {
    let x = t as f32 * 0.53 - 0.217;
    [x * 1.3, x]
}

fn fill(cache: &mut KvCache, chain: &mut StreamChain, tokens: Range<usize>) {
    for t in tokens {
        cache.append(chain, &krow(t), &vrow(t));
    }
}

fn rng_rows(n: usize, seed: u64) -> Vec<([f32; TE], [f32; TE])> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut k = [0.0f32; TE];
            let mut v = [0.0f32; TE];
            rng.fill_normal(&mut k);
            rng.fill_normal(&mut v);
            (k, v)
        })
        .collect()
}

fn fill_rows(cache: &mut KvCache, chain: &mut StreamChain, rows: &[([f32; TE], [f32; TE])]) {
    for (k, v) in rows {
        cache.append(chain, k, v);
    }
}

/// Gather head 0's full visible K/V for a chain.
fn gather(chain: &StreamChain) -> (Matrix, Matrix) {
    let n = chain.visible_len();
    let mut k = Matrix::zeros(n, TE);
    let mut v = Matrix::zeros(n, TE);
    chain.gather_head_into(0, TE, &mut k, &mut v);
    (k, v)
}

fn assert_bitwise_eq(got: &Matrix, want: &Matrix, what: &str) {
    assert_eq!(got.shape(), want.shape(), "{what}: shape mismatch");
    for (g, w) in got.data().iter().zip(want.data()) {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: bitwise mismatch ({g} vs {w})");
    }
}

// ---------------------------------------------------------------- codecs

#[test]
fn f16_round_trips_exact_values_bitwise_and_bounds_the_rest() {
    // integers < 2^11, powers of two, halves, the f16 extremes: all have
    // ≤ 10 mantissa bits, so the round trip must be the identity
    for v in [
        0.0f32, -0.0, 1.0, -1.0, 0.5, -0.25, 1.5, 333.0, -2047.0, 2048.0, 65504.0, -65504.0,
        6.103_515_625e-5, // smallest f16 normal, 2^-14
    ] {
        let rt = f16_bits_to_f32(f32_to_f16_bits(v));
        assert_eq!(rt.to_bits(), v.to_bits(), "{v} is f16-exact and must round-trip bitwise");
    }
    // everything else in the normal range: round-to-nearest-even keeps
    // the relative error within half a 10-bit-mantissa ulp, 2^-11
    let mut vals = vec![0.0f32; 4096];
    Rng::new(9).fill_normal(&mut vals);
    vals.extend([0.1, -0.3, 2049.0, 1.0e4, -7.7e-3, std::f32::consts::PI]);
    for &x in &vals {
        if x.abs() < 6.103_515_625e-5 {
            continue; // subnormal f16 range: absolute, not relative, error
        }
        let rt = f16_bits_to_f32(f32_to_f16_bits(x));
        let bound = x.abs() * (1.0 / 2048.0) * 1.0001;
        assert!((rt - x).abs() <= bound, "{x} decoded to {rt}: outside 2^-11 relative error");
    }
}

#[test]
fn f16_demoted_blocks_gather_within_relative_error_bound() {
    let tiers = TierLadder::none().with_f16(true);
    let mut c = KvCache::new(KvCacheConfig::new(BS).with_capacity_blocks(2).with_tiers(tiers), TE);
    let mut a = c.open_stream();
    fill(&mut c, &mut a, 0..4); // 2 sealed blocks: exactly at capacity
    let (k_exact, v_exact) = gather(&a);
    c.close_stream(a);
    let mut b = c.open_stream();
    fill(&mut c, &mut b, 50..52); // one sealing miss forces pressure
    c.close_stream(b);
    let s = c.stats();
    assert_eq!(s.demoted_blocks, 2, "pressure must demote, not drop");
    assert_eq!(s.evicted_blocks, 0);
    assert_eq!(s.quant_blocks, 2);

    // the replay verifies against the quantised entries by re-encoding,
    // and its gathers decode into scratch with bounded error
    let mut r = c.open_stream();
    fill(&mut c, &mut r, 0..4);
    assert_eq!(c.stats().hit_blocks, 2, "quantised entries still dedupe");
    assert_eq!(c.stats().demoted_blocks, 2, "hits never demote further");
    let (k_q, v_q) = gather(&r);
    let mut lossy = 0usize;
    for (got, want) in k_q
        .data()
        .iter()
        .chain(v_q.data())
        .zip(k_exact.data().iter().chain(v_exact.data()))
    {
        let bound = want.abs() * (1.0 / 2048.0) * 1.0001;
        assert!((got - want).abs() <= bound, "f16 decode {got} vs {want}: outside 2^-11");
        lossy += usize::from(got.to_bits() != want.to_bits());
    }
    assert!(lossy > 0, "rows were chosen to not be f16-exact: some bits must differ");
    c.close_stream(r);
}

#[test]
fn int8_demoted_blocks_gather_within_half_scale_bound() {
    let tiers = TierLadder::none().with_int8(true); // f32 demotes straight to int8
    let mut c = KvCache::new(KvCacheConfig::new(BS).with_capacity_blocks(2).with_tiers(tiers), TE);
    let mut a = c.open_stream();
    fill(&mut c, &mut a, 0..4);
    let (k_exact, v_exact) = gather(&a);
    c.close_stream(a);
    let mut b = c.open_stream();
    fill(&mut c, &mut b, 50..52);
    c.close_stream(b);
    assert_eq!(c.stats().demoted_blocks, 2);
    assert_eq!(c.stats().evicted_blocks, 0);

    let mut r = c.open_stream();
    fill(&mut c, &mut r, 0..4);
    assert_eq!(c.stats().hit_blocks, 2, "re-encoding the candidate matches the stored int8");
    let (k_q, v_q) = gather(&r);
    // the codec guarantees error ≤ scale/2 with scale the smallest power
    // of two ≥ absmax/127, so absmax/127 is a safe per-payload bound;
    // K and V are separate payloads, each spanning one whole block
    let payload_bound = |exact: &Matrix, block: usize| {
        let mut absmax = 0.0f32;
        for t in block * BS..(block + 1) * BS {
            for e in 0..TE {
                absmax = absmax.max(exact.get(t, e).abs());
            }
        }
        absmax / 127.0 * 1.0001
    };
    let mut lossy = 0usize;
    for block in 0..2 {
        let (bk, bv) = (payload_bound(&k_exact, block), payload_bound(&v_exact, block));
        for t in block * BS..(block + 1) * BS {
            for e in 0..TE {
                let (gk, wk) = (k_q.get(t, e), k_exact.get(t, e));
                let (gv, wv) = (v_q.get(t, e), v_exact.get(t, e));
                assert!((gk - wk).abs() <= bk, "int8 K {gk} vs {wk}: outside scale/2 ({bk})");
                assert!((gv - wv).abs() <= bv, "int8 V {gv} vs {wv}: outside scale/2 ({bv})");
                lossy += usize::from(gk.to_bits() != wk.to_bits());
            }
        }
    }
    assert!(lossy > 0, "rows were chosen to not be int8-exact");
    c.close_stream(r);
}

#[test]
fn int8_decode_reingested_is_a_fixed_point() {
    // quantise→dequantise→quantise idempotence, observed end to end:
    // ingest the *decoded* values into a second cache, demote them again,
    // and the second decode must equal the first bitwise
    let decode_through_cache = |rows: &[([f32; TE], [f32; TE])]| {
        let tiers = TierLadder::none().with_int8(true);
        let cfg = KvCacheConfig::new(BS).with_capacity_blocks(2).with_tiers(tiers);
        let mut c = KvCache::new(cfg, TE);
        let mut a = c.open_stream();
        fill_rows(&mut c, &mut a, rows);
        c.close_stream(a);
        let mut b = c.open_stream();
        fill(&mut c, &mut b, 50..52); // pressure: demote the ingested blocks
        c.close_stream(b);
        assert_eq!(c.stats().demoted_blocks, 2);
        let mut r = c.open_stream();
        fill_rows(&mut c, &mut r, rows);
        let out = gather(&r);
        c.close_stream(r);
        out
    };
    let rows = rng_rows(4, 17);
    let (k1, v1) = decode_through_cache(&rows);
    // re-ingest the lossy decode verbatim
    let decoded: Vec<([f32; TE], [f32; TE])> = (0..rows.len())
        .map(|t| {
            ([k1.get(t, 0), k1.get(t, 1)], [v1.get(t, 0), v1.get(t, 1)])
        })
        .collect();
    let (k2, v2) = decode_through_cache(&decoded);
    assert_bitwise_eq(&k2, &k1, "int8 K fixed point");
    assert_bitwise_eq(&v2, &v1, "int8 V fixed point");
}

// ----------------------------------------------------------- spill store

#[test]
fn spilled_blocks_rehydrate_bitwise_identical() {
    let dir = tempdir("tiers-rehydrate");
    let tiers = TierLadder::none().with_spill_dir(dir.path());
    let mut c = KvCache::new(KvCacheConfig::new(BS).with_capacity_blocks(1).with_tiers(tiers), TE);
    let rows = rng_rows(4, 3);
    let mut a = c.open_stream();
    fill_rows(&mut c, &mut a, &rows);
    let (k_exact, v_exact) = gather(&a);
    c.close_stream(a);
    let mut b = c.open_stream();
    fill(&mut c, &mut b, 50..52); // pressure: no quant rung, so archive + spill
    c.close_stream(b);
    let s = c.stats();
    assert_eq!(s.spilled_blocks, 2, "both cold blocks spill");
    assert_eq!(s.evicted_blocks, 0);

    let mut r = c.open_stream();
    fill_rows(&mut c, &mut r, &rows);
    let s = c.stats();
    assert_eq!(s.spill_hits, 2, "replay re-reads + re-verifies the archive");
    assert_eq!(s.spill_corrupt, 0);
    assert_eq!(s.hit_blocks, 2);
    let (k_r, v_r) = gather(&r);
    assert_bitwise_eq(&k_r, &k_exact, "rehydrated K");
    assert_bitwise_eq(&v_r, &v_exact, "rehydrated V");
    c.close_stream(r);
}

#[test]
fn corrupted_spill_files_degrade_to_clean_misses() {
    let dir = tempdir("tiers-faults");
    let tiers = TierLadder::none().with_spill_dir(dir.path());
    // unbounded capacity: blocks reach disk via the explicit snapshot hook
    let mut c = KvCache::new(KvCacheConfig::new(BS).with_tiers(tiers), TE);
    let rows = rng_rows(8, 5); // 4 sealed blocks, no tail
    let mut a = c.open_stream();
    fill_rows(&mut c, &mut a, &rows);
    let hashes = a.path().to_vec();
    let (k_exact, v_exact) = gather(&a);
    c.close_stream(a);
    assert_eq!(c.spill_index(), 4, "every index-only block archives");
    assert_eq!(c.stats().spilled_blocks, 4);
    assert_eq!(c.stats().resident_blocks, 0, "spilled markers hold no RAM");
    let paths: Vec<_> =
        hashes.iter().map(|&h| c.spill_store().expect("store open").block_path(h)).collect();

    // fault 0: truncated file (short read)
    let bytes = std::fs::read(&paths[0]).unwrap();
    std::fs::write(&paths[0], &bytes[..bytes.len() / 2]).unwrap();
    // fault 1: one flipped payload byte (digest mismatch on re-read)
    let mut bytes = std::fs::read(&paths[1]).unwrap();
    *bytes.last_mut().unwrap() ^= 0x55;
    std::fs::write(&paths[1], &bytes).unwrap();
    // fault 2: file missing under a live manifest entry
    std::fs::remove_file(&paths[2]).unwrap();
    // block 3 stays intact: the one clean rehydrate in the replay

    let mut r = c.open_stream();
    fill_rows(&mut c, &mut r, &rows);
    let s = c.stats();
    assert_eq!(s.spill_corrupt, 3, "each corruption is a counted clean miss");
    assert_eq!(s.spill_hits, 1, "the intact block still rehydrates");
    // every byte served is exact: corrupt blocks were re-ingested from
    // the replayed tokens, never decoded from the bad files
    let (k_r, v_r) = gather(&r);
    assert_bitwise_eq(&k_r, &k_exact, "post-fault K");
    assert_bitwise_eq(&v_r, &v_exact, "post-fault V");
    c.close_stream(r);

    // the bad files were dropped at detection, so a second snapshot
    // re-archives clean bytes and the next replay hits disk for all four
    assert_eq!(c.spill_index(), 4, "re-ingested blocks re-archive");
    let mut r2 = c.open_stream();
    fill_rows(&mut c, &mut r2, &rows);
    let s = c.stats();
    assert_eq!(s.spill_corrupt, 3, "no corruption left after re-archiving");
    assert_eq!(s.spill_hits, 1 + 4);
    let (k_r2, v_r2) = gather(&r2);
    assert_bitwise_eq(&k_r2, &k_exact, "re-archived K");
    assert_bitwise_eq(&v_r2, &v_exact, "re-archived V");
    c.close_stream(r2);
}

#[test]
fn warm_restart_replays_spilled_prefix_without_index_allocations() {
    let dir = tempdir("tiers-warm");
    let cfg = KvCacheConfig::new(BS).with_tiers(TierLadder::none().with_spill_dir(dir.path()));
    let rows = rng_rows(6, 11); // 3 sealed blocks
    let (k_exact, v_exact) = {
        let mut c = KvCache::new(cfg.clone(), TE);
        let mut a = c.open_stream();
        fill_rows(&mut c, &mut a, &rows);
        let exact = gather(&a);
        c.close_stream(a);
        assert_eq!(c.spill_index(), 3);
        exact
    }; // cache dropped: only the spill directory survives

    let mut c = KvCache::new(cfg, TE);
    let mut r1 = c.open_stream();
    fill_rows(&mut c, &mut r1, &rows);
    let s = c.stats();
    assert_eq!(s.alloc_blocks, 0, "warm restart: every sealed block rehydrates");
    assert_eq!(s.spill_hits, 3);
    assert_eq!(s.hit_blocks, 3);
    assert_eq!(s.spill_corrupt, 0);
    let (k_r, v_r) = gather(&r1);
    assert_bitwise_eq(&k_r, &k_exact, "warm-restart K");
    assert_bitwise_eq(&v_r, &v_exact, "warm-restart V");
    c.close_stream(r1);

    // steady state: once the pool has a recycled staging block, replays
    // stop touching the heap entirely
    let mut r2 = c.open_stream();
    fill_rows(&mut c, &mut r2, &rows);
    c.close_stream(r2);
    let fresh = c.fresh_allocs();
    let mut r3 = c.open_stream();
    fill_rows(&mut c, &mut r3, &rows);
    let (k_r3, _) = gather(&r3);
    assert_bitwise_eq(&k_r3, &k_exact, "steady-state K");
    c.close_stream(r3);
    assert_eq!(c.fresh_allocs(), fresh, "replay must recycle pooled blocks only");
}

#[test]
fn two_caches_share_one_spill_store() {
    let dir = tempdir("tiers-shared");
    let cfg = KvCacheConfig::new(BS).with_tiers(TierLadder::none().with_spill_dir(dir.path()));
    let rows = rng_rows(6, 23);
    let mut producer = KvCache::new(cfg.clone(), TE);
    let mut a = producer.open_stream();
    fill_rows(&mut producer, &mut a, &rows);
    let (k_exact, v_exact) = gather(&a);
    producer.close_stream(a);
    assert_eq!(producer.spill_index(), 3);

    // a second cache — standing in for a second serving process — opens
    // over the same directory while the first stays live
    let mut consumer = KvCache::new(cfg, TE);
    let mut r = consumer.open_stream();
    fill_rows(&mut consumer, &mut r, &rows);
    let s = consumer.stats();
    assert_eq!(s.spill_hits, 3, "the consumer shares the producer's archive");
    assert_eq!(s.alloc_blocks, 0);
    let (k_c, v_c) = gather(&r);
    assert_bitwise_eq(&k_c, &k_exact, "cross-process K");
    assert_bitwise_eq(&v_c, &v_exact, "cross-process V");
    consumer.close_stream(r);

    // reads are non-destructive: the producer can still replay its own
    // archive afterwards
    let mut p = producer.open_stream();
    fill_rows(&mut producer, &mut p, &rows);
    assert_eq!(producer.stats().spill_hits, 3);
    let (k_p, _) = gather(&p);
    assert_bitwise_eq(&k_p, &k_exact, "producer replay K");
    producer.close_stream(p);
}

// -------------------------------------------------- determinism contract

#[test]
fn tiers_with_headroom_change_nothing() {
    // identical op sequence on a tiers-off cache and a full-ladder cache
    // with unbounded capacity: no pressure ever fires, so stats and
    // gathered bytes must match exactly — the tiers-off bitwise contract
    // extends to "tiers on but idle"
    let dir = tempdir("tiers-idle");
    let run = |cfg: KvCacheConfig| {
        let mut c = KvCache::new(cfg, TE);
        let mut a = c.open_stream();
        fill(&mut c, &mut a, 0..6);
        c.close_stream(a);
        let mut b = c.open_stream();
        fill(&mut c, &mut b, 0..8); // replays the prefix, then extends it
        let out = gather(&b);
        c.close_stream(b);
        (format!("{:?}", c.stats()), out)
    };
    let ladder = TierLadder::none().with_f16(true).with_int8(true).with_spill_dir(dir.path());
    let (base_stats, (k_base, v_base)) = run(KvCacheConfig::new(BS));
    let (tier_stats, (k_tier, v_tier)) = run(KvCacheConfig::new(BS).with_tiers(ladder));
    assert_bitwise_eq(&k_tier, &k_base, "idle-tier K");
    assert_bitwise_eq(&v_tier, &v_base, "idle-tier V");
    assert_eq!(tier_stats, base_stats, "an idle ladder must not perturb a single counter");
}

// ------------------------------------------------------- server plumbing

#[test]
fn server_streams_demote_under_pressure_and_report_tier_counters() {
    use skeinformer::coordinator::attention_server::{self, AttentionServerConfig};
    use std::sync::Arc;
    use std::time::Duration;

    let ladder = TierLadder::none().with_f16(true).with_int8(true);
    let cfg = AttentionServerConfig {
        method: "standard".to_string(),
        d: 8,
        heads: 2,
        seq: 16,
        head_dim: 4,
        max_batch: 2,
        max_wait: Duration::from_millis(1),
        seed: 0,
        workers: None,
        queue_depth: 0,
        kv: Some(KvCacheConfig::new(2).with_capacity_blocks(2).with_tiers(ladder)),
    };
    let token_elems = cfg.heads * cfg.head_dim;
    let mut rng = Rng::new(41);
    let mut slab = || {
        let mut b = vec![0.0f32; token_elems];
        rng.fill_normal(&mut b);
        let s: Arc<[f32]> = b.into();
        s
    };
    let prompt: Vec<(Arc<[f32]>, Arc<[f32]>)> = (0..8).map(|_| (slab(), slab())).collect();
    let handle = attention_server::start(cfg.clone()).unwrap();

    let run = |tokens: &[(Arc<[f32]>, Arc<[f32]>)]| {
        let stream = handle.open_stream(2);
        for (k, v) in tokens {
            stream.append(k.clone(), v.clone());
        }
        let mut q = vec![0.0f32; cfg.heads * tokens.len() * cfg.head_dim];
        Rng::new(6).fill_normal(&mut q);
        let out = stream.query(q.into(), tokens.len()).recv().expect("stream reply");
        stream.close();
        out
    };
    run(&prompt); // seals 4 blocks, then leaves them index-only
    let other: Vec<(Arc<[f32]>, Arc<[f32]>)> = (0..2).map(|_| (slab(), slab())).collect();
    run(&other); // a different prompt pressures them down the ladder
    let replay = run(&prompt); // served through the quantised entries
    assert!(replay.iter().all(|x| x.is_finite()), "dequantised gathers must stay finite");

    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.kv_demoted_blocks, 4, "all four cold blocks demote f32 → f16");
    assert_eq!(stats.kv_hit_blocks, 4, "the replay dedupes against them");
    assert_eq!(stats.kv_evicted_blocks, 0, "the ladder absorbs the pressure");
    assert_eq!(stats.kv_spilled_blocks, 0, "no spill rung configured");
    assert_eq!(stats.kv_spill_hits, 0);
    assert_eq!(stats.kv_spill_corrupt, 0);
}
