//! Stress and lifecycle tests for the persistent worker pool and the
//! zero-copy serving path.
//!
//! These run in their own test binary on purpose: they mutate process-wide
//! pool state (shutdown, resize) and hammer the queue from many client
//! threads at once, which is exactly the serving workload the pool
//! replaced per-call `thread::scope` spawning for.  Correctness must hold
//! under any interleaving with other pool users — the pool's contract is
//! that results never depend on its size, liveness, or scheduling.

use skeinformer::attention::{BatchedAttention, HeadSpec, Skeinformer, Standard};
use skeinformer::pool;
use skeinformer::rng::Rng;
use skeinformer::tensor::BatchTensor;
use std::sync::Arc;

/// Many concurrent client threads each issuing many small parallel maps —
/// the spawn-overhead-sensitive shape the persistent pool exists for.
/// Every call must return exact, ordered results.
#[test]
fn concurrent_small_maps_from_many_threads() {
    let clients = 8;
    let calls_per_client = 200;
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                for call in 0..calls_per_client {
                    let items: Vec<usize> = (0..16).collect();
                    let out = pool::parallel_map_workers(&items, 4, |&x| x * 3 + c * 1000 + call);
                    for (i, v) in out.iter().enumerate() {
                        assert_eq!(*v, i * 3 + c * 1000 + call, "client {c} call {call}");
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread panicked");
    }
}

/// Shutdown must drain cleanly, and the next parallel call must
/// re-initialise the pool transparently — including across resizes, and
/// with bitwise-identical engine output throughout.  (One test on
/// purpose: it is the only place the global pool size is mutated, so it
/// cannot race another test's size assumptions — pool *users* stay
/// correct under any size, which the other tests exercise concurrently.)
#[test]
fn shutdown_resize_reinit_roundtrip() {
    let items: Vec<usize> = (0..64).collect();
    let want: Vec<usize> = items.iter().map(|&x| x * x).collect();

    let spec = HeadSpec::new(4, 4, 32, 8);
    let mk = |salt: u64| {
        let mut t = spec.zeros();
        Rng::new(77 + salt).fill_normal(t.data_mut());
        t
    };
    let (q, k, v) = (mk(0), mk(1), mk(2));
    let skein = Skeinformer::new(8);
    let baseline = BatchedAttention::new().run(&skein, &q, &k, &v, None, 3);

    assert_eq!(pool::parallel_map(&items, |&x| x * x), want);
    pool::shutdown_pool();
    // lazily re-created on next use, with identical results
    assert_eq!(pool::parallel_map(&items, |&x| x * x), want);
    let fresh = BatchedAttention::new().run(&skein, &q, &k, &v, None, 3);
    assert_eq!(baseline.max_abs_diff(&fresh), 0.0, "fresh pool changed results");

    // resize down, up, and back to default — results invariant throughout
    for size in [2, 1, 9, 0] {
        pool::set_pool_size(size);
        assert_eq!(pool::parallel_map(&items, |&x| x * x), want, "pool size {size}");
        let resized = BatchedAttention::new().run(&skein, &q, &k, &v, None, 3);
        assert_eq!(
            baseline.max_abs_diff(&resized),
            0.0,
            "pool size {size} changed engine results"
        );
    }
    assert_eq!(pool::pool_size(), pool::worker_count(), "0 restores the default");

    // shutdown while idle is a no-op for correctness; repeated shutdown too
    pool::shutdown_pool();
    pool::shutdown_pool();
    assert_eq!(pool::parallel_map(&items, |&x| x * x), want);
}

/// Zero-copy aliasing contract: the engine must produce bitwise-identical
/// output whether the request tensors own their storage or are
/// slab-backed `Arc<[f32]>` views of client memory — including when Q, K,
/// and V all alias one slab — and must leave client memory untouched.
#[test]
fn owned_and_slab_request_paths_are_bitwise_identical() {
    let spec = HeadSpec::new(3, 2, 40, 8);
    let mk = |salt: u64| {
        let mut t = spec.zeros();
        Rng::new(500 + salt).fill_normal(t.data_mut());
        t
    };
    let (q, k, v) = (mk(0), mk(1), mk(2));
    let to_slabs = |t: &BatchTensor| -> (Vec<Arc<[f32]>>, BatchTensor) {
        let slabs: Vec<Arc<[f32]>> =
            (0..spec.batch).map(|b| Arc::from(t.sequence(b).to_vec())).collect();
        let view = BatchTensor::from_slabs(spec.heads, spec.seq, spec.head_dim, slabs.clone());
        (slabs, view)
    };
    let (q_slabs, qs) = to_slabs(&q);
    let (_, ks) = to_slabs(&k);
    let (_, vs) = to_slabs(&v);

    for (name, method) in [
        ("standard", &Standard as &dyn skeinformer::attention::AttentionMethod),
        ("skeinformer", &Skeinformer::new(12)),
    ] {
        let owned = BatchedAttention::new().run(method, &q, &k, &v, None, 21);
        let slab = BatchedAttention::new().run(method, &qs, &ks, &vs, None, 21);
        assert_eq!(owned.max_abs_diff(&slab), 0.0, "{name}: slab path diverged");
        assert_eq!(owned, slab, "{name}: element-wise equality across storage modes");
    }

    // self-aliasing: q = k = v reading one slab three times
    let self_owned = BatchedAttention::new().run(&Standard, &q, &q, &q, None, 4);
    let self_slab = BatchedAttention::new().run(&Standard, &qs, &qs, &qs, None, 4);
    assert_eq!(self_owned.max_abs_diff(&self_slab), 0.0);

    // client memory is untouched by the run
    for (b, slab) in q_slabs.iter().enumerate() {
        assert_eq!(&slab[..], q.sequence(b), "client slab {b} mutated");
    }
}

/// A panicking task must reach the submitting thread as a panic, after
/// the batch drains — and the pool must keep serving afterwards, from
/// every client thread.
#[test]
fn pool_survives_panicking_tasks_under_load() {
    let items: Vec<usize> = (0..32).collect();
    for round in 0..4 {
        let result = std::panic::catch_unwind(|| {
            pool::parallel_map_workers(&items, 8, |&x| {
                if x == 13 {
                    panic!("injected failure, round {round}");
                }
                x + round
            })
        });
        assert!(result.is_err(), "round {round}: panic must propagate");
        let out = pool::parallel_map_workers(&items, 8, |&x| x + round);
        assert_eq!(out[31], 31 + round, "round {round}: pool unusable after panic");
    }
}
