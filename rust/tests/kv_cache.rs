//! KV-cache subsystem conformance: the paged block cache must change
//! *where bytes live*, never *which bytes are served*.
//!
//! * Bitwise cached-vs-uncached stream equivalence for every registry
//!   method (the acceptance criterion of the subsystem).
//! * Prefix sharing: a replayed prompt allocates zero new blocks for the
//!   shared region, asserted through `AttentionServerStats`.
//! * Chunked prefill ≡ per-token append: bitwise outputs and identical
//!   cache stats for every registry method, with and without a sliding
//!   window (strides crossing window-eviction boundaries).
//! * Batch-slab dedupe: a resubmitted one-shot `HeadsRequest` allocates
//!   zero new blocks (server stats) and serves bitwise the bytes the
//!   undeduped path serves; stream and batch ingest share one hash path.
//! * Refcount / copy-on-write correctness under fork + close.
//! * Eviction never drops a block a live stream still references (the
//!   heap-LRU ≡ DFS-oracle order equivalence itself is pinned in
//!   `kvcache::prefix`'s unit suite, where the oracle lives).
//! * Sliding-window sessions match a full recompute over the window at
//!   the same epoch seed, and the server's windowed streams match
//!   `BoundedSession` exactly.

use skeinformer::attention::{
    self, session_epoch, session_seed, AttentionSession, BoundedSession, SessionSpec,
};
use skeinformer::coordinator::attention_server::{
    self, stream_seed, AttentionServerConfig, AttentionServerStats, HeadsRequest,
};
use skeinformer::kvcache::{KvCache, KvCacheConfig};
use skeinformer::rng::Rng;
use skeinformer::tensor::Matrix;
use std::sync::Arc;
use std::time::Duration;

fn server_cfg(method: &str, kv: Option<KvCacheConfig>) -> AttentionServerConfig {
    AttentionServerConfig {
        method: method.to_string(),
        d: 8,
        heads: 2,
        seq: 16,
        head_dim: 4,
        max_batch: 2,
        max_wait: Duration::from_millis(1),
        seed: 0,
        workers: None,
        queue_depth: 0,
        kv,
    }
}

fn token_slabs(count: usize, token_elems: usize, seed: u64) -> Vec<(Arc<[f32]>, Arc<[f32]>)> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let mut mk = || {
                let mut b = vec![0.0f32; token_elems];
                rng.fill_normal(&mut b);
                let slab: Arc<[f32]> = b.into();
                slab
            };
            (mk(), mk())
        })
        .collect()
}

/// Run one decode stream against a fresh server: append all `tokens`,
/// querying at every step for cross-shape methods or once at the end
/// (`rows == len`) for square-only ones.  Returns the concatenated query
/// outputs plus the shutdown stats.
fn run_stream(
    cfg: &AttentionServerConfig,
    tokens: &[(Arc<[f32]>, Arc<[f32]>)],
    repilot_stride: usize,
) -> (Vec<f32>, AttentionServerStats) {
    let method = attention::by_name(&cfg.method, cfg.d).expect("registry method");
    let cross = method.supports_cross_shape();
    let token_elems = cfg.heads * cfg.head_dim;
    let handle = attention_server::start(cfg.clone()).unwrap();
    let stream = handle.open_stream(repilot_stride);
    let mut outs = Vec::new();
    let mut qrng = Rng::new(777);
    for (t, (k, v)) in tokens.iter().enumerate() {
        stream.append(k.clone(), v.clone());
        if cross {
            let mut q = vec![0.0f32; token_elems];
            qrng.fill_normal(&mut q);
            outs.extend(stream.query(q.into(), 1).recv().expect("stream reply"));
        } else if t + 1 == tokens.len() {
            // square-only methods answer one full-state query at the end
            let mut q = vec![0.0f32; cfg.heads * tokens.len() * cfg.head_dim];
            qrng.fill_normal(&mut q);
            outs.extend(stream.query(q.into(), tokens.len()).recv().expect("square reply"));
        }
    }
    stream.close();
    (outs, handle.shutdown().unwrap())
}

#[test]
fn cached_stream_is_bitwise_identical_to_uncached_for_every_method() {
    // 7 tokens at block size 2: sealed blocks + a partial tail mid-stream
    for method in attention::registry(8) {
        let name = method.name();
        let base = server_cfg(name, None);
        let cached = server_cfg(name, Some(KvCacheConfig::new(2)));
        let tokens = token_slabs(7, base.heads * base.head_dim, 21);
        let (want, _) = run_stream(&base, &tokens, 2);
        let (got, stats) = run_stream(&cached, &tokens, 2);
        assert!(!want.is_empty(), "{name}: no outputs collected");
        assert_eq!(got, want, "{name}: KV cache changed served bytes");
        assert_eq!(stats.kv_alloc_blocks, 3, "{name}: 7 tokens / block size 2");
    }
}

/// Repack per-token `[heads, head_dim]` rows `lo..hi` as one
/// `[heads, tokens, head_dim]` chunk slab (the Prefill/request layout).
fn chunk_slab(rows: &[Arc<[f32]>], lo: usize, hi: usize, heads: usize, head_dim: usize) -> Arc<[f32]> {
    let n = hi - lo;
    let mut slab = vec![0.0f32; n * heads * head_dim];
    for (i, row) in rows[lo..hi].iter().enumerate() {
        for h in 0..heads {
            let dst = (h * n + i) * head_dim;
            slab[dst..dst + head_dim].copy_from_slice(&row[h * head_dim..(h + 1) * head_dim]);
        }
    }
    slab.into()
}

/// Append `tokens` to a fresh server stream — per-token when
/// `chunks` is `None`, else via `Prefill` ops covering the given spans —
/// then issue one query (`rows = visible len` so square-only methods
/// answer too) and return (output bytes, shutdown stats).
fn run_ingest(
    cfg: &AttentionServerConfig,
    tokens: &[(Arc<[f32]>, Arc<[f32]>)],
    chunks: Option<&[(usize, usize)]>,
    query_rows: usize,
) -> (Vec<f32>, AttentionServerStats) {
    let handle = attention_server::start(cfg.clone()).unwrap();
    let stream = handle.open_stream(2);
    match chunks {
        None => {
            for (k, v) in tokens {
                stream.append(k.clone(), v.clone());
            }
        }
        Some(spans) => {
            let ks: Vec<Arc<[f32]>> = tokens.iter().map(|(k, _)| k.clone()).collect();
            let vs: Vec<Arc<[f32]>> = tokens.iter().map(|(_, v)| v.clone()).collect();
            for &(lo, hi) in spans {
                stream.prefill(
                    chunk_slab(&ks, lo, hi, cfg.heads, cfg.head_dim),
                    chunk_slab(&vs, lo, hi, cfg.heads, cfg.head_dim),
                    hi - lo,
                );
            }
        }
    }
    let mut q = vec![0.0f32; cfg.heads * query_rows * cfg.head_dim];
    Rng::new(555).fill_normal(&mut q);
    let out = stream.query(q.into(), query_rows).recv().expect("ingest query reply");
    stream.close();
    (out, handle.shutdown().unwrap())
}

#[test]
fn chunked_prefill_is_bitwise_identical_to_per_token_append_for_every_method() {
    // 7 tokens at block size 2 through chunks {3, 3, 1}: strides start
    // and end mid-block, so the tail survives across Prefill ops
    for method in attention::registry(8) {
        let name = method.name();
        let cfg = server_cfg(name, Some(KvCacheConfig::new(2)));
        let tokens = token_slabs(7, cfg.heads * cfg.head_dim, 77);
        let (want, want_stats) = run_ingest(&cfg, &tokens, None, 7);
        let (got, got_stats) = run_ingest(&cfg, &tokens, Some(&[(0, 3), (3, 6), (6, 7)]), 7);
        assert!(!want.is_empty(), "{name}: no output collected");
        assert_eq!(got, want, "{name}: chunked prefill changed served bytes");
        assert_eq!(got_stats.stream_appends, want_stats.stream_appends, "{name}");
        assert_eq!(got_stats.kv_alloc_blocks, want_stats.kv_alloc_blocks, "{name}");
        assert_eq!(got_stats.kv_hit_blocks, want_stats.kv_hit_blocks, "{name}");
        assert_eq!(got_stats.kv_evicted_blocks, want_stats.kv_evicted_blocks, "{name}");
    }
}

#[test]
fn chunked_prefill_matches_per_token_across_window_eviction_boundary() {
    // sliding window 8 over 13 tokens: front blocks are released while
    // the prefill strides are still appending — the window drops must
    // land on the same final state either way
    for method in attention::registry(8) {
        let name = method.name();
        let cfg = server_cfg(name, Some(KvCacheConfig::new(2).with_window(8)));
        let tokens = token_slabs(13, cfg.heads * cfg.head_dim, 91);
        // query rows = visible (window) length so square-only methods work
        let (want, want_stats) = run_ingest(&cfg, &tokens, None, 8);
        let (got, got_stats) = run_ingest(&cfg, &tokens, Some(&[(0, 5), (5, 11), (11, 13)]), 8);
        assert_eq!(got, want, "{name}: windowed prefill changed served bytes");
        assert_eq!(got_stats.kv_evicted_blocks, want_stats.kv_evicted_blocks, "{name}");
        assert_eq!(got_stats.kv_resident_blocks, want_stats.kv_resident_blocks, "{name}");
    }
}

#[test]
fn batch_dedupe_replay_is_zero_alloc_and_bitwise_identical_to_undeduped() {
    // seq 16 at block size 2: the request seals 8 blocks, no tail.
    // max_batch stays 2, but each submit is recv'd before the next, so
    // every request forms its own batch: batch seeds 0 and 1 on both
    // servers, making the outputs comparable bitwise per submission.
    let submissions = 2;
    for (name, masked) in [("standard", false), ("skeinformer", true)] {
        let plain_cfg = server_cfg(name, None);
        let dedupe_cfg =
            server_cfg(name, Some(KvCacheConfig::new(2).with_batch_dedupe(true)));
        let mut req = HeadsRequest::random(plain_cfg.request_elems(), &mut Rng::new(63));
        if masked {
            let mut mask = vec![1.0f32; plain_cfg.seq];
            for m in mask.iter_mut().skip(10) {
                *m = 0.0;
            }
            req = req.with_mask(mask);
        }
        let run = |cfg: &AttentionServerConfig| {
            let handle = attention_server::start(cfg.clone()).unwrap();
            let outs: Vec<Vec<f32>> = (0..submissions)
                .map(|_| handle.submit(req.clone()).recv().expect("batch reply"))
                .collect();
            (outs, handle.shutdown().unwrap())
        };
        let (want, _) = run(&plain_cfg);
        let (got, stats) = run(&dedupe_cfg);
        assert_eq!(got, want, "{name}: batch dedupe changed served bytes");
        assert_eq!(stats.kv_alloc_blocks, 8, "{name}: only the first submission allocates");
        assert_eq!(stats.kv_hit_blocks, 8, "{name}: the replay shares every sealed block");
        assert_eq!(stats.kv_evicted_blocks, 0, "{name}");
    }
}

#[test]
fn stream_and_batch_ingest_share_one_hash_path() {
    // a decode stream appends a prompt per-token; a batched request then
    // submits the same prompt as [heads, seq, head_dim] slabs — the
    // batch path must hit every block the stream sealed
    let cfg = server_cfg("standard", Some(KvCacheConfig::new(2).with_batch_dedupe(true)));
    let token_elems = cfg.heads * cfg.head_dim;
    let tokens = token_slabs(cfg.seq, token_elems, 44);
    let handle = attention_server::start(cfg.clone()).unwrap();
    let stream = handle.open_stream(1);
    for (k, v) in &tokens {
        stream.append(k.clone(), v.clone());
    }
    stream.close();

    let ks: Vec<Arc<[f32]>> = tokens.iter().map(|(k, _)| k.clone()).collect();
    let vs: Vec<Arc<[f32]>> = tokens.iter().map(|(_, v)| v.clone()).collect();
    let mut q = vec![0.0f32; cfg.request_elems()];
    Rng::new(7).fill_normal(&mut q);
    let req = HeadsRequest {
        q: q.into(),
        k: chunk_slab(&ks, 0, cfg.seq, cfg.heads, cfg.head_dim),
        v: chunk_slab(&vs, 0, cfg.seq, cfg.heads, cfg.head_dim),
        mask: None,
    };
    let out = handle.submit(req).recv().expect("batch reply");
    assert!(out.iter().all(|x| x.is_finite()));
    let stats = handle.shutdown().unwrap();
    let blocks = (cfg.seq / 2) as u64;
    assert_eq!(stats.kv_alloc_blocks, blocks, "only the stream allocates");
    assert_eq!(stats.kv_hit_blocks, blocks, "the batch slab hits the stream's blocks");
}

#[test]
fn replayed_prefix_allocates_zero_new_blocks() {
    let cfg = server_cfg("standard", Some(KvCacheConfig::new(2)));
    let token_elems = cfg.heads * cfg.head_dim;
    let tokens = token_slabs(8, token_elems, 5);
    let handle = attention_server::start(cfg.clone()).unwrap();

    let first = handle.open_stream(1);
    for (k, v) in &tokens {
        first.append(k.clone(), v.clone());
    }
    let mut q = vec![0.0f32; token_elems];
    Rng::new(3).fill_normal(&mut q);
    let q: Arc<[f32]> = q.into();
    let out_first = first.query(q.clone(), 1).recv().expect("first stream reply");
    first.close();

    // a resubmitted request replays the identical prompt
    let second = handle.open_stream(1);
    for (k, v) in &tokens {
        second.append(k.clone(), v.clone());
    }
    let out_second = second.query(q, 1).recv().expect("second stream reply");
    second.close();

    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.kv_alloc_blocks, 4, "only the first stream allocates");
    assert_eq!(stats.kv_hit_blocks, 4, "the replay shares every sealed block");
    assert_eq!(stats.kv_evicted_blocks, 0);
    // standard attention is seedless: the shared-prefix replay must also
    // reproduce the first stream's bytes
    assert_eq!(out_first, out_second);
}

#[test]
fn fork_refcounts_and_copy_on_write() {
    let mut cache = KvCache::new(KvCacheConfig::new(2), 2);
    let mut parent = cache.open_stream();
    for t in 0..5 {
        let row = [t as f32, t as f32 + 0.5];
        cache.append(&mut parent, &row, &row);
    }
    // 2 sealed + 1 tail block resident; fork shares all of them
    assert_eq!(cache.stats().resident_blocks, 3);
    let mut child = parent.fork();
    assert_eq!(cache.stats().resident_blocks, 3, "fork must not allocate");

    // diverge the child: the shared tail is copied, the parent unchanged
    cache.append(&mut child, &[9.0, 9.0], &[9.0, 9.0]);
    assert_eq!(cache.stats().resident_blocks, 4, "CoW copies exactly one block");
    let gather = |chain: &skeinformer::kvcache::StreamChain, n: usize| {
        let mut k = Matrix::zeros(n, 2);
        let mut v = Matrix::zeros(n, 2);
        chain.gather_head_into(0, 2, &mut k, &mut v);
        k
    };
    let pk = gather(&parent, 5);
    assert_eq!(pk.get(4, 0), 4.0, "parent tail must not see the child's token");
    let ck = gather(&child, 6);
    assert_eq!(ck.get(4, 0), 4.0, "shared prefix preserved");
    assert_eq!(ck.get(5, 0), 9.0, "child sees its divergent token");

    // the parent can keep appending without disturbing the child
    cache.append(&mut parent, &[7.0, 7.0], &[7.0, 7.0]);
    let ck = gather(&child, 6);
    assert_eq!(ck.get(5, 0), 9.0);

    // after both close, only the index holds blocks: the 2 shared prefix
    // blocks plus the divergent sealed block of each branch
    cache.close_stream(parent);
    cache.close_stream(child);
    assert_eq!(cache.stats().resident_blocks, 4);
}

#[test]
fn eviction_never_drops_a_block_still_referenced() {
    // capacity 2 blocks, but a live stream holds 3: the cap is exceeded
    // softly rather than evicting referenced blocks
    let mut cache = KvCache::new(KvCacheConfig::new(2).with_capacity_blocks(2), 1);
    let mut live = cache.open_stream();
    for t in 0..6 {
        cache.append(&mut live, &[t as f32], &[t as f32]);
    }
    assert_eq!(cache.stats().alloc_blocks, 3);
    assert_eq!(cache.stats().evicted_blocks, 0, "live blocks are never evicted");

    // the live stream's data must still be fully readable
    let mut k = Matrix::zeros(6, 1);
    let mut v = Matrix::zeros(6, 1);
    live.gather_head_into(0, 1, &mut k, &mut v);
    for t in 0..6 {
        assert_eq!(k.get(t, 0), t as f32);
    }

    // once the stream closes, new allocations evict its (now
    // unreferenced) index entries down to capacity
    cache.close_stream(live);
    let mut fresh = cache.open_stream();
    for t in 100..106 {
        cache.append(&mut fresh, &[t as f32], &[t as f32]);
    }
    assert!(cache.stats().evicted_blocks > 0, "unreferenced entries evict under pressure");
    let mut k = Matrix::zeros(6, 1);
    let mut v = Matrix::zeros(6, 1);
    fresh.gather_head_into(0, 1, &mut k, &mut v);
    for (i, t) in (100..106).enumerate() {
        assert_eq!(k.get(i, 0), t as f32, "fresh stream unaffected by eviction");
    }
    cache.close_stream(fresh);
}

#[test]
fn sliding_window_session_matches_window_recompute_at_epoch_seed() {
    let window = 6;
    let stride = 4;
    let total = 17;
    let p = 8;
    let mut rng = Rng::new(33);
    let mut mk = |rows: usize| {
        let mut m = Matrix::zeros(rows, p);
        rng.fill_normal(m.data_mut());
        m
    };
    let (k, v, q1) = (mk(total), mk(total), mk(1));
    for name in ["standard", "skeinformer", "linformer", "vmean"] {
        let method = attention::by_name(name, 4).expect("registry method");
        let spec = SessionSpec::new(p).with_seed(11).with_repilot_stride(stride);
        let mut session = BoundedSession::new(method, spec, window);
        for i in 0..total {
            session.append(k.row(i), v.row(i));
        }
        let got = session.query(&q1);
        // reference: the method over exactly the window rows, seeded by
        // the epoch of the TOTAL appended count
        let idx: Vec<usize> = (total - window..total).collect();
        let kw = k.gather_rows(&idx);
        let vw = v.gather_rows(&idx);
        let seed = session_seed(11, session_epoch(total, stride));
        let want = attention::by_name(name, 4)
            .unwrap()
            .compute(&q1, &kw, &vw, None, &mut Rng::new(seed));
        assert_eq!(got.max_abs_diff(&want), 0.0, "{name}: window recompute diverged");
    }
}

#[test]
fn server_windowed_stream_matches_bounded_sessions_per_head() {
    let cfg = server_cfg("skeinformer", Some(KvCacheConfig::new(2).with_window(5)));
    let stride = 2;
    let token_elems = cfg.heads * cfg.head_dim;
    let tokens = token_slabs(11, token_elems, 8);
    let handle = attention_server::start(cfg.clone()).unwrap();
    let stream = handle.open_stream(stride);
    let mut reference: Vec<BoundedSession> = (0..cfg.heads)
        .map(|h| {
            BoundedSession::new(
                attention::by_name(&cfg.method, cfg.d).unwrap(),
                SessionSpec::new(cfg.head_dim)
                    .with_seed(stream_seed(cfg.seed, 0, h as u64))
                    .with_repilot_stride(stride),
                5,
            )
        })
        .collect();
    let mut qrng = Rng::new(2);
    for (k, v) in &tokens {
        stream.append(k.clone(), v.clone());
        let mut q = vec![0.0f32; token_elems];
        qrng.fill_normal(&mut q);
        let got = stream.query(q.clone().into(), 1).recv().expect("windowed reply");
        for (h, session) in reference.iter_mut().enumerate() {
            let o = h * cfg.head_dim;
            session.append(&k[o..o + cfg.head_dim], &v[o..o + cfg.head_dim]);
            let q_head = Matrix::from_vec(1, cfg.head_dim, q[o..o + cfg.head_dim].to_vec());
            let want = session.query(&q_head);
            assert_eq!(&got[o..o + cfg.head_dim], want.data(), "head {h}");
        }
    }
    stream.close();
    let stats = handle.shutdown().unwrap();
    assert!(stats.kv_alloc_blocks >= 5, "11 tokens at block size 2 seal 5 blocks");
}

#[test]
fn windowed_streams_bound_resident_blocks() {
    // an unbounded stream grows its chain forever; a windowed one holds
    // O(window / block_size) blocks no matter how long it runs
    let mut cache = KvCache::new(KvCacheConfig::new(2).with_window(4), 1);
    let mut chain = cache.open_stream();
    for t in 0..50 {
        cache.append(&mut chain, &[t as f32], &[t as f32]);
    }
    assert_eq!(chain.appended(), 50);
    assert_eq!(chain.visible_len(), 4);
    assert!(
        chain.block_count() <= 4 / 2 + 1,
        "chain must hold only window-covering blocks, got {}",
        chain.block_count()
    );
    // with no capacity bound, window-dropped unshared blocks leave the
    // prefix index too: resident KV is O(window), not O(total tokens)
    assert_eq!(cache.stats().resident_blocks, 2);
    assert_eq!(cache.stats().evicted_blocks, 23);
    // gather sees exactly the last `window` tokens, in order
    let mut k = Matrix::zeros(4, 1);
    let mut v = Matrix::zeros(4, 1);
    chain.gather_head_into(0, 1, &mut k, &mut v);
    for (i, t) in (46..50).enumerate() {
        assert_eq!(k.get(i, 0), t as f32);
    }
    cache.close_stream(chain);
}
