//! End-to-end serving telemetry: the tiers must be exact, bounded, and
//! invisible in served bytes.
//!
//! * **Merge algebra** — cluster aggregation relies on bucket-wise
//!   histogram merge being associative and commutative; pinned against
//!   a brute-force oracle over random samples.
//! * **Bounded tracing** — per-thread flight-recorder rings wrap
//!   oldest-first and count drops exactly; drained Chrome-trace JSON is
//!   parseable and every span is well-formed (`t_end >= t_start`).
//! * **Zero-cost contract** — serving with telemetry on is bitwise
//!   identical to serving with it off: spans read clocks only, never
//!   RNG state or request data.
//! * **Cluster aggregation** — a coordinator's stats reply carries
//!   histograms whose counts equal the sum of its shards' own counts,
//!   plus the coordinator's scatter/gather spans and shard health rows.

use skeinformer::coordinator::attention_server::{self, AttentionServerConfig, HeadsRequest};
use skeinformer::coordinator::net::{self, NetClient};
use skeinformer::coordinator::shard::Coordinator;
use skeinformer::json;
use skeinformer::obs::{
    FlightRecorder, Histo, HistoSnapshot, Registry, ServeTelemetry, Span, HISTO_BUCKETS,
};
use skeinformer::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

fn cfg(method: &str) -> AttentionServerConfig {
    AttentionServerConfig {
        method: method.to_string(),
        d: 8,
        heads: 2,
        seq: 16,
        head_dim: 4,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        seed: 0,
        workers: None,
        queue_depth: 0,
        kv: None,
    }
}

fn requests(c: &AttentionServerConfig, n: usize, seed: u64) -> Vec<HeadsRequest> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| HeadsRequest::random(c.request_elems(), &mut rng)).collect()
}

/// Bucket-wise merge must be associative and commutative — any
/// aggregation tree over any shard order yields the oracle (one
/// histogram fed every sample).
#[test]
fn histogram_merge_matches_brute_force_oracle() {
    let mut rng = Rng::new(42);
    // samples spanning the full log2 range, including 0 and huge
    let samples: Vec<u64> = (0..3000)
        .map(|i| {
            let shift = (rng.next_u64() % 40) as u32;
            match i % 7 {
                0 => 0,
                1 => u64::MAX,
                _ => rng.next_u64() >> shift.min(63),
            }
        })
        .collect();
    let oracle = {
        let h = Histo::default();
        for &s in &samples {
            h.record(s);
        }
        h.snapshot()
    };
    // three uneven shards
    let parts: Vec<HistoSnapshot> = [0..500usize, 500..501, 501..3000]
        .into_iter()
        .map(|r| {
            let h = Histo::default();
            for &s in &samples[r] {
                h.record(s);
            }
            h.snapshot()
        })
        .collect();
    let (a, b, c) = (parts[0], parts[1], parts[2]);
    // ((a+b)+c)
    let mut left = a;
    left.merge(&b);
    left.merge(&c);
    // (a+(b+c))
    let mut right = b;
    right.merge(&c);
    let mut assoc = a;
    assoc.merge(&right);
    // (c+b)+a — commuted
    let mut comm = c;
    comm.merge(&b);
    comm.merge(&a);
    assert_eq!(left, oracle, "merge must equal the single-histogram oracle");
    assert_eq!(assoc, oracle, "merge must be associative");
    assert_eq!(comm, oracle, "merge must be commutative");
    assert_eq!(HistoSnapshot::merge_all(&parts), oracle);
    assert_eq!(oracle.count(), samples.len() as u64);
    assert_eq!(oracle.buckets.len(), HISTO_BUCKETS);
}

/// A tiny ring under multi-thread pressure: each writer thread keeps
/// exactly `cap` newest events, drops the rest, and counts every drop.
#[test]
fn trace_ring_wraps_oldest_first_and_counts_drops() {
    const CAP: usize = 64;
    const THREADS: u64 = 4;
    const EACH: u64 = 100;
    let rec = FlightRecorder::new(CAP);
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let rec = Arc::clone(&rec);
            std::thread::spawn(move || {
                for i in 0..EACH {
                    rec.record(Span::AttnCompute, t * 1000 + i, t * 1000 + i + 1, t, 0);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(rec.recorded(), THREADS * EACH);
    assert_eq!(rec.dropped(), THREADS * (EACH - CAP as u64));
    let evs = rec.snapshot();
    assert_eq!(evs.len(), THREADS as usize * CAP);
    for ev in &evs {
        assert!(ev.t_end_ns >= ev.t_start_ns, "span must close after it opens: {ev:?}");
        // oldest-first drop: only each thread's newest CAP survive
        let i = ev.t_start_ns % 1000;
        assert!(i >= EACH - CAP as u64, "event {i} should have been overwritten");
    }
}

/// Spans drained from a live instrumented server render as parseable
/// Chrome-trace JSON with well-formed events.
#[test]
fn live_server_trace_drains_as_well_formed_chrome_json() {
    let c = cfg("skeinformer");
    let obs = ServeTelemetry::new(true);
    let handle = attention_server::start_with_telemetry(c.clone(), Arc::clone(&obs))
        .expect("start server");
    for req in requests(&c, 3, 9) {
        let out = handle.submit(req).recv().expect("reply");
        assert_eq!(out.len(), c.request_elems());
    }
    let stream = handle.open_stream(1);
    let token_elems = stream.token_elems();
    let mut rng = Rng::new(11);
    let mut mk = || {
        let mut b = vec![0.0f32; token_elems];
        rng.fill_normal(&mut b);
        let s: Arc<[f32]> = b.into();
        s
    };
    let (k, v, q) = (mk(), mk(), mk());
    stream.append(k, v);
    stream.query(q, 1).recv().expect("stream reply");
    stream.close();
    let _ = handle.shutdown().expect("shutdown");

    let events = obs.recorder().snapshot();
    assert!(!events.is_empty(), "instrumented serving must record spans");
    for ev in &events {
        assert!(ev.t_end_ns >= ev.t_start_ns, "ill-formed span {ev:?}");
    }
    let names: std::collections::HashSet<&str> =
        events.iter().map(|e| e.span.name()).collect();
    assert!(names.contains("queue_wait"), "one-shots wait in the admission queue: {names:?}");
    assert!(names.contains("attn_compute"), "steps compute attention: {names:?}");

    let text = obs.recorder().to_chrome_trace(&c.method);
    let doc = json::parse(&text).expect("chrome trace parses as JSON");
    let arr = doc.as_arr().expect("top level is an array");
    assert_eq!(arr.len(), events.len());
    for ev in arr {
        assert_eq!(ev.req_str("ph").unwrap(), "X");
        let name = ev.req_str("name").expect("event name");
        assert!(
            [
                "queue_wait",
                "batch_form",
                "kv_ingest_hit",
                "kv_ingest_miss",
                "kv_gather",
                "attn_compute",
                "reply_write",
                "scatter_encode",
                "shard_rtt",
                "gather_wait"
            ]
            .contains(&name),
            "unknown span name {name:?}"
        );
        assert!(ev.get("ts").and_then(|t| t.as_f64()).expect("ts") >= 0.0);
        assert!(ev.get("dur").and_then(|d| d.as_f64()).expect("dur") >= 0.0);
        ev.path(&["args", "conn"]).and_then(|c| c.as_usize()).expect("args.conn");
    }
}

/// The Prometheus exposition for a small fixed registry, byte-exact:
/// name-sorted sections, cumulative skip-empty buckets, `+Inf` always
/// emitted.
#[test]
fn metrics_exposition_matches_golden() {
    let r = Registry::new();
    r.counter("skein_requests_total").add(2);
    r.gauge("skein_queue_depth").set(5);
    let h = r.histo("skein_queue_wait_ns");
    h.record(100); // le=128
    h.record(200_000); // le=262144
    let golden = "\
# TYPE skein_requests_total counter
skein_requests_total 2
# TYPE skein_queue_depth gauge
skein_queue_depth 5
# TYPE skein_queue_wait_ns histogram
skein_queue_wait_ns_bucket{le=\"128\"} 1
skein_queue_wait_ns_bucket{le=\"262144\"} 2
skein_queue_wait_ns_bucket{le=\"+Inf\"} 2
skein_queue_wait_ns_sum 200100
skein_queue_wait_ns_count 2
";
    assert_eq!(r.render_prometheus(), golden);
}

/// The zero-cost contract: the same workload served with telemetry on
/// and off produces bitwise-identical bytes — instrumentation reads
/// clocks only, never RNG state or request data.
#[test]
fn serving_is_bitwise_identical_with_telemetry_on() {
    for method in ["skeinformer", "standard"] {
        let c = cfg(method);
        let plain = attention_server::start(c.clone()).expect("start plain");
        let obs = ServeTelemetry::new(true);
        let traced = attention_server::start_with_telemetry(c.clone(), Arc::clone(&obs))
            .expect("start traced");

        // one-shots, submitted in the same order on both servers
        for (a, b) in requests(&c, 6, 3).into_iter().zip(requests(&c, 6, 3)) {
            let oa = plain.submit(a).recv().expect("plain reply");
            let ob = traced.submit(b).recv().expect("traced reply");
            assert_eq!(oa, ob, "telemetry must not perturb one-shot bytes ({method})");
        }

        // a decode stream, token by token
        let sa = plain.open_stream(1);
        let sb = traced.open_stream(1);
        let token_elems = sa.token_elems();
        let mut rng_a = Rng::new(5);
        let mut rng_b = Rng::new(5);
        let mut mk = |rng: &mut Rng| {
            let mut b = vec![0.0f32; token_elems];
            rng.fill_normal(&mut b);
            let s: Arc<[f32]> = b.into();
            s
        };
        for _ in 0..8 {
            let (ka, va, qa) = (mk(&mut rng_a), mk(&mut rng_a), mk(&mut rng_a));
            let (kb, vb, qb) = (mk(&mut rng_b), mk(&mut rng_b), mk(&mut rng_b));
            sa.append(ka, va);
            sb.append(kb, vb);
            let oa = sa.query(qa, 1).recv().expect("plain stream reply");
            let ob = sb.query(qb, 1).recv().expect("traced stream reply");
            assert_eq!(oa, ob, "telemetry must not perturb decode bytes ({method})");
        }
        sa.close();
        sb.close();

        let stats_a = plain.shutdown().expect("plain shutdown");
        let stats_b = traced.shutdown().expect("traced shutdown");
        assert_eq!(stats_a.requests, stats_b.requests);
        assert!(obs.recorder().recorded() > 0, "traced server must actually record");
        assert!(obs.h_attn_compute.snapshot().count() > 0);
    }
}

/// A coordinator's aggregated stats reply: histogram counts equal the
/// sum of the shards' own counts, the coordinator's scatter/RTT/gather
/// spans ride along, and every shard gets a health row.
#[test]
fn cluster_aggregation_sums_shard_histograms_and_reports_health() {
    const N: usize = 8;
    let c = cfg("skeinformer");
    // two engine shards, each with live telemetry, behind real TCP
    let mut shards = Vec::new();
    for i in 0..2u32 {
        let obs = ServeTelemetry::new(true);
        let handle = attention_server::start_with_telemetry(c.clone(), Arc::clone(&obs))
            .expect("start shard");
        let backend = Arc::new(net::EngineBackend::new(&handle, i, 2));
        let server = net::serve_backend(backend, "127.0.0.1:0").expect("bind shard");
        let addr = server.local_addr().to_string();
        shards.push((handle, server, addr, obs));
    }
    let addrs: Vec<String> = shards.iter().map(|s| s.2.clone()).collect();
    let coord_obs = ServeTelemetry::new(true);
    let coord = Coordinator::start_with_telemetry(
        &addrs,
        Duration::from_millis(100),
        net::NetTimeouts::default(),
        Arc::clone(&coord_obs),
    )
    .expect("start coordinator");
    let front = net::serve_backend(coord.backend(), "127.0.0.1:0").expect("bind front");
    let mut client = NetClient::connect(front.local_addr()).expect("connect front");

    for req in requests(&c, N, 21) {
        let out = client.submit(&req).expect("scattered reply");
        assert_eq!(out.len(), c.request_elems());
    }

    let sw = client.stats_full().expect("aggregated stats");
    // every shard ever added gets a health row
    assert_eq!(sw.shards.len(), 2);
    for h in &sw.shards {
        assert!(h.alive, "both shards are up: {h:?}");
        assert_eq!(h.down_drains, 0);
        assert!(addrs.contains(&h.addr));
    }
    // heads=2 over 2 shards: every request scatters into 2 sub-requests
    assert_eq!(sw.stats.requests, 2 * N as u64);
    // aggregated histogram counts == sum of the shards' own counts
    let aggregated = |name: &str| -> u64 {
        sw.histos
            .iter()
            .find(|(n, _)| n == name)
            .map_or(0, |(_, h)| h.count())
    };
    for name in ["skein_attn_compute_ns", "skein_queue_wait_ns"] {
        let shard_sum: u64 = shards
            .iter()
            .map(|(_, _, _, obs)| {
                obs.wire_snapshots()
                    .1
                    .into_iter()
                    .find(|(n, _)| n == name)
                    .map_or(0, |(_, h)| h.count())
            })
            .sum();
        assert!(shard_sum > 0, "shards must have recorded {name}");
        assert_eq!(aggregated(name), shard_sum, "aggregation must sum {name} counts");
    }
    // the coordinator's own spans ride in the same reply: one scatter
    // and one gather per request, one RTT per sub-reply — plus one RTT
    // per shard for the stats poll itself (each shard's reply is taken
    // before the merged view is assembled)
    assert_eq!(aggregated("skein_scatter_encode_ns"), N as u64);
    assert_eq!(aggregated("skein_gather_wait_ns"), N as u64);
    assert_eq!(aggregated("skein_shard_rtt_ns"), 2 * N as u64 + 2);

    drop(client);
    front.stop();
    coord.shutdown();
    for (handle, server, _, _) in shards {
        server.stop();
        let _ = handle.shutdown();
    }
}
