//! Microkernel conformance: every ISA variant is bitwise identical.
//!
//! The dispatch layer's whole contract (DESIGN.md §Microkernels) is
//! that kernel selection is a *speed knob only*: scalar, SSE2, and
//! AVX2 commit to the same fixed 8-lane accumulation order, so served
//! bytes never depend on the CPU, the `simd` feature, or the
//! `SKEIN_KERNEL` override.  These tests pin that:
//!
//! * every kernel in the table, compared pairwise across all supported
//!   ISAs via [`kernels::table_for`] (no global state touched), at
//!   awkward shapes — lengths not a multiple of 8/16, empty slices,
//!   single elements — and with NaN/inf inputs (bit-for-bit, including
//!   NaN propagation);
//! * the fused dequantise-on-gather path: decoding a sub-range of a
//!   quantised payload equals decoding everything and slicing;
//! * the `matmul` row zero-probe: the branch-free dense path and the
//!   zero-skipping path are the same accumulation sequence, pinned
//!   against an always-skip reference (which also pins that masked
//!   zero rows never multiply `0 · inf` into NaN);
//! * end to end: the full attention registry's `compute_into` under
//!   each supported ISA (forced via [`kernels::select`]), and a tiered
//!   KV cache demote/gather cycle, produce identical bits.
//!
//! The scalar table is compiled identically with and without the
//! `simd` cargo feature, so scalar ≡ avx2 in a simd build transitively
//! pins simd-on ≡ simd-off across builds.
//!
//! Tests that flip the process-wide selection serialize on a mutex and
//! restore the previous ISA before exiting (table-based tests need no
//! lock).

use skeinformer::attention::{self, AttnInputs, AttnScratch};
use skeinformer::kvcache::{f32_to_f16_bits, KvCache, KvCacheConfig, StreamChain, TierLadder};
use skeinformer::rng::Rng;
use skeinformer::tensor::kernels::{self, KernelIsa, KernelTable};
use skeinformer::tensor::{matmul, matmul_nt, matvec, softmax_rows, Matrix};
use std::sync::Mutex;

/// Serializes tests that change the process-wide kernel selection.
static SELECT_LOCK: Mutex<()> = Mutex::new(());

/// Every ISA this build/CPU can actually run (scalar always; SSE2/AVX2
/// only in a `--features simd` build on hardware that has them).
fn supported_tables() -> Vec<&'static KernelTable> {
    KernelIsa::ALL.iter().filter_map(|&isa| kernels::table_for(isa)).collect()
}

/// Shape sweep: everything around the 8-lane boundary plus empties.
const LENS: &[usize] = &[0, 1, 2, 3, 5, 7, 8, 9, 12, 15, 16, 17, 24, 31, 33, 63, 64, 100, 127];

fn gen(len: usize, seed: u64) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    Rng::new(seed).fill_normal(&mut v);
    v
}

/// As [`gen`] but with non-finite values planted at awkward positions
/// (first element, a mid-lane slot, the scalar tail).
fn gen_wild(len: usize, seed: u64) -> Vec<f32> {
    let mut v = gen(len, seed);
    if len > 0 {
        v[0] = f32::NEG_INFINITY;
    }
    if len > 5 {
        v[5] = f32::NAN;
    }
    if len > 9 {
        v[9] = f32::INFINITY;
    }
    if len > 2 {
        v[len - 1] = f32::NAN;
    }
    v
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: bit mismatch at {i} ({g} vs {w})"
        );
    }
}

#[test]
fn reductions_are_bitwise_identical_across_isas() {
    let scalar = kernels::table_for(KernelIsa::Scalar).unwrap();
    for t in supported_tables() {
        for &len in LENS {
            for (tag, a) in [("plain", gen(len, 11)), ("wild", gen_wild(len, 11))] {
                let b = gen(len, 17 + len as u64);
                let what = format!("{} len={len} {tag}", t.isa);
                assert_eq!(
                    (t.dot)(&a, &b).to_bits(),
                    (scalar.dot)(&a, &b).to_bits(),
                    "dot {what}"
                );
                assert_eq!(
                    (t.row_sum)(&a).to_bits(),
                    (scalar.row_sum)(&a).to_bits(),
                    "row_sum {what}"
                );
                assert_eq!(
                    (t.sum_sq)(&a).to_bits(),
                    (scalar.sum_sq)(&a).to_bits(),
                    "sum_sq {what}"
                );
                assert_eq!(
                    (t.row_max)(&a).to_bits(),
                    (scalar.row_max)(&a).to_bits(),
                    "row_max {what}"
                );
            }
        }
    }
}

#[test]
fn elementwise_kernels_are_bitwise_identical_across_isas() {
    let scalar = kernels::table_for(KernelIsa::Scalar).unwrap();
    for t in supported_tables() {
        for &len in LENS {
            for (tag, x) in [("plain", gen(len, 23)), ("wild", gen_wild(len, 23))] {
                let what = format!("{} len={len} {tag}", t.isa);
                // saxpy
                let mut y_got = gen(len, 29);
                let mut y_want = y_got.clone();
                (t.saxpy)(0.731, &x, &mut y_got);
                (scalar.saxpy)(0.731, &x, &mut y_want);
                assert_bits_eq(&y_got, &y_want, &format!("saxpy {what}"));
                // scale
                let mut s_got = x.clone();
                let mut s_want = x.clone();
                (t.scale)(&mut s_got, -1.75e-3);
                (scalar.scale)(&mut s_want, -1.75e-3);
                assert_bits_eq(&s_got, &s_want, &format!("scale {what}"));
                // exp_shifted, both at zero shift and a softmax-like one
                for shift in [0.0f32, 1.375, -88.0, 90.0] {
                    let mut e_got = x.clone();
                    let mut e_want = x.clone();
                    (t.exp_shifted)(&mut e_got, shift);
                    (scalar.exp_shifted)(&mut e_want, shift);
                    assert_bits_eq(&e_got, &e_want, &format!("exp_shifted({shift}) {what}"));
                }
            }
        }
    }
}

#[test]
fn dequant_kernels_are_bitwise_identical_across_isas() {
    let scalar = kernels::table_for(KernelIsa::Scalar).unwrap();
    // f16 payload: round-tripped normals plus every special encoding
    let mut halfs: Vec<u16> = gen(90, 31).iter().map(|&x| f32_to_f16_bits(x)).collect();
    halfs.extend([
        0x0000, 0x8000, // ±0
        0x7c00, 0xfc00, // ±inf
        0x7e00, 0xfe00, // quiet NaN
        0x7d55, // NaN with payload bits
        0x0001, 0x03ff, 0x8001, // subnormals
        0x7bff, 0xfbff, // ±max finite
        0x0400, // smallest normal
    ]);
    let signed: Vec<i8> = (0..103).map(|i| (i * 5 % 256) as u8 as i8).collect();
    for t in supported_tables() {
        for &len in LENS {
            let what = format!("{} len={len}", t.isa);
            let hs = &halfs[..len.min(halfs.len())];
            let mut got = vec![0.0f32; hs.len()];
            let mut want = vec![0.0f32; hs.len()];
            (t.dequant_f16)(hs, &mut got);
            (scalar.dequant_f16)(hs, &mut want);
            assert_bits_eq(&got, &want, &format!("dequant_f16 {what}"));
            let qs = &signed[..len.min(signed.len())];
            for scale in [0.0f32, 0.0625, 16.0] {
                let mut got = vec![0.0f32; qs.len()];
                let mut want = vec![0.0f32; qs.len()];
                (t.dequant_i8)(qs, scale, &mut got);
                (scalar.dequant_i8)(qs, scale, &mut want);
                assert_bits_eq(&got, &want, &format!("dequant_i8({scale}) {what}"));
            }
        }
    }
}

#[test]
fn fused_range_dequant_equals_decode_all_then_slice() {
    let scalar = kernels::table_for(KernelIsa::Scalar).unwrap();
    let halfs: Vec<u16> = gen(128, 37).iter().map(|&x| f32_to_f16_bits(x)).collect();
    let signed: Vec<i8> = (0..128).map(|i| (i * 7 % 256) as u8 as i8).collect();
    let mut full_f16 = vec![0.0f32; halfs.len()];
    (scalar.dequant_f16)(&halfs, &mut full_f16);
    let mut full_i8 = vec![0.0f32; signed.len()];
    (scalar.dequant_i8)(&signed, 0.03125, &mut full_i8);
    for t in supported_tables() {
        // the gather path decodes [offset, offset + head_dim) straight
        // from the payload; any offset/width must agree with the
        // decode-everything baseline
        for (offset, width) in [(0usize, 128usize), (3, 13), (8, 64), (17, 5), (120, 8), (64, 0)] {
            let mut got = vec![0.0f32; width];
            (t.dequant_f16)(&halfs[offset..offset + width], &mut got);
            assert_bits_eq(
                &got,
                &full_f16[offset..offset + width],
                &format!("fused f16 gather {} {offset}+{width}", t.isa),
            );
            let mut got = vec![0.0f32; width];
            (t.dequant_i8)(&signed[offset..offset + width], 0.03125, &mut got);
            assert_bits_eq(
                &got,
                &full_i8[offset..offset + width],
                &format!("fused i8 gather {} {offset}+{width}", t.isa),
            );
        }
    }
}

/// Always-skip reference for `matmul`'s ikj accumulation: one saxpy
/// stream per *nonzero* A element, in (i, k) order — the semantics the
/// row zero-probe must preserve whichever path it picks.
fn matmul_skip_reference(a: &Matrix, b: &Matrix) -> Matrix {
    let kt = kernels::active();
    let (m, ka) = a.shape();
    let n = b.cols();
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        for k in 0..ka {
            let aik = a.get(i, k);
            if aik == 0.0 {
                continue;
            }
            (kt.saxpy)(aik, b.row(k), out.row_mut(i));
        }
    }
    out
}

#[test]
fn matmul_zero_probe_paths_are_one_accumulation_order() {
    // dense A: no zeros anywhere, so every row takes the branch-free path
    let a_dense = Matrix::from_fn(9, 13, |i, j| ((i * 13 + j) as f32 * 0.19).sin() + 2.0);
    // mixed A: zero elements mid-row and one all-zero (fully masked) row
    let mut a_mixed = Matrix::from_fn(9, 13, |i, j| ((i * 13 + j) as f32 * 0.19).sin());
    for j in 0..13 {
        a_mixed.set(4, j, 0.0);
    }
    a_mixed.set(2, 3, 0.0);
    a_mixed.set(7, 12, 0.0);
    let b = Matrix::from_fn(13, 11, |i, j| ((i + j * 3) as f32 * 0.23).cos());
    for a in [&a_dense, &a_mixed] {
        let got = matmul(a, &b);
        let want = matmul_skip_reference(a, &b);
        assert_bits_eq(got.data(), want.data(), "matmul vs skip reference");
    }
    // masked-row poison: B has an inf row that only zero A coefficients
    // touch — the skip must keep 0·inf = NaN out of the masked row
    let mut b_inf = b.clone();
    for j in 0..11 {
        b_inf.set(3, j, f32::INFINITY);
    }
    let mut a_masked = a_mixed.clone();
    for i in 0..9 {
        a_masked.set(i, 3, 0.0);
    }
    let got = matmul(&a_masked, &b_inf);
    assert!(got.all_finite(), "zero coefficients must skip the inf row entirely");
    assert_bits_eq(got.data(), matmul_skip_reference(&a_masked, &b_inf).data(), "masked matmul");
}

/// Run every registry method once and return the output bits.
fn registry_outputs(n: usize, p: usize, d: usize) -> Vec<(String, Vec<u32>)> {
    let q = Matrix::from_fn(n, p, |i, j| ((i * 3 + j) as f32 * 0.13).sin());
    let k = Matrix::from_fn(n, p, |i, j| ((i + j * 5) as f32 * 0.07).cos());
    let v = Matrix::from_fn(n, p, |i, j| ((i * j) as f32 * 0.01).tanh());
    // padding mask with real zeros: exercises the -inf score rows and
    // the exp(-inf) == 0 kernel semantics
    let mask: Vec<f32> = (0..n).map(|i| if i % 7 == 6 { 0.0 } else { 1.0 }).collect();
    let mut scratch = AttnScratch::new();
    let mut outs = Vec::new();
    for method in attention::registry(d) {
        for (tag, m) in [("nomask", None), ("mask", Some(mask.as_slice()))] {
            let inputs = AttnInputs::new(&q, &k, &v).with_mask(m).with_seed(41);
            let mut out = Matrix::zeros(n, p);
            method.compute_into(&inputs, &mut out, &mut scratch);
            outs.push((
                format!("{}/{tag}", method.name()),
                out.data().iter().map(|x| x.to_bits()).collect(),
            ));
        }
    }
    outs
}

#[test]
fn full_registry_is_bitwise_identical_across_forced_isas() {
    let _guard = SELECT_LOCK.lock().unwrap();
    let prev = kernels::active_isa();
    kernels::select(KernelIsa::Scalar).unwrap();
    let baseline = registry_outputs(64, 16, 32);
    for t in supported_tables() {
        kernels::select(t.isa).unwrap();
        let got = registry_outputs(64, 16, 32);
        for ((name, want), (name2, bits)) in baseline.iter().zip(&got) {
            assert_eq!(name, name2);
            assert_eq!(
                bits, want,
                "{name}: output bits differ between scalar and {}",
                t.isa
            );
        }
    }
    kernels::select(prev).unwrap();
}

#[test]
fn matmul_family_is_bitwise_identical_across_forced_isas() {
    let _guard = SELECT_LOCK.lock().unwrap();
    let prev = kernels::active_isa();
    // odd shapes so vector bodies and scalar tails both run
    let a = Matrix::from_fn(23, 37, |i, j| ((i * 37 + j) as f32 * 0.11).sin());
    let b = Matrix::from_fn(37, 19, |i, j| ((i + j * 7) as f32 * 0.05).cos());
    let bt = b.transpose();
    let x: Vec<f32> = (0..37).map(|i| (i as f32 * 0.3).tanh()).collect();
    let mut sm = Matrix::from_fn(23, 37, |i, j| ((i * 37 + j) as f32 * 0.17).sin() * 4.0);
    // a fully-masked softmax row exercises the uniform fallback
    for j in 0..37 {
        sm.set(11, j, f32::NEG_INFINITY);
    }
    kernels::select(KernelIsa::Scalar).unwrap();
    let mm0 = matmul(&a, &b);
    let nt0 = matmul_nt(&a, &bt);
    let mv0 = matvec(&a, &x);
    let mut sx0 = sm.clone();
    softmax_rows(&mut sx0);
    for t in supported_tables() {
        kernels::select(t.isa).unwrap();
        assert_bits_eq(matmul(&a, &b).data(), mm0.data(), &format!("matmul {}", t.isa));
        assert_bits_eq(matmul_nt(&a, &bt).data(), nt0.data(), &format!("matmul_nt {}", t.isa));
        let mv = matvec(&a, &x);
        assert_bits_eq(&mv, &mv0, &format!("matvec {}", t.isa));
        let mut sx = sm.clone();
        softmax_rows(&mut sx);
        assert_bits_eq(sx.data(), sx0.data(), &format!("softmax {}", t.isa));
    }
    kernels::select(prev).unwrap();
}

/// Fill a tiered cache past capacity (forcing f16 + int8 demotion),
/// replay the prefix, and gather head 0 — the dequantise-on-gather
/// read path end to end.
fn tiered_gather_bits() -> Vec<u32> {
    const TE: usize = 6;
    const BS: usize = 4;
    let tiers = TierLadder::none().with_f16(true).with_int8(true);
    let mut c =
        KvCache::new(KvCacheConfig::new(BS).with_capacity_blocks(2).with_tiers(tiers), TE);
    let rows = |seed: u64, n: usize| {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut k = vec![0.0f32; TE];
                let mut v = vec![0.0f32; TE];
                rng.fill_normal(&mut k);
                rng.fill_normal(&mut v);
                (k, v)
            })
            .collect::<Vec<_>>()
    };
    let fill = |c: &mut KvCache, ch: &mut StreamChain, rows: &[(Vec<f32>, Vec<f32>)]| {
        for (k, v) in rows {
            c.append(ch, k, v);
        }
    };
    let prompt = rows(3, 2 * BS);
    let mut a = c.open_stream();
    fill(&mut c, &mut a, &prompt);
    c.close_stream(a);
    // pressure: a second stream demotes the sealed prompt blocks
    let mut b = c.open_stream();
    fill(&mut c, &mut b, &rows(4, 2 * BS));
    c.close_stream(b);
    assert!(c.stats().demoted_blocks > 0, "setup must force demotion");
    // replay hits the quantised entries; gather decodes them
    let mut r = c.open_stream();
    fill(&mut c, &mut r, &prompt);
    let n = r.visible_len();
    let mut k = Matrix::zeros(n, 3);
    let mut v = Matrix::zeros(n, 3);
    r.gather_head_into(1, 3, &mut k, &mut v);
    c.close_stream(r);
    k.data().iter().chain(v.data()).map(|x| x.to_bits()).collect()
}

#[test]
fn tiered_kv_gather_is_bitwise_identical_across_forced_isas() {
    let _guard = SELECT_LOCK.lock().unwrap();
    let prev = kernels::active_isa();
    kernels::select(KernelIsa::Scalar).unwrap();
    let baseline = tiered_gather_bits();
    for t in supported_tables() {
        kernels::select(t.isa).unwrap();
        assert_eq!(
            tiered_gather_bits(),
            baseline,
            "tiered gather bits differ between scalar and {}",
            t.isa
        );
    }
    kernels::select(prev).unwrap();
}
