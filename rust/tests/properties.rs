//! Cross-module property suites (prop::Runner substrate — the proptest
//! analogue).  Each property runs over many randomized cases; failures
//! report a replayable seed.

use skeinformer::attention::{registry, AttentionMethod, Skeinformer, Standard};
use skeinformer::data;
use skeinformer::json;
use skeinformer::prop::Runner;
use skeinformer::rng::Rng;
use skeinformer::sketch::{amm_error_bound, GaussianSketch, Sketch, SrhtSketch, SubSampleSketch};
use skeinformer::tensor::{
    self, frobenius_norm, matmul, matmul_nt, matmul_tn, row_sums, softmax_rows, spectral_norm,
    Matrix,
};

fn random_matrix(g: &mut skeinformer::prop::Gen, rows: usize, cols: usize) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    for x in m.data_mut() {
        *x = g.normal();
    }
    m
}

// ---------------------------------------------------------------- tensor

#[test]
fn prop_matmul_distributes_over_addition() {
    Runner::new("matmul-distributive", 40).run(|g| {
        let (m, k, n) = (g.int(1, 12), g.int(1, 12), g.int(1, 12));
        let a = random_matrix(g, m, k);
        let b = random_matrix(g, k, n);
        let c = random_matrix(g, k, n);
        let left = matmul(&a, &tensor::add(&b, &c));
        let right = tensor::add(&matmul(&a, &b), &matmul(&a, &c));
        assert!(left.max_abs_diff(&right) < 1e-3);
    });
}

#[test]
fn prop_matmul_nt_equals_explicit_transpose() {
    Runner::new("matmul-nt-transpose", 40).run(|g| {
        let (m, k, n) = (g.int(1, 16), g.int(1, 16), g.int(1, 16));
        let a = random_matrix(g, m, k);
        let b = random_matrix(g, n, k);
        assert!(matmul_nt(&a, &b).max_abs_diff(&matmul(&a, &b.transpose())) < 1e-3);
    });
}

#[test]
fn prop_softmax_rows_stochastic_and_order_preserving() {
    Runner::new("softmax-stochastic", 40).run(|g| {
        let (r, c) = (g.int(1, 10), g.int(2, 20));
        let mut m = random_matrix(g, r, c);
        let before = m.clone();
        softmax_rows(&mut m);
        for s in row_sums(&m) {
            assert!((s - 1.0).abs() < 1e-4);
        }
        // order preservation within each row
        for i in 0..r {
            for j in 1..c {
                let ord_in = before.get(i, j) > before.get(i, j - 1);
                let ord_out = m.get(i, j) > m.get(i, j - 1);
                assert_eq!(ord_in, ord_out, "softmax reordered elements");
            }
        }
    });
}

#[test]
fn prop_spectral_norm_is_submultiplicative_with_vectors() {
    // ‖Mx‖ ≤ ‖M‖₂ ‖x‖ for random vectors
    Runner::new("spectral-operator-bound", 30).run(|g| {
        let (m, n) = (g.int(2, 15), g.int(2, 15));
        let a = random_matrix(g, m, n);
        let norm = spectral_norm(&a);
        let x: Vec<f32> = (0..n).map(|_| g.normal()).collect();
        let ax = tensor::matvec(&a, &x);
        let lhs = tensor::norm2(&ax);
        let rhs = norm * tensor::norm2(&x);
        assert!(lhs <= rhs * 1.01 + 1e-4, "‖Ax‖={lhs} > ‖A‖‖x‖={rhs}");
    });
}

// ---------------------------------------------------------------- sketch

#[test]
fn prop_subsample_sketch_unbiased_for_matvec() {
    // E[S Sᵀ x] = x — averaged over draws the sketch acts like identity.
    Runner::new("sketch-unbiased", 8).run(|g| {
        let n = g.int(6, 20);
        let d = g.int(2, 8);
        let probs: Vec<f32> = (0..n).map(|_| g.f32(0.1, 1.0)).collect();
        let sk = SubSampleSketch::new(probs, d);
        let x: Vec<f32> = (0..n).map(|_| g.normal()).collect();
        let xm = Matrix::from_vec(1, n, x.clone());
        let trials = 2500;
        let mut acc = vec![0.0f64; n];
        let mut rng = Rng::new(g.int(0, 1 << 30) as u64);
        for _ in 0..trials {
            let s = sk.draw(&mut rng);
            // x S Sᵀ  (1×n): (1,d) · (n,d)ᵀ
            let xs = matmul(&xm, &s);
            let xss = matmul_nt(&xs, &s);
            for (a, &v) in acc.iter_mut().zip(xss.data()) {
                *a += v as f64;
            }
        }
        let xn = tensor::norm2(&x) as f64;
        for (i, a) in acc.iter().enumerate() {
            let mean = a / trials as f64;
            assert!(
                (mean - x[i] as f64).abs() < 0.25 * xn.max(1.0),
                "index {i}: mean {mean} vs {}",
                x[i]
            );
        }
    });
}

#[test]
fn prop_amm_bound_holds() {
    // Proposition 1's bound, randomized over shapes and probability floors.
    Runner::new("amm-bound", 10).run(|g| {
        let n = g.int(8, 32);
        let p = g.int(2, 8);
        let d = g.int(4, 16);
        let mut b = random_matrix(g, n, n);
        softmax_rows(&mut b);
        let v = random_matrix(g, n, p);
        let probs = skeinformer::sketch::amm_approximate; // silence unused warn path
        let _ = probs;
        let opt = {
            let bc = tensor::col_norms(&b);
            let vr = tensor::row_norms(&v);
            bc.iter().zip(&vr).map(|(x, y)| (x * y).max(1e-6)).collect::<Vec<_>>()
        };
        let sk = SubSampleSketch::new(opt, d);
        let exact = matmul(&b, &v);
        let bound = amm_error_bound(&b, &v, d, 1.0, 0.05);
        let mut rng = Rng::new(g.int(0, 1 << 30) as u64);
        for _ in 0..50 {
            let approx = skeinformer::sketch::amm_approximate(&b, &v, &sk, &mut rng);
            let err = frobenius_norm(&tensor::sub(&approx, &exact)).powi(2);
            assert!(err <= bound, "err {err} > bound {bound} (n={n}, d={d})");
        }
    });
}

#[test]
fn prop_gaussian_sketch_preserves_norms_on_average() {
    Runner::new("jl-average", 10).run(|g| {
        let n = g.int(8, 40);
        let d = 64;
        let sk = GaussianSketch::new(n, d);
        let x: Vec<f32> = (0..n).map(|_| g.normal()).collect();
        let xn2: f32 = x.iter().map(|a| a * a).sum();
        let mut rng = Rng::new(g.int(0, 1 << 30) as u64);
        let trials = 60;
        let mut est = 0.0f64;
        for _ in 0..trials {
            let s = sk.draw(&mut rng);
            let xm = Matrix::from_vec(1, n, x.clone());
            let proj = matmul(&xm, &s);
            est += proj.data().iter().map(|a| (a * a) as f64).sum::<f64>();
        }
        est /= trials as f64;
        assert!((est / xn2 as f64 - 1.0).abs() < 0.3, "ratio {}", est / xn2 as f64);
    });
}

#[test]
fn prop_srht_columns_are_near_orthogonal() {
    // SRHT columns are sign-flipped Hadamard columns scaled by 1/√d, so
    // (d/n)·SᵀS equals the indicator [c_a == c_b] up to f32 rounding —
    // in particular ‖(d/n)·SᵀS − I‖ is tiny whenever the sampled columns
    // are distinct.
    Runner::new("srht-orthogonal", 20).run(|g| {
        let n = g.pow2(8, 64);
        let d = g.int(2, 8).min(n);
        let sk = SrhtSketch::new(n, d);
        let seed = g.int(0, 1 << 30) as u64;
        // same seed -> draw() materialises exactly the parts draw_parts gives
        let s = sk.draw(&mut Rng::new(seed));
        let (_, cols) = sk.draw_parts(&mut Rng::new(seed));
        let sts = matmul_tn(&s, &s); // (d, d)
        let scale = d as f32 / n as f32;
        for a in 0..d {
            for b in 0..d {
                let expect = if cols[a] == cols[b] { 1.0 } else { 0.0 };
                let got = sts.get(a, b) * scale;
                assert!(
                    (got - expect).abs() < 1e-3,
                    "(d/n)·SᵀS[{a},{b}] = {got}, expected {expect} (n={n}, d={d})"
                );
            }
        }
    });
}

#[test]
fn prop_subsample_amm_unbiased_for_matrix_product() {
    // E[Aᵀ S Sᵀ B] = Aᵀ B over repeated draws — Definition 3.1's
    // expectation identity pushed through the AMM estimator, for arbitrary
    // (positive) sampling probabilities.
    Runner::new("subsample-amm-unbiased", 6).run(|g| {
        let n = g.int(8, 20);
        let p1 = g.int(2, 5);
        let p2 = g.int(2, 5);
        let d = g.int(3, 8);
        let a = random_matrix(g, n, p1);
        let b = random_matrix(g, n, p2);
        let probs: Vec<f32> = (0..n).map(|_| g.f32(0.1, 1.0)).collect();
        let sk = SubSampleSketch::new(probs, d);
        let exact = matmul_tn(&a, &b); // (p1, p2)
        let trials = 4000;
        let mut acc = vec![0.0f64; p1 * p2];
        let mut rng = Rng::new(g.int(0, 1 << 30) as u64);
        // reused draw buffers across the 4000 trials (same RNG stream and
        // draws as the allocating wrapper, no per-draw Vecs)
        let mut idx = Vec::new();
        let mut scales = Vec::new();
        for _ in 0..trials {
            sk.draw_indices_into(&mut rng, &mut idx, &mut scales);
            // Sᵀ A: (d, p1) and Sᵀ B: (d, p2) are scaled row gathers
            let sa = Matrix::from_fn(d, p1, |r, c| a.get(idx[r], c) * scales[r]);
            let sb = Matrix::from_fn(d, p2, |r, c| b.get(idx[r], c) * scales[r]);
            let est = matmul_tn(&sa, &sb); // Aᵀ S Sᵀ B
            for (acc_x, &e) in acc.iter_mut().zip(est.data()) {
                *acc_x += e as f64;
            }
        }
        let scale_ref = frobenius_norm(&exact) as f64 + 1.0;
        for (i, acc_x) in acc.iter().enumerate() {
            let mean = acc_x / trials as f64;
            let want = exact.data()[i] as f64;
            assert!(
                (mean - want).abs() < 0.15 * scale_ref,
                "entry {i}: mean {mean} vs exact {want} (n={n}, d={d})"
            );
        }
    });
}

#[test]
fn prop_gaussian_sketch_variance_matches_chi_square() {
    // With i.i.d. N(0, 1/d) entries, y = ‖Sᵀx‖² is (‖x‖²/d)·χ²_d:
    // E[y] = ‖x‖² and Var[y] = 2‖x‖⁴/d.  The sample variance over many
    // draws must sit within a 3× band of the theory value — the
    // quantitative version of "JL concentration tightens with d".
    Runner::new("gaussian-sketch-variance", 6).run(|g| {
        let n = g.int(8, 32);
        let d = g.pow2(8, 32);
        let sk = GaussianSketch::new(n, d);
        let x: Vec<f32> = (0..n).map(|_| g.normal()).collect();
        let xn2: f64 = x.iter().map(|a| (a * a) as f64).sum();
        if xn2 < 1e-3 {
            return; // astronomically unlikely degenerate draw
        }
        let trials = 500;
        let xm = Matrix::from_vec(1, n, x.clone());
        let mut rng = Rng::new(g.int(0, 1 << 30) as u64);
        let mut s1 = 0.0f64;
        let mut s2 = 0.0f64;
        for _ in 0..trials {
            let s = sk.draw(&mut rng);
            let proj = matmul(&xm, &s);
            let y: f64 = proj.data().iter().map(|a| (*a as f64) * (*a as f64)).sum();
            s1 += y;
            s2 += y * y;
        }
        let mean = s1 / trials as f64;
        let var = s2 / trials as f64 - mean * mean;
        let theory = 2.0 * xn2 * xn2 / d as f64;
        assert!(
            (mean / xn2 - 1.0).abs() < 0.2,
            "mean {mean} vs ‖x‖² {xn2} (n={n}, d={d})"
        );
        assert!(
            var > theory / 3.0 && var < theory * 3.0,
            "sample var {var} outside 3x band of theory {theory} (n={n}, d={d})"
        );
    });
}

// -------------------------------------------------------------- attention

#[test]
fn prop_every_method_finite_and_shaped_on_random_inputs() {
    Runner::new("attention-finite", 12).run(|g| {
        let n = g.pow2(16, 64);
        let p = g.pow2(4, 16);
        let d = g.pow2(4, 16).min(n);
        let q = random_matrix(g, n, p);
        let k = random_matrix(g, n, p);
        let v = random_matrix(g, n, p);
        let seed = g.int(0, 1 << 20) as u64;
        for m in registry(d) {
            let out = m.compute(&q, &k, &v, None, &mut Rng::new(seed));
            assert_eq!(out.shape(), (n, p), "{}", m.name());
            assert!(out.all_finite(), "{} non-finite", m.name());
        }
    });
}

#[test]
fn prop_standard_attention_is_permutation_equivariant_in_keys() {
    // permuting (K, V) rows together must not change the output
    Runner::new("key-permutation-invariance", 20).run(|g| {
        let n = g.int(4, 24);
        let p = g.pow2(4, 8);
        let q = random_matrix(g, n, p);
        let k = random_matrix(g, n, p);
        let v = random_matrix(g, n, p);
        let base = Standard::exact(&q, &k, &v, None);
        let mut perm: Vec<usize> = (0..n).collect();
        let seed = g.int(0, 1 << 20) as u64;
        Rng::new(seed).shuffle(&mut perm);
        let kp = k.gather_rows(&perm);
        let vp = v.gather_rows(&perm);
        let out = Standard::exact(&q, &kp, &vp, None);
        assert!(base.max_abs_diff(&out) < 1e-3);
    });
}

#[test]
fn prop_skeinformer_full_budget_close_to_exact() {
    // d == n with PSR: pilot rows exact, selected columns = all columns.
    Runner::new("skeinformer-full-budget", 15).run(|g| {
        let n = g.pow2(8, 32);
        let p = g.pow2(4, 8);
        let q = random_matrix(g, n, p);
        let k = random_matrix(g, n, p);
        let v = random_matrix(g, n, p);
        let exact = Standard::exact(&q, &k, &v, None);
        let out =
            Skeinformer::new(n).compute(&q, &k, &v, None, &mut Rng::new(g.int(0, 99999) as u64));
        assert!(
            out.max_abs_diff(&exact) < 5e-3,
            "full-budget diff {}",
            out.max_abs_diff(&exact)
        );
    });
}

#[test]
fn prop_masked_positions_never_leak() {
    // randomized version of the §4.4 invariance test, across mask sizes
    Runner::new("mask-never-leaks", 12).run(|g| {
        let n = 48;
        let p = 8;
        let valid = g.int(8, 40);
        let q = random_matrix(g, n, p);
        let mut k = random_matrix(g, n, p);
        let mut v = random_matrix(g, n, p);
        let mask: Vec<f32> = (0..n).map(|i| if i < valid { 1.0 } else { 0.0 }).collect();
        let seed = g.int(0, 1 << 20) as u64;
        let skein = Skeinformer::new(16);
        let a = skein.compute(&q, &k, &v, Some(&mask), &mut Rng::new(seed));
        for i in valid..n {
            for j in 0..p {
                k.set(i, j, g.f32(-1e3, 1e3));
                v.set(i, j, g.f32(-1e3, 1e3));
            }
        }
        let b = skein.compute(&q, &k, &v, Some(&mask), &mut Rng::new(seed));
        for i in 0..valid {
            for j in 0..p {
                assert!((a.get(i, j) - b.get(i, j)).abs() < 1e-2, "row {i} leaked");
            }
        }
    });
}

// -------------------------------------------------------------------- data

#[test]
fn prop_listops_generator_evaluator_agree() {
    Runner::new("listops-agreement", 60).run(|g| {
        let seq = g.pow2(32, 256);
        let task = data::ListOpsTask::new(seq);
        let seed = g.int(0, 1 << 30) as u64;
        let ex = data::Task::sample(&task, &mut Rng::new(seed));
        let val = data::ListOpsTask::evaluate(&ex.tokens).expect("parse");
        assert_eq!(val as i32, ex.label);
        assert!(ex.tokens.len() <= seq);
    });
}

#[test]
fn prop_batcher_invariants() {
    Runner::new("batcher-invariants", 30).run(|g| {
        let seq = g.pow2(32, 128);
        let bsz = g.pow2(1, 16);
        let name = *g.choose(data::TASK_NAMES);
        let task = data::by_name(name, seq).unwrap();
        let batcher = data::Batcher::new(task.as_ref(), bsz, seq);
        let batch = batcher.next_batch(&mut Rng::new(g.int(0, 1 << 30) as u64));
        assert_eq!(batch.tokens.len(), bsz * seq);
        assert_eq!(batch.labels.len(), bsz);
        for b in 0..bsz {
            let row_mask = &batch.mask[b * seq..(b + 1) * seq];
            let ones = row_mask.iter().take_while(|&&m| m == 1.0).count();
            assert!(ones >= 1, "{name}: empty example");
            assert!(row_mask[ones..].iter().all(|&m| m == 0.0), "{name}: non-prefix mask");
            for (i, &m) in row_mask.iter().enumerate() {
                if m == 0.0 {
                    assert_eq!(batch.tokens[b * seq + i], data::PAD);
                }
            }
            assert!((batch.labels[b] as usize) < task.classes());
        }
    });
}

// -------------------------------------------------------------------- json

#[test]
fn prop_json_roundtrip_fuzz() {
    Runner::new("json-roundtrip", 60).run(|g| {
        // build a random JSON value, serialize, reparse, compare
        fn build(g: &mut skeinformer::prop::Gen, depth: usize) -> json::Json {
            match if depth >= 3 { g.int(0, 3) } else { g.int(0, 5) } {
                0 => json::Json::Null,
                1 => json::Json::Bool(g.int(0, 1) == 1),
                2 => json::Json::Num((g.normal() * 100.0) as f64),
                3 => {
                    let len = g.int(0, 8);
                    let s: String = (0..len)
                        .map(|_| {
                            let c = g.int(0, 4);
                            match c {
                                0 => '"',
                                1 => '\\',
                                2 => '\n',
                                3 => 'é',
                                _ => 'a',
                            }
                        })
                        .collect();
                    json::Json::Str(s)
                }
                4 => {
                    let len = g.int(0, 4);
                    json::Json::Arr((0..len).map(|_| build(g, depth + 1)).collect())
                }
                _ => {
                    let len = g.int(0, 4);
                    json::Json::Obj(
                        (0..len)
                            .map(|i| (format!("k{i}"), build(g, depth + 1)))
                            .collect(),
                    )
                }
            }
        }
        let v = build(g, 0);
        let compact = json::parse(&v.to_string()).expect("compact reparse");
        assert_eq!(v, compact);
        let pretty = json::parse(&v.to_pretty()).expect("pretty reparse");
        assert_eq!(v, pretty);
    });
}

// ------------------------------------------------------------------ config

#[test]
fn prop_config_roundtrip() {
    Runner::new("config-roundtrip", 40).run(|g| {
        let mut cfg = skeinformer::config::ExperimentConfig::default();
        cfg.method = g.choose(skeinformer::config::KNOWN_METHODS).to_string();
        cfg.task = g.choose(skeinformer::config::KNOWN_TASKS).to_string();
        cfg.model.batch = g.pow2(1, 64);
        cfg.model.features = g.pow2(8, 64);
        cfg.train.max_steps = g.int(1, 1000);
        cfg.train.eval_every = g.int(1, 50);
        cfg.train.seed = g.int(0, 1 << 30) as u64;
        let j = cfg.to_json();
        let back = skeinformer::config::ExperimentConfig::from_json(&j).expect("parse");
        assert_eq!(cfg, back);
    });
}

// keep the trait import used even if a future edit drops a call site
#[allow(unused)]
fn _assert_object_safe(_: &dyn AttentionMethod) {}
