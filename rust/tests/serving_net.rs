//! Socket serving front end: the TCP path must be a transparent
//! transport over the in-process attention server.
//!
//! * **Bitwise transparency** — one-shot submits, per-token decode, and
//!   chunked prefill round-tripped through `net::serve` + [`NetClient`]
//!   produce byte-identical outputs to the in-process handle, for every
//!   registry method (seeds derive from batch index / stream id, never
//!   from transport or grid placement).
//! * **Continuous batching** — streams that join and leave the executed
//!   grid mid-run get the same bytes as streams served solo, and the
//!   scheduler reports per-step occupancy.
//! * **Robustness** — malformed, truncated, or hostile bytes never kill
//!   the accept loop or the serve thread: structurally recoverable
//!   frames answer a typed wire error on the same connection,
//!   desynchronizing input closes only that connection, and rejections
//!   carry `ServeError` codes instead of dropping reply channels.

use skeinformer::attention;
use skeinformer::coordinator::attention_server::{
    self, AttentionServerConfig, AttentionServerStats, HeadsRequest,
};
use skeinformer::coordinator::net::{self, wire, ClientError, NetClient};
use skeinformer::rng::Rng;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

fn cfg(method: &str) -> AttentionServerConfig {
    AttentionServerConfig {
        method: method.to_string(),
        d: 8,
        heads: 2,
        seq: 16,
        head_dim: 4,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        seed: 0,
        workers: None,
        queue_depth: 0,
        kv: None,
    }
}

fn requests(cfg: &AttentionServerConfig, n: usize, seed: u64) -> Vec<HeadsRequest> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| HeadsRequest::random(cfg.request_elems(), &mut rng)).collect()
}

/// Per-token (k, v, q) slabs of `[heads, head_dim]` rows.
fn token_triples(
    token_elems: usize,
    n: usize,
    seed: u64,
) -> Vec<(Arc<[f32]>, Arc<[f32]>, Arc<[f32]>)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut mk = || {
                let mut b = vec![0.0f32; token_elems];
                rng.fill_normal(&mut b);
                let s: Arc<[f32]> = b.into();
                s
            };
            (mk(), mk(), mk())
        })
        .collect()
}

/// Repack per-token `[heads, head_dim]` rows `lo..hi` as one
/// `[heads, tokens, head_dim]` chunk slab (the Prefill layout).
fn chunk_slab(rows: &[Arc<[f32]>], lo: usize, hi: usize, heads: usize, head_dim: usize) -> Vec<f32> {
    let n = hi - lo;
    let mut slab = vec![0.0f32; n * heads * head_dim];
    for (i, row) in rows[lo..hi].iter().enumerate() {
        for h in 0..heads {
            let dst = (h * n + i) * head_dim;
            slab[dst..dst + head_dim].copy_from_slice(&row[h * head_dim..(h + 1) * head_dim]);
        }
    }
    slab
}

#[test]
fn socket_submit_is_bitwise_identical_to_in_process() {
    for method in attention::registry(8) {
        let name = method.name();
        let c = cfg(name);
        let reqs = requests(&c, 5, 42);

        // in-process: submit-and-wait, so batch i of the server lifetime
        // serves request i
        let handle = attention_server::start(c.clone()).unwrap();
        let want: Vec<Vec<f32>> =
            reqs.iter().map(|r| handle.submit(r.clone()).recv().expect("reply")).collect();
        handle.shutdown().unwrap();

        // over the wire: same lifetime batch indices, same seeds
        let handle = attention_server::start(c.clone()).unwrap();
        let server = net::serve(&handle, "127.0.0.1:0").expect("bind");
        let mut client = NetClient::connect(server.local_addr()).expect("connect");
        assert_eq!(client.info().method, name);
        assert_eq!(client.info().request_elems(), c.request_elems());
        let got: Vec<Vec<f32>> =
            reqs.iter().map(|r| client.submit(r).expect("wire reply")).collect();
        drop(client);
        server.stop();
        let stats = handle.shutdown().unwrap();

        assert_eq!(got, want, "{name}: TCP transport changed served bytes");
        assert_eq!(stats.requests, 5, "{name}");
        assert!(stats.steps >= stats.batches && stats.steps > 0, "{name}: no steps recorded");
        assert!(stats.mean_step_occupancy > 0.0, "{name}: occupancy not reported");
    }
}

fn decode_in_process(
    c: &AttentionServerConfig,
    toks: &[(Arc<[f32]>, Arc<[f32]>, Arc<[f32]>)],
    cross: bool,
    q_full: &[f32],
) -> Vec<f32> {
    let handle = attention_server::start(c.clone()).unwrap();
    let stream = handle.open_stream(1);
    let mut outs = Vec::new();
    for (k, v, q) in toks {
        stream.append(k.clone(), v.clone());
        if cross {
            outs.extend(stream.query(q.clone(), 1).recv().expect("stream reply"));
        }
    }
    if !cross {
        let q: Arc<[f32]> = q_full.to_vec().into();
        outs.extend(stream.query(q, toks.len()).recv().expect("square reply"));
    }
    stream.close();
    handle.shutdown().unwrap();
    outs
}

#[test]
fn socket_stream_decode_is_bitwise_identical_to_in_process() {
    for method in attention::registry(8) {
        let name = method.name();
        let c = cfg(name);
        let cross = attention::by_name(name, c.d).expect("registry").supports_cross_shape();
        let toks = token_triples(c.heads * c.head_dim, 6, 21);
        let mut q_full = vec![0.0f32; c.heads * toks.len() * c.head_dim];
        Rng::new(555).fill_normal(&mut q_full);

        let want = decode_in_process(&c, &toks, cross, &q_full);

        let handle = attention_server::start(c.clone()).unwrap();
        let server = net::serve(&handle, "127.0.0.1:0").expect("bind");
        let mut client = NetClient::connect(server.local_addr()).expect("connect");
        let sid = client.open_stream(1).expect("open");
        let mut got = Vec::new();
        for (k, v, q) in &toks {
            client.append(sid, k, v).expect("append");
            if cross {
                got.extend(client.query(sid, 1, q).expect("wire stream reply"));
            }
        }
        if !cross {
            got.extend(client.query(sid, toks.len() as u32, &q_full).expect("wire square reply"));
        }
        client.close_stream(sid).expect("close");
        drop(client);
        server.stop();
        let stats = handle.shutdown().unwrap();

        assert!(!want.is_empty(), "{name}: no outputs collected");
        assert_eq!(got, want, "{name}: TCP transport changed decoded bytes");
        assert_eq!(stats.stream_appends, 6, "{name}");
    }
}

#[test]
fn socket_prefill_is_bitwise_identical_to_in_process_append() {
    let c = cfg("skeinformer");
    let toks = token_triples(c.heads * c.head_dim, 7, 77);
    let mut q_full = vec![0.0f32; c.heads * toks.len() * c.head_dim];
    Rng::new(999).fill_normal(&mut q_full);
    // in-process per-token appends, one square query (cross=false path)
    let want = decode_in_process(&c, &toks, false, &q_full);

    let handle = attention_server::start(c.clone()).unwrap();
    let server = net::serve(&handle, "127.0.0.1:0").expect("bind");
    let mut client = NetClient::connect(server.local_addr()).expect("connect");
    let sid = client.open_stream(1).expect("open");
    let ks: Vec<Arc<[f32]>> = toks.iter().map(|(k, _, _)| k.clone()).collect();
    let vs: Vec<Arc<[f32]>> = toks.iter().map(|(_, v, _)| v.clone()).collect();
    // chunk boundaries that start and end mid-stream
    for &(lo, hi) in &[(0usize, 3usize), (3, 6), (6, 7)] {
        let kc = chunk_slab(&ks, lo, hi, c.heads, c.head_dim);
        let vc = chunk_slab(&vs, lo, hi, c.heads, c.head_dim);
        client.prefill(sid, (hi - lo) as u32, &kc, &vc).expect("prefill");
    }
    let got = client.query(sid, toks.len() as u32, &q_full).expect("wire prefill reply");
    client.close_stream(sid).expect("close");
    drop(client);
    server.stop();
    handle.shutdown().unwrap();

    assert_eq!(got, want, "wire chunked prefill changed served bytes");
}

/// Decode `toks` on a fresh server after burning `burn` stream ids, so
/// the stream under test gets the same id it had in the combined run.
fn solo_decode(
    c: &AttentionServerConfig,
    toks: &[(Arc<[f32]>, Arc<[f32]>, Arc<[f32]>)],
    burn: usize,
) -> Vec<f32> {
    let handle = attention_server::start(c.clone()).unwrap();
    for _ in 0..burn {
        handle.open_stream(1).close();
    }
    let stream = handle.open_stream(1);
    let mut outs = Vec::new();
    for (k, v, q) in toks {
        stream.append(k.clone(), v.clone());
        outs.extend(stream.query(q.clone(), 1).recv().expect("solo reply"));
    }
    stream.close();
    handle.shutdown().unwrap();
    outs
}

#[test]
fn continuous_batching_join_and_leave_match_solo_streams() {
    // stream A decodes 6 tokens; stream B joins after A's 3rd token and
    // keeps decoding after A leaves.  During the overlap both queries are
    // in flight together, so the scheduler may co-admit them into one
    // step — served bytes must not depend on that placement.
    let c = cfg("skeinformer");
    let te = c.heads * c.head_dim;
    let toks_a = token_triples(te, 6, 21);
    let toks_b = token_triples(te, 6, 22);
    let want_a = solo_decode(&c, &toks_a, 0); // stream id 0
    let want_b = solo_decode(&c, &toks_b, 1); // stream id 1

    let handle = attention_server::start(c.clone()).unwrap();
    let a = handle.open_stream(1);
    let mut outs_a = Vec::new();
    let mut outs_b = Vec::new();
    for (k, v, q) in &toks_a[..3] {
        a.append(k.clone(), v.clone());
        outs_a.extend(a.query(q.clone(), 1).recv().expect("a solo phase"));
    }
    let b = handle.open_stream(1);
    for t in 0..3 {
        let (ka, va, qa) = &toks_a[3 + t];
        let (kb, vb, qb) = &toks_b[t];
        a.append(ka.clone(), va.clone());
        b.append(kb.clone(), vb.clone());
        // both queries pending before either reply is drained: the step
        // scheduler is free to run them side by side
        let rx_a = a.query(qa.clone(), 1);
        let rx_b = b.query(qb.clone(), 1);
        outs_a.extend(rx_a.recv().expect("a overlap"));
        outs_b.extend(rx_b.recv().expect("b overlap"));
    }
    a.close();
    for (k, v, q) in &toks_b[3..] {
        b.append(k.clone(), v.clone());
        outs_b.extend(b.query(q.clone(), 1).recv().expect("b solo phase"));
    }
    b.close();
    let stats = handle.shutdown().unwrap();

    assert_eq!(outs_a, want_a, "stream A changed bytes when sharing the grid");
    assert_eq!(outs_b, want_b, "stream B changed bytes when joining mid-run");
    assert_eq!(stats.stream_queries, 12);
    assert!(stats.steps > 0 && stats.mean_step_occupancy > 0.0);
}

#[test]
fn malformed_and_truncated_frames_never_kill_the_server() {
    let c = cfg("skeinformer");
    let handle = attention_server::start(c.clone()).unwrap();
    let server = net::serve(&handle, "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();
    let req = requests(&c, 1, 1).remove(0);

    // (a) bad magic: the connection dies without a handshake
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&[0xDE, 0xAD, 0xBE, 0xEF, 1, 0]).unwrap();
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
    }
    // (b) valid hello, then hostile bytes (0xFF length prefix blows the
    // frame cap): fatal for this connection only
    {
        let mut s = TcpStream::connect(addr).unwrap();
        wire::write_hello(&mut s).unwrap();
        s.write_all(&[0xFF; 64]).unwrap();
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
    }
    // (c) a frame truncated mid-body, then EOF: fatal, no panic
    {
        let mut s = TcpStream::connect(addr).unwrap();
        wire::write_hello(&mut s).unwrap();
        let frame = wire::encode_submit(1, &req);
        s.write_all(&frame[..frame.len() / 2]).unwrap();
        let _ = s.shutdown(std::net::Shutdown::Write);
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
    }
    // (d) a structurally malformed frame answers a typed wire error and
    // the SAME connection then serves a valid round-trip
    {
        let mut s = TcpStream::connect(addr).unwrap();
        wire::write_hello(&mut s).unwrap();
        wire::read_hello(&mut s).expect("server hello");
        match wire::read_server_frame(&mut s).expect("config frame") {
            wire::ServerFrame::Config(info) => assert_eq!(info.method, c.method),
            other => panic!("expected config frame, got {other:?}"),
        }
        // a close frame with 3 junk bytes inside its declared length
        let inner = wire::encode_close(5, 0);
        let mut bad = Vec::new();
        bad.extend_from_slice(&((inner.len() - 4 + 3) as u32).to_le_bytes());
        bad.extend_from_slice(&inner[4..]);
        bad.extend_from_slice(&[0xAA, 0xBB, 0xCC]);
        s.write_all(&bad).unwrap();
        match wire::read_server_frame(&mut s).expect("error frame") {
            wire::ServerFrame::Error { id, code, .. } => {
                assert_eq!((id, code), (5, wire::WIRE_ERROR_CODE));
            }
            other => panic!("expected wire error frame, got {other:?}"),
        }
        s.write_all(&wire::encode_submit(7, &req)).unwrap();
        match wire::read_server_frame(&mut s).expect("output frame") {
            wire::ServerFrame::Output { id, out } => {
                assert_eq!(id, 7);
                assert_eq!(out.len(), c.request_elems());
            }
            other => panic!("expected output frame, got {other:?}"),
        }
    }
    // the accept loop survived all of it: a fresh client still round-trips
    let mut client = NetClient::connect(addr).expect("accept loop died");
    let out = client.submit(&req).expect("post-fuzz round trip");
    assert_eq!(out.len(), c.request_elems());
    drop(client);
    server.stop();
    let stats: AttentionServerStats = handle.shutdown().unwrap();
    assert_eq!(stats.requests, 2, "only the two well-formed submits reached the engine");
}

#[test]
fn wire_rejections_carry_typed_serve_error_codes() {
    let c = cfg("skeinformer");
    let handle = attention_server::start(c.clone()).unwrap();
    let server = net::serve(&handle, "127.0.0.1:0").expect("bind");
    let mut client = NetClient::connect(server.local_addr()).expect("connect");
    let zero_q = vec![0.0f32; c.heads * c.head_dim];

    // unknown stream -> ServeError::UnknownStream (code 2)
    match client.query(999, 1, &zero_q) {
        Err(ClientError::Rejected { code, .. }) => assert_eq!(code, 2),
        other => panic!("expected typed rejection, got {other:?}"),
    }
    // wrong slab length -> ServeError::BadShape (code 1)
    let bad = HeadsRequest::from_vecs(vec![0.0; 3], vec![0.0; 3], vec![0.0; 3]);
    match client.submit(&bad) {
        Err(ClientError::Rejected { code, .. }) => assert_eq!(code, 1),
        other => panic!("expected typed rejection, got {other:?}"),
    }
    // a rejected fire-and-forget append surfaces on the next reply read
    // instead of being silently dropped
    let sid = client.open_stream(1).expect("open");
    client.append(sid, &[0.0], &[0.0]).expect("send");
    match client.query(sid, 1, &zero_q) {
        Err(ClientError::Rejected { code, message }) => {
            assert_eq!(code, 1, "append rejection should be BadShape: {message}");
        }
        other => panic!("expected append rejection to surface, got {other:?}"),
    }
    drop(client);
    server.stop();
    let stats = handle.shutdown().unwrap();
    // unknown-stream query, bad submit, bad append, and the valid-shaped
    // query against the (still empty) stream
    assert_eq!(stats.rejected, 4);
}
