//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These require `make artifacts` to have run; when artifacts are absent
//! the tests skip (printing why) so `cargo test` stays green on a fresh
//! clone.

use skeinformer::json;
use skeinformer::rng::Rng;
use skeinformer::runtime::{literal_f32, scalar_i32, ArtifactManifest, Runtime};
use skeinformer::synth_qkv::{generate, QkvConfig};
use skeinformer::tensor::{spectral_norm, spectral_norm_diff, Matrix};
use std::path::Path;

fn artifacts_ready() -> bool {
    Path::new("artifacts/attn_manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn attn_artifacts_load_and_execute() {
    require_artifacts!();
    let man = json::parse(&std::fs::read_to_string("artifacts/attn_manifest.json").unwrap())
        .unwrap();
    let n = man.req_usize("n").unwrap();
    let p = man.req_usize("p").unwrap();

    let rt = Runtime::cpu().unwrap();
    let skein = rt.load_hlo(Path::new("artifacts/attn_skeinformer.hlo.txt")).unwrap();
    let std_exe = rt.load_hlo(Path::new("artifacts/attn_standard.hlo.txt")).unwrap();

    let mut rng = Rng::new(3);
    let (q, k, v) = generate(&QkvConfig::pretrained(n, p), &mut rng);
    let inputs = [
        literal_f32(q.data(), &[n, p]).unwrap(),
        literal_f32(k.data(), &[n, p]).unwrap(),
        literal_f32(v.data(), &[n, p]).unwrap(),
        scalar_i32(7),
    ];
    let skein_out = skein.run(&inputs).unwrap();
    let std_out = std_exe.run(&inputs).unwrap();
    let skein_m = Matrix::from_vec(n, p, skein_out[0].to_vec::<f32>().unwrap());
    let std_m = Matrix::from_vec(n, p, std_out[0].to_vec::<f32>().unwrap());
    assert!(skein_m.all_finite());
    assert!(std_m.all_finite());

    // the pallas skeinformer kernel must approximate the exact kernel and
    // beat the trivial rank-one approximation
    let base = spectral_norm(&std_m);
    let rel = spectral_norm_diff(&skein_m, &std_m) / base;
    assert!(rel < 0.9, "kernel approximation error {rel}");

    // determinism given the same seed input
    let skein_out2 = skein.run(&inputs).unwrap();
    let again = Matrix::from_vec(n, p, skein_out2[0].to_vec::<f32>().unwrap());
    assert_eq!(skein_m.max_abs_diff(&again), 0.0);
}

#[test]
fn pallas_kernel_artifact_matches_rust_exact_attention() {
    // L1 (pallas standard kernel, through PJRT) vs L3 (pure rust) — the
    // cross-layer consistency check.
    require_artifacts!();
    let man = json::parse(&std::fs::read_to_string("artifacts/attn_manifest.json").unwrap())
        .unwrap();
    let n = man.req_usize("n").unwrap();
    let p = man.req_usize("p").unwrap();
    let rt = Runtime::cpu().unwrap();
    let std_exe = rt.load_hlo(Path::new("artifacts/attn_standard.hlo.txt")).unwrap();
    let mut rng = Rng::new(11);
    let (q, k, v) = generate(&QkvConfig::pretrained(n, p), &mut rng);
    let out = std_exe
        .run(&[
            literal_f32(q.data(), &[n, p]).unwrap(),
            literal_f32(k.data(), &[n, p]).unwrap(),
            literal_f32(v.data(), &[n, p]).unwrap(),
            scalar_i32(0),
        ])
        .unwrap();
    let kernel = Matrix::from_vec(n, p, out[0].to_vec::<f32>().unwrap());
    let rust = skeinformer::attention::Standard::exact(&q, &k, &v, None);
    let diff = kernel.max_abs_diff(&rust);
    assert!(diff < 1e-3, "pallas kernel vs rust exact attention: {diff}");
}

#[test]
fn every_method_manifest_is_consistent() {
    if !Path::new("artifacts/skeinformer_manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    for method in skeinformer::config::KNOWN_METHODS {
        let man = ArtifactManifest::load(Path::new("artifacts"), method)
            .unwrap_or_else(|e| panic!("{method}: {e:#}"));
        assert_eq!(&man.method, method);
        assert!(man.train_path().exists(), "{method}: missing train hlo");
        assert!(man.forward_path().exists(), "{method}: missing fwd hlo");
        let params = man.load_initial_params().unwrap();
        assert_eq!(params.len(), man.params.len());
        // all params finite
        for (spec, buf) in man.params.iter().zip(&params) {
            assert!(
                buf.iter().all(|x| x.is_finite()),
                "{method}: non-finite init in {}",
                spec.name
            );
        }
    }
}

#[test]
fn manifest_config_matches_default_experiment_config() {
    if !Path::new("artifacts/skeinformer_manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let man = ArtifactManifest::load(Path::new("artifacts"), "skeinformer").unwrap();
    let cfg = skeinformer::config::ExperimentConfig::default();
    assert_eq!(man.cfg("seq_len").unwrap(), cfg.model.seq_len);
    assert_eq!(man.cfg("vocab").unwrap(), cfg.model.vocab);
    assert_eq!(man.cfg("classes").unwrap(), cfg.model.classes);
    assert_eq!(man.cfg("embed").unwrap(), cfg.model.embed);
}
