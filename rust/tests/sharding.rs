//! Shard coordinator: scatter/gather across engine processes must be a
//! transparent transport, and failure must degrade typed.
//!
//! * **Bitwise transparency** — one-shot submits, per-token decode, and
//!   chunked prefill through a coordinator (1 and 2 shards, each shard
//!   a real `net::serve`d engine over TCP) produce byte-identical
//!   outputs to the in-process handle, for every registry method.
//!   Seeds are pinned per request/stream by the coordinator, so shard
//!   count and shard-side batching never show up in served bytes.
//! * **Prefix affinity** — repeats of one prompt hash to one shard, so
//!   a 2-shard cluster reaps exactly the single-shard level of
//!   `kv_hit_blocks` (the satellite contract: sharding must not shred
//!   prompt locality).
//! * **Fault injection** — killing a shard mid-stream yields typed
//!   `ShardDown` (code 7) errors for its streams, while survivor-homed
//!   streams and fresh one-shots keep serving bitwise-correct bytes;
//!   the coordinator never panics or hangs.
//! * **Spill handoff** — a gracefully retired shard archives its KV
//!   index into the shared content-addressed spill store; a shard that
//!   joins the ring afterwards warm-restarts the same prompt from the
//!   manifests (`kv_spill_hits > 0` at the coordinator).

use skeinformer::attention;
use skeinformer::coordinator::attention_server::{
    self, AttentionServerConfig, AttentionServerHandle, HeadsRequest,
};
use skeinformer::coordinator::net::{self, ClientError, NetClient, NetServer};
use skeinformer::coordinator::shard::Coordinator;
use skeinformer::kvcache::{tempdir, KvCacheConfig, TierLadder};
use skeinformer::rng::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn cfg(method: &str) -> AttentionServerConfig {
    AttentionServerConfig {
        method: method.to_string(),
        d: 8,
        heads: 2,
        seq: 16,
        head_dim: 4,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        seed: 0,
        workers: None,
        queue_depth: 0,
        kv: None,
    }
}

fn requests(cfg: &AttentionServerConfig, n: usize, seed: u64) -> Vec<HeadsRequest> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| HeadsRequest::random(cfg.request_elems(), &mut rng)).collect()
}

/// Per-token (k, v, q) slabs of `[heads, head_dim]` rows.
fn token_triples(
    token_elems: usize,
    n: usize,
    seed: u64,
) -> Vec<(Arc<[f32]>, Arc<[f32]>, Arc<[f32]>)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut mk = || {
                let mut b = vec![0.0f32; token_elems];
                rng.fill_normal(&mut b);
                let s: Arc<[f32]> = b.into();
                s
            };
            (mk(), mk(), mk())
        })
        .collect()
}

/// Repack per-token `[heads, head_dim]` rows `lo..hi` as one
/// `[heads, tokens, head_dim]` chunk slab (the Prefill layout).
fn chunk_slab(rows: &[Arc<[f32]>], lo: usize, hi: usize, heads: usize, head_dim: usize) -> Vec<f32> {
    let n = hi - lo;
    let mut slab = vec![0.0f32; n * heads * head_dim];
    for (i, row) in rows[lo..hi].iter().enumerate() {
        for h in 0..heads {
            let dst = (h * n + i) * head_dim;
            slab[dst..dst + head_dim].copy_from_slice(&row[h * head_dim..(h + 1) * head_dim]);
        }
    }
    slab
}

/// One engine shard: an in-process server behind a real TCP front.
struct Shard {
    handle: AttentionServerHandle,
    server: NetServer,
    addr: String,
}

fn spawn_shards(c: &AttentionServerConfig, n: usize) -> Vec<Shard> {
    (0..n)
        .map(|i| {
            let handle = attention_server::start(c.clone()).expect("start shard engine");
            let backend = Arc::new(net::EngineBackend::new(&handle, i as u32, n as u32));
            let server = net::serve_backend(backend, "127.0.0.1:0").expect("bind shard");
            let addr = server.local_addr().to_string();
            Shard { handle, server, addr }
        })
        .collect()
}

/// A full cluster: `n` engine shards, a coordinator over them, a TCP
/// front on the coordinator, and a client connected to that front.
fn cluster(c: &AttentionServerConfig, n: usize) -> (Vec<Shard>, Coordinator, NetServer, NetClient) {
    let shards = spawn_shards(c, n);
    let addrs: Vec<String> = shards.iter().map(|s| s.addr.clone()).collect();
    let coord = Coordinator::start(&addrs, Duration::from_millis(100)).expect("start coordinator");
    let front = net::serve_backend(coord.backend(), "127.0.0.1:0").expect("bind coordinator");
    let client = NetClient::connect(front.local_addr()).expect("connect coordinator");
    (shards, coord, front, client)
}

fn teardown(shards: Vec<Shard>, coord: Coordinator, front: NetServer, client: NetClient) {
    drop(client);
    front.stop();
    coord.shutdown();
    for s in shards {
        s.server.stop();
        let _ = s.handle.shutdown();
    }
}

/// Spin until `pred` holds (the coordinator notices deaths on its own
/// reader/heartbeat threads).  Panics after `secs` — a hang here is
/// exactly the failure mode the coordinator must not have.
fn wait_until(secs: u64, what: &str, mut pred: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !pred() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn scatter_gather_one_shots_are_bitwise_identical_to_single_process() {
    for method in attention::registry(8) {
        let name = method.name();
        let c = cfg(name);
        let reqs = requests(&c, 5, 42);

        // in-process reference: submit-and-wait, so batch i serves
        // request i with batch_seed(seed, i)
        let handle = attention_server::start(c.clone()).unwrap();
        let want: Vec<Vec<f32>> =
            reqs.iter().map(|r| handle.submit(r.clone()).recv().expect("reply")).collect();
        handle.shutdown().unwrap();

        // the coordinator pins the same per-request seeds and scatters
        // head ranges: 1 shard (degenerate scatter) and 2 shards (one
        // head each at H=2) must both gather the same bytes
        for n_shards in [1usize, 2] {
            let (shards, coord, front, mut client) = cluster(&c, n_shards);
            assert_eq!(client.info().method, name);
            assert_eq!(client.info().shard_count, n_shards as u32);
            let got: Vec<Vec<f32>> =
                reqs.iter().map(|r| client.submit(r).expect("cluster reply")).collect();
            assert_eq!(got, want, "{name}: {n_shards}-shard scatter/gather changed served bytes");
            teardown(shards, coord, front, client);
        }
    }
}

fn decode_in_process(
    c: &AttentionServerConfig,
    toks: &[(Arc<[f32]>, Arc<[f32]>, Arc<[f32]>)],
    cross: bool,
    q_full: &[f32],
) -> Vec<f32> {
    let handle = attention_server::start(c.clone()).unwrap();
    let stream = handle.open_stream(1);
    let mut outs = Vec::new();
    for (k, v, q) in toks {
        stream.append(k.clone(), v.clone());
        if cross {
            outs.extend(stream.query(q.clone(), 1).recv().expect("stream reply"));
        }
    }
    if !cross {
        let q: Arc<[f32]> = q_full.to_vec().into();
        outs.extend(stream.query(q, toks.len()).recv().expect("square reply"));
    }
    stream.close();
    handle.shutdown().unwrap();
    outs
}

#[test]
fn stream_decode_through_a_cluster_is_bitwise_identical_to_single_process() {
    for method in attention::registry(8) {
        let name = method.name();
        let c = cfg(name);
        let cross = attention::by_name(name, c.d).expect("registry").supports_cross_shape();
        let toks = token_triples(c.heads * c.head_dim, 6, 21);
        let mut q_full = vec![0.0f32; c.heads * toks.len() * c.head_dim];
        Rng::new(555).fill_normal(&mut q_full);
        let want = decode_in_process(&c, &toks, cross, &q_full);

        // a stream routes whole to one shard under the coordinator's
        // global stream id, so its bytes cannot depend on shard count
        for n_shards in [1usize, 2] {
            let (shards, coord, front, mut client) = cluster(&c, n_shards);
            let sid = client.open_stream(1).expect("open");
            let mut got = Vec::new();
            for (k, v, q) in &toks {
                client.append(sid, k, v).expect("append");
                if cross {
                    got.extend(client.query(sid, 1, q).expect("cluster stream reply"));
                }
            }
            if !cross {
                got.extend(
                    client.query(sid, toks.len() as u32, &q_full).expect("cluster square reply"),
                );
            }
            client.close_stream(sid).expect("close");
            assert!(!want.is_empty(), "{name}: no outputs collected");
            assert_eq!(got, want, "{name}: {n_shards}-shard cluster changed decoded bytes");
            teardown(shards, coord, front, client);
        }
    }
}

#[test]
fn chunked_prefill_through_a_cluster_is_bitwise_identical_to_single_process() {
    let c = cfg("skeinformer");
    let toks = token_triples(c.heads * c.head_dim, 7, 77);
    let mut q_full = vec![0.0f32; c.heads * toks.len() * c.head_dim];
    Rng::new(999).fill_normal(&mut q_full);
    let want = decode_in_process(&c, &toks, false, &q_full);
    let ks: Vec<Arc<[f32]>> = toks.iter().map(|(k, _, _)| k.clone()).collect();
    let vs: Vec<Arc<[f32]>> = toks.iter().map(|(_, v, _)| v.clone()).collect();

    for n_shards in [1usize, 2] {
        let (shards, coord, front, mut client) = cluster(&c, n_shards);
        let sid = client.open_stream(1).expect("open");
        for &(lo, hi) in &[(0usize, 3usize), (3, 6), (6, 7)] {
            let kc = chunk_slab(&ks, lo, hi, c.heads, c.head_dim);
            let vc = chunk_slab(&vs, lo, hi, c.heads, c.head_dim);
            client.prefill(sid, (hi - lo) as u32, &kc, &vc).expect("prefill");
        }
        let got = client.query(sid, toks.len() as u32, &q_full).expect("cluster prefill reply");
        client.close_stream(sid).expect("close");
        assert_eq!(got, want, "{n_shards}-shard cluster changed chunked-prefill bytes");
        teardown(shards, coord, front, client);
    }
}

/// Replay one prompt over `streams` sequential decode streams through a
/// `n_shards` cluster with a paged KV cache on every shard; return the
/// cluster-aggregated `kv_hit_blocks`.
fn prompt_replay_hits(c: &AttentionServerConfig, n_shards: usize, streams: usize) -> u64 {
    let (shards, coord, front, mut client) = cluster(c, n_shards);
    let tokens = 8usize;
    let toks = token_triples(c.heads * c.head_dim, tokens, 31);
    let ks: Vec<Arc<[f32]>> = toks.iter().map(|(k, _, _)| k.clone()).collect();
    let vs: Vec<Arc<[f32]>> = toks.iter().map(|(_, v, _)| v.clone()).collect();
    let kc = chunk_slab(&ks, 0, tokens, c.heads, c.head_dim);
    let vc = chunk_slab(&vs, 0, tokens, c.heads, c.head_dim);
    let mut q_full = vec![0.0f32; c.heads * tokens * c.head_dim];
    Rng::new(313).fill_normal(&mut q_full);
    for _ in 0..streams {
        let sid = client.open_stream(1).expect("open");
        client.prefill(sid, tokens as u32, &kc, &vc).expect("prefill");
        let out = client.query(sid, tokens as u32, &q_full).expect("query");
        assert!(out.iter().all(|x| x.is_finite()));
        client.close_stream(sid).expect("close");
    }
    let stats = coord.stats();
    teardown(shards, coord, front, client);
    stats.kv_hit_blocks
}

#[test]
fn prefix_affinity_keeps_prompt_reuse_at_single_shard_level() {
    let mut c = cfg("skeinformer");
    c.kv = Some(KvCacheConfig::new(4).with_capacity_blocks(64));
    // same prompt 4×: stream 1 allocates blocks, 2..4 hit them — but
    // only if every replay lands on the same shard's cache
    let solo = prompt_replay_hits(&c, 1, 4);
    let sharded = prompt_replay_hits(&c, 2, 4);
    assert!(solo > 0, "replayed prompt should hit cached blocks");
    assert_eq!(
        sharded, solo,
        "prefix-hash routing must keep prompt reuse on one shard (2-shard hits {sharded} \
         vs single-shard {solo})"
    );
}

#[test]
fn killing_a_shard_mid_stream_degrades_typed_and_survivors_keep_serving() {
    let c = cfg("skeinformer");
    let (shards, coord, front, mut client) = cluster(&c, 2);
    let te = c.heads * c.head_dim;
    let n_streams = 8usize;
    let tokens = 2usize;

    // 8 streams with distinct prompts, ingested but not yet queried
    let mut plans = Vec::new();
    for i in 0..n_streams {
        let toks = token_triples(te, tokens, 100 + i as u64);
        let mut q_full = vec![0.0f32; c.heads * tokens * c.head_dim];
        Rng::new(900 + i as u64).fill_normal(&mut q_full);
        let sid = client.open_stream(1).expect("open");
        for (k, v, _) in &toks {
            client.append(sid, k, v).expect("append");
        }
        plans.push((sid, toks, q_full));
    }
    // wait for the appends to land, then read the split off live stats
    wait_until(5, "appends to reach the shards", || {
        shards
            .iter()
            .map(|s| s.handle.connection().stats().map_or(0, |st| st.stream_appends))
            .sum::<u64>()
            == (n_streams * tokens) as u64
    });
    let owned: Vec<u64> = shards
        .iter()
        .map(|s| s.handle.connection().stats().expect("live stats").stream_appends / tokens as u64)
        .collect();

    // kill the busier shard abruptly: sockets sever, no graceful spill
    let victim = if owned[0] >= owned[1] { 0 } else { 1 };
    let victim_owned = owned[victim];
    let survivor_owned = owned[1 - victim];
    let mut shards = shards;
    let Shard { handle: dead_handle, server: dead_server, addr: _ } = shards.remove(victim);
    dead_server.stop();
    wait_until(5, "the coordinator to mark the shard dead", || coord.live_shards() == 1);

    // every stream answers: survivor-homed ones with the exact bytes a
    // single process would serve, victim-homed ones with typed ShardDown
    let mut down = 0;
    let mut ok = 0;
    for (sid, toks, q_full) in &plans {
        match client.query(*sid, tokens as u32, q_full) {
            Ok(out) => {
                let want = {
                    let handle = attention_server::start(c.clone()).unwrap();
                    // burn ids so the solo stream gets this stream's id
                    for _ in 0..*sid {
                        handle.open_stream(1).close();
                    }
                    let stream = handle.open_stream(1);
                    for (k, v, _) in toks {
                        stream.append(k.clone(), v.clone());
                    }
                    let q: Arc<[f32]> = q_full.clone().into();
                    let out = stream.query(q, tokens).recv().expect("solo reply");
                    stream.close();
                    handle.shutdown().unwrap();
                    out
                };
                assert_eq!(out, want, "surviving stream {sid} changed bytes after the kill");
                ok += 1;
            }
            Err(ClientError::Rejected { code, message }) => {
                assert_eq!(code, 7, "expected ShardDown, got code {code}: {message}");
                down += 1;
            }
            other => panic!("expected output or typed ShardDown, got {other:?}"),
        }
    }
    assert_eq!(ok + down, n_streams, "every stream must get a verdict — no hangs");
    assert_eq!(down as u64, victim_owned, "victim-homed streams must all answer ShardDown");
    assert_eq!(ok as u64, survivor_owned, "survivor-homed streams must all keep serving");
    assert!(down > 0, "the busier shard owned streams, so some must report ShardDown");

    // the cluster still serves: a fresh one-shot scatters over the
    // survivor alone and stays bitwise identical to a single process
    let req = requests(&c, 1, 7).remove(0);
    let handle = attention_server::start(c.clone()).unwrap();
    let want = handle.submit(req.clone()).recv().expect("reference reply");
    handle.shutdown().unwrap();
    let got = client.submit(&req).expect("post-failover submit");
    assert_eq!(got, want, "post-failover scatter changed served bytes");

    // and fresh streams re-home onto the survivor
    let toks = token_triples(te, tokens, 4242);
    let sid = client.open_stream(1).expect("open after failover");
    for (k, v, _) in &toks {
        client.append(sid, k, v).expect("append after failover");
    }
    let mut q_full = vec![0.0f32; c.heads * tokens * c.head_dim];
    Rng::new(4343).fill_normal(&mut q_full);
    let out = client.query(sid, tokens as u32, &q_full).expect("query after failover");
    assert!(out.iter().all(|x| x.is_finite()));
    client.close_stream(sid).expect("close after failover");

    let _ = dead_handle.shutdown();
    teardown(shards, coord, front, client);
}

#[test]
fn graceful_shard_exit_hands_prompts_over_via_the_spill_store() {
    let spill = tempdir("shard-handoff");
    let mut c = cfg("skeinformer");
    c.kv = Some(
        KvCacheConfig::new(4).with_capacity_blocks(64).with_tiers(
            TierLadder::parse("f16")
                .expect("tier spec")
                .with_spill_dir(spill.path().to_str().expect("utf8 path")),
        ),
    );

    // one shard serves a prompt, then retires gracefully: shutdown
    // archives its KV index into the shared spill store
    let (mut shards, coord, front, mut client) = cluster(&c, 1);
    let tokens = 8usize;
    let toks = token_triples(c.heads * c.head_dim, tokens, 31);
    let ks: Vec<Arc<[f32]>> = toks.iter().map(|(k, _, _)| k.clone()).collect();
    let vs: Vec<Arc<[f32]>> = toks.iter().map(|(_, v, _)| v.clone()).collect();
    let kc = chunk_slab(&ks, 0, tokens, c.heads, c.head_dim);
    let vc = chunk_slab(&vs, 0, tokens, c.heads, c.head_dim);
    let mut q_full = vec![0.0f32; c.heads * tokens * c.head_dim];
    Rng::new(313).fill_normal(&mut q_full);
    let sid = client.open_stream(1).expect("open");
    client.prefill(sid, tokens as u32, &kc, &vc).expect("prefill");
    let first = client.query(sid, tokens as u32, &q_full).expect("query");
    client.close_stream(sid).expect("close");
    assert!(first.iter().all(|x| x.is_finite()));

    let old = shards.remove(0);
    old.server.stop();
    let retired = old.handle.shutdown().expect("graceful shard exit");
    assert!(retired.kv_spilled_blocks > 0, "retiring shard should archive its index");
    wait_until(5, "the coordinator to notice the retirement", || coord.live_shards() == 0);

    // a replacement joins the ring over the same spill directory (its
    // cache registers the manifest at startup) and the replayed prompt
    // warm-restarts from the handed-over blocks
    let fresh = spawn_shards(&c, 1).remove(0);
    coord.add_shard(&fresh.addr).expect("add replacement shard");
    assert_eq!(coord.live_shards(), 1);
    let sid = client.open_stream(1).expect("open replay");
    client.prefill(sid, tokens as u32, &kc, &vc).expect("replay prefill");
    let got = client.query(sid, tokens as u32, &q_full).expect("replay query");
    client.close_stream(sid).expect("close replay");
    // the replay runs under the next global stream id, so the single-
    // process reference is the same replay on a fresh cacheless engine
    // (stream seeds derive from the id; the cache never changes bytes)
    let want_replay = {
        let plain = cfg("skeinformer");
        let handle = attention_server::start(plain).unwrap();
        handle.open_stream(1).close(); // burn id 0 (the first stream)
        let stream = handle.open_stream(1);
        let kc: Arc<[f32]> = kc.clone().into();
        let vc: Arc<[f32]> = vc.clone().into();
        stream.prefill(kc, vc, tokens);
        let q: Arc<[f32]> = q_full.clone().into();
        let out = stream.query(q, tokens).recv().expect("reference replay");
        stream.close();
        handle.shutdown().unwrap();
        out
    };
    assert_eq!(got, want_replay, "handed-over prompt changed bytes across the ring change");

    let stats = coord.stats();
    assert!(
        stats.kv_spill_hits > 0,
        "replacement shard should rehydrate the prompt from the spill manifests"
    );
    teardown(vec![fresh], coord, front, client);
}

#[test]
fn coordinator_relays_typed_rejections_unchanged() {
    let c = cfg("skeinformer");
    let (shards, coord, front, mut client) = cluster(&c, 2);
    let zero_q = vec![0.0f32; c.heads * c.head_dim];

    // unknown stream -> UnknownStream (code 2), from the coordinator's
    // own table — no shard round-trip
    match client.query(999, 1, &zero_q) {
        Err(ClientError::Rejected { code, .. }) => assert_eq!(code, 2),
        other => panic!("expected typed rejection, got {other:?}"),
    }
    // a stream opened but never fed has no home yet: query answers
    // EmptyStream (code 3) exactly as the engine would
    let sid = client.open_stream(1).expect("open");
    match client.query(sid, 1, &zero_q) {
        Err(ClientError::Rejected { code, .. }) => assert_eq!(code, 3),
        other => panic!("expected typed rejection, got {other:?}"),
    }
    // wrong slab length -> BadShape (code 1), validated before scatter
    let bad = HeadsRequest::from_vecs(vec![0.0; 3], vec![0.0; 3], vec![0.0; 3]);
    match client.submit(&bad) {
        Err(ClientError::Rejected { code, .. }) => assert_eq!(code, 1),
        other => panic!("expected typed rejection, got {other:?}"),
    }
    teardown(shards, coord, front, client);
}

#[test]
fn cluster_stats_aggregate_counters_across_shards() {
    let c = cfg("skeinformer");
    let (shards, coord, front, mut client) = cluster(&c, 2);
    let reqs = requests(&c, 6, 11);
    for r in &reqs {
        client.submit(r).expect("reply");
    }
    // both the wire Stats frame and the coordinator API see the merged
    // cluster counters: 6 requests × 2 head-range fragments
    let wire_stats = client.stats().expect("wire stats");
    let api_stats = coord.stats();
    for stats in [&wire_stats, &api_stats] {
        assert_eq!(stats.requests, 12, "each request scatters one fragment per shard");
        assert!(stats.batches > 0);
        assert!(stats.steps > 0);
        assert!(stats.mean_step_occupancy > 0.0);
    }
    // the fragments really did split across the shards
    for s in &shards {
        let st = s.handle.connection().stats().expect("live shard stats");
        assert_eq!(st.requests, 6, "each shard serves its head range of every request");
    }
    teardown(shards, coord, front, client);
}
