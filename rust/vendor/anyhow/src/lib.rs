//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this path dependency
//! provides the small slice of anyhow's API the codebase uses: [`Error`]
//! (a context-carrying message chain), [`Result`], the [`Context`]
//! extension trait for `Result`/`Option`, and the `anyhow!`/`bail!`/
//! `ensure!` macros.  Formatting follows anyhow's conventions: `{}` prints
//! the outermost message, `{:#}` the full `outer: ...: root` chain.
//!
//! Swap this for the real crate by pointing Cargo.toml at crates.io — no
//! call sites need to change.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with a defaulted error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: a chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Prepend a context layer.
    fn wrap<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in self.chain.iter().skip(1) {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

mod ext {
    use super::{Error, StdError};

    /// Anything convertible into [`Error`] — implemented for every std
    /// error and for `Error` itself (the same shape real anyhow uses so
    /// `.context()` works on both plain and already-wrapped results).
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().wrap(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when the condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_wraps_std_errors() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing file");
    }

    #[test]
    fn context_stacks_on_wrapped_errors() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(e.root_cause(), "inner");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing here").unwrap_err();
        assert_eq!(format!("{e}"), "nothing here");
        let v = Some(7u32);
        assert_eq!(v.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn macros() {
        fn fails(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 3);
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(fails(2).unwrap(), 2);
        assert!(format!("{:#}", fails(12).unwrap_err()).contains("x too big: 12"));
        assert!(format!("{:#}", fails(3).unwrap_err()).contains("condition failed"));
        assert!(format!("{}", fails(5).unwrap_err()).contains("five"));
    }

    #[test]
    fn question_mark_converts() {
        fn run() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(run().is_err());
    }
}
