//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The build environment has neither crates.io access nor the
//! `xla_extension` shared library, so this path dependency keeps the
//! coordinator compiling and its pure-host pieces working:
//!
//! * [`Literal`] is **fully functional** on the host side (scalar/vec1/
//!   reshape/to_vec) so the packing helpers in `runtime::literal` and
//!   their tests behave exactly like the real crate.
//! * [`PjRtClient::cpu`] succeeds and reports a `cpu` platform, but
//!   [`PjRtClient::compile`] returns a clear "PJRT unavailable" error —
//!   every artifact-driven path degrades to the same clean skip the
//!   integration tests already perform when `artifacts/` is absent.
//!
//! Swap this for the real `xla` crate (plus `xla_extension`) to execute
//! AOT HLO artifacts; no call sites need to change.

use std::fmt;

/// Stub error type (the real crate's `Error` is richer; every use site
/// only needs `Display` + `std::error::Error`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "PJRT unavailable: {what} is stubbed in this offline build \
         (no xla_extension); use the pure-rust attention engine, or rebuild \
         with the real `xla` dependency to execute HLO artifacts"
    ))
}

// ------------------------------------------------------------------ client

/// Stub PJRT client: reports a CPU platform but cannot compile.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(Self { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("XLA compilation"))
    }
}

/// Parsed HLO text (the stub stores the text verbatim; parsing/validation
/// happens in the real backend).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(Self { text })
    }
}

pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        Self { _text: proto.text.clone() }
    }
}

/// Never constructible through the stub (compile always fails); the
/// methods exist so call sites type-check.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execution"))
    }
}

pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

// ----------------------------------------------------------------- literal

/// Element storage for [`Literal`] — implementation detail, public only so
/// the [`NativeType`] trait can name it.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Elems {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Elems;
    #[doc(hidden)]
    fn unwrap(e: &Elems) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Elems {
        Elems::F32(v)
    }

    fn unwrap(e: &Elems) -> Option<Vec<Self>> {
        match e {
            Elems::F32(d) => Some(d.clone()),
            Elems::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Elems {
        Elems::I32(v)
    }

    fn unwrap(e: &Elems) -> Option<Vec<Self>> {
        match e {
            Elems::I32(d) => Some(d.clone()),
            Elems::F32(_) => None,
        }
    }
}

/// A host tensor: typed element buffer plus dimensions (row-major).
/// Fully functional — matches the real crate for host-side packing.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    elems: Elems,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(value: T) -> Self {
        Self { elems: T::wrap(vec![value]), dims: Vec::new() }
    }

    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Self {
        Self { elems: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Same elements, new dimensions; errors when the counts differ.
    pub fn reshape(&self, dims: &[i64]) -> Result<Self> {
        let count: i64 = dims.iter().product();
        if count < 0 || count as usize != self.element_count() {
            return Err(Error(format!(
                "reshape to {dims:?} incompatible with {} elements",
                self.element_count()
            )));
        }
        Ok(Self { elems: self.elems.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.elems {
            Elems::F32(v) => v.len(),
            Elems::I32(v) => v.len(),
        }
    }

    /// Copy the elements out; errors on an element-type mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.elems).ok_or_else(|| Error("literal element type mismatch".into()))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// The stub never produces real tuples; a non-tuple literal decomposes
    /// to itself (matching how run() consumes single-output executables).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Ok(vec![self.clone()])
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(lit.element_count(), 4);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3, 2]).is_err());
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalars_are_rank_zero() {
        let s = Literal::scalar(5i32);
        assert_eq!(s.element_count(), 1);
        assert!(s.array_shape().unwrap().dims().is_empty());
    }

    #[test]
    fn client_reports_cpu_but_cannot_compile() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu");
        assert_eq!(c.device_count(), 1);
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        let err = c.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("PJRT unavailable"));
    }

    #[test]
    fn missing_hlo_file_is_an_error() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
