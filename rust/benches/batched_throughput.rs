//! Batched multi-head throughput: sequences/sec for standard vs
//! skeinformer vs linformer across `B × H` grids and sequence lengths —
//! the serving-shaped counterpart of the single-head scaling bench.
//!
//! Default run covers `B×H ∈ {1×1, 4×8, 16×8}` at `n = 512` (so the quick
//! pass finishes in seconds even for exact attention); `--full` extends to
//! `n ∈ {512, 2048, 4096}`, where the paper's O(n²) vs O(n log n) gap
//! dominates.  A spawn-overhead probe then runs a small-n grid (64×8 at
//! n = 128, where per-head work is tiny and dispatch overhead is a
//! visible fraction) twice: on the persistent pool, and with the pool
//! torn down before every engine call so each run pays cold thread
//! spawn — the pre-pool per-call `thread::scope` cost.  Emits
//! `reports/batched_throughput.csv` (probe rows carry a `pool` /
//! `respawn` suffix in the method column).

use skeinformer::attention::{self, BatchedAttention};
use skeinformer::bench_util::{ascii_table, bench, write_csv, BenchConfig};
use skeinformer::pool;
use skeinformer::rng::Rng;
use skeinformer::tensor::BatchTensor;

fn random_qkv(
    batch: usize,
    heads: usize,
    seq: usize,
    dim: usize,
    seed: u64,
) -> (BatchTensor, BatchTensor, BatchTensor) {
    let mut rng = Rng::new(seed);
    let mut mk = |_salt: u64| {
        let mut t = BatchTensor::zeros(batch, heads, seq, dim);
        rng.fill_normal(t.data_mut());
        t
    };
    (mk(0), mk(1), mk(2))
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let shapes: &[(usize, usize)] = &[(1, 1), (4, 8), (16, 8)];
    let seqs: &[usize] = if full { &[512, 2048, 4096] } else { &[512] };
    let head_dim = 32;
    let d = 64;
    let methods = ["standard", "skeinformer", "linformer"];

    println!(
        "batched multi-head throughput (head_dim={head_dim}, d={d}{})",
        if full { ", --full" } else { ", quick pass; --full for n up to 4096" }
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for &n in seqs {
        for &(b, h) in shapes {
            let (q, k, v) = random_qkv(b, h, n, head_dim, 42);
            for name in methods {
                let method = attention::by_name(name, d).expect("registry method");
                let engine = BatchedAttention::new();
                let cfg = BenchConfig {
                    warmup_iters: 1,
                    measure_iters: if n >= 2048 { 3 } else { 5 },
                    max_seconds: 60.0,
                };
                let label = format!("{name} B{b}xH{h} n{n}");
                let r = bench(&label, cfg, || {
                    std::hint::black_box(engine.run(
                        method.as_ref(),
                        &q,
                        &k,
                        &v,
                        None,
                        7,
                    ));
                });
                let seqs_per_sec = b as f64 / (r.mean_ms / 1e3);
                println!("{}  ->  {seqs_per_sec:>9.2} seq/s", r.report_line());
                rows.push(vec![
                    name.to_string(),
                    format!("{b}x{h}"),
                    format!("{n}"),
                    format!("{:.2}", r.mean_ms),
                    format!("{seqs_per_sec:.2}"),
                ]);
                csv.push(format!(
                    "{name},{b},{h},{n},{:.3},{seqs_per_sec:.3}",
                    r.mean_ms
                ));
            }
        }
    }
    // Spawn-overhead probe: many tiny heads, so dispatch cost is a
    // visible fraction of the batch.  "pool" reuses the persistent
    // workers; "respawn" tears the pool down before every run, forcing a
    // cold thread spawn per call — the pre-pool baseline.
    let (pb, ph, pn) = (64usize, 8usize, 128usize);
    let (q, k, v) = random_qkv(pb, ph, pn, head_dim, 42);
    let method = attention::by_name("skeinformer", d).expect("registry method");
    let engine = BatchedAttention::new();
    let probe_cfg = BenchConfig { warmup_iters: 2, measure_iters: 10, max_seconds: 60.0 };
    for mode in ["pool", "respawn"] {
        let label = format!("skeinformer({mode}) B{pb}xH{ph} n{pn}");
        let r = bench(&label, probe_cfg, || {
            if mode == "respawn" {
                pool::shutdown_pool();
            }
            std::hint::black_box(engine.run(method.as_ref(), &q, &k, &v, None, 7));
        });
        let seqs_per_sec = pb as f64 / (r.mean_ms / 1e3);
        println!("{}  ->  {seqs_per_sec:>9.2} seq/s", r.report_line());
        rows.push(vec![
            format!("skeinformer({mode})"),
            format!("{pb}x{ph}"),
            format!("{pn}"),
            format!("{:.2}", r.mean_ms),
            format!("{seqs_per_sec:.2}"),
        ]);
        csv.push(format!(
            "skeinformer({mode}),{pb},{ph},{pn},{:.3},{seqs_per_sec:.3}",
            r.mean_ms
        ));
    }

    println!(
        "\n=== Batched throughput (sequences/sec) ===\n{}",
        ascii_table(&["Model", "BxH", "n", "ms/batch", "seq/s"], &rows)
    );
    write_csv(
        "reports/batched_throughput.csv",
        "method,batch,heads,n,mean_ms,seqs_per_sec",
        &csv,
    )
    .expect("csv");
    println!("-> reports/batched_throughput.csv");
}
