//! E4 — Table 3: total training steps and total wall-clock time to
//! convergence (the early-stopping protocol), per method, on one task.
//!
//! Paper shape: Skeinformer's total time is a small fraction of
//! Standard's (the "nearly 9× speedup on text classification" claim);
//! the O(n²) methods (standard, unreduced JLT, informer) dominate the
//! time column even when step counts are similar.

use skeinformer::bench_util::write_csv;
use skeinformer::config::ExperimentConfig;
use skeinformer::coordinator::{run_sweep, Sweep};
use skeinformer::report;

fn main() {
    if !std::path::Path::new("artifacts/skeinformer_manifest.json").exists() {
        eprintln!("table3_convergence: artifacts/ missing — run `make artifacts`; skipping");
        return;
    }
    let full = std::env::args().any(|a| a == "--full");
    let methods: Vec<&str> = if full {
        skeinformer::config::KNOWN_METHODS.to_vec()
    } else {
        vec!["standard_nodrop", "skeinformer", "linformer", "vmean"]
    };

    let mut base = ExperimentConfig::default();
    base.train.max_steps = if full { 400 } else { 100 };
    base.train.eval_every = 15;
    base.train.patience = 5;
    base.train.eval_examples = 128;

    let sweep = Sweep::new(&methods, &["listops"], base);
    let outcomes = run_sweep(&sweep, true).expect("sweep");

    println!("\n=== Table 3 (total steps / total seconds to converge) ===");
    println!("{}", report::table3(&outcomes));

    // the headline relative-speedup check
    let time_of = |m: &str| {
        outcomes.iter().find(|o| o.method == m).map(|o| o.seconds)
    };
    if let (Some(std_t), Some(skein_t)) = (time_of("standard_nodrop"), time_of("skeinformer")) {
        println!(
            "standard/skeinformer total-time ratio: {:.2}x (paper: ~3.8x on ListOps at n=1k-4k)",
            std_t / skein_t
        );
    }

    let rows: Vec<String> = outcomes
        .iter()
        .map(|o| format!("{},{},{},{:.2}", o.method, o.task, o.steps, o.seconds))
        .collect();
    write_csv("reports/table3_convergence.csv", "method,task,steps,seconds", &rows).expect("csv");
    println!("-> reports/table3_convergence.csv");
}
