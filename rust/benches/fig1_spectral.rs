//! E1 — Figure 1: spectral-norm approximation loss vs sketch size d.
//!
//! Reproduces both panels (n = 1024 and n = 4096) and both input modes
//! (pretrained-like and random-init embeddings).  For every method and
//! every d ∈ {8..256} it reports the mean relative spectral-norm loss
//! `‖BV − R‖₂ / ‖BV‖₂` ± standard error over trials, and writes the CSV
//! series `reports/figure1_*.csv` that regenerate the figure.
//!
//! Paper shape to verify: V-Mean is flat in d; Skeinformer's curve drops
//! below Informer/Linformer as d grows; the unreduced JLT beats the
//! reduced Linformer.

use skeinformer::attention::{registry, Standard};
use skeinformer::bench_util::write_csv;
use skeinformer::metrics::RunningStats;
use skeinformer::pool::parallel_map;
use skeinformer::rng::Rng;
use skeinformer::synth_qkv::{generate, QkvConfig, QkvMode};
use skeinformer::tensor::{spectral_norm, spectral_norm_diff};

fn main() {
    // default is the bounded run; --full regenerates both paper panels
    // (n=4096 across 14 methods takes ~15 min on CPU).
    let full = std::env::args().any(|a| a == "--full");
    let sizes: &[usize] = if full { &[1024, 4096] } else { &[1024] };
    let trials: u64 = if full { 8 } else { 4 };
    let p = 64;

    for &n in sizes {
        for mode in [QkvMode::Pretrained, QkvMode::RandomInit] {
            run_panel(n, p, mode, trials);
        }
    }
}

fn run_panel(n: usize, p: usize, mode: QkvMode, trials: u64) {
    let mode_name = match mode {
        QkvMode::Pretrained => "pretrained",
        QkvMode::RandomInit => "random",
    };
    println!("== Figure 1 panel: n={n} mode={mode_name} (trials={trials}) ==");
    let cfg = match mode {
        QkvMode::Pretrained => QkvConfig::pretrained(n, p),
        QkvMode::RandomInit => QkvConfig::random_init(n, p),
    };
    let mut gen_rng = Rng::new(0xF16);
    let (q, k, v) = generate(&cfg, &mut gen_rng);
    let exact = Standard::exact(&q, &k, &v, None);
    let base = spectral_norm(&exact);

    let ds: Vec<usize> = (3..=8).map(|e| 1usize << e).collect();
    let mut rows = Vec::new();
    for &d in &ds {
        let methods = registry(d);
        for method in &methods {
            if method.is_exact() {
                continue;
            }
            // trials are independent given distinct seeds -> parallel map
            let seeds: Vec<u64> = (0..trials).collect();
            let errs = parallel_map(&seeds, |&s| {
                let out = method.compute(&q, &k, &v, None, &mut Rng::new(1000 + s));
                (spectral_norm_diff(&out, &exact) / base) as f64
            });
            let mut stats = RunningStats::new();
            errs.into_iter().for_each(|e| stats.push(e));
            println!(
                "  d={d:<4} {:<20} rel-loss={:.4} ± {:.4}",
                method.name(),
                stats.mean(),
                stats.std_err()
            );
            rows.push(format!(
                "{mode_name},{n},{d},{},{:.6},{:.6}",
                method.name(),
                stats.mean(),
                stats.std_err()
            ));
        }
    }
    let path = format!("reports/figure1_n{n}_{mode_name}.csv");
    write_csv(&path, "mode,n,d,method,rel_spectral_loss,std_err", &rows).expect("write csv");
    println!("  -> {path}");

    // The paper's qualitative claims, asserted on the pretrained panel:
    if matches!(mode, QkvMode::Pretrained) {
        check_shape(&rows, n);
    }
}

/// Assert the Figure-1 orderings hold in our measurements at the largest d.
fn check_shape(rows: &[String], n: usize) {
    let at = |method: &str, d: usize| -> f64 {
        rows.iter()
            .find(|r| {
                let cols: Vec<&str> = r.split(',').collect();
                cols[2] == d.to_string() && cols[3] == method
            })
            .map(|r| r.split(',').nth(4).unwrap().parse().unwrap())
            .unwrap_or(f64::NAN)
    };
    let d = 256;
    let skein = at("skeinformer", d);
    let vmean = at("vmean", d);
    let linf = at("linformer", d);
    println!(
        "  [shape check n={n}] skeinformer {skein:.4} < vmean {vmean:.4}: {}",
        skein < vmean
    );
    println!(
        "  [shape check n={n}] skeinformer {skein:.4} < linformer {linf:.4}: {}",
        skein < linf
    );
}
