//! KV-cache probe: decode throughput and resident KV bytes with the
//! paged cache on vs off, shared-prefix vs disjoint stream workloads,
//! window ∈ {512, 2048, ∞}.
//!
//! Each run decodes `--tokens` tokens per stream (append + one-row query
//! per token) over `--streams` server streams:
//!
//! * **shared** — every stream replays the same token sequence (the
//!   resubmitted-prompt / common-system-prompt shape).  With the cache
//!   on, streams 2..S allocate zero new blocks for the shared region.
//! * **disjoint** — every stream gets its own sequence: the worst case
//!   for prefix sharing, isolating pure cache overhead.
//!
//! Reported per row: tokens/s, resident KV KiB at shutdown, and the
//! hit/alloc block counters.  The cache-off baseline's "resident" column
//! is the analytic per-session KV footprint (streams × tokens ×
//! heads × head_dim × 2 × 4 bytes) for comparison — sessions hold K/V
//! per stream, the cache dedupes it across streams and windows bound it.
//!
//! Emits `reports/kv_cache.csv`
//! (`workload,window,method,streams,tokens,tok_s,resident_kv_bytes,hit_blocks,alloc_blocks`).
//!
//! **Prefill sweep + batch-dedupe probe** (`make prefill-bench` →
//! `--prefill` runs only these):
//!
//! * **prefill** — ingest `--tokens` tokens into one cached stream at
//!   chunk ∈ {1 (per-token `Append` ops), block, 4×block} (`Prefill`
//!   ops), one final query.  Chunk 1 pays one channel message and one
//!   cache op per token; block-sized chunks amortise sealing, hashing,
//!   and prefix lookup per block — the tok/s gap is the chunked-prefill
//!   win.
//! * **dedupe** — submit one batched `HeadsRequest` 8 times with
//!   `batch_dedupe` on: submission 1 allocates `seq / block` blocks,
//!   submissions 2..8 hit them all (hit rate → 7/8).
//!
//! Emits `reports/kv_prefill.csv`
//! (`mode,chunk,method,tokens,tok_s,hit_blocks,alloc_blocks`).
//!
//! **Tier sweep** (`make tier-bench` → `--tiers` runs only this):
//! alternating shared/disjoint decode streams over a capacity-bounded
//! cache (capacity = one prompt's worth of blocks).  The disjoint
//! streams manufacture eviction pressure; the shared replays measure
//! how much of the common prompt each ladder retains — f32-only drops
//! cold blocks (replays re-allocate), f16/int8 keep them resident at
//! half/quarter bytes, spill rehydrates exact bytes from disk.
//!
//! Emits `reports/kv_tiers.csv`
//! (`config,method,streams,tokens,tok_s,hit_blocks,alloc_blocks,demoted_blocks,spilled_blocks,spill_hits,resident_kv_bytes`).
//!
//! `make cache-bench`; `--full` extends tokens 512 → 2048.

use skeinformer::bench_util::{ascii_table, write_csv};
use skeinformer::coordinator::attention_server::{
    self, AttentionServerConfig, AttentionServerStats, HeadsRequest,
};
use skeinformer::kvcache::{tempdir, KvCacheConfig, TierLadder};
use skeinformer::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

const BLOCK_SIZE: usize = 16;

fn cfg(method: &str, kv: Option<KvCacheConfig>) -> AttentionServerConfig {
    AttentionServerConfig {
        method: method.to_string(),
        d: 64,
        heads: 4,
        seq: 512,
        head_dim: 32,
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        seed: 0,
        workers: None,
        queue_depth: 0,
        kv,
    }
}

/// Decode `tokens` tokens on each of `streams` streams; returns
/// (tok/s, resident KV bytes, hit blocks, alloc blocks).
fn run(
    c: &AttentionServerConfig,
    streams: usize,
    tokens: usize,
    shared_prefix: bool,
) -> (f64, u64, u64, u64) {
    let token_elems = c.heads * c.head_dim;
    let handle = attention_server::start(c.clone()).expect("server start");
    let t0 = std::time::Instant::now();
    for s in 0..streams {
        let stream = handle.open_stream(1);
        // shared workload: identical data seed per stream → identical
        // prompt → the cache dedupes; disjoint: per-stream seed
        let data_seed = if shared_prefix { 1 } else { 1 + s as u64 };
        let mut rng = Rng::new(data_seed);
        for _ in 0..tokens {
            let mut mk = || {
                let mut b = vec![0.0f32; token_elems];
                rng.fill_normal(&mut b);
                let slab: Arc<[f32]> = b.into();
                slab
            };
            let (k, v, q) = (mk(), mk(), mk());
            stream.append(k, v);
            let out = stream.query(q, 1).recv().expect("stream reply");
            std::hint::black_box(out[0]);
        }
        stream.close();
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = handle.shutdown().expect("server shutdown");
    let resident_bytes = match &c.kv {
        Some(_) => stats.kv_resident_bytes,
        // cache off: sessions hold K/V per stream — the analytic footprint
        None => (streams * tokens * token_elems * 2 * std::mem::size_of::<f32>()) as u64,
    };
    (
        (streams * tokens) as f64 / wall,
        resident_bytes,
        stats.kv_hit_blocks,
        stats.kv_alloc_blocks,
    )
}

/// Ingest `tokens` tokens into one cached stream at the given chunk size
/// (1 = per-token `Append` ops; otherwise `Prefill` ops), then one final
/// 1-row query.  Returns (tok/s, hit blocks, alloc blocks).
fn run_prefill(c: &AttentionServerConfig, tokens: usize, chunk: usize) -> (f64, u64, u64) {
    let token_elems = c.heads * c.head_dim;
    let handle = attention_server::start(c.clone()).expect("server start");
    let stream = handle.open_stream(1);
    let mut rng = Rng::new(1);
    let t0 = std::time::Instant::now();
    if chunk <= 1 {
        for _ in 0..tokens {
            let mut mk = || {
                let mut b = vec![0.0f32; token_elems];
                rng.fill_normal(&mut b);
                let slab: Arc<[f32]> = b.into();
                slab
            };
            let (k, v) = (mk(), mk());
            stream.append(k, v);
        }
    } else {
        let mut remaining = tokens;
        while remaining > 0 {
            let n = chunk.min(remaining);
            let mut mk = || {
                let mut b = vec![0.0f32; n * token_elems];
                rng.fill_normal(&mut b);
                let slab: Arc<[f32]> = b.into();
                slab
            };
            let (k, v) = (mk(), mk());
            stream.prefill(k, v, n);
            remaining -= n;
        }
    }
    // the query synchronises: it waits behind the whole ingest
    let mut q = vec![0.0f32; token_elems];
    rng.fill_normal(&mut q);
    let out = stream.query(q.into(), 1).recv().expect("prefill query reply");
    std::hint::black_box(out[0]);
    let wall = t0.elapsed().as_secs_f64();
    stream.close();
    let stats = handle.shutdown().expect("server shutdown");
    (tokens as f64 / wall, stats.kv_hit_blocks, stats.kv_alloc_blocks)
}

/// Submit one batched request `submissions` times with batch-dedupe on.
/// Returns (requests/s, hit blocks, alloc blocks).
fn run_dedupe_probe(c: &AttentionServerConfig, submissions: usize) -> (f64, u64, u64) {
    let handle = attention_server::start(c.clone()).expect("server start");
    let req = HeadsRequest::random(c.request_elems(), &mut Rng::new(2));
    let t0 = std::time::Instant::now();
    for _ in 0..submissions {
        let out = handle.submit(req.clone()).recv().expect("batch reply");
        std::hint::black_box(out[0]);
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = handle.shutdown().expect("server shutdown");
    (submissions as f64 / wall, stats.kv_hit_blocks, stats.kv_alloc_blocks)
}

/// The prefill-chunk sweep + batch-dedupe hit-rate probe
/// (`make prefill-bench`).
fn run_prefill_suite(method: &str, tokens: usize) {
    println!(
        "prefill probe: method={method} tokens={tokens} block-size={BLOCK_SIZE} \
         chunk in {{1, {BLOCK_SIZE}, {}}}",
        4 * BLOCK_SIZE
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for chunk in [1, BLOCK_SIZE, 4 * BLOCK_SIZE] {
        let c = cfg(method, Some(KvCacheConfig::new(BLOCK_SIZE)));
        let (tok_s, hits, allocs) = run_prefill(&c, tokens, chunk);
        let label = if chunk == 1 { "1 (per-token)".to_string() } else { chunk.to_string() };
        println!("  prefill chunk={label:<14} {tok_s:>10.1} tok/s  hits={hits} allocs={allocs}");
        rows.push(vec![
            "prefill".into(),
            label,
            format!("{tok_s:.1}"),
            hits.to_string(),
            allocs.to_string(),
        ]);
        csv.push(format!("prefill,{chunk},{method},{tokens},{tok_s:.2},{hits},{allocs}"));
    }

    let submissions = 8;
    let c = cfg(method, Some(KvCacheConfig::new(BLOCK_SIZE).with_batch_dedupe(true)));
    let (req_s, hits, allocs) = run_dedupe_probe(&c, submissions);
    let rate = hits as f64 / (hits + allocs).max(1) as f64;
    println!(
        "  dedupe  {submissions} submissions    {req_s:>10.1} req/s  hits={hits} \
         allocs={allocs} (hit rate {:.0}%)",
        rate * 100.0
    );
    rows.push(vec![
        "dedupe".into(),
        format!("{submissions} subs"),
        format!("{req_s:.1}"),
        hits.to_string(),
        allocs.to_string(),
    ]);
    csv.push(format!("dedupe,{submissions},{method},{},{req_s:.2},{hits},{allocs}", c.seq));

    println!(
        "\n{}",
        ascii_table(&["mode", "chunk", "tok/s (req/s)", "hits", "allocs"], &rows)
    );
    if let Err(e) = write_csv(
        "reports/kv_prefill.csv",
        "mode,chunk,method,tokens,tok_s,hit_blocks,alloc_blocks",
        &csv,
    ) {
        eprintln!("csv write failed: {e}");
    } else {
        eprintln!("rows written to reports/kv_prefill.csv");
    }
}

/// One tier-sweep run: `rounds` sequential decode streams, even rounds
/// replaying the shared prompt, odd rounds unique.  Returns (tok/s,
/// shutdown stats).
fn run_tier_workload(
    c: &AttentionServerConfig,
    rounds: usize,
    tokens: usize,
) -> (f64, AttentionServerStats) {
    let token_elems = c.heads * c.head_dim;
    let handle = attention_server::start(c.clone()).expect("server start");
    let t0 = std::time::Instant::now();
    for round in 0..rounds {
        // K/V come from `rng` only (queries use their own stream) so
        // every even round appends bit-identical prompt slabs
        let data_seed = if round % 2 == 0 { 1 } else { 1000 + round as u64 };
        let mut rng = Rng::new(data_seed);
        let mut qrng = Rng::new(7);
        let stream = handle.open_stream(1);
        for _ in 0..tokens {
            let mut mk = || {
                let mut b = vec![0.0f32; token_elems];
                rng.fill_normal(&mut b);
                let slab: Arc<[f32]> = b.into();
                slab
            };
            let (k, v) = (mk(), mk());
            stream.append(k, v);
            let mut q = vec![0.0f32; token_elems];
            qrng.fill_normal(&mut q);
            let out = stream.query(q.into(), 1).recv().expect("stream reply");
            std::hint::black_box(out[0]);
        }
        stream.close();
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = handle.shutdown().expect("server shutdown");
    ((rounds * tokens) as f64 / wall, stats)
}

/// The tier-ladder sweep (`make tier-bench`): f32-only vs f16 vs int8 vs
/// the full quant ladder vs spill-to-disk, all at the same capacity.
fn run_tier_suite(method: &str, tokens: usize) {
    let rounds = 6;
    let cap = (tokens / BLOCK_SIZE).max(1); // one prompt's worth of blocks
    let spill = tempdir("bench-tiers");
    println!(
        "kv-tier sweep: method={method} rounds={rounds} tokens={tokens} \
         capacity={cap} blocks (block-size {BLOCK_SIZE})"
    );
    let ladders: Vec<(&str, TierLadder)> = vec![
        ("f32", TierLadder::none()),
        ("f16", TierLadder::none().with_f16(true)),
        ("int8", TierLadder::none().with_int8(true)),
        ("f16-int8", TierLadder::none().with_f16(true).with_int8(true)),
        ("spill", TierLadder::none().with_spill_dir(spill.path())),
    ];
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for (label, ladder) in ladders {
        let kv = KvCacheConfig::new(BLOCK_SIZE).with_capacity_blocks(cap).with_tiers(ladder);
        let c = cfg(method, Some(kv));
        let (tok_s, s) = run_tier_workload(&c, rounds, tokens);
        println!(
            "  {label:<9} {tok_s:>9.1} tok/s  hits={} allocs={} demoted={} spilled={} \
             spill-hits={} {:>9.1} KiB KV",
            s.kv_hit_blocks,
            s.kv_alloc_blocks,
            s.kv_demoted_blocks,
            s.kv_spilled_blocks,
            s.kv_spill_hits,
            s.kv_resident_bytes as f64 / 1024.0
        );
        rows.push(vec![
            label.to_string(),
            format!("{tok_s:.1}"),
            s.kv_hit_blocks.to_string(),
            s.kv_alloc_blocks.to_string(),
            s.kv_demoted_blocks.to_string(),
            s.kv_spilled_blocks.to_string(),
            s.kv_spill_hits.to_string(),
            format!("{:.1}", s.kv_resident_bytes as f64 / 1024.0),
        ]);
        csv.push(format!(
            "{label},{method},{rounds},{tokens},{tok_s:.2},{},{},{},{},{},{}",
            s.kv_hit_blocks,
            s.kv_alloc_blocks,
            s.kv_demoted_blocks,
            s.kv_spilled_blocks,
            s.kv_spill_hits,
            s.kv_resident_bytes
        ));
    }
    println!(
        "\n{}",
        ascii_table(
            &["config", "tok/s", "hits", "allocs", "demoted", "spilled", "spill-hits", "resident KiB"],
            &rows
        )
    );
    if let Err(e) = write_csv(
        "reports/kv_tiers.csv",
        "config,method,streams,tokens,tok_s,hit_blocks,alloc_blocks,demoted_blocks,\
         spilled_blocks,spill_hits,resident_kv_bytes",
        &csv,
    ) {
        eprintln!("csv write failed: {e}");
    } else {
        eprintln!("rows written to reports/kv_tiers.csv");
    }
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let prefill_only = std::env::args().any(|a| a == "--prefill");
    let tiers_only = std::env::args().any(|a| a == "--tiers");
    let tokens = if full { 2048 } else { 512 };
    let streams = 4;
    let method = "skeinformer";
    if prefill_only {
        run_prefill_suite(method, tokens);
        return;
    }
    if tiers_only {
        run_tier_suite(method, tokens);
        return;
    }
    println!(
        "kv-cache probe: method={method} streams={streams} tokens={tokens} \
         block-size={BLOCK_SIZE}{}",
        if full { " (--full)" } else { "" }
    );

    // (label, kv config): ∞ = cache on, no window
    let windows: [(&str, Option<usize>); 3] =
        [("512", Some(512)), ("2048", Some(2048)), ("inf", None)];
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut record = |workload: &str, window: &str, kv: Option<KvCacheConfig>| {
        let c = cfg(method, kv);
        let shared = workload == "shared";
        let (tok_s, bytes, hits, allocs) = run(&c, streams, tokens, shared);
        println!(
            "  {workload:<9} window={window:<5} {tok_s:>9.1} tok/s  {:>9.1} KiB KV  \
             hits={hits} allocs={allocs}",
            bytes as f64 / 1024.0
        );
        rows.push(vec![
            workload.to_string(),
            window.to_string(),
            format!("{tok_s:.1}"),
            format!("{:.1}", bytes as f64 / 1024.0),
            hits.to_string(),
            allocs.to_string(),
        ]);
        csv.push(format!(
            "{workload},{window},{method},{streams},{tokens},{tok_s:.2},{bytes},{hits},{allocs}"
        ));
    };

    for workload in ["shared", "disjoint"] {
        // cache-off baseline (window label "off")
        record(workload, "off", None);
        for (label, window) in windows {
            let mut kv = KvCacheConfig::new(BLOCK_SIZE);
            if let Some(w) = window {
                kv = kv.with_window(w);
            }
            record(workload, label, Some(kv));
        }
    }

    println!(
        "\n{}",
        ascii_table(
            &["workload", "window", "tok/s", "resident KiB", "hits", "allocs"],
            &rows
        )
    );
    if let Err(e) = write_csv(
        "reports/kv_cache.csv",
        "workload,window,method,streams,tokens,tok_s,resident_kv_bytes,hit_blocks,alloc_blocks",
        &csv,
    ) {
        eprintln!("csv write failed: {e}");
    } else {
        eprintln!("rows written to reports/kv_cache.csv");
    }

    println!();
    run_prefill_suite(method, tokens);
    println!();
    run_tier_suite(method, tokens);
}
