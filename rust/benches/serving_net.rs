//! Serving-throughput probe for the TCP front end: requests/s and
//! per-step scheduler occupancy over the socket vs the in-process
//! handle (`make net-bench`).
//!
//! Three rows per run, all submitting the same one-shot workload with a
//! bounded in-flight window per lane:
//!
//! * **in-process** — `AttentionServerHandle::submit` straight into the
//!   serve thread: the transport-free ceiling.
//! * **net-1** — one `NetClient` connection: adds frame encode/decode,
//!   two socket hops, and the per-connection reader/writer threads.
//! * **net-4** — four concurrent connections, each its own round-robin
//!   admission lane: continuous batching fills steps from multiple
//!   lanes, so `step-occ` here is the multi-tenant packing the
//!   in-process single-lane rows cannot show.
//!
//! The engine work is identical in every row (same shape, same seeds by
//! lifetime batch index), so the req/s gap is pure transport overhead
//! and the occupancy column shows what admission does with more lanes.
//!
//! Emits `reports/serving_net.csv`
//! (`mode,method,clients,requests,req_s,p50_ms,p95_ms,steps,step_occupancy`).
//!
//! Flags: `--method M` (default skeinformer), `--requests N` (default
//! 64), `--window W` in-flight per lane (default 8), `--full` (256
//! requests).

use skeinformer::bench_util::{ascii_table, write_csv};
use skeinformer::cli::Args;
use skeinformer::coordinator::attention_server::{self, AttentionServerConfig, HeadsRequest};
use skeinformer::coordinator::net::{self, NetClient};
use skeinformer::metrics::Percentiles;
use skeinformer::rng::Rng;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

fn cfg(method: &str) -> AttentionServerConfig {
    AttentionServerConfig {
        method: method.to_string(),
        d: 64,
        heads: 4,
        seq: 256,
        head_dim: 32,
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        seed: 0,
        workers: None,
        queue_depth: 0,
        kv: None,
    }
}

struct Run {
    wall: f64,
    latency_ms: Vec<f64>,
    steps: u64,
    step_occupancy: f64,
}

fn run_in_process(c: &AttentionServerConfig, total: usize, window: usize) -> anyhow::Result<Run> {
    let handle = attention_server::start(c.clone())?;
    let mut rng = Rng::new(100);
    let mut latency_ms = Vec::new();
    let mut inflight = VecDeque::new();
    let t0 = Instant::now();
    for _ in 0..total {
        let req = HeadsRequest::random(c.request_elems(), &mut rng);
        inflight.push_back((handle.submit(req), Instant::now()));
        if inflight.len() >= window {
            let (rx, sent) = inflight.pop_front().expect("non-empty window");
            rx.recv()?;
            latency_ms.push(sent.elapsed().as_secs_f64() * 1e3);
        }
    }
    while let Some((rx, sent)) = inflight.pop_front() {
        rx.recv()?;
        latency_ms.push(sent.elapsed().as_secs_f64() * 1e3);
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = handle.shutdown()?;
    Ok(Run { wall, latency_ms, steps: stats.steps, step_occupancy: stats.mean_step_occupancy })
}

fn run_net(
    c: &AttentionServerConfig,
    total: usize,
    clients: usize,
    window: usize,
) -> anyhow::Result<Run> {
    let handle = attention_server::start(c.clone())?;
    let server = net::serve(&handle, "127.0.0.1:0")?;
    let addr = server.local_addr();
    let per = total / clients;
    let elems = c.request_elems();
    let t0 = Instant::now();
    let joins: Vec<_> = (0..clients)
        .map(|ci| {
            std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
                let mut client = NetClient::connect(addr)?;
                let mut rng = Rng::new(100 + ci as u64);
                let mut latency_ms = Vec::new();
                let mut inflight = VecDeque::new();
                for _ in 0..per {
                    let req = HeadsRequest::random(elems, &mut rng);
                    inflight.push_back((client.submit_async(&req)?, Instant::now()));
                    if inflight.len() >= window {
                        let (id, sent) = inflight.pop_front().expect("non-empty window");
                        client.wait_output(id)?;
                        latency_ms.push(sent.elapsed().as_secs_f64() * 1e3);
                    }
                }
                while let Some((id, sent)) = inflight.pop_front() {
                    client.wait_output(id)?;
                    latency_ms.push(sent.elapsed().as_secs_f64() * 1e3);
                }
                Ok(latency_ms)
            })
        })
        .collect();
    let mut latency_ms = Vec::new();
    for j in joins {
        latency_ms.extend(j.join().map_err(|_| anyhow::anyhow!("client thread panicked"))??);
    }
    let wall = t0.elapsed().as_secs_f64();
    server.stop();
    let stats = handle.shutdown()?;
    Ok(Run { wall, latency_ms, steps: stats.steps, step_occupancy: stats.mean_step_occupancy })
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let method = args.get_or("method", "skeinformer").to_string();
    let total = if args.switch("full") { 256 } else { args.get_usize("requests", 64)? };
    let window = args.get_usize("window", 8)?;
    let c = cfg(&method);
    eprintln!(
        "serving-net bench: method={method} requests={total} window={window} \
         shape B<={} H={} n={} p={}",
        c.max_batch, c.heads, c.seq, c.head_dim
    );

    let mut table = Vec::new();
    let mut csv = Vec::new();
    for (mode, clients) in [("in-process", 0usize), ("net", 1), ("net", 4)] {
        let run = if clients == 0 {
            run_in_process(&c, total, window)?
        } else {
            run_net(&c, total, clients, window)?
        };
        let served = run.latency_ms.len();
        let mut lat = Percentiles::default();
        for &ms in &run.latency_ms {
            lat.push(ms);
        }
        let req_s = served as f64 / run.wall;
        let label =
            if clients == 0 { mode.to_string() } else { format!("{mode}-{clients}") };
        table.push(vec![
            label.clone(),
            format!("{served}"),
            format!("{req_s:.1}"),
            format!("{:.2}", lat.percentile(50.0)),
            format!("{:.2}", lat.percentile(95.0)),
            format!("{}", run.steps),
            format!("{:.3}", run.step_occupancy),
        ]);
        csv.push(format!(
            "{label},{method},{clients},{served},{req_s:.2},{:.3},{:.3},{},{:.4}",
            lat.percentile(50.0),
            lat.percentile(95.0),
            run.steps,
            run.step_occupancy
        ));
    }
    println!(
        "{}",
        ascii_table(
            &["mode", "served", "req/s", "p50 ms", "p95 ms", "steps", "step-occ"],
            &table
        )
    );
    write_csv(
        "reports/serving_net.csv",
        "mode,method,clients,requests,req_s,p50_ms,p95_ms,steps,step_occupancy",
        &csv,
    )?;
    eprintln!("rows written to reports/serving_net.csv");
    Ok(())
}
