//! E8 — §4.5 complexity claim: O(n log n) Skeinformer vs O(n²) Standard.
//!
//! Sweeps n ∈ {256 .. 4096} at fixed d and measures wall-clock of the
//! pure-rust implementations.  Reports the empirical scaling exponent
//! (log-log slope) per method and the skeinformer-vs-standard speedup at
//! each n — the crossover shape the paper's complexity analysis predicts.

use skeinformer::attention::by_name;
use skeinformer::bench_util::{bench, write_csv, BenchConfig};
use skeinformer::rng::Rng;
use skeinformer::synth_qkv::{generate, QkvConfig};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: Vec<usize> =
        if quick { vec![256, 512, 1024] } else { vec![256, 512, 1024, 2048, 4096] };
    let d = 128;
    let p = 64;
    let bcfg = BenchConfig {
        warmup_iters: 1,
        measure_iters: if quick { 3 } else { 5 },
        max_seconds: 90.0,
    };

    let methods = ["standard", "skeinformer", "informer", "linformer", "performer"];
    let mut results: Vec<(String, usize, f64)> = Vec::new();
    for &n in &sizes {
        let mut rng = Rng::new(42);
        let (q, k, v) = generate(&QkvConfig::pretrained(n, p), &mut rng);
        for name in methods {
            let method = by_name(name, d).unwrap();
            let r = bench(&format!("{name}@n={n}"), bcfg, || {
                std::hint::black_box(method.compute(&q, &k, &v, None, &mut Rng::new(1)));
            });
            println!("  {}", r.report_line());
            results.push((name.to_string(), n, r.mean_ms));
        }
    }

    println!("\nempirical scaling exponents (log2 time / log2 n):");
    for name in methods {
        let series: Vec<(usize, f64)> = results
            .iter()
            .filter(|(m, ..)| m == name)
            .map(|(_, n, t)| (*n, *t))
            .collect();
        let first = series.first().unwrap();
        let last = series.last().unwrap();
        let slope = ((last.1 / first.1).log2()) / ((last.0 as f64 / first.0 as f64).log2());
        println!("  {name:<14} exponent ≈ {slope:.2}");
    }

    println!("\nskeinformer speedup over standard:");
    let mut csv = Vec::new();
    for &n in &sizes {
        let t = |m: &str| {
            results
                .iter()
                .find(|(mm, nn, _)| mm == m && *nn == n)
                .map(|(.., t)| *t)
                .unwrap()
        };
        let speedup = t("standard") / t("skeinformer");
        println!("  n={n:<6} {speedup:.2}x");
        csv.push(format!("{n},{:.3},{:.3},{speedup:.3}", t("standard"), t("skeinformer")));
    }
    write_csv("reports/scaling.csv", "n,standard_ms,skeinformer_ms,speedup", &csv).expect("csv");
    println!("-> reports/scaling.csv");
}
