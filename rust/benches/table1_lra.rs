//! E2 — Table 1: classification accuracy on the synthetic LRA suite.
//!
//! Trains the 2-layer/64-dim/2-head transformer (the paper's experimental
//! model) for every method × task through the AOT train-step artifacts and
//! prints the Table-1-shaped accuracy grid plus the paper-vs-measured
//! comparison.  Absolute numbers differ from the paper (synthetic tasks,
//! CPU substrate — see DESIGN.md §4); the *orderings* are the
//! reproduction target.
//!
//! Default is a bounded-budget run (subset of methods, 2 tasks, capped
//! steps) so `cargo bench` completes in minutes; pass `--full` for all 16
//! methods × 5 tasks.

use skeinformer::bench_util::write_csv;
use skeinformer::config::ExperimentConfig;
use skeinformer::coordinator::{run_sweep, Sweep};
use skeinformer::report;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    if !std::path::Path::new("artifacts/skeinformer_manifest.json").exists() {
        eprintln!("table1_lra: artifacts/ missing — run `make artifacts` first; skipping");
        return;
    }

    let methods: Vec<&str> = if full {
        skeinformer::config::KNOWN_METHODS.to_vec()
    } else {
        vec!["standard_nodrop", "vmean", "skeinformer", "skein_uniform", "informer", "linformer"]
    };
    let tasks: Vec<&str> = if full {
        skeinformer::data::TASK_NAMES.to_vec()
    } else {
        vec!["listops", "text"]
    };

    let mut base = ExperimentConfig::default();
    base.train.max_steps = if full { 400 } else { 80 };
    base.train.eval_every = 20;
    base.train.patience = 6;
    base.train.eval_examples = 128;

    let sweep = Sweep::new(&methods, &tasks, base);
    let outcomes = run_sweep(&sweep, true).expect("sweep failed");

    println!("\n=== Table 1 (accuracy %, synthetic LRA) ===");
    println!("{}", report::table1(&outcomes));
    println!("=== Paper vs measured ===");
    println!("{}", report::paper_vs_measured(&outcomes));

    let rows: Vec<String> = outcomes
        .iter()
        .map(|o| {
            format!(
                "{},{},{},{:.4},{:.4},{:.1},{:.2}",
                o.method, o.task, o.steps, o.best_accuracy, o.final_accuracy, o.seconds,
                o.ms_per_step
            )
        })
        .collect();
    write_csv(
        "reports/table1_lra.csv",
        "method,task,steps,best_acc,final_acc,seconds,ms_per_step",
        &rows,
    )
    .expect("write csv");
    println!("-> reports/table1_lra.csv");
}
