//! E7 — Figure 2: validation loss vs training wall-clock time.
//!
//! Trains a set of methods on one task, recording the (seconds, val_loss)
//! series at every evaluation, and writes `reports/figure2_<task>.csv`
//! plus an ASCII sparkline so the convergence ordering is visible in the
//! bench output.  Paper shape: the efficient methods reach the long-time
//! limit in a fraction of Standard's wall-clock; Skeinformer finds equal
//! or lower validation loss.

use skeinformer::bench_util::write_csv;
use skeinformer::config::ExperimentConfig;
use skeinformer::coordinator::{run_sweep, Sweep};
use skeinformer::report;

fn sparkline(series: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if series.is_empty() {
        return String::new();
    }
    let min = series.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = series.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (max - min).max(1e-9);
    series
        .iter()
        .map(|x| BARS[(((x - min) / span) * 7.0).round() as usize])
        .collect()
}

fn main() {
    if !std::path::Path::new("artifacts/skeinformer_manifest.json").exists() {
        eprintln!("fig2_loss_curves: artifacts/ missing — run `make artifacts`; skipping");
        return;
    }
    let full = std::env::args().any(|a| a == "--full");
    let methods: Vec<&str> = if full {
        vec!["standard_nodrop", "vmean", "skeinformer", "skein_uniform", "informer",
             "linformer", "performer", "nystromformer"]
    } else {
        vec!["standard_nodrop", "skeinformer", "linformer", "vmean"]
    };
    let task = std::env::args()
        .skip_while(|a| a != "--task")
        .nth(1)
        .unwrap_or_else(|| "listops".into());

    let mut base = ExperimentConfig::default();
    base.train.max_steps = if full { 300 } else { 100 };
    base.train.eval_every = 10;
    base.train.patience = 30; // run to the step cap: we want the full curve
    base.train.eval_examples = 128;

    let sweep = Sweep::new(&methods, &[task.as_str()], base);
    let outcomes = run_sweep(&sweep, true).expect("sweep");

    println!("\n=== Figure 2: validation-loss curves ({task}) ===");
    for o in &outcomes {
        let losses: Vec<f64> = o.history.points().iter().map(|p| p.val_loss).collect();
        println!(
            "{:<18} {}  (final {:.3}, best {:.3}, {:.0}s)",
            o.method,
            sparkline(&losses),
            losses.last().copied().unwrap_or(f64::NAN),
            o.history.best_val_loss().unwrap_or(f64::NAN),
            o.seconds
        );
    }

    let (header, rows) = report::figure2_csv(&outcomes);
    let path = format!("reports/figure2_{task}.csv");
    write_csv(&path, &header, &rows).expect("csv");
    println!("-> {path}");
}
