//! Streaming-decode probe: per-token cost of append-one-token decode
//! through an [`AttentionSession`] vs recomputing the whole prefix from
//! scratch each token — the workload the Attention API v2 sessions exist
//! for.
//!
//! For each method × context length `n ∈ {512, 2048}`:
//!
//! * **session** — prefill a session with `n` tokens, then measure the
//!   steady-state decode step: one `append` + one 1-row `query`
//!   (re-pilot stride 1, the most conservative setting).
//! * **recompute** — measure one full `compute_into` over the `n×p`
//!   state: the per-token cost of the no-session serving loop, which
//!   re-runs the method on the whole prefix for every generated token.
//!
//! Reported as tokens/s; emits `reports/streaming_decode.csv`.  The gap
//! is the point: exact-incremental sessions (vmean O(p), linformer
//! O(d·p), standard O(n·p)) beat the O(n·d)–O(n²) recompute by orders of
//! magnitude, while recompute-backed sessions (skeinformer) track the
//! method's own linear cost.
//!
//! `--full` extends to n = 4096.

use skeinformer::attention::{self, AttnInputs, AttnScratch, SessionSpec};
use skeinformer::bench_util::{ascii_table, bench, write_csv, BenchConfig};
use skeinformer::rng::Rng;
use skeinformer::tensor::Matrix;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let seqs: &[usize] = if full { &[512, 2048, 4096] } else { &[512, 2048] };
    let head_dim = 32;
    let d = 64;
    let methods = ["standard", "vmean", "linformer", "skeinformer"];
    let decode_steps = 32u32;

    println!(
        "streaming decode: session append+query vs full recompute per token \
         (head_dim={head_dim}, d={d}{})",
        if full { ", --full" } else { "" }
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for &n in seqs {
        // token stream + decode queries
        let mut rng = Rng::new(7);
        let mk = |rng: &mut Rng, rows: usize| {
            let mut m = Matrix::zeros(rows, head_dim);
            rng.fill_normal(m.data_mut());
            m
        };
        let k = mk(&mut rng, n + decode_steps as usize + 8);
        let v = mk(&mut rng, n + decode_steps as usize + 8);
        let q1 = mk(&mut rng, 1);

        for name in methods {
            let method = attention::by_name(name, d).expect("registry method");

            // --- session path: steady-state decode step at context ~n ---
            let mut session =
                method.begin_session(SessionSpec::new(head_dim).with_seed(1).with_capacity_hint(n));
            for i in 0..n {
                session.append(k.row(i), v.row(i));
            }
            let mut scratch = AttnScratch::new();
            let mut out = Matrix::zeros(1, head_dim);
            let mut t = n;
            let cfg = BenchConfig { warmup_iters: 2, measure_iters: decode_steps, max_seconds: 30.0 };
            let r = bench(&format!("{name} session n{n}"), cfg, || {
                session.append(k.row(t), v.row(t));
                t += 1;
                session.query_into(&q1, &mut out, &mut scratch);
                std::hint::black_box(out.get(0, 0));
            });
            let tok_s_session = 1e3 / r.mean_ms;
            println!("{}  ->  {tok_s_session:>12.1} tok/s", r.report_line());

            // --- recompute path: full prefix recompute per token ---
            let kp = k.gather_rows(&(0..n).collect::<Vec<_>>());
            let vp = v.gather_rows(&(0..n).collect::<Vec<_>>());
            let inputs = AttnInputs::new(&q1, &kp, &vp).with_seed(1);
            let mut out_full = Matrix::zeros(1, head_dim);
            let cfg = BenchConfig {
                warmup_iters: 1,
                measure_iters: if n >= 2048 { 5 } else { 10 },
                max_seconds: 30.0,
            };
            let r2 = bench(&format!("{name} recompute n{n}"), cfg, || {
                method.compute_into(&inputs, &mut out_full, &mut scratch);
                std::hint::black_box(out_full.get(0, 0));
            });
            let tok_s_recompute = 1e3 / r2.mean_ms;
            println!("{}  ->  {tok_s_recompute:>12.1} tok/s", r2.report_line());

            rows.push(vec![
                name.to_string(),
                format!("{n}"),
                format!("{:.4}", r.mean_ms),
                format!("{tok_s_session:.1}"),
                format!("{:.4}", r2.mean_ms),
                format!("{tok_s_recompute:.1}"),
                format!("{:.1}x", tok_s_session / tok_s_recompute),
            ]);
            csv.push(format!(
                "{name},{n},{:.5},{tok_s_session:.2},{:.5},{tok_s_recompute:.2}",
                r.mean_ms, r2.mean_ms
            ));
        }
    }

    println!(
        "\n=== Streaming decode (per-token) ===\n{}",
        ascii_table(
            &["Model", "n", "session ms/tok", "session tok/s", "recompute ms/tok", "recompute tok/s", "speedup"],
            &rows
        )
    );
    write_csv(
        "reports/streaming_decode.csv",
        "method,n,session_ms_per_tok,session_tok_s,recompute_ms_per_tok,recompute_tok_s",
        &csv,
    )
    .expect("csv");
    println!("-> reports/streaming_decode.csv");
}
