//! §Perf — hot-path microbenchmarks for the optimization loop.
//!
//! Times the individual stages of the L3 skeinformer pipeline (pilot
//! matmul+softmax, probability estimation, weighted sampling, sampled
//! matmul+assemble) plus the core tensor kernels, so EXPERIMENTS.md §Perf
//! can attribute end-to-end gains to specific stages.  Also times the
//! PJRT execute round-trip when artifacts are present (the training-loop
//! hot path).

use skeinformer::attention::{AttentionMethod, Skeinformer};
use skeinformer::bench_util::{bench, BenchConfig};
use skeinformer::rng::Rng;
use skeinformer::synth_qkv::{generate, QkvConfig};
use skeinformer::tensor::{matmul, matmul_nt, softmax_rows, Matrix};

fn main() {
    let n = 2048;
    let p = 64;
    let d = 128;
    let bcfg = BenchConfig { warmup_iters: 2, measure_iters: 8, max_seconds: 60.0 };

    let mut rng = Rng::new(9);
    let (q, k, v) = generate(&QkvConfig::pretrained(n, p), &mut rng);

    // --- tensor kernels ---
    let a = Matrix::from_fn(n, p, |i, j| ((i * 13 + j) % 7) as f32 * 0.1);
    let b = Matrix::from_fn(p, n, |i, j| ((i + j * 3) % 5) as f32 * 0.1);
    println!("{}", bench("matmul (n,p)x(p,n)", bcfg, || {
        std::hint::black_box(matmul(&a, &b));
    }).report_line());
    println!("{}", bench("matmul_nt QK^T strip (n,p)x(d,p)", bcfg, || {
        let kd = k.gather_rows(&(0..d).collect::<Vec<_>>());
        std::hint::black_box(matmul_nt(&q, &kd));
    }).report_line());
    println!("{}", bench("softmax_rows (d,n)", bcfg, || {
        let mut s = Matrix::from_fn(d, n, |i, j| ((i * j) % 11) as f32 * 0.2 - 1.0);
        softmax_rows(&mut s);
        std::hint::black_box(s);
    }).report_line());

    // --- skeinformer stages ---
    let skein = Skeinformer::new(d);
    println!("{}", bench("stage: pilot (lines 1-3)", bcfg, || {
        let mut r = Rng::new(1);
        std::hint::black_box(skein.pilot(&q, &k, None, &mut r));
    }).report_line());
    let (pilot_idx, bj) = skein.pilot(&q, &k, None, &mut Rng::new(1));
    let _ = pilot_idx;
    println!("{}", bench("stage: probabilities (eq. 5)", bcfg, || {
        std::hint::black_box(Skeinformer::probabilities(&bj, &v, None));
    }).report_line());
    let weights = Skeinformer::probabilities(&bj, &v, None);
    println!("{}", bench("stage: weighted sampling (line 5)", bcfg, || {
        let mut r = Rng::new(2);
        std::hint::black_box(r.weighted_without_replacement(&weights, d));
    }).report_line());
    println!("{}", bench("skeinformer end-to-end", bcfg, || {
        let mut r = Rng::new(3);
        std::hint::black_box(skein.compute(&q, &k, &v, None, &mut r));
    }).report_line());
    println!("{}", bench("standard end-to-end (reference)", bcfg, || {
        std::hint::black_box(skeinformer::attention::Standard::exact(&q, &k, &v, None));
    }).report_line());

    // --- PJRT train-step round trip (the coordinator hot path) ---
    if std::path::Path::new("artifacts/skeinformer_manifest.json").exists() {
        use skeinformer::config::ExperimentConfig;
        use skeinformer::data::Batcher;
        use skeinformer::runtime::Runtime;
        use skeinformer::train::TrainSession;
        let rt = Runtime::cpu().expect("rt");
        let cfg = ExperimentConfig::default();
        let mut session = TrainSession::load(&rt, &cfg).expect("session");
        let task = skeinformer::data::by_name("listops", session.seq_len()).unwrap();
        let batcher = Batcher::new(task.as_ref(), session.batch(), session.seq_len());
        let mut drng = Rng::new(4);
        let batch = batcher.next_batch(&mut drng);
        session.step(&batch).expect("warmup");
        println!("{}", bench("PJRT train step (batch 32, skeinformer)", bcfg, || {
            let b = batcher.next_batch(&mut drng);
            session.step(&b).expect("step");
        }).report_line());
        println!("{}", bench("PJRT forward (batch 32)", bcfg, || {
            std::hint::black_box(session.forward(&batch).expect("fwd"));
        }).report_line());
        println!("{}", bench("data: batcher.next_batch", bcfg, || {
            std::hint::black_box(batcher.next_batch(&mut drng));
        }).report_line());
    } else {
        eprintln!("(artifacts missing — skipping PJRT round-trip benches)");
    }
}
