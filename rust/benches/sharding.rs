//! Shard-coordinator scaling probe: req/s and per-shard scheduler
//! occupancy as the cluster grows (`make shard-bench`).
//!
//! One row per cluster size in {1, 2, 4}: each run spins N engine
//! shards (each a real `net::serve`d process-in-a-thread), a
//! coordinator over them, and `--clients` concurrent connections
//! pushing the same windowed one-shot workload through the
//! coordinator's TCP front.  Heads scatter `H / N` per shard, so the
//! per-request engine work drops with N while framing/gather overhead
//! grows — the table shows where that trade crosses over for this
//! shape.  `shard-occ` is the step occupancy each shard's scheduler
//! reports, aggregated by the coordinator (weighted by steps), and
//! `shard-req` the per-shard fragment count (requests × N / N shards).
//!
//! Emits `reports/sharding.csv`
//! (`shards,method,clients,requests,req_s,p50_ms,p95_ms,shard_req,shard_occupancy`).
//!
//! Flags: `--method M` (default skeinformer), `--requests N` (default
//! 64), `--window W` in-flight per client (default 8), `--clients C`
//! (default 2), `--full` (256 requests).

use skeinformer::bench_util::{ascii_table, write_csv};
use skeinformer::cli::Args;
use skeinformer::coordinator::attention_server::{self, AttentionServerConfig, HeadsRequest};
use skeinformer::coordinator::net::{self, NetClient};
use skeinformer::coordinator::shard::Coordinator;
use skeinformer::metrics::Percentiles;
use skeinformer::rng::Rng;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn cfg(method: &str) -> AttentionServerConfig {
    AttentionServerConfig {
        method: method.to_string(),
        d: 64,
        heads: 4,
        seq: 256,
        head_dim: 32,
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        seed: 0,
        workers: None,
        queue_depth: 0,
        kv: None,
    }
}

struct Run {
    wall: f64,
    latency_ms: Vec<f64>,
    shard_requests: u64,
    shard_occupancy: f64,
}

fn run_cluster(
    c: &AttentionServerConfig,
    n_shards: usize,
    total: usize,
    clients: usize,
    window: usize,
) -> anyhow::Result<Run> {
    let shards: Vec<_> = (0..n_shards)
        .map(|i| -> anyhow::Result<_> {
            let handle = attention_server::start(c.clone())?;
            let backend =
                Arc::new(net::EngineBackend::new(&handle, i as u32, n_shards as u32));
            let server = net::serve_backend(backend, "127.0.0.1:0")?;
            let addr = server.local_addr().to_string();
            Ok((handle, server, addr))
        })
        .collect::<anyhow::Result<_>>()?;
    let addrs: Vec<String> = shards.iter().map(|(_, _, a)| a.clone()).collect();
    let coord = Coordinator::start(&addrs, Duration::from_millis(500))?;
    let front = net::serve_backend(coord.backend(), "127.0.0.1:0")?;
    let addr = front.local_addr();

    let per = total / clients;
    let elems = c.request_elems();
    let t0 = Instant::now();
    let joins: Vec<_> = (0..clients)
        .map(|ci| {
            std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
                let mut client = NetClient::connect(addr)?;
                let mut rng = Rng::new(100 + ci as u64);
                let mut latency_ms = Vec::new();
                let mut inflight = VecDeque::new();
                for _ in 0..per {
                    let req = HeadsRequest::random(elems, &mut rng);
                    inflight.push_back((client.submit_async(&req)?, Instant::now()));
                    if inflight.len() >= window {
                        let (id, sent) = inflight.pop_front().expect("non-empty window");
                        client.wait_output(id)?;
                        latency_ms.push(sent.elapsed().as_secs_f64() * 1e3);
                    }
                }
                while let Some((id, sent)) = inflight.pop_front() {
                    client.wait_output(id)?;
                    latency_ms.push(sent.elapsed().as_secs_f64() * 1e3);
                }
                Ok(latency_ms)
            })
        })
        .collect();
    let mut latency_ms = Vec::new();
    for j in joins {
        latency_ms.extend(j.join().map_err(|_| anyhow::anyhow!("client thread panicked"))??);
    }
    let wall = t0.elapsed().as_secs_f64();

    // cluster-aggregated counters before teardown: per-shard fragment
    // load and the steps-weighted mean step occupancy
    let stats = coord.stats();
    let shard_requests = stats.requests / n_shards as u64;
    let shard_occupancy = stats.mean_step_occupancy;
    front.stop();
    coord.shutdown();
    for (handle, server, _) in shards {
        server.stop();
        handle.shutdown()?;
    }
    Ok(Run { wall, latency_ms, shard_requests, shard_occupancy })
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let method = args.get_or("method", "skeinformer").to_string();
    let total = if args.switch("full") { 256 } else { args.get_usize("requests", 64)? };
    let window = args.get_usize("window", 8)?;
    let clients = args.get_usize("clients", 2)?.max(1);
    let c = cfg(&method);
    eprintln!(
        "sharding bench: method={method} requests={total} clients={clients} window={window} \
         shape B<={} H={} n={} p={}",
        c.max_batch, c.heads, c.seq, c.head_dim
    );

    let mut table = Vec::new();
    let mut csv = Vec::new();
    for n_shards in [1usize, 2, 4] {
        let run = run_cluster(&c, n_shards, total, clients, window)?;
        let served = run.latency_ms.len();
        let mut lat = Percentiles::default();
        for &ms in &run.latency_ms {
            lat.push(ms);
        }
        let req_s = served as f64 / run.wall;
        table.push(vec![
            format!("{n_shards}"),
            format!("{served}"),
            format!("{req_s:.1}"),
            format!("{:.2}", lat.percentile(50.0)),
            format!("{:.2}", lat.percentile(95.0)),
            format!("{}", run.shard_requests),
            format!("{:.3}", run.shard_occupancy),
        ]);
        csv.push(format!(
            "{n_shards},{method},{clients},{served},{req_s:.2},{:.3},{:.3},{},{:.4}",
            lat.percentile(50.0),
            lat.percentile(95.0),
            run.shard_requests,
            run.shard_occupancy
        ));
    }
    println!(
        "{}",
        ascii_table(
            &["shards", "served", "req/s", "p50 ms", "p95 ms", "shard-req", "shard-occ"],
            &table
        )
    );
    write_csv(
        "reports/sharding.csv",
        "shards,method,clients,requests,req_s,p50_ms,p95_ms,shard_req,shard_occupancy",
        &csv,
    )?;
    eprintln!("rows written to reports/sharding.csv");
    Ok(())
}
