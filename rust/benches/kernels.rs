//! §Kernels — per-kernel, per-ISA throughput sweep.
//!
//! Times every microkernel in the dispatch table ([`kernels::table_for`],
//! so all supported ISAs are measured in one run without touching the
//! process-wide selection) across vector lengths bracketing the head
//! dims the attention hot paths use (p ∈ {32..256}) and the longer rows
//! of the softmax/dequant passes.  A reimplementation of the seed's
//! 4-way unrolled scalar dot rides along as the `legacy4` baseline —
//! the acceptance bar for the SIMD work is AVX2 dot ≥ 2× `legacy4` at
//! d ∈ {64, 128}.
//!
//! Emits `reports/kernels.csv` (`kernel,isa,len,ns_per_call,gops`);
//! `gops` is GFLOP/s for the arithmetic kernels (2 flops/element for
//! dot/saxpy/sum_sq, 1 for row_sum/row_max/scale) and Gelem/s for
//! `exp_shifted` and the dequant decoders.  Run via `make kernel-bench`
//! (which builds `--features simd`; without the feature only the
//! scalar rows appear).

use skeinformer::bench_util::write_csv;
use skeinformer::rng::Rng;
use skeinformer::tensor::kernels::{self, KernelIsa, KernelTable};
use std::hint::black_box;
use std::time::Instant;

/// ns per call, best of 5 trials of `reps` calls each.
fn time_ns(mut f: impl FnMut(), reps: u32) -> f64 {
    f(); // warm
    let mut best = f64::INFINITY;
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..reps {
            f();
        }
        best = best.min(t0.elapsed().as_nanos() as f64 / reps as f64);
    }
    best
}

fn reps_for(len: usize) -> u32 {
    (16_000_000 / len.max(1)).clamp(2_000, 1_000_000) as u32
}

fn gen(len: usize, seed: u64) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    Rng::new(seed).fill_normal(&mut v);
    v
}

/// The seed's inner dot kernel (pre-microkernel `matmul_nt`): 4-way
/// unrolled scalar accumulation.  Kept here verbatim as the before
/// baseline the CSV compares every ISA against.
fn legacy_dot4(a: &[f32], b: &[f32]) -> f32 {
    let k = a.len();
    let chunks = k / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let o = c * 4;
        s0 += a[o] * b[o];
        s1 += a[o + 1] * b[o + 1];
        s2 += a[o + 2] * b[o + 2];
        s3 += a[o + 3] * b[o + 3];
    }
    let mut acc = (s0 + s2) + (s1 + s3);
    for o in chunks * 4..k {
        acc += a[o] * b[o];
    }
    acc
}

struct Sink {
    rows: Vec<String>,
}

impl Sink {
    fn emit(&mut self, kernel: &str, isa: &str, len: usize, ns: f64, flops_per_elem: f64) {
        let gops = flops_per_elem * len as f64 / ns;
        println!("{kernel:<12} {isa:<8} len={len:<6} {ns:>9.2} ns/call  {gops:>7.3} Gop/s");
        self.rows.push(format!("{kernel},{isa},{len},{ns:.2},{gops:.3}"));
    }
}

fn main() {
    let tables: Vec<&'static KernelTable> =
        KernelIsa::ALL.iter().filter_map(|&isa| kernels::table_for(isa)).collect();
    println!(
        "kernel sweep: active={} available={:?} (simd feature {})",
        kernels::active_isa(),
        tables.iter().map(|t| t.isa.name()).collect::<Vec<_>>(),
        if cfg!(feature = "simd") { "on" } else { "off" }
    );
    let mut sink = Sink { rows: Vec::new() };

    // --- dot (the matmul_nt / matvec inner loop) ---
    for &len in &[32usize, 64, 128, 256, 1024, 4096] {
        let a = gen(len, 1);
        let b = gen(len, 2);
        let reps = reps_for(len);
        let ns = time_ns(|| { black_box(legacy_dot4(black_box(&a), black_box(&b))); }, reps);
        sink.emit("dot", "legacy4", len, ns, 2.0);
        for t in &tables {
            let ns = time_ns(|| { black_box((t.dot)(black_box(&a), black_box(&b))); }, reps);
            sink.emit("dot", t.isa.name(), len, ns, 2.0);
        }
    }

    // --- element-wise streams ---
    for &len in &[128usize, 1024, 4096] {
        let x = gen(len, 3);
        let reps = reps_for(len);
        for t in &tables {
            let mut y = gen(len, 4);
            // coefficient 0 keeps y numerically stable across reps
            let ns = time_ns(|| (t.saxpy)(black_box(0.0), black_box(&x), &mut y), reps);
            sink.emit("saxpy", t.isa.name(), len, ns, 2.0);
            let mut s = gen(len, 5);
            let ns = time_ns(|| (t.scale)(black_box(&mut s), black_box(1.0)), reps);
            sink.emit("scale", t.isa.name(), len, ns, 1.0);
            // shift 90 drives every element to exactly 0, a fixed point
            // of the kernel, so reps measure a steady state
            let mut e = gen(len, 6);
            let ns = time_ns(|| (t.exp_shifted)(black_box(&mut e), black_box(90.0)), reps);
            sink.emit("exp_shifted", t.isa.name(), len, ns, 1.0);
        }
    }

    // --- row reductions (softmax / norms passes) ---
    for &len in &[128usize, 1024, 4096] {
        let x = gen(len, 7);
        let reps = reps_for(len);
        for t in &tables {
            let ns = time_ns(|| { black_box((t.row_sum)(black_box(&x))); }, reps);
            sink.emit("row_sum", t.isa.name(), len, ns, 1.0);
            let ns = time_ns(|| { black_box((t.row_max)(black_box(&x))); }, reps);
            sink.emit("row_max", t.isa.name(), len, ns, 1.0);
            let ns = time_ns(|| { black_box((t.sum_sq)(black_box(&x))); }, reps);
            sink.emit("sum_sq", t.isa.name(), len, ns, 2.0);
        }
    }

    // --- dequantise (tiered KV gather path) ---
    for &len in &[64usize, 1024] {
        let halfs: Vec<u16> =
            gen(len, 8).iter().map(|&x| skeinformer::kvcache::f32_to_f16_bits(x)).collect();
        let signed: Vec<i8> = (0..len).map(|i| (i * 5 % 256) as u8 as i8).collect();
        let mut out = vec![0.0f32; len];
        let reps = reps_for(len);
        for t in &tables {
            let ns = time_ns(|| (t.dequant_f16)(black_box(&halfs), &mut out), reps);
            sink.emit("dequant_f16", t.isa.name(), len, ns, 1.0);
            let ns = time_ns(|| (t.dequant_i8)(black_box(&signed), black_box(0.0625), &mut out), reps);
            sink.emit("dequant_i8", t.isa.name(), len, ns, 1.0);
        }
    }

    write_csv("reports/kernels.csv", "kernel,isa,len,ns_per_call,gops", &sink.rows)
        .expect("write reports/kernels.csv");
    println!("-> reports/kernels.csv");
}
