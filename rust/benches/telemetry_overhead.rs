//! Telemetry-overhead probe: what span tracing and the metrics
//! registry cost on the serving hot path (`make obs-bench`).
//!
//! Three rows, all submitting the same in-process one-shot workload
//! (transport-free, so instrumentation cost is maximally visible — any
//! socket hop would dwarf it):
//!
//! * **off** — `--no-telemetry` semantics: every record site is one
//!   untaken branch, no clock reads.
//! * **on** — metrics + flight recorder live: two clock reads and a
//!   ring write per span, histogram `fetch_add`s per sample.
//! * **on+trace** — as **on**, plus a live consumer thread draining
//!   the ring (`snapshot`) and rendering the Prometheus exposition
//!   every 50 ms, the cost a `--metrics-addr` scraper plus
//!   `--trace-out` drain adds while serving.
//!
//! The engine work is identical in every row (same shape, same seeds
//! by lifetime batch index — telemetry never touches RNG state, pinned
//! by `rust/tests/telemetry.rs`), so the req/s deltas are pure
//! instrumentation overhead.
//!
//! Emits `reports/telemetry.csv`
//! (`mode,method,requests,req_s,p50_ms,p95_ms,overhead_pct,spans,dropped`).
//!
//! Flags: `--method M` (default skeinformer), `--requests N` (default
//! 64), `--window W` in-flight (default 8), `--full` (256 requests).

use skeinformer::bench_util::{ascii_table, write_csv};
use skeinformer::cli::Args;
use skeinformer::coordinator::attention_server::{self, AttentionServerConfig, HeadsRequest};
use skeinformer::metrics::Percentiles;
use skeinformer::obs::ServeTelemetry;
use skeinformer::rng::Rng;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn cfg(method: &str) -> AttentionServerConfig {
    AttentionServerConfig {
        method: method.to_string(),
        d: 64,
        heads: 4,
        seq: 256,
        head_dim: 32,
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        seed: 0,
        workers: None,
        queue_depth: 0,
        kv: None,
    }
}

struct Run {
    wall: f64,
    latency_ms: Vec<f64>,
    spans: u64,
    dropped: u64,
}

/// One serving run with the given telemetry bundle; `drain` adds the
/// live scrape/trace consumer thread.
fn run(
    c: &AttentionServerConfig,
    total: usize,
    window: usize,
    obs: Arc<ServeTelemetry>,
    drain: bool,
) -> anyhow::Result<Run> {
    let handle = attention_server::start_with_telemetry(c.clone(), Arc::clone(&obs))?;
    let stop = Arc::new(AtomicBool::new(false));
    let consumer = drain.then(|| {
        let obs = Arc::clone(&obs);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut scrapes = 0u64;
            while !stop.load(Ordering::Relaxed) {
                // what a Prometheus scraper + trace drain cost mid-run
                let _ = obs.render().len();
                let _ = obs.recorder().snapshot().len();
                scrapes += 1;
                std::thread::sleep(Duration::from_millis(50));
            }
            scrapes
        })
    });

    let mut rng = Rng::new(100);
    let mut latency_ms = Vec::new();
    let mut inflight = VecDeque::new();
    let t0 = Instant::now();
    for _ in 0..total {
        let req = HeadsRequest::random(c.request_elems(), &mut rng);
        inflight.push_back((handle.submit(req), Instant::now()));
        if inflight.len() >= window {
            let (rx, sent) = inflight.pop_front().expect("non-empty window");
            rx.recv()?;
            latency_ms.push(sent.elapsed().as_secs_f64() * 1e3);
        }
    }
    while let Some((rx, sent)) = inflight.pop_front() {
        rx.recv()?;
        latency_ms.push(sent.elapsed().as_secs_f64() * 1e3);
    }
    let wall = t0.elapsed().as_secs_f64();

    stop.store(true, Ordering::Relaxed);
    if let Some(j) = consumer {
        let scrapes = j.join().map_err(|_| anyhow::anyhow!("consumer thread panicked"))?;
        eprintln!("  (consumer drained {scrapes} scrape+trace cycles mid-run)");
    }
    handle.shutdown()?;
    Ok(Run { wall, latency_ms, spans: obs.recorder().recorded(), dropped: obs.recorder().dropped() })
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let method = args.get_or("method", "skeinformer").to_string();
    let total = if args.switch("full") { 256 } else { args.get_usize("requests", 64)? };
    let window = args.get_usize("window", 8)?;
    let c = cfg(&method);
    eprintln!(
        "telemetry-overhead bench: method={method} requests={total} window={window} \
         shape B<={} H={} n={} p={}",
        c.max_batch, c.heads, c.seq, c.head_dim
    );

    let mut table = Vec::new();
    let mut csv = Vec::new();
    let mut base_req_s = 0.0f64;
    for (mode, enabled, drain) in
        [("off", false, false), ("on", true, false), ("on+trace", true, true)]
    {
        let r = run(&c, total, window, ServeTelemetry::new(enabled), drain)?;
        let served = r.latency_ms.len();
        let mut lat = Percentiles::default();
        for &ms in &r.latency_ms {
            lat.push(ms);
        }
        let req_s = served as f64 / r.wall;
        if mode == "off" {
            base_req_s = req_s;
        }
        // throughput lost vs the kill-switched baseline (negative =
        // faster than baseline, i.e. noise floor)
        let overhead_pct = 100.0 * (base_req_s - req_s) / base_req_s;
        table.push(vec![
            mode.to_string(),
            format!("{served}"),
            format!("{req_s:.1}"),
            format!("{:.2}", lat.percentile(50.0)),
            format!("{:.2}", lat.percentile(95.0)),
            format!("{overhead_pct:+.1}%"),
            format!("{}", r.spans),
            format!("{}", r.dropped),
        ]);
        csv.push(format!(
            "{mode},{method},{served},{req_s:.2},{:.3},{:.3},{overhead_pct:.2},{},{}",
            lat.percentile(50.0),
            lat.percentile(95.0),
            r.spans,
            r.dropped
        ));
    }
    println!(
        "{}",
        ascii_table(
            &["mode", "served", "req/s", "p50 ms", "p95 ms", "overhead", "spans", "dropped"],
            &table
        )
    );
    write_csv(
        "reports/telemetry.csv",
        "mode,method,requests,req_s,p50_ms,p95_ms,overhead_pct,spans,dropped",
        &csv,
    )?;
    eprintln!("rows written to reports/telemetry.csv");
    Ok(())
}
