//! E6 — Table 5: leading-term FLOPs of each attention method.
//!
//! Prints the symbolic leading terms exactly as the paper's Appendix A.2
//! reports them (p = 32, d = 256), evaluates them at n ∈ {1024, 4096},
//! and cross-checks the analytic model against *measured* wall-clock of
//! the pure-rust implementations (time should scale like the FLOPs model:
//! standard grows ~quadratically between the two n, the O(n log n) group
//! ~linearly).

use skeinformer::attention::{by_name, registry};
use skeinformer::bench_util::{ascii_table, bench, write_csv, BenchConfig};
use skeinformer::flops::{leading_flops, leading_flops_symbolic};
use skeinformer::rng::Rng;
use skeinformer::synth_qkv::{generate, QkvConfig};

fn main() {
    let d = 256u64;
    let p = 32u64;

    // --- the symbolic table, verbatim ---
    let mut rows = Vec::new();
    for m in ["standard", "bigbird", "performer", "nystromformer", "linformer", "informer",
              "skeinformer"] {
        rows.push(vec![
            m.to_string(),
            leading_flops_symbolic(m).unwrap().to_string(),
            format!("{:.2}G", leading_flops(m, 1024, d, p).unwrap() as f64 / 1e9),
            format!("{:.2}G", leading_flops(m, 4096, d, p).unwrap() as f64 / 1e9),
        ]);
    }
    rows.push(vec!["reformer".into(), "input-dependent".into(), "-".into(), "-".into()]);
    println!(
        "=== Table 5 (leading FLOPs terms, p={p}, d={d}) ===\n{}",
        ascii_table(&["Model", "Leading term", "n=1024", "n=4096"], &rows)
    );

    // --- measured scaling cross-check ---
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: [usize; 2] = if quick { [512, 1024] } else { [1024, 4096] };
    println!("measured wall-clock of the rust implementations (d=256):");
    let mut csv = Vec::new();
    let bcfg = BenchConfig { warmup_iters: 1, measure_iters: if quick { 3 } else { 5 }, max_seconds: 60.0 };
    for name in ["standard", "skeinformer", "informer", "linformer", "performer",
                 "nystromformer", "bigbird"] {
        let mut times = Vec::new();
        for &n in &sizes {
            let method = by_name(name, 256).unwrap();
            let mut rng = Rng::new(5);
            let (q, k, v) = generate(&QkvConfig::pretrained(n, p as usize), &mut rng);
            let r = bench(&format!("{name}@n={n}"), bcfg, || {
                let out = method.compute(&q, &k, &v, None, &mut Rng::new(1));
                std::hint::black_box(out);
            });
            println!("  {}", r.report_line());
            times.push(r.mean_ms);
        }
        let measured_ratio = times[1] / times[0].max(1e-9);
        let model_ratio = leading_flops(name, sizes[1] as u64, d, p).unwrap() as f64
            / leading_flops(name, sizes[0] as u64, d, p).unwrap() as f64;
        println!(
            "    time ratio n{}→n{}: measured {measured_ratio:.1}x, FLOPs model {model_ratio:.1}x",
            sizes[0], sizes[1]
        );
        csv.push(format!(
            "{name},{},{},{:.3},{:.3},{measured_ratio:.3},{model_ratio:.3}",
            sizes[0], sizes[1], times[0], times[1]
        ));
    }
    write_csv(
        "reports/table5_flops.csv",
        "method,n_small,n_large,ms_small,ms_large,measured_ratio,model_ratio",
        &csv,
    )
    .expect("csv");
    println!("-> reports/table5_flops.csv");

    // also dump the full registry at d for completeness
    let _ = registry(256);
}
