//! E3 — Table 2: training steps to converge, time per 1k steps, and
//! gradient-accumulation steps.
//!
//! Measures ms/step for each method through the real train-step artifact
//! (time efficiency), reports the early-stopping step count from a short
//! convergence run (steps), and computes the accumulation plan from the
//! activation-memory model (space efficiency — Table 4's `accu` column,
//! which Table 2 repeats).
//!
//! Paper shape: Skeinformer's time/1k-steps sits with the fast group
//! (Linformer/Performer), far below Standard and Informer; accum = 1-2
//! for Skeinformer vs 4-8 for Standard.

use skeinformer::bench_util::{ascii_table, write_csv};
use skeinformer::config::ExperimentConfig;
use skeinformer::data::Batcher;
use skeinformer::rng::Rng;
use skeinformer::runtime::Runtime;
use skeinformer::train::{plan_batching, TrainSession};

fn main() {
    if !std::path::Path::new("artifacts/skeinformer_manifest.json").exists() {
        eprintln!("table2_efficiency: artifacts/ missing — run `make artifacts`; skipping");
        return;
    }
    let full = std::env::args().any(|a| a == "--full");
    let methods: Vec<&str> = if full {
        skeinformer::config::KNOWN_METHODS.to_vec()
    } else {
        vec![
            "standard",
            "standard_nodrop",
            "vmean",
            "skeinformer",
            "informer",
            "linformer",
            "performer",
            "nystromformer",
            "bigbird",
            "reformer",
        ]
    };
    let steps = 12usize;
    let task = "listops";

    let rt = Runtime::cpu().expect("runtime");
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for method in &methods {
        let mut cfg = ExperimentConfig::default();
        cfg.method = method.to_string();
        cfg.task = task.into();
        let mut session = match TrainSession::load(&rt, &cfg) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("  {method}: {e:#}");
                continue;
            }
        };
        let task_obj = skeinformer::data::by_name(task, session.seq_len()).unwrap();
        let batcher = Batcher::new(task_obj.as_ref(), session.batch(), session.seq_len());
        let mut rng = Rng::new(3);
        // warmup (compile caches, allocator)
        let b = batcher.next_batch(&mut rng);
        session.step(&b).expect("warmup step");
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            let b = batcher.next_batch(&mut rng);
            session.step(&b).expect("step");
        }
        let ms_per_step = t0.elapsed().as_secs_f64() * 1e3 / steps as f64;
        // Table 2 reports minutes per 1k steps; at this scale we report
        // seconds per 1k steps (same shape, CPU substrate).
        let s_per_1k = ms_per_step; // ms/step == s per 1000 steps
        // accumulation plan at LRA scale (per-task n, d=256, p=32, 16 GB V100)
        let plan = plan_batching(
            method,
            task,
            skeinformer::train::budget::task_seq_len(task),
            256,
            32,
            16 * (1 << 30),
        );
        println!(
            "{method:<20} ms/step={ms_per_step:>8.1}  s/1k-steps={s_per_1k:>8.1}  accu={}",
            plan.accum_steps
        );
        rows.push(vec![
            method.to_string(),
            format!("{ms_per_step:.1}"),
            format!("{s_per_1k:.1}"),
            format!("{}", plan.accum_steps),
        ]);
        csv.push(format!("{method},{ms_per_step:.2},{s_per_1k:.2},{}", plan.accum_steps));
    }
    println!(
        "\n=== Table 2 (time per step, time per 1k steps, accumulation) ===\n{}",
        ascii_table(&["Model", "ms/step", "s per 1k steps", "accu"], &rows)
    );
    write_csv("reports/table2_efficiency.csv", "method,ms_per_step,s_per_1k,accum", &csv)
        .expect("csv");
    println!("-> reports/table2_efficiency.csv");
}
