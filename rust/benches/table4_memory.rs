//! E5 — Table 4: actual batch size under the memory budget, with
//! gradient-accumulation steps to reach each task's effective batch.
//!
//! Driven by the analytic activation-memory model (`flops.rs`) at the
//! paper's scale (n = 1024, d = 256, p = 32, 16 GB device).  Paper shape
//! to verify: Skeinformer / Linformer / V-Mean run the full effective
//! batch (accu = 1-2); Standard and the unreduced JLT need 4-16×
//! accumulation; the no-row-norm ablation is worse than the full method.

use skeinformer::bench_util::{ascii_table, write_csv};
use skeinformer::data::TASK_NAMES;
use skeinformer::train::{
    budget::{effective_batch, task_seq_len},
    plan_batching,
};

fn main() {
    let d = 256u64;
    let p = 32u64;
    let budget = 16u64 << 30; // 16 GB V100

    println!("Table 4: actual batch size (bz) and accumulation (accu) under {}GB", budget >> 30);
    let mut headers = vec!["Model".to_string()];
    for t in TASK_NAMES {
        headers.push(format!("{t}({}) bz", effective_batch(t)));
        headers.push("accu".into());
    }
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for method in skeinformer::config::KNOWN_METHODS {
        let mut row = vec![method.to_string()];
        let mut csv_row = method.to_string();
        for task in TASK_NAMES {
            let plan = plan_batching(method, task, task_seq_len(task), d, p, budget);
            row.push(format!("{}", plan.actual_batch));
            row.push(format!("{}", plan.accum_steps));
            csv_row.push_str(&format!(",{},{}", plan.actual_batch, plan.accum_steps));
        }
        rows.push(row);
        csv.push(csv_row);
    }
    println!("{}", ascii_table(&header_refs, &rows));

    // shape checks against the paper's Table 4
    let check = |m: &str, t: &str| plan_batching(m, t, task_seq_len(t), d, p, budget);
    let skein = check("skeinformer", "text");
    let std = check("standard", "text");
    let jlt = check("linformer_jlt", "text");
    println!(
        "shape: skeinformer accu {} <= standard accu {} <= unreduced-JLT accu {}",
        skein.accum_steps, std.accum_steps, jlt.accum_steps
    );
    assert!(skein.accum_steps <= std.accum_steps);
    assert!(skein.actual_batch >= std.actual_batch);

    let mut header = "method".to_string();
    for t in TASK_NAMES {
        header.push_str(&format!(",{t}_bz,{t}_accu"));
    }
    write_csv("reports/table4_memory.csv", &header, &csv).expect("csv");
    println!("-> reports/table4_memory.csv");
}
