//! Experiment coordinator: sweeps (method × task) experiments, collects
//! [`TrainOutcome`]s, and renders the paper's tables.  This is the L3
//! entrypoint the `skein` CLI and the table benches drive.
//!
//! Two serving paths live here: [`server`] (token sequences through the
//! AOT/PJRT artifacts) and [`attention_server`] (raw Q/K/V head slabs
//! through the pure-rust [`crate::attention::BatchedAttention`] engine —
//! no artifacts required).  [`net`] puts a TCP front end on the latter:
//! a length-prefixed binary wire protocol whose f32 payloads land
//! directly in `Arc<[f32]>` slabs, preserving the zero-copy path end to
//! end (`skein serve --listen` / `skein client`).  [`shard`] scales
//! that front end across processes: a coordinator scatters head ranges
//! over N engine shards and gathers the replies, speaking the same
//! wire protocol on both sides (`skein coordinator`).

pub mod attention_server;
pub mod net;
pub mod server;
pub mod shard;

use crate::config::ExperimentConfig;
use crate::runtime::Runtime;
use crate::train::{run_experiment, TrainOutcome};
use anyhow::Result;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Dynamic-batching collect shared by both serving paths: block for the
/// first request, then drain the queue until `max` requests are pending
/// or `max_wait` has elapsed — "wait for a full batch, else flush".
/// Returns `None` when every sender has dropped (server shutdown).
pub(crate) fn collect_batch<T>(
    rx: &mpsc::Receiver<T>,
    max: usize,
    max_wait: Duration,
) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut pending = vec![first];
    let deadline = Instant::now() + max_wait;
    while pending.len() < max {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(r) => pending.push(r),
            Err(_) => break, // timeout or disconnect: flush what we have
        }
    }
    Some(pending)
}

/// A sweep request: the cross product of methods and tasks.
#[derive(Clone, Debug)]
pub struct Sweep {
    pub methods: Vec<String>,
    pub tasks: Vec<String>,
    pub base: ExperimentConfig,
}

impl Sweep {
    pub fn new(methods: &[&str], tasks: &[&str], base: ExperimentConfig) -> Self {
        Self {
            methods: methods.iter().map(|s| s.to_string()).collect(),
            tasks: tasks.iter().map(|s| s.to_string()).collect(),
            base,
        }
    }

    /// Expand into per-experiment configs.
    pub fn configs(&self) -> Vec<ExperimentConfig> {
        let mut out = Vec::with_capacity(self.methods.len() * self.tasks.len());
        for task in &self.tasks {
            for method in &self.methods {
                let mut cfg = self.base.clone();
                cfg.method = method.clone();
                cfg.task = task.clone();
                out.push(cfg);
            }
        }
        out
    }
}

/// Run a sweep sequentially (PJRT clients are not `Send`; experiment-level
/// parallelism would need one process per worker) with progress logging.
pub fn run_sweep(sweep: &Sweep, verbose: bool) -> Result<Vec<TrainOutcome>> {
    let rt = Runtime::cpu()?;
    let configs = sweep.configs();
    let total = configs.len();
    let mut outcomes = Vec::with_capacity(total);
    for (i, cfg) in configs.iter().enumerate() {
        if verbose {
            eprintln!("[sweep {}/{}] {} on {}", i + 1, total, cfg.method, cfg.task);
        }
        let outcome = run_experiment(&rt, cfg)?;
        if verbose {
            eprintln!(
                "    steps={} best_acc={:.4} {:.1}s ({:.1} ms/step)",
                outcome.steps, outcome.best_accuracy, outcome.seconds, outcome.ms_per_step
            );
        }
        outcomes.push(outcome);
    }
    Ok(outcomes)
}

/// Group outcomes as (task → method → outcome) for table rendering.
pub fn index_outcomes<'a>(
    outcomes: &'a [TrainOutcome],
) -> std::collections::BTreeMap<&'a str, std::collections::BTreeMap<&'a str, &'a TrainOutcome>> {
    let mut map: std::collections::BTreeMap<&str, std::collections::BTreeMap<&str, &TrainOutcome>> =
        Default::default();
    for o in outcomes {
        map.entry(o.task.as_str()).or_default().insert(o.method.as_str(), o);
    }
    map
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::History;

    #[test]
    fn sweep_expands_cross_product() {
        let sweep = Sweep::new(
            &["skeinformer", "standard"],
            &["listops", "text"],
            ExperimentConfig::default(),
        );
        let cfgs = sweep.configs();
        assert_eq!(cfgs.len(), 4);
        assert!(cfgs.iter().any(|c| c.method == "standard" && c.task == "text"));
        for c in &cfgs {
            c.validate().unwrap();
        }
    }

    #[test]
    fn outcome_indexing() {
        let mk = |method: &str, task: &str, acc: f64| TrainOutcome {
            method: method.into(),
            task: task.into(),
            steps: 10,
            best_accuracy: acc,
            final_accuracy: acc,
            seconds: 1.0,
            ms_per_step: 100.0,
            grad_accum: 1,
            history: History::new(),
        };
        let outcomes = vec![
            mk("skeinformer", "listops", 0.4),
            mk("standard", "listops", 0.35),
            mk("skeinformer", "text", 0.7),
        ];
        let idx = index_outcomes(&outcomes);
        assert_eq!(idx["listops"]["skeinformer"].best_accuracy, 0.4);
        assert_eq!(idx["text"].len(), 1);
    }
}
