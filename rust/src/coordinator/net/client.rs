//! Small blocking TCP client for the wire protocol — what `skein
//! client`, the shard coordinator's tests, and the socket round-trip
//! tests/benches use.
//!
//! One [`NetClient`] owns one connection.  Ops map one-to-one onto
//! [`ClientFrame`](super::wire::ClientFrame)s; replies are matched by
//! request id.  Because one connection is one server-side fairness lane
//! and the scheduler preserves per-lane order, replies for pipelined
//! requests arrive in submission order — [`NetClient::submit_async`] /
//! [`NetClient::wait_output`] exploit that for throughput benching,
//! while the plain methods are strictly call-and-wait.
//!
//! Server-side rejections surface as [`ClientError::Rejected`] carrying
//! the wire error code: 0 is a framing error, `1..` are
//! [`ServeError::code`](crate::coordinator::attention_server::ServeError::code)
//! values — never a hang or an opaque `RecvError` panic.
//!
//! # Timeouts and liveness
//!
//! Every socket op is bounded by [`NetTimeouts`] (connect, read,
//! write); a dead peer can never hang a blocking call forever.  A read
//! timeout alone does not fail the op: the server may simply be deep in
//! a batch.  The client sends one `Ping` probe instead — the server
//! answers pongs straight from its read loop, so *any* arriving frame
//! proves liveness and the wait continues.  Only a second silent
//! timeout (probe unanswered) reports [`ClientError::TimedOut`].
//! `Pong` frames can overtake compute replies for the same reason, so
//! the reply reader skips them wherever they appear.

use super::wire::{
    encode_append, encode_close, encode_open, encode_open_with_stream, encode_ping,
    encode_prefill, encode_query, encode_stats_req, encode_submit, encode_submit_routed,
    read_hello, read_server_frame, read_server_frame_or_idle, write_hello, FrameError,
    ServerFrame, ServerInfo, ServerRead, StatsWire,
};
use crate::coordinator::attention_server::{AttentionServerStats, HeadsRequest, SubmitRoute};
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Socket deadlines for one [`NetClient`] connection.
#[derive(Clone, Copy, Debug)]
pub struct NetTimeouts {
    /// TCP connect deadline (per resolved address).
    pub connect: Duration,
    /// Read deadline per wait window; a first expiry triggers a ping
    /// probe, a second reports [`ClientError::TimedOut`].
    pub read: Duration,
    /// Write deadline for sending one frame.
    pub write: Duration,
}

impl Default for NetTimeouts {
    fn default() -> Self {
        NetTimeouts {
            connect: Duration::from_secs(5),
            read: Duration::from_secs(10),
            write: Duration::from_secs(10),
        }
    }
}

/// Client-side failure modes.
#[derive(Debug)]
pub enum ClientError {
    /// Socket-level failure (includes the server disconnecting).
    Io(io::Error),
    /// The byte stream violated the protocol (bad magic/version, unknown
    /// frame kind, reply for a request we never made…).
    Protocol(String),
    /// The server answered with a typed error frame: `code` 0 is a
    /// wire-level framing error, `1..` are `ServeError::code` values.
    Rejected { code: u8, message: String },
    /// The peer stayed silent past the read timeout *and* ignored a
    /// ping probe — presumed dead (a merely busy server answers pongs
    /// from its read loop).
    TimedOut,
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io error: {e}"),
            ClientError::Protocol(s) => write!(f, "protocol error: {s}"),
            ClientError::Rejected { code, message } => {
                write!(f, "rejected (code {code}): {message}")
            }
            ClientError::TimedOut => {
                write!(f, "peer silent past the read timeout (ping probe unanswered)")
            }
        }
    }
}

impl std::error::Error for ClientError {}

impl From<io::Error> for ClientError {
    fn from(e: io::Error) -> Self {
        ClientError::Io(e)
    }
}

impl From<FrameError> for ClientError {
    fn from(e: FrameError) -> Self {
        ClientError::Protocol(e.to_string())
    }
}

/// A blocking connection to a `skein serve --listen` front end (or a
/// `skein coordinator` presenting a whole cluster behind the same
/// protocol).
pub struct NetClient {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
    info: ServerInfo,
    next_id: u64,
}

impl NetClient {
    /// Connect and handshake with [`NetTimeouts::default`]; returns
    /// once the server's config frame (its served shape) has been
    /// received.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self, ClientError> {
        Self::connect_with(addr, NetTimeouts::default())
    }

    /// [`connect`](Self::connect) with explicit deadlines.  Resolution
    /// happens up front so the connect timeout applies per address; the
    /// read/write deadlines stay armed on the socket for the
    /// connection's whole life.
    pub fn connect_with(
        addr: impl ToSocketAddrs,
        timeouts: NetTimeouts,
    ) -> Result<Self, ClientError> {
        let mut last_err: Option<io::Error> = None;
        let mut sock = None;
        for resolved in addr.to_socket_addrs()? {
            match TcpStream::connect_timeout(&resolved, timeouts.connect) {
                Ok(s) => {
                    sock = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let sock = match sock {
            Some(s) => s,
            None => {
                return Err(last_err
                    .unwrap_or_else(|| {
                        io::Error::new(io::ErrorKind::InvalidInput, "address resolved to nothing")
                    })
                    .into())
            }
        };
        let _ = sock.set_nodelay(true);
        sock.set_read_timeout(Some(timeouts.read))?;
        sock.set_write_timeout(Some(timeouts.write))?;
        let mut w = BufWriter::new(sock.try_clone()?);
        write_hello(&mut w)?;
        w.flush()?;
        let mut r = BufReader::new(sock);
        read_hello(&mut r)?;
        let info = match read_server_frame(&mut r)? {
            ServerFrame::Config(info) => info,
            other => {
                return Err(ClientError::Protocol(format!(
                    "expected config frame after hello, got {other:?}"
                )))
            }
        };
        Ok(NetClient { r, w, info, next_id: 0 })
    }

    /// The served shape advertised in the handshake.
    pub fn info(&self) -> &ServerInfo {
        &self.info
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn send(&mut self, frame: Vec<u8>) -> Result<(), ClientError> {
        self.w.write_all(&frame)?;
        self.w.flush()?;
        Ok(())
    }

    /// Read one frame, absorbing read timeouts with the ping-probe
    /// discipline (see the [module docs](self)).
    fn read_frame(&mut self) -> Result<ServerFrame, ClientError> {
        let mut probed = false;
        loop {
            match read_server_frame_or_idle(&mut self.r) {
                Ok(ServerRead::Frame(frame)) => return Ok(frame),
                Ok(ServerRead::Idle) => {
                    if probed {
                        return Err(ClientError::TimedOut);
                    }
                    probed = true;
                    let id = self.fresh_id();
                    self.send(encode_ping(id))?;
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Read replies until `want`'s arrives.  An error frame for an
    /// *earlier* pipelined op (e.g. a rejected fire-and-forget append)
    /// also surfaces here as [`ClientError::Rejected`] — failures are
    /// reported, never swallowed.  Pong frames (answers to our idle
    /// probes, delivered out of band by the server's read loop) are
    /// skipped.
    fn read_reply(&mut self, want: u64) -> Result<ServerFrame, ClientError> {
        loop {
            match self.read_frame()? {
                ServerFrame::Pong { .. } => continue,
                ServerFrame::Error { id, code, message } => {
                    let prefix = if id == want { String::new() } else { format!("op {id}: ") };
                    return Err(ClientError::Rejected {
                        code,
                        message: format!("{prefix}{message}"),
                    });
                }
                frame @ (ServerFrame::Output { .. }
                | ServerFrame::OpenOk { .. }
                | ServerFrame::StatsOk { .. }) => {
                    let id = match &frame {
                        ServerFrame::Output { id, .. }
                        | ServerFrame::OpenOk { id, .. }
                        | ServerFrame::StatsOk { id, .. } => *id,
                        _ => unreachable!(),
                    };
                    return if id == want {
                        Ok(frame)
                    } else {
                        Err(ClientError::Protocol(format!(
                            "reply for request {id} while awaiting {want}"
                        )))
                    };
                }
                ServerFrame::Config(_) => {
                    return Err(ClientError::Protocol("unexpected config frame".into()))
                }
            }
        }
    }

    fn expect_output(&mut self, want: u64) -> Result<Vec<f32>, ClientError> {
        match self.read_reply(want)? {
            ServerFrame::Output { out, .. } => Ok(out),
            other => Err(ClientError::Protocol(format!("expected output frame, got {other:?}"))),
        }
    }

    /// Send a one-shot request and block for its output slab.
    pub fn submit(&mut self, req: &HeadsRequest) -> Result<Vec<f32>, ClientError> {
        let id = self.submit_async(req)?;
        self.wait_output(id)
    }

    /// Pipeline a one-shot request; pair with [`wait_output`]
    /// (awaited in submission order) for throughput benching.
    ///
    /// [`wait_output`]: Self::wait_output
    pub fn submit_async(&mut self, req: &HeadsRequest) -> Result<u64, ClientError> {
        let id = self.fresh_id();
        self.send(encode_submit(id, req))?;
        Ok(id)
    }

    /// Pipeline a head-range-routed sub-request (the shard
    /// coordinator's scatter path; see [`SubmitRoute`]).
    pub fn submit_routed_async(
        &mut self,
        req: &HeadsRequest,
        route: SubmitRoute,
    ) -> Result<u64, ClientError> {
        let id = self.fresh_id();
        self.send(encode_submit_routed(id, req, Some(route)))?;
        Ok(id)
    }

    /// Send a head-range-routed sub-request and block for its
    /// `[head_hi - head_lo, seq, head_dim]` output slab.
    pub fn submit_routed(
        &mut self,
        req: &HeadsRequest,
        route: SubmitRoute,
    ) -> Result<Vec<f32>, ClientError> {
        let id = self.submit_routed_async(req, route)?;
        self.wait_output(id)
    }

    /// Block for a pipelined request's output slab.
    pub fn wait_output(&mut self, id: u64) -> Result<Vec<f32>, ClientError> {
        self.expect_output(id)
    }

    /// Open a decode stream; returns the server-assigned stream id.
    pub fn open_stream(&mut self, repilot_stride: u32) -> Result<u64, ClientError> {
        let id = self.fresh_id();
        self.send(encode_open(id, repilot_stride))?;
        match self.read_reply(id)? {
            ServerFrame::OpenOk { stream, .. } => Ok(stream),
            other => Err(ClientError::Protocol(format!("expected open-ok frame, got {other:?}"))),
        }
    }

    /// Open a decode stream under a caller-chosen id (the shard
    /// coordinator pins global stream ids so per-stream seed derivation
    /// is placement-independent).  The server adopts the id; the reply
    /// echoes it back.
    pub fn open_stream_with_id(
        &mut self,
        repilot_stride: u32,
        stream: u64,
    ) -> Result<u64, ClientError> {
        let id = self.fresh_id();
        self.send(encode_open_with_stream(id, repilot_stride, Some(stream)))?;
        match self.read_reply(id)? {
            ServerFrame::OpenOk { stream, .. } => Ok(stream),
            other => Err(ClientError::Protocol(format!("expected open-ok frame, got {other:?}"))),
        }
    }

    /// Append one token (`k`/`v` are `[heads, head_dim]` rows).
    /// Fire-and-forget: a server-side rejection surfaces on the next
    /// reply-bearing op.
    pub fn append(&mut self, stream: u64, k: &[f32], v: &[f32]) -> Result<(), ClientError> {
        let id = self.fresh_id();
        self.send(encode_append(id, stream, k, v))
    }

    /// Bulk-append `tokens` tokens (`k`/`v` are `[heads, tokens,
    /// head_dim]` slabs).  Fire-and-forget like [`append`](Self::append).
    pub fn prefill(
        &mut self,
        stream: u64,
        tokens: u32,
        k: &[f32],
        v: &[f32],
    ) -> Result<(), ClientError> {
        let id = self.fresh_id();
        self.send(encode_prefill(id, stream, tokens, k, v))
    }

    /// Query `rows` rows per head (`q` is `[heads, rows, head_dim]`);
    /// blocks for the `[heads, rows, head_dim]` output slab.
    pub fn query(&mut self, stream: u64, rows: u32, q: &[f32]) -> Result<Vec<f32>, ClientError> {
        let id = self.fresh_id();
        self.send(encode_query(id, stream, rows, q))?;
        self.expect_output(id)
    }

    /// Drop a stream's server-side state (fire-and-forget).
    pub fn close_stream(&mut self, stream: u64) -> Result<(), ClientError> {
        let id = self.fresh_id();
        self.send(encode_close(id, stream))
    }

    /// Explicit liveness check: send a ping and block for its pong.
    pub fn ping(&mut self) -> Result<(), ClientError> {
        let want = self.fresh_id();
        self.send(encode_ping(want))?;
        loop {
            match self.read_frame()? {
                ServerFrame::Pong { id } if id >= want => return Ok(()),
                ServerFrame::Pong { .. } => continue, // an older probe's answer
                ServerFrame::Error { code, message, .. } => {
                    return Err(ClientError::Rejected { code, message })
                }
                other => {
                    return Err(ClientError::Protocol(format!(
                        "expected pong frame, got {other:?}"
                    )))
                }
            }
        }
    }

    /// Poll the server's live [`AttentionServerStats`] snapshot (the
    /// counter portion of [`stats_full`](Self::stats_full)).
    pub fn stats(&mut self) -> Result<AttentionServerStats, ClientError> {
        Ok(self.stats_full()?.stats)
    }

    /// Poll the server's full stats payload: engine counters plus
    /// telemetry gauge/histogram snapshots and — against a coordinator
    /// — per-shard health rows.  `skein top` renders this.
    pub fn stats_full(&mut self) -> Result<StatsWire, ClientError> {
        let id = self.fresh_id();
        self.send(encode_stats_req(id))?;
        match self.read_reply(id)? {
            ServerFrame::StatsOk { stats, .. } => Ok(*stats),
            other => Err(ClientError::Protocol(format!("expected stats frame, got {other:?}"))),
        }
    }
}
