//! Small blocking TCP client for the wire protocol — what `skein
//! client` and the socket round-trip tests/benches use.
//!
//! One [`NetClient`] owns one connection.  Ops map one-to-one onto
//! [`ClientFrame`](super::wire::ClientFrame)s; replies are matched by
//! request id.  Because one connection is one server-side fairness lane
//! and the scheduler preserves per-lane order, replies for pipelined
//! requests arrive in submission order — [`NetClient::submit_async`] /
//! [`NetClient::wait_output`] exploit that for throughput benching,
//! while the plain methods are strictly call-and-wait.
//!
//! Server-side rejections surface as [`ClientError::Rejected`] carrying
//! the wire error code: 0 is a framing error, `1..` are
//! [`ServeError::code`](crate::coordinator::attention_server::ServeError::code)
//! values — never a hang or an opaque `RecvError` panic.

use super::wire::{
    encode_append, encode_close, encode_open, encode_prefill, encode_query, encode_submit,
    read_hello, read_server_frame, write_hello, FrameError, ServerFrame, ServerInfo,
};
use crate::coordinator::attention_server::HeadsRequest;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};

/// Client-side failure modes.
#[derive(Debug)]
pub enum ClientError {
    /// Socket-level failure (includes the server disconnecting).
    Io(io::Error),
    /// The byte stream violated the protocol (bad magic/version, unknown
    /// frame kind, reply for a request we never made…).
    Protocol(String),
    /// The server answered with a typed error frame: `code` 0 is a
    /// wire-level framing error, `1..` are `ServeError::code` values.
    Rejected { code: u8, message: String },
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io error: {e}"),
            ClientError::Protocol(s) => write!(f, "protocol error: {s}"),
            ClientError::Rejected { code, message } => {
                write!(f, "rejected (code {code}): {message}")
            }
        }
    }
}

impl std::error::Error for ClientError {}

impl From<io::Error> for ClientError {
    fn from(e: io::Error) -> Self {
        ClientError::Io(e)
    }
}

impl From<FrameError> for ClientError {
    fn from(e: FrameError) -> Self {
        ClientError::Protocol(e.to_string())
    }
}

/// A blocking connection to a `skein serve --listen` front end.
pub struct NetClient {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
    info: ServerInfo,
    next_id: u64,
}

impl NetClient {
    /// Connect and handshake; returns once the server's config frame
    /// (its served shape) has been received.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self, ClientError> {
        let sock = TcpStream::connect(addr)?;
        let _ = sock.set_nodelay(true);
        let mut w = BufWriter::new(sock.try_clone()?);
        write_hello(&mut w)?;
        w.flush()?;
        let mut r = BufReader::new(sock);
        read_hello(&mut r)?;
        let info = match read_server_frame(&mut r)? {
            ServerFrame::Config(info) => info,
            other => {
                return Err(ClientError::Protocol(format!(
                    "expected config frame after hello, got {other:?}"
                )))
            }
        };
        Ok(NetClient { r, w, info, next_id: 0 })
    }

    /// The served shape advertised in the handshake.
    pub fn info(&self) -> &ServerInfo {
        &self.info
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn send(&mut self, frame: Vec<u8>) -> Result<(), ClientError> {
        self.w.write_all(&frame)?;
        self.w.flush()?;
        Ok(())
    }

    /// Read replies until `want`'s arrives.  An error frame for an
    /// *earlier* pipelined op (e.g. a rejected fire-and-forget append)
    /// also surfaces here as [`ClientError::Rejected`] — failures are
    /// reported, never swallowed.
    fn read_reply(&mut self, want: u64) -> Result<ServerFrame, ClientError> {
        match read_server_frame(&mut self.r)? {
            ServerFrame::Error { id, code, message } => {
                let prefix = if id == want { String::new() } else { format!("op {id}: ") };
                Err(ClientError::Rejected { code, message: format!("{prefix}{message}") })
            }
            frame @ (ServerFrame::Output { .. } | ServerFrame::OpenOk { .. }) => {
                let id = match &frame {
                    ServerFrame::Output { id, .. } | ServerFrame::OpenOk { id, .. } => *id,
                    ServerFrame::Config(_) => unreachable!(),
                };
                if id == want {
                    Ok(frame)
                } else {
                    Err(ClientError::Protocol(format!(
                        "reply for request {id} while awaiting {want}"
                    )))
                }
            }
            ServerFrame::Config(_) => {
                Err(ClientError::Protocol("unexpected config frame".into()))
            }
        }
    }

    fn expect_output(&mut self, want: u64) -> Result<Vec<f32>, ClientError> {
        match self.read_reply(want)? {
            ServerFrame::Output { out, .. } => Ok(out),
            other => Err(ClientError::Protocol(format!("expected output frame, got {other:?}"))),
        }
    }

    /// Send a one-shot request and block for its output slab.
    pub fn submit(&mut self, req: &HeadsRequest) -> Result<Vec<f32>, ClientError> {
        let id = self.submit_async(req)?;
        self.wait_output(id)
    }

    /// Pipeline a one-shot request; pair with [`wait_output`]
    /// (awaited in submission order) for throughput benching.
    ///
    /// [`wait_output`]: Self::wait_output
    pub fn submit_async(&mut self, req: &HeadsRequest) -> Result<u64, ClientError> {
        let id = self.fresh_id();
        self.send(encode_submit(id, req))?;
        Ok(id)
    }

    /// Block for a pipelined request's output slab.
    pub fn wait_output(&mut self, id: u64) -> Result<Vec<f32>, ClientError> {
        self.expect_output(id)
    }

    /// Open a decode stream; returns the server-assigned stream id.
    pub fn open_stream(&mut self, repilot_stride: u32) -> Result<u64, ClientError> {
        let id = self.fresh_id();
        self.send(encode_open(id, repilot_stride))?;
        match self.read_reply(id)? {
            ServerFrame::OpenOk { stream, .. } => Ok(stream),
            other => Err(ClientError::Protocol(format!("expected open-ok frame, got {other:?}"))),
        }
    }

    /// Append one token (`k`/`v` are `[heads, head_dim]` rows).
    /// Fire-and-forget: a server-side rejection surfaces on the next
    /// reply-bearing op.
    pub fn append(&mut self, stream: u64, k: &[f32], v: &[f32]) -> Result<(), ClientError> {
        let id = self.fresh_id();
        self.send(encode_append(id, stream, k, v))
    }

    /// Bulk-append `tokens` tokens (`k`/`v` are `[heads, tokens,
    /// head_dim]` slabs).  Fire-and-forget like [`append`](Self::append).
    pub fn prefill(
        &mut self,
        stream: u64,
        tokens: u32,
        k: &[f32],
        v: &[f32],
    ) -> Result<(), ClientError> {
        let id = self.fresh_id();
        self.send(encode_prefill(id, stream, tokens, k, v))
    }

    /// Query `rows` rows per head (`q` is `[heads, rows, head_dim]`);
    /// blocks for the `[heads, rows, head_dim]` output slab.
    pub fn query(&mut self, stream: u64, rows: u32, q: &[f32]) -> Result<Vec<f32>, ClientError> {
        let id = self.fresh_id();
        self.send(encode_query(id, stream, rows, q))?;
        self.expect_output(id)
    }

    /// Drop a stream's server-side state (fire-and-forget).
    pub fn close_stream(&mut self, stream: u64) -> Result<(), ClientError> {
        let id = self.fresh_id();
        self.send(encode_close(id, stream))
    }
}
