//! TCP accept loop feeding a running
//! [`attention_server`](crate::coordinator::attention_server).
//!
//! One OS thread per connection reads frames and dispatches them into
//! the serve thread through a per-socket
//! [`ServerConnection`] (its own round-robin fairness lane); replies are
//! encoded *on the serve thread* by [`ReplyTo`] closures and pushed into
//! a bounded per-connection writer queue drained by a companion writer
//! thread.  The serve thread therefore never blocks on a socket: if a
//! client stops reading and its writer queue fills
//! ([`WRITER_QUEUE_FRAMES`] frames), the connection is killed rather
//! than letting replies pile up in memory — combined with the bounded
//! server inbox (`queue_depth`) this is the protocol's backpressure
//! story end to end.
//!
//! Error discipline follows [`wire`](super::wire): structurally
//! malformed frames answer with an error frame (code
//! [`WIRE_ERROR_CODE`](super::wire::WIRE_ERROR_CODE)) and the
//! connection lives on; desynchronizing input closes the connection.
//! Nothing a client sends can panic the accept loop or the serve
//! thread — semantically bad ops come back as typed
//! [`ServeError`] frames.  When a connection ends (client close, kill,
//! or [`NetServer::stop`]), any decode streams it opened and never
//! closed are closed server-side so their KV state is released.

use super::wire::{
    encode_config, encode_error, encode_open_ok, encode_output, read_client_frame, read_hello,
    write_hello, ClientFrame, FrameError, ServerInfo, WIRE_ERROR_CODE,
};
use crate::coordinator::attention_server::{
    AttentionServerHandle, ReplyTo, ServeError, ServerConnection, StreamOp,
};
use std::collections::HashSet;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

/// Bound on per-connection queued reply frames before the client is
/// considered too slow and its connection is killed.
pub const WRITER_QUEUE_FRAMES: usize = 256;

/// A running TCP front end.  Dropping it (or calling
/// [`stop`](Self::stop)) stops accepting and disconnects live clients;
/// the underlying [`AttentionServerHandle`] stays up and is shut down
/// separately.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept_join: Option<JoinHandle<()>>,
}

impl NetServer {
    /// The bound listen address (with the OS-assigned port when the
    /// caller bound port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and disconnect live clients.  In-flight ops
    /// already handed to the serve thread still complete server-side.
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        let Some(join) = self.accept_join.take() else { return };
        self.stop.store(true, Ordering::SeqCst);
        // self-connect to unblock the blocking accept(); the accepted
        // socket is discarded once the loop sees the stop flag
        let _ = TcpStream::connect(self.addr);
        let _ = join.join();
        for sock in self.conns.lock().unwrap().drain(..) {
            let _ = sock.shutdown(Shutdown::Both);
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Bind `addr` (e.g. `"127.0.0.1:7878"`, or port 0 for OS-assigned) and
/// start serving `handle` over TCP.  Returns once the listener is bound;
/// accepting runs on a background thread.
pub fn serve(handle: &AttentionServerHandle, addr: &str) -> io::Result<NetServer> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
    let base = handle.connection();
    let cfg = handle.config();
    let info = ServerInfo {
        method: cfg.method.clone(),
        d: cfg.d as u32,
        heads: cfg.heads as u32,
        seq: cfg.seq as u32,
        head_dim: cfg.head_dim as u32,
        max_batch: cfg.max_batch as u32,
    };
    let accept_join = {
        let stop = Arc::clone(&stop);
        let conns = Arc::clone(&conns);
        std::thread::spawn(move || accept_loop(listener, base, info, stop, conns))
    };
    Ok(NetServer { addr: local, stop, conns, accept_join: Some(accept_join) })
}

fn accept_loop(
    listener: TcpListener,
    base: ServerConnection,
    info: ServerInfo,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
) {
    loop {
        let sock = match listener.accept() {
            Ok((sock, _)) => sock,
            Err(_) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let _ = sock.set_nodelay(true);
        if let Ok(clone) = sock.try_clone() {
            conns.lock().unwrap().push(clone);
        }
        let conn = base.sibling();
        let info = info.clone();
        std::thread::spawn(move || {
            let _ = serve_connection(sock, conn, info);
        });
    }
}

/// The serve thread's side of one reply: encoded frames go through a
/// bounded queue to the writer thread.  A full queue means the client
/// is not draining replies — kill the connection instead of blocking
/// the serve thread or buffering unboundedly.
#[derive(Clone)]
struct ReplyPipe {
    tx: mpsc::SyncSender<Vec<u8>>,
    sock: Arc<TcpStream>,
}

impl ReplyPipe {
    fn push(&self, frame: Vec<u8>) {
        if self.tx.try_send(frame).is_err() {
            let _ = self.sock.shutdown(Shutdown::Both);
        }
    }
}

fn verdict_frame(id: u64, r: Result<Vec<f32>, ServeError>) -> Vec<u8> {
    match r {
        Ok(out) => encode_output(id, &out),
        Err(e) => encode_error(id, e.code(), &e.to_string()),
    }
}

fn serve_connection(sock: TcpStream, conn: ServerConnection, info: ServerInfo) -> io::Result<()> {
    let mut r = BufReader::new(sock.try_clone()?);
    // handshake: verify the client's hello, answer with ours plus the
    // config frame advertising the served shape
    {
        let mut hw = BufWriter::new(sock.try_clone()?);
        if read_hello(&mut r).is_err() {
            let _ = sock.shutdown(Shutdown::Both);
            return Ok(());
        }
        write_hello(&mut hw)?;
        hw.write_all(&encode_config(&info))?;
        hw.flush()?;
    }
    let (wtx, wrx) = mpsc::sync_channel::<Vec<u8>>(WRITER_QUEUE_FRAMES);
    let writer = {
        let sock = sock.try_clone()?;
        std::thread::spawn(move || writer_loop(sock, wrx))
    };
    let pipe = ReplyPipe { tx: wtx, sock: Arc::new(sock.try_clone()?) };
    // streams this connection opened and has not closed — released when
    // the connection ends so abandoned decode state cannot leak
    let mut open: HashSet<u64> = HashSet::new();
    loop {
        match read_client_frame(&mut r) {
            Ok(frame) => dispatch(frame, &conn, &pipe, &mut open),
            Err(FrameError::Malformed { id, reason }) => {
                pipe.push(encode_error(id, WIRE_ERROR_CODE, &reason));
            }
            Err(FrameError::Fatal(_)) => break,
        }
    }
    for sid in open.drain() {
        conn.stream_op(sid, StreamOp::Close, None);
    }
    drop(pipe); // last writer sender: the writer thread drains and exits
    let _ = writer.join();
    let _ = sock.shutdown(Shutdown::Both);
    Ok(())
}

fn dispatch(
    frame: ClientFrame,
    conn: &ServerConnection,
    pipe: &ReplyPipe,
    open: &mut HashSet<u64>,
) {
    match frame {
        ClientFrame::Submit { id, req } => {
            let p = pipe.clone();
            conn.submit_with(req, ReplyTo::from_fn(move |r| p.push(verdict_frame(id, r))));
        }
        ClientFrame::Open { id, repilot_stride } => {
            let sid = conn.open_stream_id(repilot_stride as usize);
            open.insert(sid);
            pipe.push(encode_open_ok(id, sid));
        }
        ClientFrame::Append { id, stream, k, v } => {
            let p = pipe.clone();
            let err = ReplyTo::error_sink(move |r| {
                if let Err(e) = r {
                    p.push(encode_error(id, e.code(), &e.to_string()));
                }
            });
            conn.stream_op(stream, StreamOp::Append { k, v }, Some(err));
        }
        ClientFrame::Prefill { id, stream, tokens, k, v } => {
            let p = pipe.clone();
            let err = ReplyTo::error_sink(move |r| {
                if let Err(e) = r {
                    p.push(encode_error(id, e.code(), &e.to_string()));
                }
            });
            conn.stream_op(
                stream,
                StreamOp::Prefill { k, v, tokens: tokens as usize },
                Some(err),
            );
        }
        ClientFrame::Query { id, stream, rows, q } => {
            let p = pipe.clone();
            let reply = ReplyTo::from_fn(move |r| p.push(verdict_frame(id, r)));
            conn.stream_op(stream, StreamOp::Query { q, rows: rows as usize, reply }, None);
        }
        ClientFrame::Close { id: _, stream } => {
            open.remove(&stream);
            conn.stream_op(stream, StreamOp::Close, None);
        }
    }
}

/// Drain encoded frames to the socket, batching everything already
/// queued into one flush.
fn writer_loop(sock: TcpStream, rx: mpsc::Receiver<Vec<u8>>) {
    let mut w = BufWriter::new(sock);
    'outer: while let Ok(frame) = rx.recv() {
        if w.write_all(&frame).is_err() {
            break;
        }
        loop {
            match rx.try_recv() {
                Ok(f) => {
                    if w.write_all(&f).is_err() {
                        break 'outer;
                    }
                }
                Err(_) => break, // empty or disconnected: flush what we have
            }
        }
        if w.flush().is_err() {
            break;
        }
    }
    let _ = w.flush();
}
