//! TCP accept loop feeding a [`WireBackend`] — the in-process
//! [`attention_server`](crate::coordinator::attention_server) engine
//! ([`serve`]) or the shard coordinator
//! ([`crate::coordinator::shard`], via [`serve_backend`]).
//!
//! One OS thread per connection reads frames and dispatches them into
//! the backend through a per-socket [`WireLane`] (for the engine
//! backend, a [`ServerConnection`] with its own round-robin fairness
//! lane); replies are encoded *on the serve thread* by [`ReplyTo`]
//! closures and pushed into a bounded per-connection writer queue
//! drained by a companion writer thread.  The serve thread therefore
//! never blocks on a socket: if a client stops reading and its writer
//! queue fills ([`WRITER_QUEUE_FRAMES`] frames), the connection is
//! killed rather than letting replies pile up in memory — combined with
//! the bounded server inbox (`queue_depth`) this is the protocol's
//! backpressure story end to end.
//!
//! Error discipline follows [`wire`](super::wire): structurally
//! malformed frames answer with an error frame (code
//! [`WIRE_ERROR_CODE`](super::wire::WIRE_ERROR_CODE)) and the
//! connection lives on; desynchronizing input closes the connection.
//! Nothing a client sends can panic the accept loop or the serve
//! thread — semantically bad ops come back as typed
//! [`ServeError`] frames.  When a connection ends (client close, kill,
//! or [`NetServer::stop`]), any decode streams it opened and never
//! closed are closed server-side so their KV state is released.
//!
//! # Idle discipline
//!
//! Each connection socket carries a read timeout of
//! [`READ_IDLE_PROBE`].  A timeout *between* frames is recoverable
//! ([`read_client_frame_or_idle`]): the connection stays up and an idle
//! counter ticks; any complete frame — including a `Ping`, which is
//! answered with `Pong` straight from the read loop, never touching the
//! backend — resets it.  After [`READ_IDLE_BUDGET`] consecutive silent
//! probes (~one minute by default) the peer is presumed dead and the
//! connection is closed, releasing its streams.  A client that wants to
//! hold a connection open across think time just pings (which
//! [`super::NetClient`] does automatically on its own read timeouts).

use super::wire::{
    encode_config, encode_error, encode_open_ok, encode_output, encode_pong, encode_stats_ok,
    read_client_frame_or_idle, read_hello, write_hello, ClientFrame, ClientRead, FrameError,
    ServerInfo, StatsWire, WIRE_ERROR_CODE,
};
use crate::coordinator::attention_server::{
    AttentionServerHandle, HeadsRequest, ReplyTo, ServeError, ServerConnection, StreamOp,
    SubmitRoute,
};
use crate::obs::{ServeTelemetry, Span};
use std::collections::HashSet;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Bound on per-connection queued reply frames before the client is
/// considered too slow and its connection is killed.
pub const WRITER_QUEUE_FRAMES: usize = 256;

/// Per-socket read timeout: how often a silent connection wakes the
/// read loop to tick its idle counter.
pub const READ_IDLE_PROBE: Duration = Duration::from_secs(10);

/// Consecutive silent [`READ_IDLE_PROBE`] timeouts tolerated before a
/// peer is presumed dead and its connection closed (6 × 10 s ≈ one
/// minute of total silence; any frame, including a `Ping`, resets it).
pub const READ_IDLE_BUDGET: u32 = 6;

/// What the accept loop serves: a shape/identity advertisement for the
/// handshake plus per-connection [`WireLane`]s.  Implemented by the
/// in-process engine ([`serve`]) and by the shard coordinator
/// ([`crate::coordinator::shard::Coordinator`]).
pub trait WireBackend: Send + Sync {
    /// The config frame advertised to every client at handshake.
    fn info(&self) -> ServerInfo;
    /// A fresh lane for one accepted connection.
    fn lane(&self) -> Box<dyn WireLane>;
    /// The backend's telemetry bundle, when it has one — the writer
    /// threads record reply-write spans through it.  `None` (the
    /// default) wires the front end with no-op telemetry.
    fn telemetry(&self) -> Option<Arc<ServeTelemetry>> {
        None
    }
}

/// One connection's dispatch surface: everything a wire client can ask
/// for, minus `Ping` (answered in the read loop without touching the
/// backend).  Implementations must never block indefinitely — a lane
/// that cannot answer must fail typed ([`ServeError`]) through the
/// supplied [`ReplyTo`]s.
pub trait WireLane: Send {
    /// One-shot request, optionally head-range routed (see
    /// [`SubmitRoute`]).
    fn submit(&self, req: HeadsRequest, route: Option<SubmitRoute>, reply: ReplyTo);
    /// Open a decode stream; `explicit` pins the stream id (the shard
    /// coordinator pushes global ids down so seed derivations match).
    /// Returns the stream id actually opened.
    fn open_stream(&self, repilot_stride: usize, explicit: Option<u64>) -> u64;
    /// One raw stream op with an optional error reporter.
    fn stream_op(&self, stream: u64, op: StreamOp, err: Option<ReplyTo>);
    /// Live stats snapshot — counters plus telemetry gauge/histogram
    /// snapshots — or `None` if the backend is gone.
    fn stats(&self) -> Option<StatsWire>;
}

impl WireLane for ServerConnection {
    fn submit(&self, req: HeadsRequest, route: Option<SubmitRoute>, reply: ReplyTo) {
        self.submit_routed(req, route, reply);
    }

    fn open_stream(&self, repilot_stride: usize, explicit: Option<u64>) -> u64 {
        match explicit {
            Some(id) => {
                self.open_stream_with_id(id, repilot_stride);
                id
            }
            None => self.open_stream_id(repilot_stride),
        }
    }

    fn stream_op(&self, stream: u64, op: StreamOp, err: Option<ReplyTo>) {
        ServerConnection::stream_op(self, stream, op, err);
    }

    fn stats(&self) -> Option<StatsWire> {
        let stats = ServerConnection::stats(self)?;
        let (gauges, histos) = self.telemetry().wire_snapshots();
        Some(StatsWire { stats, gauges, histos, shards: Vec::new() })
    }
}

/// The in-process engine as a [`WireBackend`]: one
/// [`ServerConnection`] sibling per accepted socket.
pub struct EngineBackend {
    base: ServerConnection,
    info: ServerInfo,
}

impl EngineBackend {
    /// Wrap a running server.  `shard_index`/`shard_count` only
    /// annotate the handshake (`0, 0` = not a shard); the engine always
    /// serves its full configured head range.
    pub fn new(handle: &AttentionServerHandle, shard_index: u32, shard_count: u32) -> Self {
        let cfg = handle.config();
        EngineBackend {
            base: handle.connection(),
            info: ServerInfo {
                method: cfg.method.clone(),
                d: cfg.d as u32,
                heads: cfg.heads as u32,
                seq: cfg.seq as u32,
                head_dim: cfg.head_dim as u32,
                max_batch: cfg.max_batch as u32,
                seed: cfg.seed,
                shard_index,
                shard_count,
            },
        }
    }
}

impl WireBackend for EngineBackend {
    fn info(&self) -> ServerInfo {
        self.info.clone()
    }

    fn lane(&self) -> Box<dyn WireLane> {
        Box::new(self.base.sibling())
    }

    fn telemetry(&self) -> Option<Arc<ServeTelemetry>> {
        Some(Arc::clone(self.base.telemetry()))
    }
}

/// A running TCP front end.  Dropping it (or calling
/// [`stop`](Self::stop)) stops accepting and disconnects live clients;
/// the underlying backend (engine handle or coordinator) stays up and
/// is shut down separately.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept_join: Option<JoinHandle<()>>,
}

impl NetServer {
    /// The bound listen address (with the OS-assigned port when the
    /// caller bound port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and disconnect live clients.  In-flight ops
    /// already handed to the serve thread still complete server-side.
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        let Some(join) = self.accept_join.take() else { return };
        self.stop.store(true, Ordering::SeqCst);
        // self-connect to unblock the blocking accept(); the accepted
        // socket is discarded once the loop sees the stop flag
        let _ = TcpStream::connect(self.addr);
        let _ = join.join();
        for sock in self.conns.lock().unwrap().drain(..) {
            let _ = sock.shutdown(Shutdown::Both);
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Bind `addr` (e.g. `"127.0.0.1:7878"`, or port 0 for OS-assigned) and
/// start serving `handle` over TCP.  Returns once the listener is bound;
/// accepting runs on a background thread.
pub fn serve(handle: &AttentionServerHandle, addr: &str) -> io::Result<NetServer> {
    serve_backend(Arc::new(EngineBackend::new(handle, 0, 0)), addr)
}

/// [`serve`] generalized over the backend: the shard coordinator plugs
/// in here, presenting the whole cluster behind the same wire protocol
/// a single engine speaks.
pub fn serve_backend(backend: Arc<dyn WireBackend>, addr: &str) -> io::Result<NetServer> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
    let accept_join = {
        let stop = Arc::clone(&stop);
        let conns = Arc::clone(&conns);
        // resolve the telemetry bundle once — backends without one get
        // a single shared no-op bundle, not one per connection
        let obs = backend.telemetry().unwrap_or_else(ServeTelemetry::disabled);
        std::thread::spawn(move || accept_loop(listener, backend, obs, stop, conns))
    };
    Ok(NetServer { addr: local, stop, conns, accept_join: Some(accept_join) })
}

fn accept_loop(
    listener: TcpListener,
    backend: Arc<dyn WireBackend>,
    obs: Arc<ServeTelemetry>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
) {
    loop {
        let sock = match listener.accept() {
            Ok((sock, _)) => sock,
            Err(_) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let _ = sock.set_nodelay(true);
        if let Ok(clone) = sock.try_clone() {
            conns.lock().unwrap().push(clone);
        }
        let lane = backend.lane();
        let info = backend.info();
        let obs = Arc::clone(&obs);
        std::thread::spawn(move || {
            let _ = serve_connection(sock, lane, info, obs);
        });
    }
}

/// The serve thread's side of one reply: encoded frames go through a
/// bounded queue to the writer thread.  A full queue means the client
/// is not draining replies — kill the connection instead of blocking
/// the serve thread or buffering unboundedly.
#[derive(Clone)]
struct ReplyPipe {
    tx: mpsc::SyncSender<Vec<u8>>,
    sock: Arc<TcpStream>,
}

impl ReplyPipe {
    fn push(&self, frame: Vec<u8>) {
        if self.tx.try_send(frame).is_err() {
            let _ = self.sock.shutdown(Shutdown::Both);
        }
    }
}

fn verdict_frame(id: u64, r: Result<Vec<f32>, ServeError>) -> Vec<u8> {
    match r {
        Ok(out) => encode_output(id, &out),
        Err(e) => encode_error(id, e.code(), &e.to_string()),
    }
}

fn serve_connection(
    sock: TcpStream,
    lane: Box<dyn WireLane>,
    info: ServerInfo,
    obs: Arc<ServeTelemetry>,
) -> io::Result<()> {
    let mut r = BufReader::new(sock.try_clone()?);
    // handshake: verify the client's hello, answer with ours plus the
    // config frame advertising the served shape
    {
        let mut hw = BufWriter::new(sock.try_clone()?);
        if read_hello(&mut r).is_err() {
            let _ = sock.shutdown(Shutdown::Both);
            return Ok(());
        }
        write_hello(&mut hw)?;
        hw.write_all(&encode_config(&info))?;
        hw.flush()?;
    }
    // idle discipline: wake every READ_IDLE_PROBE to tick the idle
    // counter; READ_IDLE_BUDGET silent probes in a row ends the
    // connection (a live-but-quiet client pings, which resets it)
    let _ = sock.set_read_timeout(Some(READ_IDLE_PROBE));
    let (wtx, wrx) = mpsc::sync_channel::<Vec<u8>>(WRITER_QUEUE_FRAMES);
    let writer = {
        let sock = sock.try_clone()?;
        std::thread::spawn(move || writer_loop(sock, wrx, obs))
    };
    let pipe = ReplyPipe { tx: wtx, sock: Arc::new(sock.try_clone()?) };
    // streams this connection opened and has not closed — released when
    // the connection ends so abandoned decode state cannot leak
    let mut open: HashSet<u64> = HashSet::new();
    let mut idle: u32 = 0;
    loop {
        match read_client_frame_or_idle(&mut r) {
            Ok(ClientRead::Frame(frame)) => {
                idle = 0;
                dispatch(frame, lane.as_ref(), &pipe, &mut open);
            }
            Ok(ClientRead::Idle) => {
                idle += 1;
                if idle >= READ_IDLE_BUDGET {
                    break; // presumed-dead peer
                }
            }
            Err(FrameError::Malformed { id, reason }) => {
                idle = 0;
                pipe.push(encode_error(id, WIRE_ERROR_CODE, &reason));
            }
            Err(FrameError::Fatal(_)) => break,
        }
    }
    for sid in open.drain() {
        lane.stream_op(sid, StreamOp::Close, None);
    }
    drop(pipe); // last writer sender: the writer thread drains and exits
    let _ = writer.join();
    let _ = sock.shutdown(Shutdown::Both);
    Ok(())
}

fn dispatch(frame: ClientFrame, lane: &dyn WireLane, pipe: &ReplyPipe, open: &mut HashSet<u64>) {
    match frame {
        ClientFrame::Submit { id, req, route } => {
            let p = pipe.clone();
            lane.submit(req, route, ReplyTo::from_fn(move |r| p.push(verdict_frame(id, r))));
        }
        ClientFrame::Open { id, repilot_stride, stream } => {
            let sid = lane.open_stream(repilot_stride as usize, stream);
            open.insert(sid);
            pipe.push(encode_open_ok(id, sid));
        }
        ClientFrame::Append { id, stream, k, v } => {
            let p = pipe.clone();
            let err = ReplyTo::error_sink(move |r| {
                if let Err(e) = r {
                    p.push(encode_error(id, e.code(), &e.to_string()));
                }
            });
            lane.stream_op(stream, StreamOp::Append { k, v }, Some(err));
        }
        ClientFrame::Prefill { id, stream, tokens, k, v } => {
            let p = pipe.clone();
            let err = ReplyTo::error_sink(move |r| {
                if let Err(e) = r {
                    p.push(encode_error(id, e.code(), &e.to_string()));
                }
            });
            lane.stream_op(
                stream,
                StreamOp::Prefill { k, v, tokens: tokens as usize },
                Some(err),
            );
        }
        ClientFrame::Query { id, stream, rows, q } => {
            let p = pipe.clone();
            let reply = ReplyTo::from_fn(move |r| p.push(verdict_frame(id, r)));
            lane.stream_op(stream, StreamOp::Query { q, rows: rows as usize, reply }, None);
        }
        ClientFrame::Close { id: _, stream } => {
            open.remove(&stream);
            lane.stream_op(stream, StreamOp::Close, None);
        }
        // liveness: answered right here so a busy backend can never
        // stall the heartbeat
        ClientFrame::Ping { id } => pipe.push(encode_pong(id)),
        ClientFrame::Stats { id } => match lane.stats() {
            Some(stats) => pipe.push(encode_stats_ok(id, &stats)),
            None => {
                let e = ServeError::Shutdown;
                pipe.push(encode_error(id, e.code(), &e.to_string()));
            }
        },
    }
}

/// Drain encoded frames to the socket, batching everything already
/// queued into one flush.  Each drain cycle — first frame through the
/// flush — closes one reply-write span (the writer thread has its own
/// flight-recorder ring, so recording is contention-free).
fn writer_loop(sock: TcpStream, rx: mpsc::Receiver<Vec<u8>>, obs: Arc<ServeTelemetry>) {
    let mut w = BufWriter::new(sock);
    'outer: while let Ok(frame) = rx.recv() {
        let t0 = obs.now();
        if w.write_all(&frame).is_err() {
            break;
        }
        loop {
            match rx.try_recv() {
                Ok(f) => {
                    if w.write_all(&f).is_err() {
                        break 'outer;
                    }
                }
                Err(_) => break, // empty or disconnected: flush what we have
            }
        }
        if w.flush().is_err() {
            break;
        }
        obs.span(Span::ReplyWrite, t0, 0, 0);
    }
    let _ = w.flush();
}
