//! The length-prefixed binary wire protocol shared by the TCP server
//! and the blocking client.
//!
//! # Framing
//!
//! A connection opens with a 6-byte hello in each direction — [`MAGIC`]
//! (u32) then [`VERSION`] (u16), all little-endian like every integer on
//! the wire — after which both directions speak *frames*:
//!
//! ```text
//! [len: u32][kind: u8][body: len-1 bytes]
//! ```
//!
//! `len` counts the kind byte plus the body and is capped at
//! [`MAX_FRAME_BYTES`].  Client→server kinds are `0x01..=0x08`
//! ([`ClientFrame`]); server→client kinds are `0x80..=0x85`
//! ([`ServerFrame`]).  Every f32 slab inside a body is a `u32` element
//! count followed by that many little-endian f32s, and every request
//! frame carries a client-chosen `id: u64` echoed by the reply frame so
//! pipelined requests can be matched up.
//!
//! # Heartbeats and idleness
//!
//! Version 2 adds a liveness pair: a [`ClientFrame::Ping`] is answered
//! with a [`ServerFrame::Pong`] directly from the server's read loop
//! (it never enters the engine queue), so any peer can distinguish "the
//! connection is quiet" from "the peer is gone".  Servers read frames
//! through [`read_client_frame_or_idle`] with a socket read timeout: a
//! timeout **before the first length byte** of a frame is a recoverable
//! [`ClientRead::Idle`] tick (the accept loop counts these and closes
//! only after a long idle budget), while a timeout **inside** a frame
//! means the peer died mid-write and is fatal.  Version 2 also adds a
//! stats pair ([`ClientFrame::Stats`]/[`ServerFrame::StatsOk`]) so a
//! shard coordinator can poll live [`AttentionServerStats`] snapshots,
//! an optional head-range route on submit frames, and an optional
//! caller-chosen stream id on open frames (both used by the shard
//! scatter/gather path — see `coordinator::shard`).
//!
//! # Error discipline
//!
//! Because frames are length-delimited, a *structurally* malformed body
//! (fields don't add up to `len`) leaves the byte stream in sync: the
//! decoder skips the remainder of the frame and reports
//! [`FrameError::Malformed`], which the server answers with an error
//! frame (code [`WIRE_ERROR_CODE`]) and the connection continues — the
//! fuzz tests in `rust/tests/serving_net.rs` pin that the serve thread
//! survives arbitrary bytes.  Only desynchronizing conditions are fatal
//! ([`FrameError::Fatal`]): a bad magic/version, an unknown frame kind,
//! an oversized `len`, or the stream ending mid-frame.  *Semantically*
//! malformed ops (wrong slab length for the server's shape, unknown
//! stream ids…) are not the wire layer's business: they flow through to
//! the engine, which rejects them with a typed
//! [`ServeError`](crate::coordinator::attention_server::ServeError)
//! that comes back as an error frame carrying
//! [`ServeError::code`](crate::coordinator::attention_server::ServeError::code).
//!
//! # Zero-copy ingest
//!
//! [`read_f32_slab`] reads payload bytes directly into a freshly
//! allocated `Arc<[f32]>` — the same slab the engine then reads in
//! place via [`HeadsRequest`] — so a request's K/V/Q payloads are
//! copied exactly once off the socket, with no intermediate buffer.

use crate::coordinator::attention_server::{AttentionServerStats, HeadsRequest, SubmitRoute};
use crate::obs::{HistoSnapshot, HISTO_BUCKETS};
use std::io::{self, Read, Write};
use std::sync::Arc;

/// `"SKNF"` — the protocol magic.
pub const MAGIC: u32 = 0x534B_4E46;
/// Protocol version (bumped on any frame-layout change).  Version 2:
/// submit flags byte (mask + head-range route), open flags byte
/// (explicit stream id), ping/pong heartbeats, stats polling, and the
/// seed/shard fields in the config frame.
pub const VERSION: u16 = 2;
/// Upper bound on one frame's `len` field (256 MiB): anything larger is
/// a corrupt or hostile length prefix, not a payload this server shapes.
pub const MAX_FRAME_BYTES: u32 = 1 << 28;

/// Error-frame code for wire-level (framing) errors; engine rejections
/// use their [`ServeError::code`] values `1..`.
///
/// [`ServeError::code`]: crate::coordinator::attention_server::ServeError::code
pub const WIRE_ERROR_CODE: u8 = 0;

// client→server frame kinds
pub const KIND_SUBMIT: u8 = 0x01;
pub const KIND_OPEN: u8 = 0x02;
pub const KIND_APPEND: u8 = 0x03;
pub const KIND_PREFILL: u8 = 0x04;
pub const KIND_QUERY: u8 = 0x05;
pub const KIND_CLOSE: u8 = 0x06;
pub const KIND_PING: u8 = 0x07;
pub const KIND_STATS: u8 = 0x08;
// server→client frame kinds
pub const KIND_CONFIG: u8 = 0x80;
pub const KIND_OUTPUT: u8 = 0x81;
pub const KIND_ERROR: u8 = 0x82;
pub const KIND_OPEN_OK: u8 = 0x83;
pub const KIND_PONG: u8 = 0x84;
pub const KIND_STATS_OK: u8 = 0x85;

// submit-frame flag bits
const SUBMIT_FLAG_MASK: u8 = 0x01;
const SUBMIT_FLAG_ROUTE: u8 = 0x02;
// open-frame flag bits
const OPEN_FLAG_STREAM: u8 = 0x01;

/// The server shape a connection learns from the handshake's config
/// frame — everything a client needs to build well-formed payloads.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServerInfo {
    pub method: String,
    pub d: u32,
    pub heads: u32,
    pub seq: u32,
    pub head_dim: u32,
    pub max_batch: u32,
    /// The server's base RNG seed — the shard coordinator cross-checks
    /// that every shard derives the same per-head streams.
    pub seed: u64,
    /// This server's shard index when launched with `--shard-index`
    /// (`shard_count == 0` means "not a shard").
    pub shard_index: u32,
    /// Declared shard-ring size (`--shard-of`); 0 when standalone.
    pub shard_count: u32,
}

impl ServerInfo {
    /// Elements per request slab (`heads * seq * head_dim`).
    pub fn request_elems(&self) -> usize {
        self.heads as usize * self.seq as usize * self.head_dim as usize
    }

    /// Elements per stream token slab (`heads * head_dim`).
    pub fn token_elems(&self) -> usize {
        self.heads as usize * self.head_dim as usize
    }
}

/// One shard's health row in a coordinator's stats reply — what
/// `skein top` renders as the shard table.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardHealth {
    /// The shard's address as the coordinator dials it.
    pub addr: String,
    /// Milliseconds since the coordinator last heard any frame from
    /// this shard (heartbeat replies included).
    pub heartbeat_age_ms: u64,
    /// Replies the coordinator is still waiting on from this shard.
    pub pending: u64,
    /// Cumulative replies drained with `ShardDown` when this shard's
    /// connection was killed.
    pub down_drains: u64,
    /// The shard's own admission-queue depth gauge at its last stats
    /// poll (0 when unknown).
    pub queue_depth: u64,
    /// False once the connection was declared dead.
    pub alive: bool,
}

/// The full payload of a stats reply: the engine counter snapshot plus
/// the telemetry snapshots — named gauges and mergeable histogram
/// buckets ([`HistoSnapshot`]) — and, from a coordinator, per-shard
/// health rows.  Histograms merge bucket-wise
/// ([`HistoSnapshot::merge`]), which is how the coordinator folds
/// shard latency distributions into one cluster view without losing
/// quantile fidelity beyond the bucket width.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StatsWire {
    pub stats: AttentionServerStats,
    /// `(name, value)` gauge snapshots, exposition-ready.
    pub gauges: Vec<(String, u64)>,
    /// `(name, snapshot)` histogram snapshots, bucket-mergeable.
    pub histos: Vec<(String, HistoSnapshot)>,
    /// Per-shard health (empty from a plain engine server).
    pub shards: Vec<ShardHealth>,
}

impl StatsWire {
    /// Wrap a bare counter snapshot (no telemetry attached).
    pub fn from_stats(stats: AttentionServerStats) -> Self {
        StatsWire { stats, ..Default::default() }
    }
}

/// One decoded client→server frame.
#[derive(Debug)]
pub enum ClientFrame {
    /// A one-shot batched request (`id` echoed by the output frame).
    /// `route`, when present, restricts computation to a head range at
    /// an explicit seed (the shard scatter path).
    Submit { id: u64, req: HeadsRequest, route: Option<SubmitRoute> },
    /// Open a decode stream; answered by an open-ok frame carrying the
    /// stream id.  `stream`, when present, is a caller-chosen id the
    /// server must adopt (the coordinator keeps shard-side stream ids
    /// aligned with its own seed-bearing global ids).
    Open { id: u64, repilot_stride: u32, stream: Option<u64> },
    /// Append one token to a stream (no success reply; failures answer
    /// with an error frame).
    Append { id: u64, stream: u64, k: Arc<[f32]>, v: Arc<[f32]> },
    /// Bulk-append `tokens` tokens to a stream.
    Prefill { id: u64, stream: u64, tokens: u32, k: Arc<[f32]>, v: Arc<[f32]> },
    /// Query a stream; answered by an output frame.
    Query { id: u64, stream: u64, rows: u32, q: Arc<[f32]> },
    /// Drop a stream's server-side state (no reply).
    Close { id: u64, stream: u64 },
    /// Liveness probe; answered with a pong frame from the read loop
    /// (never queued behind engine work).
    Ping { id: u64 },
    /// Poll a live stats snapshot; answered with a stats-ok frame.
    Stats { id: u64 },
}

/// One decoded server→client frame.
#[derive(Debug)]
pub enum ServerFrame {
    /// The handshake's shape advertisement.
    Config(ServerInfo),
    /// A request's output slab.
    Output { id: u64, out: Vec<f32> },
    /// A typed rejection: `code` 0 is a wire-level error, `1..` are
    /// [`ServeError::code`](crate::coordinator::attention_server::ServeError::code)s.
    Error { id: u64, code: u8, message: String },
    /// A stream was opened; `stream` is the adopted id.
    OpenOk { id: u64, stream: u64 },
    /// Reply to a ping.
    Pong { id: u64 },
    /// Reply to a stats poll: a live snapshot (means computed over the
    /// work so far; counters monotone) plus telemetry gauge/histogram
    /// snapshots and — from a coordinator — per-shard health.
    StatsOk { id: u64, stats: Box<StatsWire> },
}

/// Result of [`read_client_frame_or_idle`]: a decoded frame, or a
/// recoverable read-timeout tick that fired between frames.
#[derive(Debug)]
pub enum ClientRead {
    Frame(ClientFrame),
    Idle,
}

/// Result of [`read_server_frame_or_idle`]: the client-side mirror of
/// [`ClientRead`].
#[derive(Debug)]
pub enum ServerRead {
    Frame(ServerFrame),
    Idle,
}

/// Decode failure modes; see the [module docs](self) for the
/// recoverable/fatal split.
#[derive(Debug)]
pub enum FrameError {
    /// The stream is desynchronized or gone: close the connection.
    Fatal(String),
    /// This frame was structurally malformed but fully consumed — the
    /// stream is still in sync.  `id` is the frame's request id when it
    /// could be parsed (0 otherwise).
    Malformed { id: u64, reason: String },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Fatal(s) => write!(f, "fatal wire error: {s}"),
            FrameError::Malformed { id, reason } => {
                write!(f, "malformed frame (id {id}): {reason}")
            }
        }
    }
}

impl std::error::Error for FrameError {}

fn fatal_io(what: &str, e: io::Error) -> FrameError {
    FrameError::Fatal(format!("{what}: {e}"))
}

// ---------------------------------------------------------------------
// primitive readers/writers
// ---------------------------------------------------------------------

fn read_u8(r: &mut impl Read) -> io::Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u16(r: &mut impl Read) -> io::Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(r: &mut impl Read) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Read `n` little-endian f32s straight into a fresh `Arc<[f32]>` —
/// the zero-copy ingest path (the engine reads this slab in place).
pub fn read_f32_slab(r: &mut impl Read, n: usize) -> io::Result<Arc<[f32]>> {
    let mut slab: Arc<[f32]> = vec![0.0f32; n].into();
    {
        let dst = Arc::get_mut(&mut slab).expect("fresh arc is uniquely owned");
        // SAFETY: a [f32] of n elements is exactly 4n bytes with no
        // padding; every byte is overwritten by read_exact before any
        // f32 is read back.
        let bytes =
            unsafe { std::slice::from_raw_parts_mut(dst.as_mut_ptr().cast::<u8>(), n * 4) };
        r.read_exact(bytes)?;
        if cfg!(target_endian = "big") {
            // the wire is little-endian; swap in place on BE hosts
            for x in dst.iter_mut() {
                *x = f32::from_bits(x.to_bits().swap_bytes());
            }
        }
    }
    Ok(slab)
}

/// Append `xs` to `buf` as little-endian f32 bytes.
fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.reserve(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

/// A length-counted slab: `u32` element count + payload.
fn put_slab(buf: &mut Vec<u8>, xs: &[f32]) {
    put_u32(buf, xs.len() as u32);
    put_f32s(buf, xs);
}

fn read_slab(r: &mut impl Read, cap_elems: u32) -> io::Result<Arc<[f32]>> {
    let n = read_u32(r)?;
    if n > cap_elems {
        // a count that alone exceeds the frame cap cannot be honest;
        // surface as a body-overrun (the Take limiter EOFs)
        return Err(io::Error::new(io::ErrorKind::InvalidData, "slab count exceeds frame"));
    }
    read_f32_slab(r, n as usize)
}

// ---------------------------------------------------------------------
// handshake
// ---------------------------------------------------------------------

/// Write the 6-byte hello (both directions use the same bytes).
pub fn write_hello(w: &mut impl Write) -> io::Result<()> {
    w.write_all(&MAGIC.to_le_bytes())?;
    w.write_all(&VERSION.to_le_bytes())
}

/// Read and verify the peer's hello.
pub fn read_hello(r: &mut impl Read) -> Result<(), FrameError> {
    let magic = read_u32(r).map_err(|e| fatal_io("reading magic", e))?;
    if magic != MAGIC {
        return Err(FrameError::Fatal(format!("bad magic {magic:#010x}")));
    }
    let version = read_u16(r).map_err(|e| fatal_io("reading version", e))?;
    if version != VERSION {
        return Err(FrameError::Fatal(format!(
            "protocol version mismatch: peer {version}, ours {VERSION}"
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// frame encoders (full frame bytes, header included)
// ---------------------------------------------------------------------

/// Finish a frame: prepend `[len][kind]` to an encoded body.
fn frame(kind: u8, body: Vec<u8>) -> Vec<u8> {
    let len = (body.len() + 1) as u32;
    let mut out = Vec::with_capacity(body.len() + 5);
    out.extend_from_slice(&len.to_le_bytes());
    out.push(kind);
    out.extend_from_slice(&body);
    out
}

pub fn encode_submit(id: u64, req: &HeadsRequest) -> Vec<u8> {
    encode_submit_routed(id, req, None)
}

/// [`encode_submit`] with an optional head-range route (flags bit 1):
/// the body is `id, flags, [head_lo, head_hi, seed,] q, k, v, [mask]`.
pub fn encode_submit_routed(id: u64, req: &HeadsRequest, route: Option<SubmitRoute>) -> Vec<u8> {
    encode_submit_sliced(id, &req.q, &req.k, &req.v, req.mask.as_deref(), route)
}

/// [`encode_submit_routed`] over raw slices — the shard coordinator
/// scatters a client request by slicing its `Arc<[f32]>` slabs in
/// place (head-major layout makes every head range contiguous), so
/// sub-request bytes go straight from the client's slabs to the shard
/// socket with no intermediate copies.
pub fn encode_submit_sliced(
    id: u64,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: Option<&[f32]>,
    route: Option<SubmitRoute>,
) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    let mut flags = 0u8;
    if mask.is_some() {
        flags |= SUBMIT_FLAG_MASK;
    }
    if route.is_some() {
        flags |= SUBMIT_FLAG_ROUTE;
    }
    body.push(flags);
    if let Some(r) = route {
        put_u32(&mut body, r.head_lo);
        put_u32(&mut body, r.head_hi);
        put_u64(&mut body, r.seed);
    }
    put_slab(&mut body, q);
    put_slab(&mut body, k);
    put_slab(&mut body, v);
    if let Some(mask) = mask {
        put_slab(&mut body, mask);
    }
    frame(KIND_SUBMIT, body)
}

pub fn encode_open(id: u64, repilot_stride: u32) -> Vec<u8> {
    encode_open_with_stream(id, repilot_stride, None)
}

/// [`encode_open`] with an optional caller-chosen stream id (flags
/// bit 0): the body is `id, repilot_stride, flags, [stream]`.
pub fn encode_open_with_stream(id: u64, repilot_stride: u32, stream: Option<u64>) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_u32(&mut body, repilot_stride);
    let flags = if stream.is_some() { OPEN_FLAG_STREAM } else { 0 };
    body.push(flags);
    if let Some(s) = stream {
        put_u64(&mut body, s);
    }
    frame(KIND_OPEN, body)
}

pub fn encode_append(id: u64, stream: u64, k: &[f32], v: &[f32]) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_u64(&mut body, stream);
    put_slab(&mut body, k);
    put_slab(&mut body, v);
    frame(KIND_APPEND, body)
}

pub fn encode_prefill(id: u64, stream: u64, tokens: u32, k: &[f32], v: &[f32]) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_u64(&mut body, stream);
    put_u32(&mut body, tokens);
    put_slab(&mut body, k);
    put_slab(&mut body, v);
    frame(KIND_PREFILL, body)
}

pub fn encode_query(id: u64, stream: u64, rows: u32, q: &[f32]) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_u64(&mut body, stream);
    put_u32(&mut body, rows);
    put_slab(&mut body, q);
    frame(KIND_QUERY, body)
}

pub fn encode_close(id: u64, stream: u64) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_u64(&mut body, stream);
    frame(KIND_CLOSE, body)
}

pub fn encode_ping(id: u64) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    frame(KIND_PING, body)
}

pub fn encode_pong(id: u64) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    frame(KIND_PONG, body)
}

pub fn encode_stats_req(id: u64) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    frame(KIND_STATS, body)
}

/// The 15 monotone counters of a stats snapshot, in wire order.
fn stats_counters(s: &AttentionServerStats) -> [u64; 15] {
    [
        s.requests,
        s.batches,
        s.steps,
        s.rejected,
        s.stream_appends,
        s.stream_queries,
        s.kv_hit_blocks,
        s.kv_alloc_blocks,
        s.kv_evicted_blocks,
        s.kv_resident_blocks,
        s.kv_resident_bytes,
        s.kv_demoted_blocks,
        s.kv_spilled_blocks,
        s.kv_spill_hits,
        s.kv_spill_corrupt,
    ]
}

/// A u16-length-prefixed string (names and addresses are short).
fn put_str(buf: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    let bytes = &bytes[..bytes.len().min(u16::MAX as usize)];
    buf.extend_from_slice(&(bytes.len() as u16).to_le_bytes());
    buf.extend_from_slice(bytes);
}

fn read_str(r: &mut impl Read, what: &'static str) -> io::Result<String> {
    let len = read_u16(r)? as usize;
    let mut bytes = vec![0u8; len];
    r.read_exact(&mut bytes)?;
    String::from_utf8(bytes).map_err(|_| io::Error::new(io::ErrorKind::InvalidData, what))
}

pub fn encode_stats_ok(id: u64, stats: &StatsWire) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    for c in stats_counters(&stats.stats) {
        put_u64(&mut body, c);
    }
    let s = &stats.stats;
    for m in [s.mean_queue_ms, s.mean_occupancy, s.mean_step_occupancy, s.mean_batch_ms] {
        put_u64(&mut body, m.to_bits());
    }
    put_u32(&mut body, stats.gauges.len() as u32);
    for (name, value) in &stats.gauges {
        put_str(&mut body, name);
        put_u64(&mut body, *value);
    }
    put_u32(&mut body, stats.histos.len() as u32);
    for (name, h) in &stats.histos {
        put_str(&mut body, name);
        put_u64(&mut body, h.sum);
        // bucket count on the wire so a build with a different
        // HISTO_BUCKETS still decodes (extra buckets fold into +Inf)
        put_u32(&mut body, h.buckets.len() as u32);
        for b in h.buckets {
            put_u64(&mut body, b);
        }
    }
    put_u32(&mut body, stats.shards.len() as u32);
    for sh in &stats.shards {
        put_str(&mut body, &sh.addr);
        put_u64(&mut body, sh.heartbeat_age_ms);
        put_u64(&mut body, sh.pending);
        put_u64(&mut body, sh.down_drains);
        put_u64(&mut body, sh.queue_depth);
        body.push(u8::from(sh.alive));
    }
    frame(KIND_STATS_OK, body)
}

pub fn encode_config(info: &ServerInfo) -> Vec<u8> {
    let mut body = Vec::new();
    let name = info.method.as_bytes();
    body.extend_from_slice(&(name.len() as u16).to_le_bytes());
    body.extend_from_slice(name);
    put_u32(&mut body, info.d);
    put_u32(&mut body, info.heads);
    put_u32(&mut body, info.seq);
    put_u32(&mut body, info.head_dim);
    put_u32(&mut body, info.max_batch);
    put_u64(&mut body, info.seed);
    put_u32(&mut body, info.shard_index);
    put_u32(&mut body, info.shard_count);
    frame(KIND_CONFIG, body)
}

pub fn encode_output(id: u64, out: &[f32]) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_slab(&mut body, out);
    frame(KIND_OUTPUT, body)
}

pub fn encode_error(id: u64, code: u8, message: &str) -> Vec<u8> {
    let msg = message.as_bytes();
    let msg = &msg[..msg.len().min(u16::MAX as usize)];
    let mut body = Vec::new();
    put_u64(&mut body, id);
    body.push(code);
    body.extend_from_slice(&(msg.len() as u16).to_le_bytes());
    body.extend_from_slice(msg);
    frame(KIND_ERROR, body)
}

pub fn encode_open_ok(id: u64, stream: u64) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_u64(&mut body, stream);
    frame(KIND_OPEN_OK, body)
}

// ---------------------------------------------------------------------
// frame decoders
// ---------------------------------------------------------------------

/// Read one frame header; `Ok((kind, body_len))`.
fn read_header(r: &mut impl Read) -> Result<(u8, u32), FrameError> {
    let len = read_u32(r).map_err(|e| fatal_io("reading frame length", e))?;
    if len == 0 {
        return Err(FrameError::Fatal("zero-length frame".into()));
    }
    if len > MAX_FRAME_BYTES {
        return Err(FrameError::Fatal(format!(
            "frame length {len} exceeds cap {MAX_FRAME_BYTES}"
        )));
    }
    let kind = read_u8(r).map_err(|e| fatal_io("reading frame kind", e))?;
    Ok((kind, len - 1))
}

/// Run `parse` against exactly `body_len` bytes of `r`.  A structurally
/// short or long body is drained and reported [`FrameError::Malformed`]
/// (the stream stays in sync); a body the underlying stream cannot
/// supply is [`FrameError::Fatal`].
fn with_body<R: Read, T>(
    r: &mut R,
    body_len: u32,
    parse: impl FnOnce(&mut io::Take<&mut R>) -> io::Result<(u64, T)>,
) -> Result<T, FrameError> {
    let mut body = r.take(u64::from(body_len));
    match parse(&mut body) {
        Ok((id, value)) => {
            if body.limit() == 0 {
                Ok(value)
            } else {
                drain(&mut body, id, "trailing bytes after frame body")
            }
        }
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof && body.limit() == 0 => {
            // the Take limiter ran dry: the frame was short but fully
            // consumed — recoverable
            Err(FrameError::Malformed { id: 0, reason: "frame body too short".into() })
        }
        Err(e) if e.kind() == io::ErrorKind::InvalidData => drain(&mut body, 0, "bad slab count"),
        Err(e) => Err(fatal_io("reading frame body", e)),
    }
}

/// Discard the rest of a malformed body; fatal if the stream ends first.
fn drain<R: Read, T>(body: &mut io::Take<&mut R>, id: u64, reason: &str) -> Result<T, FrameError> {
    match io::copy(body, &mut io::sink()) {
        Ok(_) if body.limit() == 0 => {
            Err(FrameError::Malformed { id, reason: reason.to_string() })
        }
        _ => Err(FrameError::Fatal("stream ended inside a frame body".into())),
    }
}

/// Decode one client→server frame.
pub fn read_client_frame(r: &mut impl Read) -> Result<ClientFrame, FrameError> {
    let (kind, body_len) = read_header(r)?;
    read_client_body(r, kind, body_len)
}

/// [`read_client_frame`] for sockets with a read timeout: a timeout (or
/// `WouldBlock`) **before the first byte of the length prefix** is the
/// recoverable [`ClientRead::Idle`] — the connection is quiet, not
/// broken.  A timeout anywhere inside a frame still reports
/// [`FrameError::Fatal`]: the peer died mid-write and the stream can
/// never resynchronize.
pub fn read_client_frame_or_idle(r: &mut impl Read) -> Result<ClientRead, FrameError> {
    let mut len_buf = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) => {
                return Err(FrameError::Fatal(if got == 0 {
                    "connection closed".into()
                } else {
                    "stream ended inside a frame header".into()
                }))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e)
                if got == 0
                    && matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
            {
                return Ok(ClientRead::Idle)
            }
            Err(e) => return Err(fatal_io("reading frame length", e)),
        }
    }
    let len = u32::from_le_bytes(len_buf);
    if len == 0 {
        return Err(FrameError::Fatal("zero-length frame".into()));
    }
    if len > MAX_FRAME_BYTES {
        return Err(FrameError::Fatal(format!("frame length {len} exceeds cap {MAX_FRAME_BYTES}")));
    }
    let kind = read_u8(r).map_err(|e| fatal_io("reading frame kind", e))?;
    read_client_body(r, kind, len - 1).map(ClientRead::Frame)
}

fn read_client_body(r: &mut impl Read, kind: u8, body_len: u32) -> Result<ClientFrame, FrameError> {
    match kind {
        KIND_SUBMIT => with_body(r, body_len, |b| {
            let id = read_u64(b)?;
            let flags = read_u8(b)?;
            let route = if flags & SUBMIT_FLAG_ROUTE != 0 {
                let head_lo = read_u32(b)?;
                let head_hi = read_u32(b)?;
                let seed = read_u64(b)?;
                Some(SubmitRoute { head_lo, head_hi, seed })
            } else {
                None
            };
            let q = read_slab(b, MAX_FRAME_BYTES / 4)?;
            let k = read_slab(b, MAX_FRAME_BYTES / 4)?;
            let v = read_slab(b, MAX_FRAME_BYTES / 4)?;
            let mask = if flags & SUBMIT_FLAG_MASK != 0 {
                Some(read_slab(b, MAX_FRAME_BYTES / 4)?)
            } else {
                None
            };
            Ok((id, ClientFrame::Submit { id, req: HeadsRequest { q, k, v, mask }, route }))
        }),
        KIND_OPEN => with_body(r, body_len, |b| {
            let id = read_u64(b)?;
            let repilot_stride = read_u32(b)?;
            let flags = read_u8(b)?;
            let stream =
                if flags & OPEN_FLAG_STREAM != 0 { Some(read_u64(b)?) } else { None };
            Ok((id, ClientFrame::Open { id, repilot_stride, stream }))
        }),
        KIND_APPEND => with_body(r, body_len, |b| {
            let id = read_u64(b)?;
            let stream = read_u64(b)?;
            let k = read_slab(b, MAX_FRAME_BYTES / 4)?;
            let v = read_slab(b, MAX_FRAME_BYTES / 4)?;
            Ok((id, ClientFrame::Append { id, stream, k, v }))
        }),
        KIND_PREFILL => with_body(r, body_len, |b| {
            let id = read_u64(b)?;
            let stream = read_u64(b)?;
            let tokens = read_u32(b)?;
            let k = read_slab(b, MAX_FRAME_BYTES / 4)?;
            let v = read_slab(b, MAX_FRAME_BYTES / 4)?;
            Ok((id, ClientFrame::Prefill { id, stream, tokens, k, v }))
        }),
        KIND_QUERY => with_body(r, body_len, |b| {
            let id = read_u64(b)?;
            let stream = read_u64(b)?;
            let rows = read_u32(b)?;
            let q = read_slab(b, MAX_FRAME_BYTES / 4)?;
            Ok((id, ClientFrame::Query { id, stream, rows, q }))
        }),
        KIND_CLOSE => with_body(r, body_len, |b| {
            let id = read_u64(b)?;
            let stream = read_u64(b)?;
            Ok((id, ClientFrame::Close { id, stream }))
        }),
        KIND_PING => with_body(r, body_len, |b| {
            let id = read_u64(b)?;
            Ok((id, ClientFrame::Ping { id }))
        }),
        KIND_STATS => with_body(r, body_len, |b| {
            let id = read_u64(b)?;
            Ok((id, ClientFrame::Stats { id }))
        }),
        other => Err(FrameError::Fatal(format!("unknown client frame kind {other:#04x}"))),
    }
}

/// Decode one server→client frame.
pub fn read_server_frame(r: &mut impl Read) -> Result<ServerFrame, FrameError> {
    let (kind, body_len) = read_header(r)?;
    read_server_body(r, kind, body_len)
}

/// [`read_server_frame`] for sockets with a read timeout — the client
/// mirror of [`read_client_frame_or_idle`], with the same
/// between-frames-recoverable / mid-frame-fatal split.  `NetClient`
/// uses the idle tick to send a ping probe instead of blocking forever
/// on a dead server.
pub fn read_server_frame_or_idle(r: &mut impl Read) -> Result<ServerRead, FrameError> {
    let mut len_buf = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) => {
                return Err(FrameError::Fatal(if got == 0 {
                    "connection closed".into()
                } else {
                    "stream ended inside a frame header".into()
                }))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e)
                if got == 0
                    && matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
            {
                return Ok(ServerRead::Idle)
            }
            Err(e) => return Err(fatal_io("reading frame length", e)),
        }
    }
    let len = u32::from_le_bytes(len_buf);
    if len == 0 {
        return Err(FrameError::Fatal("zero-length frame".into()));
    }
    if len > MAX_FRAME_BYTES {
        return Err(FrameError::Fatal(format!("frame length {len} exceeds cap {MAX_FRAME_BYTES}")));
    }
    let kind = read_u8(r).map_err(|e| fatal_io("reading frame kind", e))?;
    read_server_body(r, kind, len - 1).map(ServerRead::Frame)
}

fn read_server_body(r: &mut impl Read, kind: u8, body_len: u32) -> Result<ServerFrame, FrameError> {
    match kind {
        KIND_CONFIG => with_body(r, body_len, |b| {
            let name_len = read_u16(b)? as usize;
            let mut name = vec![0u8; name_len];
            b.read_exact(&mut name)?;
            let method = String::from_utf8(name)
                .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "bad method utf8"))?;
            let d = read_u32(b)?;
            let heads = read_u32(b)?;
            let seq = read_u32(b)?;
            let head_dim = read_u32(b)?;
            let max_batch = read_u32(b)?;
            let seed = read_u64(b)?;
            let shard_index = read_u32(b)?;
            let shard_count = read_u32(b)?;
            Ok((
                0,
                ServerFrame::Config(ServerInfo {
                    method,
                    d,
                    heads,
                    seq,
                    head_dim,
                    max_batch,
                    seed,
                    shard_index,
                    shard_count,
                }),
            ))
        }),
        KIND_OUTPUT => with_body(r, body_len, |b| {
            let id = read_u64(b)?;
            let out = read_slab(b, MAX_FRAME_BYTES / 4)?;
            Ok((id, ServerFrame::Output { id, out: out.to_vec() }))
        }),
        KIND_ERROR => with_body(r, body_len, |b| {
            let id = read_u64(b)?;
            let code = read_u8(b)?;
            let msg_len = read_u16(b)? as usize;
            let mut msg = vec![0u8; msg_len];
            b.read_exact(&mut msg)?;
            let message = String::from_utf8_lossy(&msg).into_owned();
            Ok((id, ServerFrame::Error { id, code, message }))
        }),
        KIND_OPEN_OK => with_body(r, body_len, |b| {
            let id = read_u64(b)?;
            let stream = read_u64(b)?;
            Ok((id, ServerFrame::OpenOk { id, stream }))
        }),
        KIND_PONG => with_body(r, body_len, |b| {
            let id = read_u64(b)?;
            Ok((id, ServerFrame::Pong { id }))
        }),
        KIND_STATS_OK => with_body(r, body_len, |b| {
            let id = read_u64(b)?;
            let mut c = [0u64; 15];
            for slot in c.iter_mut() {
                *slot = read_u64(b)?;
            }
            let mean_queue_ms = f64::from_bits(read_u64(b)?);
            let mean_occupancy = f64::from_bits(read_u64(b)?);
            let mean_step_occupancy = f64::from_bits(read_u64(b)?);
            let mean_batch_ms = f64::from_bits(read_u64(b)?);
            let stats = AttentionServerStats {
                requests: c[0],
                batches: c[1],
                steps: c[2],
                rejected: c[3],
                stream_appends: c[4],
                stream_queries: c[5],
                kv_hit_blocks: c[6],
                kv_alloc_blocks: c[7],
                kv_evicted_blocks: c[8],
                kv_resident_blocks: c[9],
                kv_resident_bytes: c[10],
                kv_demoted_blocks: c[11],
                kv_spilled_blocks: c[12],
                kv_spill_hits: c[13],
                kv_spill_corrupt: c[14],
                mean_queue_ms,
                mean_occupancy,
                mean_step_occupancy,
                mean_batch_ms,
            };
            let n_gauges = read_u32(b)?;
            if n_gauges > 4096 {
                return Err(io::Error::new(io::ErrorKind::InvalidData, "gauge count"));
            }
            let mut gauges = Vec::with_capacity(n_gauges as usize);
            for _ in 0..n_gauges {
                let name = read_str(b, "bad gauge name utf8")?;
                gauges.push((name, read_u64(b)?));
            }
            let n_histos = read_u32(b)?;
            if n_histos > 4096 {
                return Err(io::Error::new(io::ErrorKind::InvalidData, "histo count"));
            }
            let mut histos = Vec::with_capacity(n_histos as usize);
            for _ in 0..n_histos {
                let name = read_str(b, "bad histo name utf8")?;
                let sum = read_u64(b)?;
                let nbuckets = read_u32(b)? as usize;
                if nbuckets > 1024 {
                    return Err(io::Error::new(io::ErrorKind::InvalidData, "bucket count"));
                }
                let mut snap = HistoSnapshot { sum, ..Default::default() };
                for i in 0..nbuckets {
                    let count = read_u64(b)?;
                    // a peer with more buckets folds its tail into +Inf
                    let slot = i.min(HISTO_BUCKETS - 1);
                    snap.buckets[slot] += count;
                }
                histos.push((name, snap));
            }
            let n_shards = read_u32(b)?;
            if n_shards > 4096 {
                return Err(io::Error::new(io::ErrorKind::InvalidData, "shard count"));
            }
            let mut shards = Vec::with_capacity(n_shards as usize);
            for _ in 0..n_shards {
                let addr = read_str(b, "bad shard addr utf8")?;
                let heartbeat_age_ms = read_u64(b)?;
                let pending = read_u64(b)?;
                let down_drains = read_u64(b)?;
                let queue_depth = read_u64(b)?;
                let alive = read_u8(b)? != 0;
                shards.push(ShardHealth {
                    addr,
                    heartbeat_age_ms,
                    pending,
                    down_drains,
                    queue_depth,
                    alive,
                });
            }
            let stats = Box::new(StatsWire { stats, gauges, histos, shards });
            Ok((id, ServerFrame::StatsOk { id, stats }))
        }),
        other => Err(FrameError::Fatal(format!("unknown server frame kind {other:#04x}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip_client(bytes: Vec<u8>) -> Result<ClientFrame, FrameError> {
        read_client_frame(&mut Cursor::new(bytes))
    }

    #[test]
    fn submit_roundtrips_with_and_without_mask() {
        let req = HeadsRequest::from_vecs(vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]);
        match roundtrip_client(encode_submit(7, &req)).unwrap() {
            ClientFrame::Submit { id, req: got, route } => {
                assert_eq!(id, 7);
                assert_eq!(&got.q[..], &[1.0, 2.0]);
                assert_eq!(&got.k[..], &[3.0, 4.0]);
                assert_eq!(&got.v[..], &[5.0, 6.0]);
                assert!(got.mask.is_none());
                assert!(route.is_none());
            }
            other => panic!("wrong frame: {other:?}"),
        }
        let masked = req.with_mask(vec![1.0, 0.0]);
        match roundtrip_client(encode_submit(8, &masked)).unwrap() {
            ClientFrame::Submit { req: got, .. } => {
                assert_eq!(&got.mask.unwrap()[..], &[1.0, 0.0]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn routed_submit_roundtrips_with_and_without_mask() {
        let route = SubmitRoute { head_lo: 2, head_hi: 5, seed: 0xDEAD_BEEF_u64 };
        let req = HeadsRequest::from_vecs(vec![1.0], vec![2.0], vec![3.0]);
        match roundtrip_client(encode_submit_routed(9, &req, Some(route))).unwrap() {
            ClientFrame::Submit { id, req: got, route: got_route } => {
                assert_eq!(id, 9);
                assert_eq!(got_route, Some(route));
                assert!(got.mask.is_none());
                assert_eq!(&got.q[..], &[1.0]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        let masked = req.with_mask(vec![0.0]);
        match roundtrip_client(encode_submit_routed(10, &masked, Some(route))).unwrap() {
            ClientFrame::Submit { req: got, route: got_route, .. } => {
                assert_eq!(got_route, Some(route));
                assert_eq!(&got.mask.unwrap()[..], &[0.0]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn open_with_explicit_stream_roundtrips() {
        match roundtrip_client(encode_open_with_stream(6, 4, Some(17))).unwrap() {
            ClientFrame::Open { id, repilot_stride, stream } => {
                assert_eq!((id, repilot_stride, stream), (6, 4, Some(17)));
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn heartbeat_and_stats_frames_roundtrip() {
        match roundtrip_client(encode_ping(21)).unwrap() {
            ClientFrame::Ping { id } => assert_eq!(id, 21),
            other => panic!("wrong frame: {other:?}"),
        }
        match roundtrip_client(encode_stats_req(22)).unwrap() {
            ClientFrame::Stats { id } => assert_eq!(id, 22),
            other => panic!("wrong frame: {other:?}"),
        }
        match read_server_frame(&mut Cursor::new(encode_pong(23))).unwrap() {
            ServerFrame::Pong { id } => assert_eq!(id, 23),
            other => panic!("wrong frame: {other:?}"),
        }
        let stats = StatsWire::from_stats(AttentionServerStats {
            requests: 5,
            batches: 3,
            steps: 7,
            rejected: 1,
            stream_appends: 40,
            stream_queries: 11,
            kv_hit_blocks: 2,
            kv_resident_bytes: 1 << 20,
            mean_step_occupancy: 0.625,
            mean_batch_ms: 1.75,
            ..Default::default()
        });
        match read_server_frame(&mut Cursor::new(encode_stats_ok(24, &stats))).unwrap() {
            ServerFrame::StatsOk { id, stats: got } => {
                assert_eq!(id, 24);
                assert_eq!(got.stats.requests, 5);
                assert_eq!(got.stats.steps, 7);
                assert_eq!(got.stats.stream_appends, 40);
                assert_eq!(got.stats.kv_resident_bytes, 1 << 20);
                assert_eq!(got.stats.mean_step_occupancy.to_bits(), 0.625f64.to_bits());
                assert_eq!(got.stats.mean_batch_ms.to_bits(), 1.75f64.to_bits());
                assert!(got.gauges.is_empty() && got.histos.is_empty() && got.shards.is_empty());
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn stats_ok_roundtrips_gauges_histos_and_shards() {
        let mut h = HistoSnapshot::default();
        h.sum = 12_345;
        h.buckets[0] = 2;
        h.buckets[7] = 5;
        h.buckets[HISTO_BUCKETS - 1] = 1;
        let stats = StatsWire {
            stats: AttentionServerStats { requests: 9, ..Default::default() },
            gauges: vec![("skein_queue_depth".into(), 3), ("skein_trace_dropped_total".into(), 0)],
            histos: vec![("skein_queue_wait_ns".into(), h)],
            shards: vec![
                ShardHealth {
                    addr: "127.0.0.1:7971".into(),
                    heartbeat_age_ms: 120,
                    pending: 2,
                    down_drains: 0,
                    queue_depth: 4,
                    alive: true,
                },
                ShardHealth { addr: "127.0.0.1:7972".into(), alive: false, ..Default::default() },
            ],
        };
        match read_server_frame(&mut Cursor::new(encode_stats_ok(31, &stats))).unwrap() {
            ServerFrame::StatsOk { id, stats: got } => {
                assert_eq!(id, 31);
                assert_eq!(*got, stats);
                assert_eq!(got.histos[0].1.count(), 8);
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    /// A reader that yields `WouldBlock` once the scripted bytes run
    /// out — the shape of a socket with a read timeout and no traffic.
    struct TimeoutAfter {
        bytes: Cursor<Vec<u8>>,
    }

    impl Read for TimeoutAfter {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            match self.bytes.read(buf) {
                Ok(0) => Err(io::Error::new(io::ErrorKind::WouldBlock, "idle")),
                other => other,
            }
        }
    }

    #[test]
    fn idle_timeout_between_frames_is_recoverable_but_mid_frame_is_fatal() {
        // no bytes at all: Idle
        let mut quiet = TimeoutAfter { bytes: Cursor::new(Vec::new()) };
        assert!(matches!(read_client_frame_or_idle(&mut quiet), Ok(ClientRead::Idle)));
        // a whole frame then silence: the frame decodes, the next read is Idle
        let mut one = TimeoutAfter { bytes: Cursor::new(encode_close(3, 4)) };
        match read_client_frame_or_idle(&mut one).unwrap() {
            ClientRead::Frame(ClientFrame::Close { id, stream }) => {
                assert_eq!((id, stream), (3, 4));
            }
            other => panic!("wrong read: {other:?}"),
        }
        assert!(matches!(read_client_frame_or_idle(&mut one), Ok(ClientRead::Idle)));
        // silence striking inside a frame is fatal — the stream can
        // never resynchronize
        let full = encode_close(5, 6);
        let mut torn = TimeoutAfter { bytes: Cursor::new(full[..full.len() - 3].to_vec()) };
        assert!(matches!(read_client_frame_or_idle(&mut torn), Err(FrameError::Fatal(_))));
    }

    #[test]
    fn stream_frames_roundtrip() {
        match roundtrip_client(encode_open(1, 3)).unwrap() {
            ClientFrame::Open { id, repilot_stride, stream } => {
                assert_eq!((id, repilot_stride), (1, 3));
                assert!(stream.is_none());
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match roundtrip_client(encode_append(2, 9, &[1.0], &[2.0])).unwrap() {
            ClientFrame::Append { id, stream, k, v } => {
                assert_eq!((id, stream), (2, 9));
                assert_eq!((&k[..], &v[..]), (&[1.0f32][..], &[2.0f32][..]));
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match roundtrip_client(encode_prefill(3, 9, 2, &[1.0, 2.0], &[3.0, 4.0])).unwrap() {
            ClientFrame::Prefill { tokens, k, .. } => {
                assert_eq!(tokens, 2);
                assert_eq!(&k[..], &[1.0, 2.0]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match roundtrip_client(encode_query(4, 9, 1, &[0.5])).unwrap() {
            ClientFrame::Query { rows, q, .. } => {
                assert_eq!(rows, 1);
                assert_eq!(&q[..], &[0.5]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match roundtrip_client(encode_close(5, 9)).unwrap() {
            ClientFrame::Close { id, stream } => assert_eq!((id, stream), (5, 9)),
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn server_frames_roundtrip() {
        let info = ServerInfo {
            method: "skeinformer".into(),
            d: 64,
            heads: 4,
            seq: 512,
            head_dim: 32,
            max_batch: 8,
            seed: 99,
            shard_index: 1,
            shard_count: 4,
        };
        match read_server_frame(&mut Cursor::new(encode_config(&info))).unwrap() {
            ServerFrame::Config(got) => assert_eq!(got, info),
            other => panic!("wrong frame: {other:?}"),
        }
        match read_server_frame(&mut Cursor::new(encode_output(11, &[1.5, -2.5]))).unwrap() {
            ServerFrame::Output { id, out } => {
                assert_eq!(id, 11);
                assert_eq!(out, vec![1.5, -2.5]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match read_server_frame(&mut Cursor::new(encode_error(12, 2, "unknown stream 9"))).unwrap()
        {
            ServerFrame::Error { id, code, message } => {
                assert_eq!((id, code), (12, 2));
                assert_eq!(message, "unknown stream 9");
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match read_server_frame(&mut Cursor::new(encode_open_ok(13, 4))).unwrap() {
            ServerFrame::OpenOk { id, stream } => assert_eq!((id, stream), (13, 4)),
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn hello_verifies_magic_and_version() {
        let mut buf = Vec::new();
        write_hello(&mut buf).unwrap();
        assert!(read_hello(&mut Cursor::new(buf.clone())).is_ok());
        let mut bad_magic = buf.clone();
        bad_magic[0] ^= 0xFF;
        assert!(matches!(
            read_hello(&mut Cursor::new(bad_magic)),
            Err(FrameError::Fatal(_))
        ));
        let mut bad_version = buf;
        bad_version[4] ^= 0xFF;
        assert!(matches!(
            read_hello(&mut Cursor::new(bad_version)),
            Err(FrameError::Fatal(_))
        ));
    }

    #[test]
    fn short_body_is_recoverable_and_leaves_the_stream_in_sync() {
        // an append frame whose body claims more slab elements than the
        // frame holds: malformed, but the next frame must still decode
        let mut bytes = encode_append(1, 2, &[1.0, 2.0], &[3.0, 4.0]);
        // corrupt the k-slab count (body offset: 8 id + 8 stream)
        let count_at = 4 + 1 + 8 + 8;
        bytes[count_at..count_at + 4].copy_from_slice(&100u32.to_le_bytes());
        bytes.extend_from_slice(&encode_close(9, 2));
        let mut cur = Cursor::new(bytes);
        assert!(matches!(
            read_client_frame(&mut cur),
            Err(FrameError::Malformed { .. })
        ));
        match read_client_frame(&mut cur).unwrap() {
            ClientFrame::Close { id, stream } => assert_eq!((id, stream), (9, 2)),
            other => panic!("stream out of sync after malformed frame: {other:?}"),
        }
    }

    #[test]
    fn trailing_bytes_are_recoverable() {
        // a close frame with 3 junk bytes appended inside its length
        let inner = encode_close(5, 6);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&((inner.len() - 4 + 3) as u32).to_le_bytes());
        bytes.extend_from_slice(&inner[4..]);
        bytes.extend_from_slice(&[0xAA, 0xBB, 0xCC]);
        bytes.extend_from_slice(&encode_close(7, 8));
        let mut cur = Cursor::new(bytes);
        assert!(matches!(
            read_client_frame(&mut cur),
            Err(FrameError::Malformed { id: 5, .. })
        ));
        match read_client_frame(&mut cur).unwrap() {
            ClientFrame::Close { id, .. } => assert_eq!(id, 7),
            other => panic!("stream out of sync: {other:?}"),
        }
    }

    #[test]
    fn fatal_conditions_close_the_connection() {
        // unknown kind
        let mut bytes = vec![0u8; 0];
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.push(0x7F);
        bytes.push(0);
        assert!(matches!(
            read_client_frame(&mut Cursor::new(bytes)),
            Err(FrameError::Fatal(_))
        ));
        // oversized length prefix
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&(MAX_FRAME_BYTES + 1).to_le_bytes());
        bytes.push(KIND_CLOSE);
        assert!(matches!(
            read_client_frame(&mut Cursor::new(bytes)),
            Err(FrameError::Fatal(_))
        ));
        // truncated mid-frame: header promises more than the stream holds
        let full = encode_close(1, 2);
        let truncated = full[..full.len() - 4].to_vec();
        assert!(matches!(
            read_client_frame(&mut Cursor::new(truncated)),
            Err(FrameError::Fatal(_))
        ));
        // zero-length frame
        let bytes = 0u32.to_le_bytes().to_vec();
        assert!(matches!(
            read_client_frame(&mut Cursor::new(bytes)),
            Err(FrameError::Fatal(_))
        ));
    }

    #[test]
    fn slab_ingest_is_bitwise() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32).sin()).collect();
        let mut buf = Vec::new();
        put_f32s(&mut buf, &xs);
        let slab = read_f32_slab(&mut Cursor::new(buf), xs.len()).unwrap();
        assert_eq!(&slab[..], &xs[..]);
    }
}
