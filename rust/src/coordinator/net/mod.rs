//! TCP front end for the engine-backed
//! [`attention_server`](crate::coordinator::attention_server): a
//! length-prefixed binary wire protocol ([`wire`]), an accept loop
//! feeding the serve thread ([`server`]), and a small blocking client
//! ([`client`]) — the plumbing behind `skein serve --listen ADDR` and
//! `skein client`.
//!
//! Layering: [`wire`] owns bytes (framing, zero-copy `Arc<[f32]>` slab
//! ingest, recoverable-vs-fatal decode errors), [`server`] owns threads
//! (one reader + one writer per connection, bounded queues both ways so
//! a slow client cannot OOM or stall the serve thread), and the serve
//! loop itself is untouched transport-wise — wire connections are just
//! more [`ServerConnection`](crate::coordinator::attention_server::ServerConnection)s,
//! so the continuous-batching scheduler, per-connection fairness, and
//! seed derivation are identical to the in-process path and served
//! bytes are bitwise identical (pinned by `rust/tests/serving_net.rs`).
//!
//! The accept loop is generic over a [`WireBackend`]: `skein serve
//! --listen` plugs in the in-process engine, `skein coordinator` plugs
//! in the shard scatter/gather layer
//! ([`crate::coordinator::shard::Coordinator`]) — same protocol, same
//! client.

pub mod client;
pub mod server;
pub mod wire;

pub use client::{ClientError, NetClient, NetTimeouts};
pub use server::{
    serve, serve_backend, EngineBackend, NetServer, WireBackend, WireLane, READ_IDLE_BUDGET,
    READ_IDLE_PROBE, WRITER_QUEUE_FRAMES,
};
pub use wire::{ServerInfo, ShardHealth, StatsWire, MAGIC, MAX_FRAME_BYTES, VERSION};
