//! Shard coordinator: scale the serving layer across engine processes.
//!
//! A [`Coordinator`] accepts client traffic on the same wire protocol
//! as a single `skein serve --listen` worker
//! ([`net`](crate::coordinator::net) — clients cannot tell the
//! difference) and spreads the work over N engine shards, each an
//! ordinary `skein serve --listen` process:
//!
//! - **One-shot requests scatter by head range.**  Heads `[0, H)` are
//!   split contiguously across the live shards; because slabs are
//!   head-major, each sub-request is a zero-copy *slice* of the
//!   client's `Arc<[f32]>` slabs, written straight to the shard socket
//!   ([`wire::encode_submit_sliced`](crate::coordinator::net::wire::encode_submit_sliced)).
//!   Every sub-request carries a
//!   [`SubmitRoute`](crate::coordinator::attention_server::SubmitRoute)
//!   pinning the global
//!   head offset and the request seed
//!   (`batch_seed(coordinator_seed, request_index)`), so head `h`
//!   computes with `Rng::new(seed ^ h_global)` exactly as one process
//!   would have — the gathered output is bitwise identical no matter
//!   how the shards batch the fragments.
//! - **Decode streams route whole, by prompt prefix.**  Per-stream KV
//!   state cannot be split the way stateless one-shots can, so a
//!   stream is homed on the consistent-hash [`ring`] keyed by the
//!   FNV-1a hash of its first ingested K chunk.  Repeats of a prompt
//!   land on the same shard, keeping that shard's `PrefixIndex` and
//!   tiered KV cache hot; when the ring changes, only the dead/new
//!   shard's arc re-homes.  Re-homed prompts warm-restart from the
//!   content-addressed spill manifests when the shards share a
//!   `--kv-spill-dir`.
//! - **Failure degrades typed, never hangs.**  A heartbeat thread
//!   pings every shard; a closed socket kills its connection
//!   immediately and silence past the miss budget kills it too.
//!   Killing a connection drains every in-flight completion with
//!   [`ServeError::ShardDown`](crate::coordinator::attention_server::ServeError),
//!   so scattered requests and homed streams answer with a typed error
//!   while the ring re-forms around the survivors.
//!
//! Surfaced as `skein coordinator --shards H1:P1,H2:P2,... --listen
//! ADDR`; shards advertise their placement via `skein serve --shard-of
//! N --shard-index I`.  All shards must run the same shape and
//! `--seed` as each other (checked at connect from the config
//! handshake).  See `DESIGN.md` §7 and `rust/tests/sharding.rs`.

mod conn;
mod coordinator;
pub mod ring;

pub use coordinator::{Coordinator, DEFAULT_HEARTBEAT, HEARTBEAT_MISSES};
