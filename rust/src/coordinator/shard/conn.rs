//! One coordinator→shard connection: a wire-protocol socket with an
//! asynchronous reader thread and a pending-completion map.
//!
//! Unlike [`NetClient`](crate::coordinator::net::NetClient) (strictly
//! call-and-wait), a [`ShardConn`] must keep many requests in flight —
//! one scattered client request fans sub-requests across every shard —
//! so replies are matched to completions by request id on a dedicated
//! reader thread.  Every registered completion is guaranteed exactly
//! one verdict: a matching reply, or `ServeError::ShardDown` when the
//! connection dies ([`ShardConn::kill`] drains the map).  That verdict
//! discipline is what makes coordinator failover hang-free.
//!
//! Liveness: any received frame stamps `last_rx`.  The coordinator's
//! heartbeat thread sends pings and kills connections whose `last_rx`
//! goes stale; a closed or errored socket kills the connection
//! immediately from the reader thread.

use crate::coordinator::attention_server::{ReplyTo, ServeError, SubmitRoute};
use crate::coordinator::net::wire::{
    encode_append, encode_close, encode_open_with_stream, encode_ping, encode_prefill,
    encode_query, encode_stats_req, encode_submit_sliced, read_hello, read_server_frame,
    write_hello, ServerFrame, ServerInfo, StatsWire,
};
use crate::coordinator::net::NetTimeouts;
use crate::obs::{ServeTelemetry, Span};
use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// What a registered request id is waiting for.
enum Expect {
    /// An `Output` (or `Error`) frame — fired with the slab or the
    /// relayed [`ServeError::Remote`].
    Output(ReplyTo),
    /// An `OpenOk` ack.  The receiver half may already be dropped
    /// (fire-and-forget opens); the send then fails silently.
    Open(mpsc::Sender<Result<u64, ServeError>>),
    /// A `StatsOk` snapshot.
    Stats(mpsc::Sender<Result<StatsWire, ServeError>>),
}

impl Expect {
    /// Deliver a terminal failure (connection death / drain).
    fn fail(self, e: ServeError) {
        match self {
            Expect::Output(reply) => reply.send(Err(e)),
            Expect::Open(tx) => {
                let _ = tx.send(Err(e));
            }
            Expect::Stats(tx) => {
                let _ = tx.send(Err(e));
            }
        }
    }
}

/// A live (until killed) connection to one engine shard.
pub(crate) struct ShardConn {
    addr: String,
    info: ServerInfo,
    sock: TcpStream,
    w: Mutex<BufWriter<TcpStream>>,
    /// Pending completions keyed by request id; the `u64` is the
    /// telemetry send timestamp (0 when disabled) closing a `ShardRtt`
    /// span when the reply matches.
    pending: Mutex<HashMap<u64, (u64, Expect)>>,
    next_id: AtomicU64,
    last_rx: Mutex<Instant>,
    dead: AtomicBool,
    /// Cumulative completions drained with `ShardDown` by [`kill`](Self::kill).
    down_drains: AtomicU64,
    obs: Arc<ServeTelemetry>,
}

impl ShardConn {
    /// Connect, handshake, and start the reader thread.
    pub(crate) fn connect(
        addr: &str,
        timeouts: NetTimeouts,
        obs: Arc<ServeTelemetry>,
    ) -> io::Result<Arc<ShardConn>> {
        let mut last_err: Option<io::Error> = None;
        let mut sock = None;
        for resolved in addr.to_socket_addrs()? {
            match TcpStream::connect_timeout(&resolved, timeouts.connect) {
                Ok(s) => {
                    sock = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let Some(sock) = sock else {
            return Err(last_err.unwrap_or_else(|| {
                io::Error::new(io::ErrorKind::InvalidInput, "address resolved to nothing")
            }));
        };
        let _ = sock.set_nodelay(true);
        // the handshake is the one blocking read on this thread: bound it
        sock.set_read_timeout(Some(timeouts.read))?;
        sock.set_write_timeout(Some(timeouts.write))?;
        let mut w = BufWriter::new(sock.try_clone()?);
        write_hello(&mut w)?;
        w.flush()?;
        let mut r = BufReader::new(sock.try_clone()?);
        read_hello(&mut r).map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?;
        let info = match read_server_frame(&mut r) {
            Ok(ServerFrame::Config(info)) => info,
            other => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("expected config frame from shard, got {other:?}"),
                ))
            }
        };
        // after the handshake the reader blocks indefinitely; death is
        // signalled by socket close (ours via kill(), theirs via EOF)
        sock.set_read_timeout(None)?;
        let conn = Arc::new(ShardConn {
            addr: addr.to_string(),
            info,
            sock,
            w: Mutex::new(w),
            pending: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(0),
            last_rx: Mutex::new(Instant::now()),
            dead: AtomicBool::new(false),
            down_drains: AtomicU64::new(0),
            obs,
        });
        {
            let conn = Arc::clone(&conn);
            std::thread::spawn(move || reader_loop(r, conn));
        }
        Ok(conn)
    }

    /// The shard's address as configured.
    pub(crate) fn addr(&self) -> &str {
        &self.addr
    }

    /// The shape the shard advertised at handshake.
    pub(crate) fn info(&self) -> &ServerInfo {
        &self.info
    }

    /// True once the connection has been killed (socket death, missed
    /// heartbeats, or coordinator shutdown).
    pub(crate) fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Instant of the most recently received frame.
    pub(crate) fn last_rx(&self) -> Instant {
        *self.last_rx.lock().unwrap()
    }

    /// Completions currently awaiting a reply from this shard.
    pub(crate) fn pending_depth(&self) -> u64 {
        self.pending.lock().unwrap().len() as u64
    }

    /// Cumulative completions failed with `ShardDown` by [`kill`](Self::kill).
    pub(crate) fn down_drains(&self) -> u64 {
        self.down_drains.load(Ordering::Relaxed)
    }

    fn down(&self) -> ServeError {
        ServeError::ShardDown { shard: self.addr.clone() }
    }

    /// Mark dead, close the socket, and fail every pending completion
    /// with `ShardDown`.  Idempotent; callable from any thread.
    pub(crate) fn kill(&self) {
        if self.dead.swap(true, Ordering::SeqCst) {
            return;
        }
        let _ = self.sock.shutdown(Shutdown::Both);
        let drained: Vec<Expect> = {
            let mut pending = self.pending.lock().unwrap();
            pending.drain().map(|(_, (_, e))| e).collect()
        };
        self.down_drains.fetch_add(drained.len() as u64, Ordering::Relaxed);
        for expect in drained {
            expect.fail(self.down());
        }
    }

    /// Register `expect` under a fresh id and send `frame(id)`.  On a
    /// dead connection or send failure the expectation fails with
    /// `ShardDown` (never silently dropped).
    fn send_expect(
        &self,
        expect: Option<Expect>,
        frame: impl FnOnce(u64) -> Vec<u8>,
    ) -> Result<(), ServeError> {
        if self.is_dead() {
            if let Some(e) = expect {
                e.fail(self.down());
            }
            return Err(self.down());
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if let Some(e) = expect {
            self.pending.lock().unwrap().insert(id, (self.obs.now(), e));
        }
        let bytes = frame(id);
        let sent = {
            let mut w = self.w.lock().unwrap();
            w.write_all(&bytes).and_then(|_| w.flush())
        };
        if sent.is_err() {
            // kill() drains the expectation we just registered
            self.kill();
            return Err(self.down());
        }
        Ok(())
    }

    /// Scatter one sub-request: slices of the client's slabs plus the
    /// head-range route.  `reply` gets the `[width, seq, head_dim]`
    /// output slab or a typed error.
    pub(crate) fn submit_sliced(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        mask: Option<&[f32]>,
        route: SubmitRoute,
        reply: ReplyTo,
    ) {
        let _ = self.send_expect(Some(Expect::Output(reply)), |id| {
            encode_submit_sliced(id, q, k, v, mask, Some(route))
        });
    }

    /// Open a stream under the coordinator's global id.  Fire-and-forget:
    /// the wire preserves op order, so ops queued behind the open apply
    /// after it; the `OpenOk` ack is consumed and discarded.
    pub(crate) fn open_stream(&self, stream: u64, repilot_stride: u32) -> Result<(), ServeError> {
        let (tx, _rx) = mpsc::channel();
        self.send_expect(Some(Expect::Open(tx)), |id| {
            encode_open_with_stream(id, repilot_stride, Some(stream))
        })
    }

    /// Forward one single-token append.  Fire-and-forget (the engine
    /// answers only on error, and those surface on the stream's next
    /// query).
    pub(crate) fn append(&self, stream: u64, k: &[f32], v: &[f32]) -> Result<(), ServeError> {
        self.send_expect(None, |id| encode_append(id, stream, k, v))
    }

    /// Forward one bulk append.  Fire-and-forget like
    /// [`append`](Self::append).
    pub(crate) fn prefill(
        &self,
        stream: u64,
        tokens: u32,
        k: &[f32],
        v: &[f32],
    ) -> Result<(), ServeError> {
        self.send_expect(None, |id| encode_prefill(id, stream, tokens, k, v))
    }

    /// Forward one query; `reply` gets the output slab or a typed error.
    pub(crate) fn query(&self, stream: u64, rows: u32, q: &[f32], reply: ReplyTo) {
        let _ = self.send_expect(Some(Expect::Output(reply)), |id| {
            encode_query(id, stream, rows, q)
        });
    }

    /// Forward a stream close (fire-and-forget).
    pub(crate) fn close_stream(&self, stream: u64) -> Result<(), ServeError> {
        self.send_expect(None, |id| encode_close(id, stream))
    }

    /// Send a heartbeat ping; the pong stamps `last_rx`.
    pub(crate) fn ping(&self) {
        let _ = self.send_expect(None, encode_ping);
    }

    /// Poll the shard's live stats (blocking; bounded by connection
    /// death — a killed connection fails the wait with `ShardDown`).
    pub(crate) fn stats(&self) -> Result<StatsWire, ServeError> {
        let (tx, rx) = mpsc::channel();
        self.send_expect(Some(Expect::Stats(tx)), encode_stats_req)?;
        rx.recv().unwrap_or_else(|_| Err(self.down()))
    }
}

/// Match replies to pending completions until the connection dies.
fn reader_loop(mut r: BufReader<TcpStream>, conn: Arc<ShardConn>) {
    loop {
        let frame = match read_server_frame(&mut r) {
            Ok(f) => f,
            Err(_) => break, // EOF, socket error, or desync: the shard is gone
        };
        *conn.last_rx.lock().unwrap() = Instant::now();
        // matched replies close a ShardRtt span opened at send time
        let take = |id: u64| -> Option<Expect> {
            let (t0, expect) = conn.pending.lock().unwrap().remove(&id)?;
            conn.obs.span(Span::ShardRtt, t0, 0, id);
            Some(expect)
        };
        match frame {
            ServerFrame::Output { id, out } => {
                if let Some(Expect::Output(reply)) = take(id) {
                    reply.send(Ok(out));
                }
            }
            ServerFrame::Error { id, code, message } => match take(id) {
                Some(expect) => expect.fail(ServeError::Remote { code, message }),
                // an unregistered id is a fire-and-forget op's error
                // report (append/prefill/close): the coordinator
                // validated shapes up front, so this is a semantic race
                // that the stream's next reply-bearing op will surface
                None => {}
            },
            ServerFrame::OpenOk { id, stream } => {
                if let Some(Expect::Open(tx)) = take(id) {
                    let _ = tx.send(Ok(stream));
                }
            }
            ServerFrame::StatsOk { id, stats } => {
                if let Some(Expect::Stats(tx)) = take(id) {
                    let _ = tx.send(Ok(*stats));
                }
            }
            ServerFrame::Pong { .. } => {} // last_rx already stamped
            ServerFrame::Config(_) => break, // protocol violation: desync
        }
    }
    conn.kill();
}
