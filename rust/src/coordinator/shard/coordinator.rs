//! The coordinator proper: scatter/gather over one-shot head ranges,
//! prefix-affinity stream homing, heartbeat failover, and cluster
//! stats aggregation.  See the [module docs](super) for the invariants.

use super::conn::ShardConn;
use super::ring::{prefix_hash, HashRing};
use crate::coordinator::attention_server::{
    batch_seed, validate_request, AttentionServerConfig, AttentionServerStats, HeadsRequest,
    ReplyTo, ServeError, StreamOp, SubmitRoute,
};
use crate::coordinator::net::{
    NetTimeouts, ServerInfo, ShardHealth, StatsWire, WireBackend, WireLane,
};
use crate::obs::{HistoSnapshot, ServeTelemetry, Span};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

/// Default heartbeat cadence (`skein coordinator --heartbeat-ms`).
pub const DEFAULT_HEARTBEAT: Duration = Duration::from_millis(1000);

/// Missed-heartbeat multiplier: a shard silent for `HEARTBEAT_MISSES ×
/// heartbeat` is declared dead (its socket is also watched directly, so
/// a *closed* shard is detected immediately — this bound only covers
/// silent partitions).
pub const HEARTBEAT_MISSES: u32 = 3;

/// Where a decode stream lives.
enum StreamRoute {
    /// Opened but no tokens ingested yet — homing waits for the first
    /// chunk so the prompt prefix can drive placement.
    Unrouted { repilot_stride: u32 },
    /// Homed on one shard (whole stream: per-stream KV state cannot be
    /// split the way per-head one-shots can).
    Homed { shard: Arc<ShardConn> },
}

/// Shared state behind every lane, the backend, and the heartbeat
/// thread.
struct CoordShared {
    /// Shape/validation config assembled from the shard handshakes;
    /// `validate_request` against this keeps coordinator rejections
    /// byte-identical to the engine's.
    cfg: AttentionServerConfig,
    /// All shards ever added; dead ones stay (flagged) so ring indices
    /// remain stable.
    shards: RwLock<Vec<Arc<ShardConn>>>,
    ring: RwLock<HashRing>,
    streams: Mutex<HashMap<u64, StreamRoute>>,
    next_stream: AtomicU64,
    /// One-shot request counter: request `r` is pinned to
    /// `batch_seed(cfg.seed, r)`, mirroring a single engine executing
    /// call-and-wait submissions as singleton batches.
    next_request: AtomicU64,
    stop: AtomicBool,
    timeouts: NetTimeouts,
    /// Coordinator-side telemetry: scatter encode, per-shard RTT, and
    /// gather wait spans.  Shard-side spans live in the shards' own
    /// bundles and arrive merged through their `Stats` replies.
    obs: Arc<ServeTelemetry>,
}

impl CoordShared {
    fn no_live(&self) -> ServeError {
        ServeError::ShardDown { shard: "no live shards".into() }
    }

    /// Snapshot of the live connections.
    fn live(&self) -> Vec<Arc<ShardConn>> {
        self.shards.read().unwrap().iter().filter(|c| !c.is_dead()).cloned().collect()
    }

    /// Rebuild the ring over the currently-live shard set.
    fn rebuild_ring(&self) {
        let shards = self.shards.read().unwrap();
        let ring = HashRing::build(
            shards
                .iter()
                .enumerate()
                .filter(|(_, c)| !c.is_dead())
                .map(|(i, c)| (i, c.addr())),
        );
        *self.ring.write().unwrap() = ring;
    }

    /// The live shard owning `key`, rebuilding the ring past any shard
    /// that died since the last rebuild.
    fn home_for(&self, key: u64) -> Result<Arc<ShardConn>, ServeError> {
        for _ in 0..4 {
            let Some(idx) = self.ring.read().unwrap().route(key) else {
                return Err(self.no_live());
            };
            let conn = Arc::clone(&self.shards.read().unwrap()[idx]);
            if !conn.is_dead() {
                return Ok(conn);
            }
            self.rebuild_ring();
        }
        Err(self.no_live())
    }

    /// Scatter heads `[lo, hi)` of one request across the live shards
    /// and gather the contiguous head-major output.  Every sub-request
    /// carries the same pinned `seed`, so shard-side batching cannot
    /// perturb results; any sub-failure answers `reply` with the first
    /// typed error (never a hang: every registered completion gets
    /// exactly one verdict, `ShardDown` included).
    fn scatter(&self, req: &HeadsRequest, lo: usize, hi: usize, seed: u64, reply: ReplyTo) {
        let live = self.live();
        if live.is_empty() {
            reply.send(Err(self.no_live()));
            return;
        }
        let per_head = self.cfg.seq * self.cfg.head_dim;
        let width = hi - lo;
        let parts = live.len().min(width);
        let base = width / parts;
        let extra = width % parts;
        struct Gather {
            out: Vec<f32>,
            remaining: usize,
            reply: Option<ReplyTo>,
            /// Telemetry scatter timestamp (0 when disabled): a
            /// `GatherWait` span closes when the gather resolves.
            t0_ns: u64,
            obs: Arc<ServeTelemetry>,
        }
        impl Gather {
            fn resolve(&mut self) -> Option<ReplyTo> {
                let reply = self.reply.take()?;
                self.obs.span(Span::GatherWait, self.t0_ns, 0, 0);
                Some(reply)
            }
        }
        let t_scatter = self.obs.now();
        let gather = Arc::new(Mutex::new(Gather {
            out: vec![0.0; width * per_head],
            remaining: parts,
            reply: Some(reply),
            t0_ns: t_scatter,
            obs: Arc::clone(&self.obs),
        }));
        let mut cursor = lo;
        for (i, shard) in live.iter().take(parts).enumerate() {
            let sub_lo = cursor;
            let sub_hi = sub_lo + base + usize::from(i < extra);
            cursor = sub_hi;
            let off = (sub_lo - lo) * per_head;
            let g = Arc::clone(&gather);
            let cb = ReplyTo::from_fn(move |r| {
                let mut g = g.lock().unwrap();
                match r {
                    Ok(part) => {
                        let end = off + part.len();
                        if end <= g.out.len() {
                            g.out[off..end].copy_from_slice(&part);
                        }
                        g.remaining -= 1;
                        if g.remaining == 0 {
                            if let Some(reply) = g.resolve() {
                                let out = std::mem::take(&mut g.out);
                                reply.send(Ok(out));
                            }
                        }
                    }
                    Err(e) => {
                        if let Some(reply) = g.resolve() {
                            reply.send(Err(e));
                        }
                    }
                }
            });
            // head-major layout: a head range is one contiguous slice
            // of each client slab — scatter slices in place, no copies
            shard.submit_sliced(
                &req.q[sub_lo * per_head..sub_hi * per_head],
                &req.k[sub_lo * per_head..sub_hi * per_head],
                &req.v[sub_lo * per_head..sub_hi * per_head],
                req.mask.as_deref(),
                SubmitRoute { head_lo: sub_lo as u32, head_hi: sub_hi as u32, seed },
                cb,
            );
        }
        // slab slicing + sub-request sends for this scatter are done;
        // the per-shard RTTs and the gather tail run from here
        self.obs.span(Span::ScatterEncode, t_scatter, 0, 0);
    }

    /// Merge the live shards' stats payloads into one cluster view:
    /// engine counters via [`AttentionServerStats::merge_weighted`],
    /// gauges summed by name, histograms merged bucket-wise by name
    /// (exact — see [`HistoSnapshot::merge`]), plus the coordinator's
    /// own scatter/RTT/gather histograms and one [`ShardHealth`] row
    /// per shard ever added (dead ones flagged, not dropped).
    fn merged_stats(&self) -> StatsWire {
        fn add_gauges(into: &mut Vec<(String, u64)>, from: &[(String, u64)]) {
            for (name, v) in from {
                match into.iter_mut().find(|(n, _)| n == name) {
                    Some((_, acc)) => *acc += v,
                    None => into.push((name.clone(), *v)),
                }
            }
        }
        fn add_histos(into: &mut Vec<(String, HistoSnapshot)>, from: &[(String, HistoSnapshot)]) {
            for (name, snap) in from {
                match into.iter_mut().find(|(n, _)| n == name) {
                    Some((_, acc)) => acc.merge(snap),
                    None => into.push((name.clone(), *snap)),
                }
            }
        }
        let mut engine = Vec::new();
        let mut gauges = Vec::new();
        let mut histos = Vec::new();
        let mut shards_out = Vec::new();
        let conns = self.shards.read().unwrap().clone();
        for conn in &conns {
            let mut health = ShardHealth {
                addr: conn.addr().to_string(),
                heartbeat_age_ms: conn.last_rx().elapsed().as_millis() as u64,
                pending: conn.pending_depth(),
                down_drains: conn.down_drains(),
                queue_depth: 0,
                alive: !conn.is_dead(),
            };
            if health.alive {
                if let Ok(s) = conn.stats() {
                    health.queue_depth = s
                        .gauges
                        .iter()
                        .find(|(n, _)| n == "skein_queue_depth")
                        .map_or(0, |(_, v)| *v);
                    add_gauges(&mut gauges, &s.gauges);
                    add_histos(&mut histos, &s.histos);
                    engine.push(s.stats);
                }
            }
            shards_out.push(health);
        }
        let (own_gauges, own_histos) = self.obs.wire_snapshots();
        add_gauges(&mut gauges, &own_gauges);
        add_histos(&mut histos, &own_histos);
        StatsWire {
            stats: AttentionServerStats::merge_weighted(&engine),
            gauges,
            histos,
            shards: shards_out,
        }
    }

    /// One [`ShardHealth`] row per shard, without polling shard stats
    /// (cheap: local connection state only, no wire round trips).
    fn shard_health(&self) -> Vec<ShardHealth> {
        self.shards
            .read()
            .unwrap()
            .iter()
            .map(|conn| ShardHealth {
                addr: conn.addr().to_string(),
                heartbeat_age_ms: conn.last_rx().elapsed().as_millis() as u64,
                pending: conn.pending_depth(),
                down_drains: conn.down_drains(),
                queue_depth: 0,
                alive: !conn.is_dead(),
            })
            .collect()
    }

    fn open_stream_entry(&self, id: u64, repilot_stride: u32) {
        self.streams.lock().unwrap().insert(id, StreamRoute::Unrouted { repilot_stride });
    }

    /// Home an unrouted stream on the prefix hash of its first chunk.
    fn home_stream(
        &self,
        stream: u64,
        repilot_stride: u32,
        first_k: &[f32],
    ) -> Result<Arc<ShardConn>, ServeError> {
        let shard = self.home_for(prefix_hash(first_k))?;
        shard.open_stream(stream, repilot_stride)?;
        self.streams
            .lock()
            .unwrap()
            .insert(stream, StreamRoute::Homed { shard: Arc::clone(&shard) });
        Ok(shard)
    }

    /// The home shard for an ingest/query op, homing on first contact.
    /// `first_k` supplies the routing key when the stream is still
    /// unrouted (`None` for ops that cannot home, e.g. query).
    fn stream_shard(
        &self,
        stream: u64,
        first_k: Option<&[f32]>,
    ) -> Result<Arc<ShardConn>, ServeError> {
        let route = {
            let streams = self.streams.lock().unwrap();
            match streams.get(&stream) {
                None => return Err(ServeError::UnknownStream(stream)),
                Some(StreamRoute::Unrouted { repilot_stride }) => Err(*repilot_stride),
                Some(StreamRoute::Homed { shard }) => Ok(Arc::clone(shard)),
            }
        };
        match route {
            Ok(shard) => {
                if shard.is_dead() {
                    Err(ServeError::ShardDown { shard: shard.addr().to_string() })
                } else {
                    Ok(shard)
                }
            }
            Err(stride) => match first_k {
                Some(k) => self.home_stream(stream, stride, k),
                // a query against a stream with no tokens yet: the
                // engine's verdict, answered without touching a shard
                None => Err(ServeError::EmptyStream(stream)),
            },
        }
    }
}

/// One connection's dispatch surface over the coordinator.
struct CoordLane(Arc<CoordShared>);

impl WireLane for CoordLane {
    fn submit(&self, req: HeadsRequest, route: Option<SubmitRoute>, reply: ReplyTo) {
        let s = &self.0;
        if let Err(e) = validate_request(&s.cfg, &req, route.as_ref()) {
            reply.send(Err(e));
            return;
        }
        // an unrouted client submit gets the seed a single engine
        // would have derived for it; a routed one (client chaining
        // through coordinators) keeps its pinned seed and range
        let (lo, hi, seed) = match route {
            None => {
                let r = s.next_request.fetch_add(1, Ordering::Relaxed);
                (0, s.cfg.heads, batch_seed(s.cfg.seed, r))
            }
            Some(r) => (r.head_lo as usize, r.head_hi as usize, r.seed),
        };
        s.scatter(&req, lo, hi, seed, reply);
    }

    fn open_stream(&self, repilot_stride: usize, explicit: Option<u64>) -> u64 {
        let s = &self.0;
        let id = match explicit {
            Some(id) => {
                s.next_stream.fetch_max(id + 1, Ordering::Relaxed);
                id
            }
            None => s.next_stream.fetch_add(1, Ordering::Relaxed),
        };
        s.open_stream_entry(id, repilot_stride as u32);
        id
    }

    fn stream_op(&self, stream: u64, op: StreamOp, err: Option<ReplyTo>) {
        let s = &self.0;
        let fail = |err: Option<ReplyTo>, e: ServeError| {
            if let Some(err) = err {
                err.send(Err(e));
            }
        };
        match op {
            StreamOp::Open { repilot_stride } => {
                s.next_stream.fetch_max(stream + 1, Ordering::Relaxed);
                s.open_stream_entry(stream, repilot_stride as u32);
            }
            StreamOp::Append { k, v } => match s.stream_shard(stream, Some(&k)) {
                Ok(shard) => {
                    if let Err(e) = shard.append(stream, &k, &v) {
                        fail(err, e);
                    }
                }
                Err(e) => fail(err, e),
            },
            StreamOp::Prefill { k, v, tokens } => match s.stream_shard(stream, Some(&k)) {
                Ok(shard) => {
                    if let Err(e) = shard.prefill(stream, tokens as u32, &k, &v) {
                        fail(err, e);
                    }
                }
                Err(e) => fail(err, e),
            },
            StreamOp::Query { q, rows, reply } => match s.stream_shard(stream, None) {
                Ok(shard) => shard.query(stream, rows as u32, &q, reply),
                Err(e) => reply.send(Err(e)),
            },
            StreamOp::Close => {
                let route = s.streams.lock().unwrap().remove(&stream);
                if let Some(StreamRoute::Homed { shard }) = route {
                    if !shard.is_dead() {
                        let _ = shard.close_stream(stream);
                    }
                }
            }
        }
    }

    fn stats(&self) -> Option<StatsWire> {
        Some(self.0.merged_stats())
    }
}

struct CoordBackend(Arc<CoordShared>);

impl WireBackend for CoordBackend {
    fn info(&self) -> ServerInfo {
        let s = &self.0;
        ServerInfo {
            method: s.cfg.method.clone(),
            d: s.cfg.d as u32,
            heads: s.cfg.heads as u32,
            seq: s.cfg.seq as u32,
            head_dim: s.cfg.head_dim as u32,
            max_batch: s.cfg.max_batch as u32,
            seed: s.cfg.seed,
            shard_index: 0,
            shard_count: s.live().len() as u32,
        }
    }

    fn lane(&self) -> Box<dyn WireLane> {
        Box::new(CoordLane(Arc::clone(&self.0)))
    }

    fn telemetry(&self) -> Option<Arc<ServeTelemetry>> {
        Some(Arc::clone(&self.0.obs))
    }
}

/// A running shard coordinator.  Plug [`backend`](Self::backend) into
/// [`serve_backend`](crate::coordinator::net::serve_backend) to accept
/// client traffic, or drive [`lane`](Self::lane) in-process (tests).
pub struct Coordinator {
    shared: Arc<CoordShared>,
    heartbeat: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Connect to every shard, verify they advertise one consistent
    /// shape and seed, and start the heartbeat thread.
    pub fn start(shard_addrs: &[String], heartbeat: Duration) -> Result<Coordinator> {
        Self::start_with(shard_addrs, heartbeat, NetTimeouts::default())
    }

    /// [`start`](Self::start) with explicit socket deadlines.
    pub fn start_with(
        shard_addrs: &[String],
        heartbeat: Duration,
        timeouts: NetTimeouts,
    ) -> Result<Coordinator> {
        Self::start_with_telemetry(shard_addrs, heartbeat, timeouts, ServeTelemetry::disabled())
    }

    /// [`start_with`](Self::start_with) plus a live telemetry bundle:
    /// coordinator-side spans (scatter encode, shard RTT, gather wait)
    /// record into it, and `Stats` replies carry it merged with the
    /// shards' own snapshots.
    pub fn start_with_telemetry(
        shard_addrs: &[String],
        heartbeat: Duration,
        timeouts: NetTimeouts,
        obs: Arc<ServeTelemetry>,
    ) -> Result<Coordinator> {
        if shard_addrs.is_empty() {
            bail!("a coordinator needs at least one shard address");
        }
        let mut conns = Vec::with_capacity(shard_addrs.len());
        for addr in shard_addrs {
            let conn = ShardConn::connect(addr, timeouts, Arc::clone(&obs))
                .with_context(|| format!("connecting to shard {addr}"))?;
            conns.push(conn);
        }
        let first = conns[0].info().clone();
        for conn in &conns[1..] {
            let info = conn.info();
            if info.method != first.method
                || info.d != first.d
                || info.heads != first.heads
                || info.seq != first.seq
                || info.head_dim != first.head_dim
                || info.seed != first.seed
            {
                bail!(
                    "shard {} advertises a different shape/seed than {}",
                    conn.addr(),
                    conns[0].addr()
                );
            }
        }
        let cfg = AttentionServerConfig {
            method: first.method.clone(),
            d: first.d as usize,
            heads: first.heads as usize,
            seq: first.seq as usize,
            head_dim: first.head_dim as usize,
            max_batch: first.max_batch as usize,
            max_wait: Duration::ZERO,
            seed: first.seed,
            workers: None,
            queue_depth: 0,
            kv: None,
        };
        let shared = Arc::new(CoordShared {
            cfg,
            shards: RwLock::new(conns),
            ring: RwLock::new(HashRing::default()),
            streams: Mutex::new(HashMap::new()),
            next_stream: AtomicU64::new(0),
            next_request: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            timeouts,
            obs,
        });
        shared.rebuild_ring();
        let heartbeat_join = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || heartbeat_loop(shared, heartbeat))
        };
        Ok(Coordinator { shared, heartbeat: Some(heartbeat_join) })
    }

    /// The cluster's advertised shape (what clients see at handshake).
    pub fn info(&self) -> ServerInfo {
        CoordBackend(Arc::clone(&self.shared)).info()
    }

    /// A backend for [`serve_backend`](crate::coordinator::net::serve_backend).
    pub fn backend(&self) -> Arc<dyn WireBackend> {
        Arc::new(CoordBackend(Arc::clone(&self.shared)))
    }

    /// An in-process dispatch lane (what a wire connection would get).
    pub fn lane(&self) -> Box<dyn WireLane> {
        Box::new(CoordLane(Arc::clone(&self.shared)))
    }

    /// Connect one more shard and extend the ring.  Only streams whose
    /// ring arc the newcomer takes over re-home (consistent hashing);
    /// with a shared `--kv-spill-dir`, re-homed prompts warm-restart
    /// from the spill manifests the previous owner archived.
    pub fn add_shard(&self, addr: &str) -> Result<()> {
        let conn =
            ShardConn::connect(addr, self.shared.timeouts, Arc::clone(&self.shared.obs))
                .with_context(|| format!("connecting to shard {addr}"))?;
        let info = conn.info();
        let cfg = &self.shared.cfg;
        if info.method != cfg.method
            || info.heads as usize != cfg.heads
            || info.seq as usize != cfg.seq
            || info.head_dim as usize != cfg.head_dim
            || info.seed != cfg.seed
        {
            bail!("shard {addr} advertises a different shape/seed than the cluster");
        }
        self.shared.shards.write().unwrap().push(conn);
        self.shared.rebuild_ring();
        Ok(())
    }

    /// Live (heartbeat-responsive) shard count.
    pub fn live_shards(&self) -> usize {
        self.shared.live().len()
    }

    /// Aggregated cluster engine counters (see
    /// [`AttentionServerStats::merge_weighted`]).
    pub fn stats(&self) -> AttentionServerStats {
        self.shared.merged_stats().stats
    }

    /// The full aggregated stats payload: merged engine counters,
    /// summed gauges, bucket-merged histograms, and per-shard health
    /// rows — what a wire `Stats` request against this coordinator
    /// returns.
    pub fn stats_full(&self) -> StatsWire {
        self.shared.merged_stats()
    }

    /// Per-shard health rows from local connection state (no wire round
    /// trips; `queue_depth` is left 0 — poll
    /// [`stats_full`](Self::stats_full) for it).
    pub fn shard_health(&self) -> Vec<ShardHealth> {
        self.shared.shard_health()
    }

    /// The coordinator's telemetry bundle.
    pub fn telemetry(&self) -> &Arc<ServeTelemetry> {
        &self.shared.obs
    }

    /// Stop the heartbeat and disconnect every shard.  Pending
    /// completions fail typed (`ShardDown`) — never a hang.
    pub fn shutdown(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(join) = self.heartbeat.take() {
            let _ = join.join();
        }
        for conn in self.shared.shards.read().unwrap().iter() {
            conn.kill();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Ping live shards, declare silent ones dead, keep the ring fresh.
fn heartbeat_loop(shared: Arc<CoordShared>, every: Duration) {
    let stale_after = every * HEARTBEAT_MISSES;
    // short sleep slices so shutdown is prompt even with long cadences
    let slice = every.min(Duration::from_millis(50));
    let mut elapsed = Duration::ZERO;
    loop {
        std::thread::sleep(slice);
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        elapsed += slice;
        if elapsed < every {
            continue;
        }
        elapsed = Duration::ZERO;
        let shards = shared.shards.read().unwrap().clone();
        for conn in &shards {
            if conn.is_dead() {
                continue;
            }
            if conn.last_rx().elapsed() > stale_after {
                conn.kill(); // silent partition: missed heartbeats
            } else {
                conn.ping();
            }
        }
        // reader threads kill closed connections on their own; re-ring
        // whenever the live set no longer matches what the ring covers
        let live = shards.iter().filter(|c| !c.is_dead()).count();
        if shared.ring.read().unwrap().len() != live * super::ring::VNODES {
            shared.rebuild_ring();
        }
    }
}
