//! Consistent-hash ring for prefix-affinity stream routing.
//!
//! Each live shard owns [`VNODES`] points on a 64-bit ring (FNV-1a of
//! `"{addr}#{vnode}"`); a stream's home shard is the first point at or
//! after its prompt-prefix hash, wrapping around.  Virtual nodes keep
//! the load split near-uniform with few shards, and consistent hashing
//! keeps it *stable*: when a shard joins or dies, only the streams
//! whose arc it owned move, so the surviving shards' `PrefixIndex` and
//! tiered KV caches stay hot for everything else.

/// Virtual nodes per shard — enough to flatten the split across a
/// handful of shards without making ring rebuilds expensive.
pub const VNODES: usize = 64;

/// 64-bit FNV-1a. Small, dependency-free, and plenty uniform for ring
/// placement (this is a load-spreading hash, not a security boundary).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Hash a stream's first ingested K chunk — the prompt prefix — into a
/// ring key.  Bit-exact over the f32 payload, so the same prompt
/// always routes to the same shard while the ring holds still.
pub fn prefix_hash(k: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for x in k {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// The ring itself: sorted `(point, shard index)` pairs over the live
/// shard set.  Rebuilt from scratch on membership change (cheap at
/// [`VNODES`] × shard-count points).
#[derive(Clone, Debug, Default)]
pub struct HashRing {
    points: Vec<(u64, usize)>,
}

impl HashRing {
    /// Build from `(shard index, address)` pairs — pass only live
    /// shards; the index is what [`route`](Self::route) returns.
    pub fn build<'a>(shards: impl IntoIterator<Item = (usize, &'a str)>) -> Self {
        let mut points = Vec::new();
        for (idx, addr) in shards {
            for v in 0..VNODES {
                points.push((fnv1a(format!("{addr}#{v}").as_bytes()), idx));
            }
        }
        points.sort_unstable();
        HashRing { points }
    }

    /// The shard owning `key`: first point at or after it, wrapping.
    /// `None` only when the ring is empty (no live shards).
    pub fn route(&self, key: u64) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let i = self.points.partition_point(|&(p, _)| p < key);
        let (_, shard) = self.points[if i == self.points.len() { 0 } else { i }];
        Some(shard)
    }

    /// Number of ring points (vnodes × live shards).
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True when no live shard backs the ring.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic_and_total() {
        let ring = HashRing::build([(0, "a:1"), (1, "b:2"), (2, "c:3")]);
        assert_eq!(ring.len(), 3 * VNODES);
        for key in [0u64, 1, u64::MAX, 0xDEAD_BEEF, fnv1a(b"prompt")] {
            let first = ring.route(key).unwrap();
            assert_eq!(ring.route(key).unwrap(), first);
            assert!(first < 3);
        }
    }

    #[test]
    fn removing_a_shard_only_moves_its_own_streams() {
        let full = HashRing::build([(0, "a:1"), (1, "b:2"), (2, "c:3")]);
        let without_2 = HashRing::build([(0, "a:1"), (1, "b:2")]);
        let mut moved = 0;
        let mut kept = 0;
        for i in 0..10_000u64 {
            let key = fnv1a(&i.to_le_bytes());
            let before = full.route(key).unwrap();
            let after = without_2.route(key).unwrap();
            if before == 2 {
                assert!(after < 2, "shard 2's streams must land on a survivor");
            } else if before == after {
                kept += 1;
            } else {
                moved += 1;
            }
        }
        // consistent hashing: streams not homed on the dead shard stay put
        assert_eq!(moved, 0, "{moved} streams moved that were not on the dead shard ({kept} kept)");
    }

    #[test]
    fn vnodes_spread_load_roughly_evenly() {
        let ring = HashRing::build([(0, "a:1"), (1, "b:2"), (2, "c:3"), (3, "d:4")]);
        let mut counts = [0usize; 4];
        for i in 0..40_000u64 {
            counts[ring.route(fnv1a(&i.to_le_bytes())).unwrap()] += 1;
        }
        for &c in &counts {
            // 4 shards × 64 vnodes: each shard within a factor ~2 of fair share
            assert!(c > 40_000 / 8 && c < 40_000 / 2, "skewed split: {counts:?}");
        }
    }

    #[test]
    fn prefix_hash_is_bit_exact() {
        let a = prefix_hash(&[1.0, 2.0, -0.0]);
        assert_eq!(a, prefix_hash(&[1.0, 2.0, -0.0]));
        assert_ne!(a, prefix_hash(&[1.0, 2.0, 0.0]), "-0.0 and 0.0 differ bitwise");
    }
}
