//! Batched inference service: the L3 serving path.
//!
//! Clients submit token sequences; a dedicated runtime thread owns the
//! PJRT client (it is `Rc`-based and must not cross threads), groups
//! pending requests into fixed-shape batches (padding the remainder), runs
//! the AOT forward artifact, and answers each request with its logits.
//! Dynamic batching policy: wait up to `max_wait` for a full batch, then
//! flush whatever is pending — the standard latency/throughput knob.

use crate::config::ExperimentConfig;
use crate::data::{Batch, PAD};
use crate::runtime::Runtime;
use crate::train::TrainSession;
use anyhow::Result;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One inference request: raw tokens (≤ seq_len) and a reply channel.
struct Request {
    tokens: Vec<i32>,
    reply: mpsc::Sender<Vec<f32>>,
    enqueued: Instant,
}

/// Client handle to a running server.
pub struct ServerHandle {
    tx: mpsc::Sender<Request>,
    join: Option<std::thread::JoinHandle<Result<ServerStats>>>,
}

/// Aggregate serving statistics, reported on shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    /// Mean queueing delay (ms) — time from submit to batch formation.
    pub mean_queue_ms: f64,
    /// Mean executed batch occupancy (filled slots / capacity).
    pub mean_occupancy: f64,
}

impl ServerHandle {
    /// Submit a request; returns a receiver for the logits row.
    pub fn submit(&self, tokens: Vec<i32>) -> mpsc::Receiver<Vec<f32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let _ = self.tx.send(Request { tokens, reply: reply_tx, enqueued: Instant::now() });
        reply_rx
    }

    /// Submit a batch of sequences at once (one reply channel each).  The
    /// sequences land in the queue back-to-back, so in the common case the
    /// batcher drains whole batches without waiting out `max_wait` per
    /// straggler.  No atomicity is guaranteed: a concurrently-forming
    /// batch may still split the call across flush boundaries.
    pub fn submit_many(&self, sequences: Vec<Vec<i32>>) -> Vec<mpsc::Receiver<Vec<f32>>> {
        sequences.into_iter().map(|tokens| self.submit(tokens)).collect()
    }

    /// Stop the server and collect stats.
    pub fn shutdown(mut self) -> Result<ServerStats> {
        drop(self.tx);
        self.join
            .take()
            .expect("server already joined")
            .join()
            .map_err(|_| anyhow::anyhow!("server thread panicked"))?
    }
}

/// Start the inference server for `cfg.method` using its forward artifact.
/// `max_wait` bounds the batching delay.
pub fn start(cfg: ExperimentConfig, max_wait: Duration) -> ServerHandle {
    let (tx, rx) = mpsc::channel::<Request>();
    let join = std::thread::spawn(move || serve_loop(cfg, rx, max_wait));
    ServerHandle { tx, join: Some(join) }
}

fn serve_loop(
    cfg: ExperimentConfig,
    rx: mpsc::Receiver<Request>,
    max_wait: Duration,
) -> Result<ServerStats> {
    // The PJRT client lives (and dies) on this thread.
    let rt = Runtime::cpu()?;
    let session = TrainSession::load(&rt, &cfg)?;
    let capacity = session.batch();
    let seq_len = session.seq_len();
    let classes = session.classes();

    let mut stats = ServerStats::default();
    let mut queue_ms_sum = 0.0f64;
    let mut occupancy_sum = 0.0f64;

    loop {
        let Some(pending) = super::collect_batch(&rx, capacity, max_wait) else {
            break; // all senders dropped -> shutdown
        };

        // pack into a fixed-shape batch (pad unused slots)
        let mut tokens = vec![PAD; capacity * seq_len];
        let mut mask = vec![0.0f32; capacity * seq_len];
        for (b, req) in pending.iter().enumerate() {
            let len = req.tokens.len().min(seq_len);
            tokens[b * seq_len..b * seq_len + len].copy_from_slice(&req.tokens[..len]);
            for m in &mut mask[b * seq_len..b * seq_len + len] {
                *m = 1.0;
            }
            queue_ms_sum += req.enqueued.elapsed().as_secs_f64() * 1e3;
        }
        let batch = Batch {
            tokens,
            mask,
            labels: vec![0; capacity],
            batch: capacity,
            seq_len,
        };
        let logits = session.forward(&batch)?;
        for (b, req) in pending.iter().enumerate() {
            let row = logits[b * classes..(b + 1) * classes].to_vec();
            let _ = req.reply.send(row);
        }
        stats.requests += pending.len() as u64;
        stats.batches += 1;
        occupancy_sum += pending.len() as f64 / capacity as f64;
    }

    if stats.requests > 0 {
        stats.mean_queue_ms = queue_ms_sum / stats.requests as f64;
        stats.mean_occupancy = occupancy_sum / stats.batches.max(1) as f64;
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    // integration tests with real artifacts live in rust/tests/; packing
    // logic here is covered through them.
}
