//! Batched attention service over the pure-rust engine: the serving path
//! that needs no AOT artifacts and no PJRT.
//!
//! Clients submit one sequence per request — `Arc<[f32]>` Q/K/V slabs of
//! shape `[heads, seq, head_dim]` (plus an optional padding mask) — and a
//! dedicated engine thread groups pending requests into a `B × H` grid,
//! runs [`BatchedAttention`] across the worker pool, and answers each
//! request with its sequence's output slab.  Dynamic batching policy
//! matches the PJRT server: wait up to `max_wait` for a full batch, then
//! flush whatever is pending.
//!
//! **Zero-copy request path.**  Batch formation wraps the pending
//! requests' slabs in a slab-backed [`BatchTensor`]
//! ([`BatchTensor::from_slabs`]) — `Arc` clones, no element copies — so
//! the engine reads each client's memory in place (the optional padding
//! mask rides the same `Arc<[f32]>` convention).  The `Arc` ownership
//! rule: the client keeps its clone (requests are reusable), the server
//! holds one only for the duration of the batch, and the slab is freed
//! when the last clone drops.  Slab contents must stay immutable after
//! submission — `Arc<[f32]>` enforces this in the type.  The one
//! remaining copy on the request path is the reply (the output slab is
//! handed to the client as an owned `Vec<f32>`).
//!
//! **Batch-slab dedupe** ([`KvCacheConfig::batch_dedupe`],
//! `--kv-batch-dedupe`).  With the KV cache on, one-shot requests can be
//! routed *through* the cache: each request's K/V slabs are ingested
//! chunked ([`KvCache::append_chunk`]) into a per-request chain, so
//! their blocks content-hash into the same prefix-index paths decode
//! streams use.  A resubmitted request — or any request sharing a
//! prompt prefix with an earlier request or stream — materialises its
//! head views from shared blocks and allocates nothing new
//! (`kv_hit_blocks` counts the shares); the engine gathers each head's
//! K/V from the chain ([`StreamChain::gather_head_into`] via
//! [`BatchedAttention::run_gather_into`]) instead of reading the client
//! slab, which is bitwise the same bytes by the cache's verified-dedupe
//! contract.  The chain closes when its batch completes; sealed blocks
//! stay index-retained for future replays until capacity evicts them.
//!
//! **Invariants** (checked per request at batch formation; violators are
//! rejected and their reply channel closed): each of `q`/`k`/`v` holds
//! exactly `heads * seq * head_dim` elements, and `mask`, when present,
//! holds `seq`.
//!
//! Batch `i` of a server's lifetime computes with [`batch_seed`]`(cfg.seed,
//! i)`, and each head inside a batch follows the engine's derivation rule,
//! so a given arrival order reproduces exactly while distinct batches get
//! disjoint per-head streams.
//!
//! **Streaming decode.**  Alongside the batched one-shot path, a client
//! can [`open_stream`](AttentionServerHandle::open_stream) a stateful
//! decode stream whose [`append`](StreamHandle::append) /
//! [`query`](StreamHandle::query) ops ride the same channel — and the
//! same zero-copy `Arc<[f32]>` slab convention — as batched requests,
//! preserving per-stream op order.  The stream request path:
//!
//! 1. **Open** creates the stream's server-side KV state: with the KV
//!    cache off ([`AttentionServerConfig::kv`]` = None`), one
//!    [`AttentionSession`](crate::attention::AttentionSession) per head
//!    (seeded [`stream_seed`]`(cfg.seed, stream, head)`); with the cache
//!    on, a shared block chain in the paged
//!    [`KvCache`](crate::kvcache::KvCache) — plus live sessions only for
//!    methods whose sessions are exact-incremental (`vmean`,
//!    `linformer`: O(p)/O(d·p) state, no stored K/V).
//! 2. **Append** is O(heads · head_dim): one write into the stream's
//!    tail block (sealed blocks dedupe against the prefix index, so a
//!    replayed prompt allocates nothing) and/or one fold into each
//!    exact-incremental session.  **Prefill**
//!    ([`StreamHandle::prefill`]) bulk-appends a whole
//!    `[heads, tokens, head_dim]` chunk in one op — one channel message
//!    and per-*block* cache bookkeeping instead of per-token, bitwise
//!    identical to the equivalent append sequence.
//! 3. **Query** fans out per head across the persistent worker pool:
//!    each head answers from its session, or — cache-backed — gathers
//!    its K/V view from the block chain and recomputes at the epoch seed
//!    [`session_seed`](crate::attention::session_seed)`(`[`stream_seed`]`(cfg.seed,
//!    stream, h), epoch)`, bitwise what the equivalent session produces.
//!    Head results are a pure function of grid position, so the fan-out
//!    is worker-count invariant.
//!
//! Serving with the cache enabled is **bitwise identical** to serving
//! without it at the same seeds (`rust/tests/kv_cache.rs` pins this per
//! registry method): blocks deduplicate storage, never change the token
//! sequence a query observes.  Under
//! [`EvictionPolicy::SlidingWindow`](crate::kvcache::EvictionPolicy)
//! streams are additionally bounded to their last `window` tokens, with
//! epoch seeds still derived from the total appended count (the
//! [`BoundedSession`](crate::attention::BoundedSession) semantics).
//!
//! # Examples
//!
//! ```
//! use skeinformer::coordinator::attention_server::{self, AttentionServerConfig, HeadsRequest};
//! use skeinformer::rng::Rng;
//! use std::time::Duration;
//!
//! let cfg = AttentionServerConfig {
//!     method: "standard".into(),
//!     d: 8,
//!     heads: 2,
//!     seq: 16,
//!     head_dim: 4,
//!     max_batch: 2,
//!     max_wait: Duration::from_millis(1),
//!     seed: 0,
//!     workers: None,
//!     kv: None,
//! };
//! let handle = attention_server::start(cfg.clone()).unwrap();
//! let reply = handle.submit(HeadsRequest::random(cfg.request_elems(), &mut Rng::new(1)));
//! assert_eq!(reply.recv().unwrap().len(), cfg.request_elems());
//! handle.shutdown().unwrap();
//! ```

use crate::attention::{
    self, session_epoch, session_seed, AttentionSession, AttnInputs, AttnScratch,
    BatchedAttention, SessionSpec,
};
use crate::kvcache::{KvCache, KvCacheConfig, StreamChain};
use crate::pool;
use crate::rng::Rng;
use crate::tensor::{with_default_plan, BatchTensor, MatmulPlan, Matrix};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Resident-block cap applied when `--kv-batch-dedupe` is set without an
/// explicit `--kv-blocks`: batch-chain retention has no window-reclaim
/// path, so it must be bounded by LRU capacity pressure.  4096 blocks at
/// the default 16-token block size ≈ 64k cached tokens.
pub const DEFAULT_DEDUPE_CAPACITY_BLOCKS: usize = 4096;

/// Engine seed for batch `i` of a server's lifetime.  The engine XORs
/// small head indices into its seed, so deriving batch seeds by XOR too
/// (`base ^ i`) would collide: with `H` heads, batches `i` and `i ^ 1`
/// would reuse the same stream set.  [`crate::rng::mix`] instead.
pub fn batch_seed(base: u64, batch: u64) -> u64 {
    crate::rng::mix(base, batch)
}

/// Session seed for head `h` of stream `s`: a double
/// [`mix`](crate::rng::mix) so streams are decorrelated from each other
/// and from the batch path's `batch_seed(base, i) ^ g` family.
pub fn stream_seed(base: u64, stream: u64, head: u64) -> u64 {
    crate::rng::mix(crate::rng::mix(base, stream), head)
}

/// Server configuration: workload shape + batching policy.
#[derive(Clone, Debug)]
pub struct AttentionServerConfig {
    /// Registry name of the attention method (see `attention::by_name`).
    pub method: String,
    /// Feature budget `d` for approximate methods.
    pub d: usize,
    /// Heads per sequence.
    pub heads: usize,
    /// Sequence length n.
    pub seq: usize,
    /// Per-head feature dimension p.
    pub head_dim: usize,
    /// Max sequences per executed batch.
    pub max_batch: usize,
    /// Max time to wait for a full batch before flushing.
    pub max_wait: Duration,
    /// Base RNG seed (batch `i` computes with [`batch_seed`]`(seed, i)`).
    pub seed: u64,
    /// Worker cap for head dispatch (None = pool default).
    pub workers: Option<usize>,
    /// Paged KV cache for decode streams: block-shared storage with
    /// prefix dedup and (optionally) sliding-window eviction.  With
    /// [`KvCacheConfig::batch_dedupe`] set, one-shot batched requests
    /// are routed through the same cache (batch-slab dedupe).  `None`
    /// keeps per-stream session state only.  Enabling the cache never
    /// changes served bytes — see the [module docs](self).
    pub kv: Option<KvCacheConfig>,
}

impl AttentionServerConfig {
    /// The per-request head grid (batch dimension = 1 sequence).
    pub fn request_elems(&self) -> usize {
        self.heads * self.seq * self.head_dim
    }

    /// Build from CLI flags — the one place the flag names and defaults
    /// live (`skein serve --engine cpu` and the serving example share it):
    /// `--method --d --heads --seq --head-dim --batch --max-wait-ms
    /// --seed --workers` (workers 0 = pool default), plus the KV-cache
    /// flags `--kv-blocks N` (pool capacity in blocks; 0 with no
    /// `--kv-window` / `--kv-batch-dedupe` = cache disabled),
    /// `--kv-window W` (sliding window in tokens; 0 = keep full
    /// history), `--kv-block-size B` (tokens per block, default 16) and
    /// `--kv-batch-dedupe` (route one-shot batched request slabs through
    /// the cache too; enables the cache when set alone, with
    /// [`DEFAULT_DEDUPE_CAPACITY_BLOCKS`] as the capacity unless
    /// `--kv-blocks` says otherwise).  The global
    /// `--pool-size` flag sizes the process-wide worker pool itself and
    /// is handled by the binaries via [`crate::pool::set_pool_size`].
    pub fn from_args(args: &crate::cli::Args) -> Result<Self, crate::cli::CliError> {
        let workers = args.get_usize("workers", 0)?;
        let kv_blocks = args.get_usize("kv-blocks", 0)?;
        let kv_window = args.get_usize("kv-window", 0)?;
        let kv_block_size = args.get_usize("kv-block-size", 16)?;
        let kv_batch_dedupe = args.switch("kv-batch-dedupe");
        // batch-dedupe retention is reclaimed only by LRU capacity
        // pressure (batch chains have no sliding window), so an
        // unbounded cache would grow forever on non-repeating request
        // traffic — give dedupe a finite default capacity when the
        // operator didn't pick one
        let kv_blocks = if kv_batch_dedupe && kv_blocks == 0 {
            DEFAULT_DEDUPE_CAPACITY_BLOCKS
        } else {
            kv_blocks
        };
        let kv = (kv_blocks > 0 || kv_window > 0 || kv_batch_dedupe).then(|| {
            let cfg = KvCacheConfig::new(kv_block_size)
                .with_capacity_blocks(kv_blocks)
                .with_batch_dedupe(kv_batch_dedupe);
            if kv_window > 0 {
                cfg.with_window(kv_window)
            } else {
                cfg
            }
        });
        Ok(Self {
            method: args.get_or("method", "skeinformer").to_string(),
            d: args.get_usize("d", 64)?,
            heads: args.get_usize("heads", 4)?,
            seq: args.get_usize("seq", 512)?,
            head_dim: args.get_usize("head-dim", 32)?,
            max_batch: args.get_usize("batch", 8)?,
            max_wait: Duration::from_millis(args.get_u64("max-wait-ms", 4)?),
            seed: args.get_u64("seed", 0)?,
            workers: if workers == 0 { None } else { Some(workers) },
            kv,
        })
    }
}

/// One sequence's attention inputs: shared `[heads, seq, head_dim]`
/// row-major slabs, plus an optional length-`seq` 0/1 padding mask.
///
/// Every payload — the three slabs *and* the mask — is `Arc<[f32]>`, so
/// batch formation is fully zero-copy: the server reads the client's
/// memory in place and `Clone` only bumps reference counts, deep-copying
/// nothing.  A client that keeps its payload in `Arc<[f32]>` slabs
/// (e.g. resubmitting or fanning one slab into many requests) submits
/// with no element copies at all.  [`HeadsRequest::from_vecs`] (and
/// [`with_mask`](Self::with_mask)) are the conveniences for owned
/// buffers — note `Vec → Arc<[f32]>` allocates and copies once per
/// buffer, so hot-path clients should build `Arc` slabs up front and
/// reuse them.
#[derive(Clone, Debug)]
pub struct HeadsRequest {
    pub q: Arc<[f32]>,
    pub k: Arc<[f32]>,
    pub v: Arc<[f32]>,
    pub mask: Option<Arc<[f32]>>,
}

impl HeadsRequest {
    /// Wrap owned Q/K/V buffers (each `heads * seq * head_dim` elements,
    /// row-major `[heads, seq, head_dim]`).
    pub fn from_vecs(q: Vec<f32>, k: Vec<f32>, v: Vec<f32>) -> Self {
        Self { q: q.into(), k: k.into(), v: v.into(), mask: None }
    }

    /// Attach a length-`seq` 0/1 padding mask (owned-buffer convenience;
    /// an `Arc<[f32]>` can be assigned to `mask` directly).
    pub fn with_mask(mut self, mask: Vec<f32>) -> Self {
        self.mask = Some(mask.into());
        self
    }

    /// Dense standard-normal request of `elems = heads * seq * head_dim`
    /// values per slab — the demo/bench payload.
    pub fn random(elems: usize, rng: &mut Rng) -> Self {
        let mut mk = || {
            let mut buf = vec![0.0f32; elems];
            rng.fill_normal(&mut buf);
            buf
        };
        Self::from_vecs(mk(), mk(), mk())
    }
}

struct Pending {
    req: HeadsRequest,
    reply: mpsc::Sender<Vec<f32>>,
    enqueued: Instant,
}

/// One operation on a decode stream.  Payloads ride the same zero-copy
/// `Arc<[f32]>` slab path as [`HeadsRequest`]: the server reads them in
/// place and only the reply is an owned copy.
pub enum StreamOp {
    /// Create the stream's per-head sessions (one per configured head).
    Open {
        /// Re-pilot stride for approximating methods (see
        /// [`SessionSpec::repilot_stride`]).
        repilot_stride: usize,
    },
    /// Append one token: `k`/`v` are `[heads, head_dim]` row-major slabs.
    Append { k: Arc<[f32]>, v: Arc<[f32]> },
    /// Bulk-append `tokens` tokens in one op — the chunked-prefill
    /// ingest path.  `k`/`v` are `[heads, tokens, head_dim]` row-major
    /// slabs (the same layout as a [`HeadsRequest`] payload).  Exactly
    /// equivalent to `tokens` consecutive [`Append`](Self::Append)s of
    /// the gathered per-token rows, but with one channel message per
    /// chunk and per-*block* (not per-token) cache bookkeeping.
    Prefill { k: Arc<[f32]>, v: Arc<[f32]>, tokens: usize },
    /// Query `rows` query rows per head: `q` is `[heads, rows, head_dim]`;
    /// the reply is the `[heads, rows, head_dim]` output slab.
    Query { q: Arc<[f32]>, rows: usize, reply: mpsc::Sender<Vec<f32>> },
    /// Drop the stream's state.
    Close,
}

/// A message to the serve loop: a batched request, a stream operation,
/// or the explicit shutdown sentinel (needed because cloned stream
/// senders may outlive the handle — channel disconnect alone can no
/// longer signal shutdown).
enum ServerMsg {
    Batch(Pending),
    Stream { stream: u64, op: StreamOp },
    Shutdown,
}

/// Client handle to a running attention server.
pub struct AttentionServerHandle {
    tx: mpsc::Sender<ServerMsg>,
    next_stream: AtomicU64,
    heads: usize,
    head_dim: usize,
    join: Option<std::thread::JoinHandle<AttentionServerStats>>,
}

/// Client handle to one decode stream on a running server.  Ops sent
/// through one handle arrive in order (the channel preserves per-sender
/// order), so `append` → `query` sequences behave like local sessions.
pub struct StreamHandle {
    id: u64,
    heads: usize,
    head_dim: usize,
    tx: mpsc::Sender<ServerMsg>,
}

impl StreamHandle {
    /// Elements per `[heads, head_dim]` token slab.
    pub fn token_elems(&self) -> usize {
        self.heads * self.head_dim
    }

    /// Append one token (each slab `[heads, head_dim]`, read in place).
    pub fn append(&self, k: Arc<[f32]>, v: Arc<[f32]>) {
        let _ = self.tx.send(ServerMsg::Stream {
            stream: self.id,
            op: StreamOp::Append { k, v },
        });
    }

    /// Bulk-append `tokens` tokens in one op (each slab
    /// `[heads, tokens, head_dim]`, read in place) — the chunked-prefill
    /// path for ingesting a whole prompt.  Bitwise equivalent to
    /// [`append`](Self::append)ing each token's rows in order.
    pub fn prefill(&self, k: Arc<[f32]>, v: Arc<[f32]>, tokens: usize) {
        let _ = self.tx.send(ServerMsg::Stream {
            stream: self.id,
            op: StreamOp::Prefill { k, v, tokens },
        });
    }

    /// Query `rows` query rows per head (`q` is `[heads, rows, head_dim]`,
    /// read in place); returns a receiver for the output slab.  The
    /// receiver errors if the op is rejected (bad shape, unknown stream,
    /// empty stream, or a cross-shape query against a square-only method).
    pub fn query(&self, q: Arc<[f32]>, rows: usize) -> mpsc::Receiver<Vec<f32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let _ = self.tx.send(ServerMsg::Stream {
            stream: self.id,
            op: StreamOp::Query { q, rows, reply: reply_tx },
        });
        reply_rx
    }

    /// Drop the stream's server-side state.
    pub fn close(self) {
        let _ = self.tx.send(ServerMsg::Stream { stream: self.id, op: StreamOp::Close });
    }
}

/// Aggregate serving statistics, reported on shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct AttentionServerStats {
    pub requests: u64,
    pub batches: u64,
    /// Requests or stream ops dropped for malformed payloads (wrong
    /// slab/mask length, unknown stream, invalid query shape).
    pub rejected: u64,
    /// Stream tokens appended across all streams.
    pub stream_appends: u64,
    /// Stream queries answered across all streams.
    pub stream_queries: u64,
    /// KV cache: sealed blocks deduplicated against the prefix index
    /// (zero for the cache-off configuration).
    pub kv_hit_blocks: u64,
    /// KV cache: sealed blocks newly inserted into the index.
    pub kv_alloc_blocks: u64,
    /// KV cache: blocks evicted from the prefix index — under capacity
    /// pressure, or as sliding-window drops when no capacity bound is
    /// configured.
    pub kv_evicted_blocks: u64,
    /// KV cache: distinct blocks resident at shutdown.
    pub kv_resident_blocks: u64,
    /// KV cache: resident KV bytes at shutdown
    /// ([`KvCache::resident_kv_bytes`] — the one place the block-geometry
    /// byte accounting lives).
    pub kv_resident_bytes: u64,
    /// Mean queueing delay (ms) — time from submit to batch formation.
    pub mean_queue_ms: f64,
    /// Mean executed batch occupancy (filled slots / max_batch).
    pub mean_occupancy: f64,
    /// Mean engine time per executed batch (ms).
    pub mean_batch_ms: f64,
}

impl AttentionServerHandle {
    /// Submit a request; returns a receiver for the output slab.  The
    /// receiver errors if the request is rejected (malformed payload).
    pub fn submit(&self, req: HeadsRequest) -> mpsc::Receiver<Vec<f32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let _ = self.tx.send(ServerMsg::Batch(Pending {
            req,
            reply: reply_tx,
            enqueued: Instant::now(),
        }));
        reply_rx
    }

    /// Open a streaming decode session set (one [`AttentionSession`] per
    /// configured head, server-side) and return its handle.
    pub fn open_stream(&self, repilot_stride: usize) -> StreamHandle {
        let id = self.next_stream.fetch_add(1, Ordering::Relaxed);
        let _ = self.tx.send(ServerMsg::Stream { stream: id, op: StreamOp::Open { repilot_stride } });
        StreamHandle { id, heads: self.heads, head_dim: self.head_dim, tx: self.tx.clone() }
    }

    /// Stop the server and collect stats.  Live [`StreamHandle`]s do not
    /// block shutdown (an explicit sentinel ends the serve loop); their
    /// later ops simply error out client-side.  Ops already queued ahead
    /// of the shutdown are still processed.
    pub fn shutdown(mut self) -> Result<AttentionServerStats> {
        let _ = self.tx.send(ServerMsg::Shutdown);
        drop(self.tx);
        self.join
            .take()
            .expect("server already joined")
            .join()
            .map_err(|_| anyhow::anyhow!("attention server thread panicked"))
    }
}

/// Start the engine-backed server; validates the method name up front.
/// [`AttentionServerHandle::shutdown`] stops it even while
/// [`StreamHandle`]s are still alive.
pub fn start(cfg: AttentionServerConfig) -> Result<AttentionServerHandle> {
    anyhow::ensure!(
        attention::by_name(&cfg.method, cfg.d).is_some(),
        "unknown attention method {:?}",
        cfg.method
    );
    anyhow::ensure!(cfg.max_batch > 0, "max_batch must be positive");
    let (tx, rx) = mpsc::channel::<ServerMsg>();
    let heads = cfg.heads;
    let head_dim = cfg.head_dim;
    let join = std::thread::spawn(move || serve_loop(cfg, rx));
    Ok(AttentionServerHandle {
        tx,
        next_stream: AtomicU64::new(0),
        heads,
        head_dim,
        join: Some(join),
    })
}

/// Per-stream server-side state.  At least one of the two KV holders is
/// present:
///
/// * `sessions` — one [`AttentionSession`] per head.  Present when the
///   cache is off, and when the method's session is exact-incremental
///   (`vmean`/`linformer`) on an unwindowed cached stream — their state
///   is O(p)/O(d·p) and duplicates nothing.
/// * `chain` — the stream's block chain in the shared [`KvCache`].
///   Present whenever the cache is on; the sole KV holder for
///   recompute-backed methods (their sessions would duplicate the
///   blocks' storage) and for every method under a sliding window.
struct StreamState {
    sessions: Option<Vec<Box<dyn AttentionSession>>>,
    chain: Option<StreamChain>,
    /// Effective re-pilot stride (clamped ≥ 1) — the epoch basis for
    /// cache-backed queries.
    repilot_stride: usize,
}

impl StreamState {
    /// Tokens a query computes over (window-clamped for cached streams).
    fn len(&self) -> usize {
        match (&self.sessions, &self.chain) {
            (Some(sessions), _) => sessions.first().map_or(0, |s| s.len()),
            (None, Some(chain)) => chain.visible_len(),
            (None, None) => 0,
        }
    }
}

fn serve_loop(cfg: AttentionServerConfig, rx: mpsc::Receiver<ServerMsg>) -> AttentionServerStats {
    let method = attention::by_name(&cfg.method, cfg.d).expect("method validated in start()");
    let mut engine = BatchedAttention::new();
    if let Some(w) = cfg.workers {
        engine = engine.with_workers(w);
    }
    let elems = cfg.request_elems();

    let mut stats = AttentionServerStats::default();
    let mut queue_ms_sum = 0.0f64;
    let mut occupancy_sum = 0.0f64;
    let mut batch_ms_sum = 0.0f64;
    let mut streams: std::collections::HashMap<u64, StreamState> = Default::default();
    let mut kv_cache: Option<KvCache> = cfg.kv.map(|kv| KvCache::new(kv, cfg.heads * cfg.head_dim));
    let mut out_cache: Option<BatchTensor> = None;

    loop {
        let Some(msgs) = collect_msgs(&rx, cfg.max_batch, cfg.max_wait) else {
            break; // all senders dropped -> shutdown
        };
        // stream ops apply immediately, in arrival order; batched
        // requests accumulate and flush as engine grids below
        let mut shutting_down = false;
        let mut pending = Vec::new();
        for msg in msgs {
            match msg {
                ServerMsg::Batch(p) => pending.push(p),
                ServerMsg::Stream { stream, op } => handle_stream_op(
                    &cfg,
                    method.as_ref(),
                    &mut kv_cache,
                    &mut streams,
                    stream,
                    op,
                    &mut stats,
                ),
                ServerMsg::Shutdown => shutting_down = true,
            }
        }
        if pending.is_empty() {
            if shutting_down {
                break;
            }
            continue;
        }

        // drop malformed payloads (their reply sender closes -> client
        // recv errors); keep the rest
        pending.retain(|p| {
            let r = &p.req;
            let ok = r.q.len() == elems
                && r.k.len() == elems
                && r.v.len() == elems
                && r.mask.as_ref().is_none_or(|m| m.len() == cfg.seq);
            if !ok {
                stats.rejected += 1;
            }
            ok
        });
        if pending.is_empty() {
            // the sentinel must survive an all-malformed drain too
            if shutting_down {
                break;
            }
            continue;
        }

        // execute in max_batch-sized chunks (the urgent stream-query
        // drain in collect_msgs may have pulled in more than one batch's
        // worth), packing each grid zero-copy: the requests' slabs are
        // wrapped in place (Arc clones, no element copies)
        for chunk in pending.chunks(cfg.max_batch) {
            let slab_views = |get: fn(&HeadsRequest) -> &Arc<[f32]>| {
                BatchTensor::from_slabs(
                    cfg.heads,
                    cfg.seq,
                    cfg.head_dim,
                    chunk.iter().map(|p| Arc::clone(get(&p.req))).collect(),
                )
            };
            let q = slab_views(|r| &r.q);
            // batch-slab dedupe: ingest each request's K/V through the
            // shared cache (chunked, per-request chain) so a resubmitted
            // or prompt-shared request materialises its head views from
            // shared blocks; otherwise wrap the client slabs in place
            let chains: Option<Vec<StreamChain>> = match kv_cache.as_mut() {
                Some(cache) if cache.cfg().batch_dedupe => Some(
                    chunk
                        .iter()
                        .map(|p| {
                            let mut chain = cache.open_batch_stream();
                            cache.append_chunk(
                                &mut chain,
                                &p.req.k,
                                &p.req.v,
                                cfg.seq,
                                cfg.head_dim,
                            );
                            chain
                        })
                        .collect(),
                ),
                _ => None,
            };
            let kv = chains
                .is_none()
                .then(|| (slab_views(|r| &r.k), slab_views(|r| &r.v)));
            let any_mask = chunk.iter().any(|p| p.req.mask.is_some());
            let mut masks = if any_mask {
                Some(Matrix::full(chunk.len(), cfg.seq, 1.0))
            } else {
                None
            };
            for (b, p) in chunk.iter().enumerate() {
                if let (Some(mm), Some(req_mask)) = (masks.as_mut(), p.req.mask.as_ref()) {
                    mm.set_row(b, &req_mask[..]);
                }
                queue_ms_sum += p.enqueued.elapsed().as_secs_f64() * 1e3;
            }

            let t0 = Instant::now();
            let seed = batch_seed(cfg.seed, stats.batches);
            // reuse the output tensor across equal-occupancy batches —
            // with the engine's in-place head writes the steady-state
            // request path allocates only the per-request reply copies
            let mut out = match out_cache.take() {
                Some(t) if t.batch() == chunk.len() => t,
                _ => BatchTensor::zeros(chunk.len(), cfg.heads, cfg.seq, cfg.head_dim),
            };
            match (&chains, &kv) {
                (Some(chains), _) => {
                    // cache-backed K/V: the engine gathers each head's
                    // rows from the (possibly shared) blocks — bitwise
                    // what the slab tensors hold, per the verified-dedupe
                    // contract
                    let fill = |b: usize, h: usize, km: &mut Matrix, vm: &mut Matrix| {
                        chains[b].gather_head_into(h, cfg.head_dim, km, vm);
                    };
                    engine.run_gather_into(
                        method.as_ref(),
                        &q,
                        cfg.seq,
                        &fill,
                        masks.as_ref(),
                        seed,
                        &mut out,
                    );
                }
                (None, Some((k, v))) => {
                    engine.run_into(method.as_ref(), &q, k, v, masks.as_ref(), seed, &mut out)
                }
                (None, None) => unreachable!("kv tensors built whenever chains are absent"),
            }
            if let (Some(chains), Some(cache)) = (chains, kv_cache.as_mut()) {
                // sealed blocks stay index-retained for future replays
                // (until capacity pressure evicts them); tails and chain
                // refcounts are returned to the pool
                for chain in chains {
                    cache.close_stream(chain);
                }
            }
            batch_ms_sum += t0.elapsed().as_secs_f64() * 1e3;

            for (b, p) in chunk.iter().enumerate() {
                let _ = p.reply.send(out.sequence(b).to_vec());
            }
            out_cache = Some(out);
            stats.requests += chunk.len() as u64;
            stats.batches += 1;
            occupancy_sum += chunk.len() as f64 / cfg.max_batch as f64;
        }
        if shutting_down {
            break;
        }
    }

    if stats.requests > 0 {
        stats.mean_queue_ms = queue_ms_sum / stats.requests as f64;
    }
    if stats.batches > 0 {
        stats.mean_occupancy = occupancy_sum / stats.batches as f64;
        stats.mean_batch_ms = batch_ms_sum / stats.batches as f64;
    }
    if let Some(cache) = &kv_cache {
        let kv = cache.stats();
        stats.kv_hit_blocks = kv.hit_blocks;
        stats.kv_alloc_blocks = kv.alloc_blocks;
        stats.kv_evicted_blocks = kv.evicted_blocks;
        stats.kv_resident_blocks = kv.resident_blocks;
        stats.kv_resident_bytes = cache.resident_kv_bytes();
    }
    stats
}

/// Stream-aware dynamic batching: like
/// [`collect_batch`](super::collect_batch), but only *batched* requests
/// count toward `max`, and a pending stream **query** short-circuits the
/// wait — a decode client is blocked on that reply, so making it sit out
/// the `max_wait` batch-formation deadline would put a ~`max_wait` floor
/// under every decoded token.  When a query is seen, whatever is already
/// queued is drained without blocking and the flush happens immediately.
/// Appends and opens carry no reply and batch freely.
fn collect_msgs(
    rx: &mpsc::Receiver<ServerMsg>,
    max_batch: usize,
    max_wait: Duration,
) -> Option<Vec<ServerMsg>> {
    // queries (a client is blocked on the reply) and the shutdown
    // sentinel both short-circuit the batching wait
    let is_query = |m: &ServerMsg| {
        matches!(
            m,
            ServerMsg::Stream { op: StreamOp::Query { .. }, .. } | ServerMsg::Shutdown
        )
    };
    let first = rx.recv().ok()?;
    let mut urgent = is_query(&first);
    let mut batch_count = usize::from(matches!(first, ServerMsg::Batch(_)));
    let mut pending = vec![first];
    let deadline = Instant::now() + max_wait;
    while batch_count < max_batch && !urgent {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(m) => {
                urgent = is_query(&m);
                batch_count += usize::from(matches!(m, ServerMsg::Batch(_)));
                pending.push(m);
            }
            Err(_) => break, // timeout or disconnect: flush what we have
        }
    }
    if urgent {
        // drain only what is already queued (no blocking), then flush so
        // the query's reply is not delayed behind batch formation
        while let Ok(m) = rx.try_recv() {
            pending.push(m);
        }
    }
    Some(pending)
}

/// Apply one stream op to the server's stream table.  Malformed ops are
/// rejected (counted, reply channel dropped) rather than allowed to panic
/// the serve thread: shape checks here mirror the capability checks the
/// attention layer enforces.
#[allow(clippy::too_many_arguments)]
fn handle_stream_op(
    cfg: &AttentionServerConfig,
    method: &dyn attention::AttentionMethod,
    kv_cache: &mut Option<KvCache>,
    streams: &mut std::collections::HashMap<u64, StreamState>,
    stream: u64,
    op: StreamOp,
    stats: &mut AttentionServerStats,
) {
    let token_elems = cfg.heads * cfg.head_dim;
    match op {
        StreamOp::Open { repilot_stride } => {
            let chain = kv_cache.as_mut().map(|c| c.open_stream());
            // live sessions hold the KV state when the cache is off; with
            // the cache on, only exact-incremental sessions survive (tiny
            // state, no stored K/V) — and only without a window, which
            // incremental accumulators cannot evict from
            let windowed = cfg.kv.is_some_and(|kv| kv.window().is_some());
            let use_sessions =
                chain.is_none() || (method.session_is_exact_incremental() && !windowed);
            let sessions = use_sessions.then(|| {
                (0..cfg.heads)
                    .map(|h| {
                        method.begin_session(
                            SessionSpec::new(cfg.head_dim)
                                .with_seed(stream_seed(cfg.seed, stream, h as u64))
                                .with_repilot_stride(repilot_stride)
                                .with_capacity_hint(cfg.seq),
                        )
                    })
                    .collect()
            });
            let old = streams.insert(
                stream,
                StreamState { sessions, chain, repilot_stride: repilot_stride.max(1) },
            );
            // re-opened id (only possible with a misbehaving client):
            // release the displaced state's blocks instead of leaking them
            if let Some(old) = old {
                if let (Some(old_chain), Some(cache)) = (old.chain, kv_cache.as_mut()) {
                    cache.close_stream(old_chain);
                }
            }
        }
        StreamOp::Append { k, v } => {
            let Some(state) = streams.get_mut(&stream) else {
                stats.rejected += 1;
                return;
            };
            if k.len() != token_elems || v.len() != token_elems {
                stats.rejected += 1;
                return;
            }
            if let Some(chain) = &mut state.chain {
                let cache = kv_cache.as_mut().expect("stream chain implies a cache");
                cache.append(chain, &k, &v);
            }
            if let Some(sessions) = &mut state.sessions {
                for (h, session) in sessions.iter_mut().enumerate() {
                    let o = h * cfg.head_dim;
                    session.append(&k[o..o + cfg.head_dim], &v[o..o + cfg.head_dim]);
                }
            }
            stats.stream_appends += 1;
        }
        StreamOp::Prefill { k, v, tokens } => {
            let Some(state) = streams.get_mut(&stream) else {
                stats.rejected += 1;
                return;
            };
            if tokens == 0 || k.len() != tokens * token_elems || v.len() != tokens * token_elems {
                stats.rejected += 1;
                return;
            }
            if let Some(chain) = &mut state.chain {
                let cache = kv_cache.as_mut().expect("stream chain implies a cache");
                cache.append_chunk(chain, &k, &v, tokens, cfg.head_dim);
            }
            if let Some(sessions) = &mut state.sessions {
                // head h's rows are contiguous in the [heads, tokens,
                // head_dim] slab; sessions are independent per head, so
                // folding all of one head's tokens before the next head's
                // leaves every per-head state identical to per-token order
                for (h, session) in sessions.iter_mut().enumerate() {
                    let base = h * tokens * cfg.head_dim;
                    for t in 0..tokens {
                        let o = base + t * cfg.head_dim;
                        session.append(&k[o..o + cfg.head_dim], &v[o..o + cfg.head_dim]);
                    }
                }
            }
            stats.stream_appends += tokens as u64;
        }
        StreamOp::Query { q, rows, reply } => {
            let Some(state) = streams.get_mut(&stream) else {
                stats.rejected += 1;
                return;
            };
            let len = state.len();
            let shape_ok = rows > 0 && q.len() == cfg.heads * rows * cfg.head_dim;
            // square-only methods can only answer full-state queries
            let cross_ok = method.supports_cross_shape() || rows == len;
            if len == 0 || !shape_ok || !cross_ok {
                stats.rejected += 1;
                return; // dropping `reply` signals the rejection
            }
            let mut out_slab = vec![0.0f32; cfg.heads * rows * cfg.head_dim];
            run_head_queries(cfg, method, state, stream, &q, rows, &mut out_slab);
            let _ = reply.send(out_slab);
            stats.stream_queries += 1;
        }
        StreamOp::Close => {
            if let Some(state) = streams.remove(&stream) {
                if let (Some(chain), Some(cache)) = (state.chain, kv_cache.as_mut()) {
                    cache.close_stream(chain);
                }
            }
        }
    }
}

/// Answer one stream query by fanning the per-head work across the
/// persistent worker pool.  Head `h` touches only its own session (or its
/// own read-only chain view) and writes only its own span of `out_slab`,
/// so tasks are disjoint; each head's bytes are a pure function of its
/// inputs and seed, so the result is bitwise invariant to the worker
/// count — the same contract [`BatchedAttention`] holds for the batch
/// path.
fn run_head_queries(
    cfg: &AttentionServerConfig,
    method: &dyn attention::AttentionMethod,
    state: &mut StreamState,
    stream: u64,
    q: &[f32],
    rows: usize,
    out_slab: &mut [f32],
) {
    let head_dim = cfg.head_dim;
    let head_elems = rows * head_dim;
    let workers = cfg.workers.unwrap_or_else(pool::pool_size).max(1);
    // mirror the engine's oversubscription policy: when the head grid
    // alone saturates the pool, inner matmuls go single-threaded
    let inner_plan = if cfg.heads.min(workers) >= pool::pool_size() {
        MatmulPlan::SingleThread
    } else {
        MatmulPlan::Auto
    };
    let heads: Vec<usize> = (0..cfg.heads).collect();
    let out_ptr = pool::SendPtr(out_slab.as_mut_ptr());
    let StreamState { sessions, chain, repilot_stride } = state;
    let stride = *repilot_stride;
    if let Some(sessions) = sessions {
        let sess_ptr = pool::SendPtr(sessions.as_mut_ptr());
        pool::parallel_map_workers(&heads, workers, |&h| {
            // force whole-struct capture of the raw-ptr wrappers
            let sess_ptr = sess_ptr;
            let out_ptr = out_ptr;
            // SAFETY: each head index is claimed by exactly one task
            // (parallel_map_workers' disjoint-index contract), head h
            // touches only sessions[h] and out_slab[h * head_elems ..],
            // and the call does not return until every task completed —
            // so accesses never alias and never outlive the borrows.
            let session = unsafe { &mut *sess_ptr.0.add(h) };
            let mut scratch = AttnScratch::new();
            let qbuf = scratch.buf_from(&q[h * head_elems..(h + 1) * head_elems]);
            let q_head = Matrix::from_vec(rows, head_dim, qbuf);
            let mut out = scratch.matrix(rows, head_dim);
            with_default_plan(inner_plan, || {
                session.query_into(&q_head, &mut out, &mut scratch)
            });
            unsafe {
                std::slice::from_raw_parts_mut(out_ptr.0.add(h * head_elems), head_elems)
                    .copy_from_slice(out.data());
            }
            scratch.recycle(out);
            scratch.recycle_buf(q_head.into_vec());
        });
    } else {
        let chain: &StreamChain = chain.as_ref().expect("stream holds sessions or a chain");
        let n = chain.visible_len();
        // the seed rule RecomputeSession (and BoundedSession, under a
        // window) applies: epoch over the TOTAL appended count
        let epoch = session_epoch(chain.appended(), stride);
        pool::parallel_map_workers(&heads, workers, |&h| {
            let out_ptr = out_ptr;
            let mut scratch = AttnScratch::new();
            let mut k = scratch.matrix(n, head_dim);
            let mut v = scratch.matrix(n, head_dim);
            chain.gather_head_into(h, head_dim, &mut k, &mut v);
            let qbuf = scratch.buf_from(&q[h * head_elems..(h + 1) * head_elems]);
            let q_head = Matrix::from_vec(rows, head_dim, qbuf);
            let mut out = scratch.matrix(rows, head_dim);
            let seed = session_seed(stream_seed(cfg.seed, stream, h as u64), epoch);
            let inputs = AttnInputs::new(&q_head, &k, &v).with_seed(seed);
            with_default_plan(inner_plan, || method.compute_into(&inputs, &mut out, &mut scratch));
            // SAFETY: disjoint spans, see the session branch above.
            unsafe {
                std::slice::from_raw_parts_mut(out_ptr.0.add(h * head_elems), head_elems)
                    .copy_from_slice(out.data());
            }
            scratch.recycle(out);
            scratch.recycle_buf(q_head.into_vec());
            scratch.recycle(v);
            scratch.recycle(k);
        });
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{HeadSpec, Standard};
    use crate::rng::Rng;

    fn cfg(method: &str, max_batch: usize) -> AttentionServerConfig {
        AttentionServerConfig {
            method: method.to_string(),
            d: 8,
            heads: 2,
            seq: 16,
            head_dim: 4,
            max_batch,
            max_wait: Duration::from_millis(2),
            seed: 0,
            workers: None,
            kv: None,
        }
    }

    fn random_request(cfg: &AttentionServerConfig, seed: u64) -> HeadsRequest {
        HeadsRequest::random(cfg.request_elems(), &mut Rng::new(seed))
    }

    #[test]
    fn batch_seeds_do_not_collide_across_nearby_batches() {
        // the engine XORs head indices 0..B*H into the seed; the sets
        // {batch_seed(s,i) ^ g} must be disjoint across batches
        let mut seen = std::collections::HashSet::new();
        for batch in 0..64u64 {
            for g in 0..16u64 {
                assert!(
                    seen.insert(batch_seed(0, batch) ^ g),
                    "stream seed reused at batch {batch}, head {g}"
                );
            }
        }
    }

    #[test]
    fn serves_requests_and_reports_stats() {
        let c = cfg("standard", 4);
        let handle = start(c.clone()).unwrap();
        let rxs: Vec<_> = (0..6).map(|i| handle.submit(random_request(&c, i))).collect();
        for rx in rxs {
            let out = rx.recv().expect("reply");
            assert_eq!(out.len(), c.request_elems());
            assert!(out.iter().all(|x| x.is_finite()));
        }
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.requests, 6);
        assert!(stats.batches >= 2, "6 requests at max_batch 4 need >= 2 batches");
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn single_sequence_batch_matches_direct_engine_call() {
        let c = cfg("standard", 1); // batch size 1: deterministic packing
        let handle = start(c.clone()).unwrap();
        let req = random_request(&c, 9);
        let got = handle.submit(req.clone()).recv().unwrap();
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.batches, 1);

        let spec = HeadSpec::new(1, c.heads, c.seq, c.head_dim);
        let q = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.q.to_vec());
        let k = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.k.to_vec());
        let v = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.v.to_vec());
        // the first batch of a server's lifetime computes with batch_seed(seed, 0)
        let want =
            BatchedAttention::new().run(&Standard, &q, &k, &v, None, batch_seed(c.seed, 0));
        assert!(spec.matches(&want));
        assert_eq!(got, want.data().to_vec());
    }

    #[test]
    fn malformed_requests_are_rejected_not_wedged() {
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let bad = HeadsRequest::from_vecs(vec![0.0; 3], vec![0.0; 3], vec![0.0; 3]);
        let bad_rx = handle.submit(bad);
        let good_rx = handle.submit(random_request(&c, 1));
        assert!(good_rx.recv().is_ok());
        assert!(bad_rx.recv().is_err(), "malformed request must not get a reply");
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn unknown_method_is_rejected_up_front() {
        assert!(start(cfg("no-such-method", 2)).is_err());
    }

    #[test]
    fn shared_slab_requests_are_served_in_place() {
        // q, k, and v may all alias ONE client allocation — the zero-copy
        // path must read it in place without tripping over the aliasing,
        // and the client's clone must survive the request untouched.
        let c = cfg("standard", 1);
        let mut buf = vec![0.0f32; c.request_elems()];
        Rng::new(5).fill_normal(&mut buf);
        let slab: Arc<[f32]> = buf.clone().into();
        let req =
            HeadsRequest { q: slab.clone(), k: slab.clone(), v: slab.clone(), mask: None };
        let handle = start(c.clone()).unwrap();
        let got = handle.submit(req).recv().unwrap();
        handle.shutdown().unwrap();
        assert_eq!(got.len(), c.request_elems());
        assert!(got.iter().all(|x| x.is_finite()));
        assert_eq!(&slab[..], &buf[..], "client slab must be untouched");

        // and it matches the owned-Vec construction bitwise
        let handle = start(c.clone()).unwrap();
        let owned = HeadsRequest::from_vecs(buf.clone(), buf.clone(), buf.clone());
        let got_owned = handle.submit(owned).recv().unwrap();
        handle.shutdown().unwrap();
        assert_eq!(got, got_owned);
    }

    #[test]
    fn stream_decode_matches_direct_session_math() {
        // standard-method stream: a one-row query after t appends must
        // equal exact cross attention of that query against the appended
        // keys, per head
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let stream = handle.open_stream(1);
        let mut rng = Rng::new(3);
        let token_elems = c.heads * c.head_dim;
        let mut ks: Vec<Arc<[f32]>> = Vec::new();
        let mut vs: Vec<Arc<[f32]>> = Vec::new();
        for _ in 0..6 {
            let mut k = vec![0.0f32; token_elems];
            let mut v = vec![0.0f32; token_elems];
            rng.fill_normal(&mut k);
            rng.fill_normal(&mut v);
            let (k, v): (Arc<[f32]>, Arc<[f32]>) = (k.into(), v.into());
            stream.append(k.clone(), v.clone());
            ks.push(k);
            vs.push(v);
        }
        let mut q = vec![0.0f32; token_elems]; // one query row per head
        rng.fill_normal(&mut q);
        let got = stream.query(q.clone().into(), 1).recv().expect("stream reply");
        assert_eq!(got.len(), token_elems);

        for h in 0..c.heads {
            let o = h * c.head_dim;
            let k_mat = crate::tensor::Matrix::from_rows(
                &ks.iter().map(|t| t[o..o + c.head_dim].to_vec()).collect::<Vec<_>>(),
            );
            let v_mat = crate::tensor::Matrix::from_rows(
                &vs.iter().map(|t| t[o..o + c.head_dim].to_vec()).collect::<Vec<_>>(),
            );
            let q_mat = crate::tensor::Matrix::from_vec(1, c.head_dim, q[o..o + c.head_dim].to_vec());
            let want = Standard::exact(&q_mat, &k_mat, &v_mat, None);
            for j in 0..c.head_dim {
                assert!(
                    (got[o + j] - want.get(0, j)).abs() < 1e-5,
                    "head {h} col {j}: {} vs {}",
                    got[o + j],
                    want.get(0, j)
                );
            }
        }

        stream.close();
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.stream_appends, 6);
        assert_eq!(stats.stream_queries, 1);
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn stream_rejections_do_not_wedge_the_server() {
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let stream = handle.open_stream(1);
        // query before any append -> rejected, reply channel closes
        let early = stream.query(vec![0.0f32; c.heads * c.head_dim].into(), 1);
        assert!(early.recv().is_err());
        // malformed append (wrong slab size) -> rejected
        let bad: Arc<[f32]> = vec![0.0f32; 3].into();
        stream.append(bad.clone(), bad);
        // a good request still flows
        let ok = handle.submit(random_request(&c, 1));
        assert!(ok.recv().is_ok());
        stream.close();
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.rejected, 2);
        assert_eq!(stats.stream_appends, 0);
    }

    #[test]
    fn shutdown_completes_with_a_live_stream_handle() {
        // the stream handle's cloned sender must not wedge shutdown
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let stream = handle.open_stream(1);
        let token_elems = c.heads * c.head_dim;
        stream.append(vec![0.5f32; token_elems].into(), vec![0.5f32; token_elems].into());
        let stats = handle.shutdown().expect("shutdown must not hang");
        assert_eq!(stats.stream_appends, 1);
        // late ops on the dead server are silently dropped client-side
        let late = stream.query(vec![0.0f32; token_elems].into(), 1);
        assert!(late.recv().is_err());
    }

    #[test]
    fn stream_and_batch_seed_families_are_disjoint_enough() {
        let mut seen = std::collections::HashSet::new();
        for s in 0..32u64 {
            for h in 0..8u64 {
                assert!(seen.insert(stream_seed(0, s, h)), "stream seed reuse at ({s},{h})");
            }
        }
        for b in 0..32u64 {
            for g in 0..8u64 {
                assert!(
                    seen.insert(batch_seed(0, b) ^ g),
                    "stream/batch seed collision at batch {b} head {g}"
                );
            }
        }
    }

    /// Decode `tokens` tokens through one stream (append + 1-row query
    /// per token) and return the concatenated query outputs.
    fn decode_stream(c: &AttentionServerConfig, tokens: usize, data_seed: u64) -> Vec<f32> {
        let handle = start(c.clone()).unwrap();
        let stream = handle.open_stream(1);
        let token_elems = c.heads * c.head_dim;
        let mut rng = Rng::new(data_seed);
        let mut outs = Vec::new();
        for _ in 0..tokens {
            let mut mk = || {
                let mut b = vec![0.0f32; token_elems];
                rng.fill_normal(&mut b);
                let slab: Arc<[f32]> = b.into();
                slab
            };
            let (k, v, q) = (mk(), mk(), mk());
            stream.append(k, v);
            outs.extend(stream.query(q, 1).recv().expect("stream reply"));
        }
        stream.close();
        handle.shutdown().unwrap();
        outs
    }

    #[test]
    fn cached_streams_are_bitwise_identical_to_uncached() {
        // block size 2 so the 7-token stream seals blocks mid-run; the
        // full per-registry-method sweep lives in rust/tests/kv_cache.rs
        for method in ["standard", "skeinformer", "vmean", "linformer"] {
            let base = cfg(method, 2);
            let mut cached = base.clone();
            cached.kv = Some(crate::kvcache::KvCacheConfig::new(2));
            let want = decode_stream(&base, 7, 42);
            let got = decode_stream(&cached, 7, 42);
            assert_eq!(got, want, "{method}: cache changed served bytes");
        }
    }

    #[test]
    fn kv_stats_count_prefix_sharing() {
        let mut c = cfg("standard", 2);
        c.kv = Some(crate::kvcache::KvCacheConfig::new(2));
        let handle = start(c.clone()).unwrap();
        let token_elems = c.heads * c.head_dim;
        let mut rng = Rng::new(9);
        let tokens: Vec<(Arc<[f32]>, Arc<[f32]>)> = (0..6)
            .map(|_| {
                let mut mk = || {
                    let mut b = vec![0.0f32; token_elems];
                    rng.fill_normal(&mut b);
                    let slab: Arc<[f32]> = b.into();
                    slab
                };
                (mk(), mk())
            })
            .collect();
        // two streams replaying the same prompt: the second allocates
        // zero new blocks for the shared region
        let s0 = handle.open_stream(1);
        for (k, v) in &tokens {
            s0.append(k.clone(), v.clone());
        }
        let s1 = handle.open_stream(1);
        for (k, v) in &tokens {
            s1.append(k.clone(), v.clone());
        }
        s0.close();
        s1.close();
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.kv_alloc_blocks, 3, "first stream's sealed blocks only");
        assert_eq!(stats.kv_hit_blocks, 3, "second stream shares every sealed block");
        assert_eq!(stats.kv_evicted_blocks, 0);
        assert_eq!(stats.kv_resident_blocks, 3, "index retains the shared blocks");
    }

    #[test]
    fn sliding_window_stream_matches_bounded_session() {
        let mut c = cfg("skeinformer", 2);
        c.kv = Some(crate::kvcache::KvCacheConfig::new(2).with_window(4));
        let stride = 3usize;
        let handle = start(c.clone()).unwrap();
        let stream = handle.open_stream(stride);
        let token_elems = c.heads * c.head_dim;
        let mut rng = Rng::new(17);
        let mut mk = |rng: &mut Rng| {
            let mut b = vec![0.0f32; token_elems];
            rng.fill_normal(&mut b);
            let slab: Arc<[f32]> = b.into();
            slab
        };
        // reference: one BoundedSession per head at the stream's seeds
        let mut reference: Vec<crate::attention::BoundedSession> = (0..c.heads)
            .map(|h| {
                crate::attention::BoundedSession::new(
                    crate::attention::by_name(&c.method, c.d).unwrap(),
                    SessionSpec::new(c.head_dim)
                        .with_seed(stream_seed(c.seed, 0, h as u64))
                        .with_repilot_stride(stride),
                    4,
                )
            })
            .collect();
        for _ in 0..9 {
            let (k, v, q) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
            stream.append(k.clone(), v.clone());
            let got = stream.query(q.clone(), 1).recv().expect("windowed stream reply");
            for (h, session) in reference.iter_mut().enumerate() {
                let o = h * c.head_dim;
                session.append(&k[o..o + c.head_dim], &v[o..o + c.head_dim]);
                let q_head = Matrix::from_vec(1, c.head_dim, q[o..o + c.head_dim].to_vec());
                let want = session.query(&q_head);
                assert_eq!(
                    &got[o..o + c.head_dim],
                    want.data(),
                    "head {h} diverged from BoundedSession"
                );
            }
        }
        stream.close();
        handle.shutdown().unwrap();
    }

    #[test]
    fn masked_requests_flow_through() {
        let mut c = cfg("skeinformer", 2);
        c.d = 4;
        let handle = start(c.clone()).unwrap();
        let mut req = random_request(&c, 3);
        let mut mask = vec![1.0f32; c.seq];
        for m in mask.iter_mut().skip(12) {
            *m = 0.0;
        }
        req.mask = Some(mask.into());
        let out = handle.submit(req).recv().unwrap();
        assert_eq!(out.len(), c.request_elems());
        assert!(out.iter().all(|x| x.is_finite()));
        handle.shutdown().unwrap();
    }

    #[test]
    fn prefill_matches_per_token_appends_bitwise() {
        // the full per-registry-method sweep lives in rust/tests/kv_cache.rs
        let mut c = cfg("skeinformer", 2);
        c.kv = Some(crate::kvcache::KvCacheConfig::new(2));
        let token_elems = c.heads * c.head_dim;
        let tokens = 7usize;
        let mut rng = Rng::new(31);
        let mut k_rows = Vec::new();
        let mut v_rows = Vec::new();
        for _ in 0..tokens {
            let mut mk = || {
                let mut b = vec![0.0f32; token_elems];
                rng.fill_normal(&mut b);
                b
            };
            k_rows.push(mk());
            v_rows.push(mk());
        }
        let mut q = vec![0.0f32; token_elems];
        rng.fill_normal(&mut q);
        let q: Arc<[f32]> = q.into();

        // reference: per-token appends, one final 1-row query
        let handle = start(c.clone()).unwrap();
        let s = handle.open_stream(2);
        for t in 0..tokens {
            s.append(k_rows[t].clone().into(), v_rows[t].clone().into());
        }
        let want = s.query(q.clone(), 1).recv().expect("per-token reply");
        s.close();
        let want_stats = handle.shutdown().unwrap();

        // chunked: the same tokens through Prefill ops of {4, 3}
        let to_chunk = |rows: &[Vec<f32>], lo: usize, hi: usize| -> Arc<[f32]> {
            let n = hi - lo;
            let mut slab = vec![0.0f32; n * token_elems];
            for (i, row) in rows[lo..hi].iter().enumerate() {
                for h in 0..c.heads {
                    let dst = (h * n + i) * c.head_dim;
                    slab[dst..dst + c.head_dim]
                        .copy_from_slice(&row[h * c.head_dim..(h + 1) * c.head_dim]);
                }
            }
            slab.into()
        };
        let handle = start(c.clone()).unwrap();
        let s = handle.open_stream(2);
        for (lo, hi) in [(0usize, 4usize), (4, 7)] {
            s.prefill(to_chunk(&k_rows, lo, hi), to_chunk(&v_rows, lo, hi), hi - lo);
        }
        let got = s.query(q, 1).recv().expect("prefill reply");
        s.close();
        let got_stats = handle.shutdown().unwrap();

        assert_eq!(got, want, "prefill changed served bytes");
        assert_eq!(got_stats.stream_appends, want_stats.stream_appends);
        assert_eq!(got_stats.kv_alloc_blocks, want_stats.kv_alloc_blocks);
        assert_eq!(got_stats.kv_hit_blocks, want_stats.kv_hit_blocks);
    }

    #[test]
    fn batch_dedupe_replay_hits_every_block() {
        let mut c = cfg("standard", 1); // batch size 1: one batch per submit
        c.kv = Some(crate::kvcache::KvCacheConfig::new(2).with_batch_dedupe(true));
        let handle = start(c.clone()).unwrap();
        let req = random_request(&c, 4);
        let first = handle.submit(req.clone()).recv().expect("first reply");
        let second = handle.submit(req).recv().expect("resubmitted reply");
        // standard attention is seedless: the replay reproduces the bytes
        assert_eq!(first, second);
        let stats = handle.shutdown().unwrap();
        let blocks = (c.seq / 2) as u64; // seq 16 at block size 2
        assert_eq!(stats.kv_alloc_blocks, blocks, "only the first submission allocates");
        assert_eq!(stats.kv_hit_blocks, blocks, "the replay shares every sealed block");
    }
}
