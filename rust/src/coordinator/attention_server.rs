//! Batched attention service over the pure-rust engine: the serving path
//! that needs no AOT artifacts and no PJRT.
//!
//! Clients submit one sequence per request — `Arc<[f32]>` Q/K/V slabs of
//! shape `[heads, seq, head_dim]` (plus an optional padding mask) — and a
//! dedicated engine thread admits pending work into per-step `B × H`
//! grids, runs [`BatchedAttention`] across the worker pool, and answers
//! each request with its sequence's output slab.
//!
//! **Continuous batching.**  The scheduler admits work per *step* rather
//! than per collected batch: every queued unit — a one-shot request or a
//! decode stream's pending query — counts one slot, and each step admits
//! up to `max_batch` slots, so decode streams join and leave the
//! executed grid between steps instead of waiting for a fixed batch to
//! form.  Batch formation waits at most `max_wait` for extra slots, and
//! never while a stream query is pending (a decode client is blocked on
//! that reply, so making it sit out the formation deadline would put a
//! ~`max_wait` floor under every decoded token).  Admission is
//! round-robin across client connections
//! ([`AttentionServerHandle::connection`]), so one chatty connection
//! cannot starve the rest; ops from one connection stay in submission
//! order.  Backpressure: the server inbox is a *bounded* channel
//! ([`AttentionServerConfig::queue_depth`] slots), so a client that
//! outruns the serve thread blocks in `submit` instead of growing an
//! unbounded queue — the wire front end ([`super::net`]) converts that
//! into TCP backpressure.
//!
//! **Determinism.**  Seeds never depend on grid placement: batch `i` of
//! a server's lifetime computes with [`batch_seed`]`(cfg.seed, i)` (each
//! head inside follows the engine's derivation rule), and a stream query
//! computes head `h` at
//! [`session_seed`](crate::attention::session_seed)`(`[`stream_seed`]`(cfg.seed,
//! stream, h), epoch)` where the epoch counts the stream's appended
//! tokens.  Head results are pure functions of (inputs, seed), so the
//! continuous-batching scheduler — whatever mix of streams shares a step
//! — serves bitwise the bytes the fixed-batch path served, and the TCP
//! path is bitwise the in-process path (`rust/tests/serving_net.rs` pins
//! both).
//!
//! **Typed rejections.**  Every malformed op answers
//! `Err(`[`ServeError`]`)` through its [`ReplyRx`] (the wire path maps
//! the same error to an explicit error frame): wrong slab/mask lengths,
//! unknown streams, empty-stream queries, and cross-shape queries
//! against square-only methods all name their failure instead of
//! silently closing the reply channel.  Rejections count in
//! [`AttentionServerStats::rejected`].
//!
//! **Zero-copy request path.**  Batch formation wraps the admitted
//! requests' slabs in a slab-backed [`BatchTensor`]
//! ([`BatchTensor::from_slabs`]) — `Arc` clones, no element copies — so
//! the engine reads each client's memory in place (the optional padding
//! mask rides the same `Arc<[f32]>` convention).  The `Arc` ownership
//! rule: the client keeps its clone (requests are reusable), the server
//! holds one only for the duration of the step, and the slab is freed
//! when the last clone drops.  Slab contents must stay immutable after
//! submission — `Arc<[f32]>` enforces this in the type.  The one
//! remaining copy on the request path is the reply (the output slab is
//! handed to the client as an owned `Vec<f32>`).
//!
//! **Batch-slab dedupe** ([`KvCacheConfig::batch_dedupe`],
//! `--kv-batch-dedupe`).  With the KV cache on, one-shot requests can be
//! routed *through* the cache: each request's K/V slabs are ingested
//! chunked ([`KvCache::append_chunk`]) into a per-request chain, so
//! their blocks content-hash into the same prefix-index paths decode
//! streams use.  A resubmitted request — or any request sharing a
//! prompt prefix with an earlier request or stream — materialises its
//! head views from shared blocks and allocates nothing new
//! (`kv_hit_blocks` counts the shares); the engine gathers each head's
//! K/V from the chain ([`StreamChain::gather_head_into`] via
//! [`BatchedAttention::run_gather_into`]) instead of reading the client
//! slab, which is bitwise the same bytes by the cache's verified-dedupe
//! contract.  The chain closes when its batch completes; under a pure
//! LRU policy sealed blocks stay index-retained for future replays until
//! capacity evicts them, while a sliding-window config releases a batch
//! chain's non-shared blocks immediately (a burst of one-shots must not
//! pin the pool against windowed streams — see
//! [`KvCache::close_stream`]).
//!
//! **Streaming decode.**  Alongside the batched one-shot path, a client
//! can [`open_stream`](AttentionServerHandle::open_stream) a stateful
//! decode stream whose [`append`](StreamHandle::append) /
//! [`query`](StreamHandle::query) ops ride the same channel — and the
//! same zero-copy `Arc<[f32]>` slab convention — as batched requests,
//! preserving per-stream op order (ops that arrive while a query is in
//! flight are deferred and applied, in order, when it completes).  The
//! stream request path:
//!
//! 1. **Open** creates the stream's server-side KV state: with the KV
//!    cache off ([`AttentionServerConfig::kv`]` = None`), one
//!    [`AttentionSession`](crate::attention::AttentionSession) per head
//!    (seeded [`stream_seed`]`(cfg.seed, stream, head)`); with the cache
//!    on, a shared block chain in the paged
//!    [`KvCache`](crate::kvcache::KvCache) — plus live sessions only for
//!    methods whose sessions are exact-incremental (`vmean`,
//!    `linformer`: O(p)/O(d·p) state, no stored K/V).
//! 2. **Append** is O(heads · head_dim): one write into the stream's
//!    tail block (sealed blocks dedupe against the prefix index, so a
//!    replayed prompt allocates nothing) and/or one fold into each
//!    exact-incremental session.  **Prefill**
//!    ([`StreamHandle::prefill`]) bulk-appends a whole
//!    `[heads, tokens, head_dim]` chunk in one op — one channel message
//!    and per-*block* cache bookkeeping instead of per-token, bitwise
//!    identical to the equivalent append sequence.
//! 3. **Query** joins the next step's grid and fans out per head across
//!    the persistent worker pool: each head answers from its session, or
//!    — cache-backed — gathers its K/V view from the block chain and
//!    recomputes at the epoch seed, bitwise what the equivalent session
//!    produces.  Multiple streams' queries admitted into one step
//!    compute in the same fan-out, one task per (stream, head).
//!
//! Serving with the cache enabled is **bitwise identical** to serving
//! without it at the same seeds (`rust/tests/kv_cache.rs` pins this per
//! registry method): blocks deduplicate storage, never change the token
//! sequence a query observes.  Under
//! [`EvictionPolicy::SlidingWindow`](crate::kvcache::EvictionPolicy)
//! streams are additionally bounded to their last `window` tokens, with
//! epoch seeds still derived from the total appended count (the
//! [`BoundedSession`](crate::attention::BoundedSession) semantics).
//!
//! # Examples
//!
//! ```
//! use skeinformer::coordinator::attention_server::{self, AttentionServerConfig, HeadsRequest};
//! use skeinformer::rng::Rng;
//! use std::time::Duration;
//!
//! let cfg = AttentionServerConfig {
//!     method: "standard".into(),
//!     d: 8,
//!     heads: 2,
//!     seq: 16,
//!     head_dim: 4,
//!     max_batch: 2,
//!     max_wait: Duration::from_millis(1),
//!     seed: 0,
//!     workers: None,
//!     queue_depth: 0,
//!     kv: None,
//! };
//! let handle = attention_server::start(cfg.clone()).unwrap();
//! let reply = handle.submit(HeadsRequest::random(cfg.request_elems(), &mut Rng::new(1)));
//! assert_eq!(reply.recv().unwrap().len(), cfg.request_elems());
//! handle.shutdown().unwrap();
//! ```

use crate::attention::{
    self, session_epoch, session_seed, AttentionSession, AttnInputs, AttnScratch,
    BatchedAttention, SessionSpec,
};
use crate::kvcache::{KvCache, KvCacheConfig, StreamChain, TierLadder};
use crate::obs::{self, ServeTelemetry, Span};
use crate::pool;
use crate::rng::Rng;
use crate::tensor::{with_default_plan, BatchTensor, MatmulPlan, Matrix};
use anyhow::Result;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Resident-block cap applied when `--kv-batch-dedupe` is set without an
/// explicit `--kv-blocks`: batch-chain retention under a pure LRU policy
/// has no window-reclaim path, so it must be bounded by capacity
/// pressure.  4096 blocks at the default 16-token block size ≈ 64k
/// cached tokens.
pub const DEFAULT_DEDUPE_CAPACITY_BLOCKS: usize = 4096;

/// Server inbox depth used when [`AttentionServerConfig::queue_depth`]
/// is 0: enough to keep a busy step pipeline fed, small enough that a
/// stalled serve thread pushes back on clients within ~one step's worth
/// of traffic rather than buffering slabs without bound.
pub const DEFAULT_QUEUE_DEPTH: usize = 1024;

/// Engine seed for batch `i` of a server's lifetime.  The engine XORs
/// small head indices into its seed, so deriving batch seeds by XOR too
/// (`base ^ i`) would collide: with `H` heads, batches `i` and `i ^ 1`
/// would reuse the same stream set.  [`crate::rng::mix`] instead.
pub fn batch_seed(base: u64, batch: u64) -> u64 {
    crate::rng::mix(base, batch)
}

/// Session seed for head `h` of stream `s`: a double
/// [`mix`](crate::rng::mix) so streams are decorrelated from each other
/// and from the batch path's `batch_seed(base, i) ^ g` family.
pub fn stream_seed(base: u64, stream: u64, head: u64) -> u64 {
    crate::rng::mix(crate::rng::mix(base, stream), head)
}

/// Why the server rejected (or failed to answer) a request or stream op.
///
/// Every rejection reaches the client as `Err(ServeError)` through its
/// [`ReplyRx`] — the reply channel is never silently dropped — and the
/// wire front end maps [`code`](Self::code) into an explicit error
/// frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// A payload slab or mask had the wrong length for the server shape.
    BadShape {
        /// Which payload failed the length check.
        what: &'static str,
    },
    /// The op named a stream id with no server-side state (never opened,
    /// already closed, or displaced by a re-open).
    UnknownStream(u64),
    /// A query against a stream with no appended tokens.
    EmptyStream(u64),
    /// A `rows != len` query against a method that only answers square
    /// (full-state) queries.
    CrossShapeUnsupported {
        /// Query rows requested.
        rows: usize,
        /// Tokens the stream holds.
        len: usize,
    },
    /// The server shut down (or the op was sent after shutdown) before
    /// this op was answered.
    Shutdown,
    /// The reply channel disconnected without a verdict — only seen if
    /// the serve thread died abnormally.
    Disconnected,
    /// The engine shard holding this request's or stream's state died
    /// (missed heartbeats or a broken connection).  Emitted by the
    /// shard coordinator ([`crate::coordinator::shard`]) — a typed
    /// degradation, never a hang: in-flight work on the dead shard is
    /// answered with this, streams homed there stay rejected until
    /// reopened, and fresh one-shots re-scatter across the survivors.
    ShardDown {
        /// The dead shard's address.
        shard: String,
    },
    /// A typed error relayed verbatim from an engine shard by the
    /// coordinator: `code` is the shard's original wire code and
    /// `message` its original rendering, so a client behind a
    /// one-shard coordinator sees byte-identical error frames to one
    /// talking to the engine directly.
    Remote {
        /// The shard's original [`ServeError::code`] value.
        code: u8,
        /// The shard's original `Display` rendering.
        message: String,
    },
}

impl ServeError {
    /// Stable one-byte code for the wire error frame (see
    /// [`super::net`]).  0 is reserved for wire-level (framing) errors.
    pub fn code(&self) -> u8 {
        match self {
            ServeError::BadShape { .. } => 1,
            ServeError::UnknownStream(_) => 2,
            ServeError::EmptyStream(_) => 3,
            ServeError::CrossShapeUnsupported { .. } => 4,
            ServeError::Shutdown => 5,
            ServeError::Disconnected => 6,
            ServeError::ShardDown { .. } => 7,
            ServeError::Remote { code, .. } => *code,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::BadShape { what } => write!(f, "malformed payload: bad {what} length"),
            ServeError::UnknownStream(id) => write!(f, "unknown stream {id}"),
            ServeError::EmptyStream(id) => write!(f, "query on empty stream {id}"),
            ServeError::CrossShapeUnsupported { rows, len } => write!(
                f,
                "method answers square queries only ({rows} query rows vs {len} stream tokens)"
            ),
            ServeError::Shutdown => write!(f, "server shut down before answering"),
            ServeError::Disconnected => write!(f, "reply channel disconnected"),
            ServeError::ShardDown { shard } => write!(f, "shard unavailable: {shard}"),
            ServeError::Remote { message, .. } => write!(f, "{message}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// The server's side of one reply: a single-shot callback fired with the
/// output slab or a typed [`ServeError`].  Dropping an unfired `ReplyTo`
/// built by [`channel`](Self::channel) (e.g. the op died in a channel on
/// shutdown) fires `Err(ServeError::Shutdown)` so the client always gets
/// a verdict.
pub struct ReplyTo {
    f: Option<Box<dyn FnOnce(Result<Vec<f32>, ServeError>) + Send>>,
    /// Fire `Err(Shutdown)` on unfired drop.  Error sinks (wire-path
    /// append/prefill error reporters) set this false: on success they
    /// are dropped unfired by design.
    reply_expected: bool,
}

impl ReplyTo {
    /// An in-process reply pair: the server fires the `ReplyTo`, the
    /// client blocks on the [`ReplyRx`].
    pub fn channel() -> (ReplyTo, ReplyRx) {
        let (tx, rx) = mpsc::channel();
        (
            ReplyTo {
                f: Some(Box::new(move |r| {
                    let _ = tx.send(r);
                })),
                reply_expected: true,
            },
            ReplyRx(rx),
        )
    }

    /// A reply that runs `f` with the verdict (the wire path encodes a
    /// frame here).  Unfired drop still reports `Err(Shutdown)` to `f`.
    pub(crate) fn from_fn(f: impl FnOnce(Result<Vec<f32>, ServeError>) + Send + 'static) -> Self {
        ReplyTo { f: Some(Box::new(f)), reply_expected: true }
    }

    /// An error-only sink: `f` runs if the op *fails*; success (and
    /// shutdown-drop) are silent.  Used for ops with no success payload
    /// (append/prefill) on the wire path.
    pub(crate) fn error_sink(
        f: impl FnOnce(Result<Vec<f32>, ServeError>) + Send + 'static,
    ) -> Self {
        ReplyTo { f: Some(Box::new(f)), reply_expected: false }
    }

    /// Fire the reply (single-shot; consumes the handle).
    pub(crate) fn send(mut self, r: Result<Vec<f32>, ServeError>) {
        if let Some(f) = self.f.take() {
            f(r);
        }
    }
}

impl Drop for ReplyTo {
    fn drop(&mut self) {
        if self.reply_expected {
            if let Some(f) = self.f.take() {
                f(Err(ServeError::Shutdown));
            }
        }
    }
}

impl std::fmt::Debug for ReplyTo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReplyTo").field("fired", &self.f.is_none()).finish()
    }
}

/// Client side of one reply: yields the output slab or the typed
/// rejection.  [`recv`](Self::recv) never panics — a dead server
/// surfaces as `Err(ServeError::Shutdown)` (fired by the op's
/// [`ReplyTo`] drop) or `Err(ServeError::Disconnected)`.
pub struct ReplyRx(mpsc::Receiver<Result<Vec<f32>, ServeError>>);

impl ReplyRx {
    /// Block for the verdict.
    pub fn recv(&self) -> Result<Vec<f32>, ServeError> {
        self.0.recv().unwrap_or(Err(ServeError::Disconnected))
    }

    /// The underlying receiver, for `select`-style loops that want the
    /// raw channel (e.g. the serving example's latency collector).
    pub fn into_inner(self) -> mpsc::Receiver<Result<Vec<f32>, ServeError>> {
        self.0
    }
}

/// Server configuration: workload shape + scheduling policy.
#[derive(Clone, Debug)]
pub struct AttentionServerConfig {
    /// Registry name of the attention method (see `attention::by_name`).
    pub method: String,
    /// Feature budget `d` for approximate methods.
    pub d: usize,
    /// Heads per sequence.
    pub heads: usize,
    /// Sequence length n.
    pub seq: usize,
    /// Per-head feature dimension p.
    pub head_dim: usize,
    /// Max admitted slots per scheduler step (one-shot requests and
    /// stream queries each count one slot).
    pub max_batch: usize,
    /// Max time to wait for extra one-shot slots before running a
    /// partial step (stream queries never wait).
    pub max_wait: Duration,
    /// Base RNG seed (batch `i` computes with [`batch_seed`]`(seed, i)`).
    pub seed: u64,
    /// Worker cap for head dispatch (None = pool default).
    pub workers: Option<usize>,
    /// Server inbox depth in messages — the backpressure bound on
    /// in-flight work (clients block in `submit` once it fills).
    /// 0 = [`DEFAULT_QUEUE_DEPTH`].
    pub queue_depth: usize,
    /// Paged KV cache for decode streams: block-shared storage with
    /// prefix dedup and (optionally) sliding-window eviction.  With
    /// [`KvCacheConfig::batch_dedupe`] set, one-shot batched requests
    /// are routed through the same cache (batch-slab dedupe).  `None`
    /// keeps per-stream session state only.  Enabling the cache never
    /// changes served bytes — see the [module docs](self).
    pub kv: Option<KvCacheConfig>,
}

impl AttentionServerConfig {
    /// The per-request head grid (batch dimension = 1 sequence).
    pub fn request_elems(&self) -> usize {
        self.heads * self.seq * self.head_dim
    }

    /// Build from CLI flags — the one place the flag names and defaults
    /// live (`skein serve --engine cpu` and the serving example share it):
    /// `--method --d --heads --seq --head-dim --batch --max-wait-ms
    /// --seed --workers --queue-depth` (workers 0 = pool default,
    /// queue-depth 0 = [`DEFAULT_QUEUE_DEPTH`]), plus the KV-cache
    /// flags `--kv-blocks N` (pool capacity in blocks; 0 with no
    /// `--kv-window` / `--kv-batch-dedupe` = cache disabled),
    /// `--kv-window W` (sliding window in tokens; 0 = keep full
    /// history), `--kv-block-size B` (tokens per block, default 16) and
    /// `--kv-batch-dedupe` (route one-shot batched request slabs through
    /// the cache too; enables the cache when set alone, with
    /// [`DEFAULT_DEDUPE_CAPACITY_BLOCKS`] as the capacity unless
    /// `--kv-blocks` says otherwise).  The tier ladder rides two more
    /// flags: `--kv-tiers f16,int8` (quantised demotion rungs; any
    /// subset) and `--kv-spill-dir PATH` (content-addressed spill store
    /// — enables warm restarts over the same directory).  Either tier
    /// flag enables the cache when set alone.  The global
    /// `--pool-size` flag sizes the process-wide worker pool itself and
    /// is handled by the binaries via [`crate::pool::set_pool_size`].
    pub fn from_args(args: &crate::cli::Args) -> Result<Self, crate::cli::CliError> {
        let workers = args.get_usize("workers", 0)?;
        let kv_blocks = args.get_usize("kv-blocks", 0)?;
        let kv_window = args.get_usize("kv-window", 0)?;
        let kv_block_size = args.get_usize("kv-block-size", 16)?;
        let kv_batch_dedupe = args.switch("kv-batch-dedupe");
        // batch-dedupe retention is reclaimed only by LRU capacity
        // pressure (batch chains have no sliding window), so an
        // unbounded cache would grow forever on non-repeating request
        // traffic — give dedupe a finite default capacity when the
        // operator didn't pick one
        let kv_blocks = if kv_batch_dedupe && kv_blocks == 0 {
            DEFAULT_DEDUPE_CAPACITY_BLOCKS
        } else {
            kv_blocks
        };
        let mut kv_tiers = match args.get("kv-tiers") {
            Some(spec) => TierLadder::parse(spec).map_err(|_| crate::cli::CliError::BadValue {
                flag: "kv-tiers".into(),
                value: spec.into(),
                expected: "comma-separated subset of f16, int8",
            })?,
            None => TierLadder::none(),
        };
        if let Some(dir) = args.get("kv-spill-dir") {
            kv_tiers = kv_tiers.with_spill_dir(dir);
        }
        let enable =
            kv_blocks > 0 || kv_window > 0 || kv_batch_dedupe || kv_tiers.enabled();
        let kv = enable.then(|| {
            let cfg = KvCacheConfig::new(kv_block_size)
                .with_capacity_blocks(kv_blocks)
                .with_batch_dedupe(kv_batch_dedupe)
                .with_tiers(kv_tiers);
            if kv_window > 0 {
                cfg.with_window(kv_window)
            } else {
                cfg
            }
        });
        Ok(Self {
            method: args.get_or("method", "skeinformer").to_string(),
            d: args.get_usize("d", 64)?,
            heads: args.get_usize("heads", 4)?,
            seq: args.get_usize("seq", 512)?,
            head_dim: args.get_usize("head-dim", 32)?,
            max_batch: args.get_usize("batch", 8)?,
            max_wait: Duration::from_millis(args.get_u64("max-wait-ms", 4)?),
            seed: args.get_u64("seed", 0)?,
            workers: if workers == 0 { None } else { Some(workers) },
            queue_depth: args.get_usize("queue-depth", 0)?,
            kv,
        })
    }
}

/// One sequence's attention inputs: shared `[heads, seq, head_dim]`
/// row-major slabs, plus an optional length-`seq` 0/1 padding mask.
///
/// Every payload — the three slabs *and* the mask — is `Arc<[f32]>`, so
/// batch formation is fully zero-copy: the server reads the client's
/// memory in place and `Clone` only bumps reference counts, deep-copying
/// nothing.  A client that keeps its payload in `Arc<[f32]>` slabs
/// (e.g. resubmitting or fanning one slab into many requests) submits
/// with no element copies at all.  [`HeadsRequest::from_vecs`] (and
/// [`with_mask`](Self::with_mask)) are the conveniences for owned
/// buffers — note `Vec → Arc<[f32]>` allocates and copies once per
/// buffer, so hot-path clients should build `Arc` slabs up front and
/// reuse them.
#[derive(Clone, Debug)]
pub struct HeadsRequest {
    pub q: Arc<[f32]>,
    pub k: Arc<[f32]>,
    pub v: Arc<[f32]>,
    pub mask: Option<Arc<[f32]>>,
}

impl HeadsRequest {
    /// Wrap owned Q/K/V buffers (each `heads * seq * head_dim` elements,
    /// row-major `[heads, seq, head_dim]`).
    pub fn from_vecs(q: Vec<f32>, k: Vec<f32>, v: Vec<f32>) -> Self {
        Self { q: q.into(), k: k.into(), v: v.into(), mask: None }
    }

    /// Attach a length-`seq` 0/1 padding mask (owned-buffer convenience;
    /// an `Arc<[f32]>` can be assigned to `mask` directly).
    pub fn with_mask(mut self, mask: Vec<f32>) -> Self {
        self.mask = Some(mask.into());
        self
    }

    /// Dense standard-normal request of `elems = heads * seq * head_dim`
    /// values per slab — the demo/bench payload.
    pub fn random(elems: usize, rng: &mut Rng) -> Self {
        let mut mk = || {
            let mut buf = vec![0.0f32; elems];
            rng.fill_normal(&mut buf);
            buf
        };
        Self::from_vecs(mk(), mk(), mk())
    }
}

/// Head-range routing tag on a one-shot request, set by the shard
/// coordinator when it scatters one client request across engine
/// processes.  `q`/`k`/`v` then carry only heads `[head_lo, head_hi)`
/// of the global request (each slab `(head_hi - head_lo) * seq *
/// head_dim` elements), and head `h` of the sub-request draws from
/// `Rng::new(seed ^ (head_lo + h))` — the seed is pinned by the
/// coordinator (`batch_seed(coordinator_seed, request_index)`), so the
/// result is bitwise identical to the head slice a single process
/// would have computed, no matter how shards batch the sub-requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SubmitRoute {
    /// First global head (inclusive) carried by this sub-request.
    pub head_lo: u32,
    /// One past the last global head carried by this sub-request.
    pub head_hi: u32,
    /// Explicit base seed; replaces the shard's own
    /// `batch_seed(cfg.seed, batches)` derivation.
    pub seed: u64,
}

impl SubmitRoute {
    /// Heads carried by this sub-request.
    pub fn width(&self) -> usize {
        (self.head_hi - self.head_lo) as usize
    }
}

struct Pending {
    req: HeadsRequest,
    route: Option<SubmitRoute>,
    reply: ReplyTo,
    enqueued: Instant,
    conn: u64,
}

/// One operation on a decode stream.  Payloads ride the same zero-copy
/// `Arc<[f32]>` slab path as [`HeadsRequest`]: the server reads them in
/// place and only the reply is an owned copy.
pub enum StreamOp {
    /// Create the stream's per-head sessions (one per configured head).
    Open {
        /// Re-pilot stride for approximating methods (see
        /// [`SessionSpec::repilot_stride`]).
        repilot_stride: usize,
    },
    /// Append one token: `k`/`v` are `[heads, head_dim]` row-major slabs.
    Append { k: Arc<[f32]>, v: Arc<[f32]> },
    /// Bulk-append `tokens` tokens in one op — the chunked-prefill
    /// ingest path.  `k`/`v` are `[heads, tokens, head_dim]` row-major
    /// slabs (the same layout as a [`HeadsRequest`] payload).  Exactly
    /// equivalent to `tokens` consecutive [`Append`](Self::Append)s of
    /// the gathered per-token rows, but with one channel message per
    /// chunk and per-*block* (not per-token) cache bookkeeping.
    Prefill { k: Arc<[f32]>, v: Arc<[f32]>, tokens: usize },
    /// Query `rows` query rows per head: `q` is `[heads, rows, head_dim]`;
    /// the reply is the `[heads, rows, head_dim]` output slab.
    Query { q: Arc<[f32]>, rows: usize, reply: ReplyTo },
    /// Drop the stream's state.
    Close,
}

/// A message to the serve loop: a batched request, a stream operation,
/// or the explicit shutdown sentinel (needed because cloned stream
/// senders may outlive the handle — channel disconnect alone can no
/// longer signal shutdown).  `err` is an optional error reporter for
/// ops with no success reply of their own (wire-path append/prefill).
enum ServerMsg {
    Batch(Pending),
    Stream { conn: u64, stream: u64, op: StreamOp, err: Option<ReplyTo> },
    /// Live stats snapshot request (counters plus means-so-far); the
    /// wire `Stats` frame and the shard coordinator's aggregation poll
    /// land here.
    Stats(mpsc::Sender<AttentionServerStats>),
    Shutdown,
}

/// State shared by the handle, its connections, and stream handles.
struct HandleShared {
    tx: mpsc::SyncSender<ServerMsg>,
    next_stream: AtomicU64,
    next_conn: AtomicU64,
    cfg: AttentionServerConfig,
    obs: Arc<ServeTelemetry>,
}

impl HandleShared {
    /// Send with backpressure: a full inbox blocks the caller; a dead
    /// server drops the message, firing each carried [`ReplyTo`] with
    /// `Err(Shutdown)` so clients still get verdicts.
    fn send(&self, msg: ServerMsg) {
        let _ = self.tx.send(msg);
    }
}

/// Client handle to a running attention server.
pub struct AttentionServerHandle {
    shared: Arc<HandleShared>,
    join: Option<std::thread::JoinHandle<AttentionServerStats>>,
}

/// One client connection's sender: ops sent through one connection stay
/// in submission order and share one round-robin admission lane, so a
/// chatty connection cannot starve the others.  The handle's own
/// [`submit`](AttentionServerHandle::submit) /
/// [`open_stream`](AttentionServerHandle::open_stream) ride the
/// implicit connection 0.
#[derive(Clone)]
pub struct ServerConnection {
    shared: Arc<HandleShared>,
    conn: u64,
}

impl ServerConnection {
    /// Submit a request; returns the reply receiver.
    pub fn submit(&self, req: HeadsRequest) -> ReplyRx {
        let (reply, rx) = ReplyTo::channel();
        self.submit_with(req, reply);
        rx
    }

    /// Submit with an explicit reply target (the wire path passes a
    /// frame-encoding [`ReplyTo`] here).
    pub(crate) fn submit_with(&self, req: HeadsRequest, reply: ReplyTo) {
        self.submit_routed(req, None, reply);
    }

    /// Submit a possibly head-range-routed request (see [`SubmitRoute`]).
    pub(crate) fn submit_routed(
        &self,
        req: HeadsRequest,
        route: Option<SubmitRoute>,
        reply: ReplyTo,
    ) {
        self.shared.send(ServerMsg::Batch(Pending {
            req,
            route,
            reply,
            enqueued: Instant::now(),
            conn: self.conn,
        }));
    }

    /// Open a decode stream on this connection and return its handle.
    pub fn open_stream(&self, repilot_stride: usize) -> StreamHandle {
        let id = self.open_stream_id(repilot_stride);
        StreamHandle { id, conn: self.conn, shared: Arc::clone(&self.shared) }
    }

    /// Open a decode stream and return only its id (the wire path keeps
    /// ids, not handles).
    pub(crate) fn open_stream_id(&self, repilot_stride: usize) -> u64 {
        let id = self.shared.next_stream.fetch_add(1, Ordering::Relaxed);
        self.stream_op(id, StreamOp::Open { repilot_stride }, None);
        id
    }

    /// Open a decode stream under a caller-chosen id.  The shard
    /// coordinator assigns global stream ids and pushes them down so a
    /// stream's `stream_seed` derivation matches what a single process
    /// would have used; `fetch_max` keeps locally minted ids from ever
    /// colliding with adopted ones.
    pub(crate) fn open_stream_with_id(&self, stream: u64, repilot_stride: usize) {
        self.shared.next_stream.fetch_max(stream + 1, Ordering::Relaxed);
        self.stream_op(stream, StreamOp::Open { repilot_stride }, None);
    }

    /// A live stats snapshot from the serve thread (counters plus
    /// means-so-far), or `None` if the server is gone.  The shard
    /// coordinator polls this over the wire to aggregate cluster stats.
    pub fn stats(&self) -> Option<AttentionServerStats> {
        let (tx, rx) = mpsc::channel();
        self.shared.send(ServerMsg::Stats(tx));
        rx.recv().ok()
    }

    /// Send one raw stream op, with an optional error reporter for ops
    /// that have no success reply of their own.
    pub(crate) fn stream_op(&self, stream: u64, op: StreamOp, err: Option<ReplyTo>) {
        self.shared.send(ServerMsg::Stream { conn: self.conn, stream, op, err });
    }

    /// A sibling connection with its own fairness lane — the TCP accept
    /// loop mints one per socket without holding the server handle.
    pub(crate) fn sibling(&self) -> ServerConnection {
        ServerConnection {
            shared: Arc::clone(&self.shared),
            conn: self.shared.next_conn.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// The server's configuration (the wire handshake advertises the
    /// shape from here).
    pub(crate) fn cfg(&self) -> &AttentionServerConfig {
        &self.shared.cfg
    }

    /// The server's telemetry bundle — the wire front end snapshots its
    /// gauges and histograms into the `StatsOk` frame, and its writer
    /// threads record reply-write spans through it.
    pub(crate) fn telemetry(&self) -> &Arc<ServeTelemetry> {
        &self.shared.obs
    }
}

/// Client handle to one decode stream on a running server.  Ops sent
/// through one handle arrive in order (the channel preserves per-sender
/// order) and apply in order even when pipelined past an in-flight
/// query, so `append` → `query` sequences behave like local sessions.
pub struct StreamHandle {
    id: u64,
    conn: u64,
    shared: Arc<HandleShared>,
}

impl StreamHandle {
    /// The server-side stream id (what the wire protocol carries).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Elements per `[heads, head_dim]` token slab.
    pub fn token_elems(&self) -> usize {
        self.shared.cfg.heads * self.shared.cfg.head_dim
    }

    fn conn(&self) -> ServerConnection {
        ServerConnection { shared: Arc::clone(&self.shared), conn: self.conn }
    }

    /// Append one token (each slab `[heads, head_dim]`, read in place).
    /// A malformed append is rejected server-side (counted in
    /// [`AttentionServerStats::rejected`]); the next query surfaces the
    /// stream's true state.
    pub fn append(&self, k: Arc<[f32]>, v: Arc<[f32]>) {
        self.conn().stream_op(self.id, StreamOp::Append { k, v }, None);
    }

    /// Bulk-append `tokens` tokens in one op (each slab
    /// `[heads, tokens, head_dim]`, read in place) — the chunked-prefill
    /// path for ingesting a whole prompt.  Bitwise equivalent to
    /// [`append`](Self::append)ing each token's rows in order.
    pub fn prefill(&self, k: Arc<[f32]>, v: Arc<[f32]>, tokens: usize) {
        self.conn().stream_op(self.id, StreamOp::Prefill { k, v, tokens }, None);
    }

    /// Query `rows` query rows per head (`q` is `[heads, rows, head_dim]`,
    /// read in place); returns the reply receiver.  Rejections (bad
    /// shape, unknown stream, empty stream, or a cross-shape query
    /// against a square-only method) arrive as typed
    /// `Err(`[`ServeError`]`)` values.
    pub fn query(&self, q: Arc<[f32]>, rows: usize) -> ReplyRx {
        let (reply, rx) = ReplyTo::channel();
        self.conn().stream_op(self.id, StreamOp::Query { q, rows, reply }, None);
        rx
    }

    /// Drop the stream's server-side state.
    pub fn close(self) {
        self.conn().stream_op(self.id, StreamOp::Close, None);
    }
}

/// Aggregate serving statistics, reported on shutdown.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AttentionServerStats {
    pub requests: u64,
    pub batches: u64,
    /// Scheduler steps executed.  Each step admits up to `max_batch`
    /// slots — one-shot requests and stream queries combined — so with
    /// decode streams in play `steps >= batches`.
    pub steps: u64,
    /// Requests or stream ops rejected for malformed payloads (wrong
    /// slab/mask length, unknown stream, invalid query shape).  Every
    /// rejection also answers its client with a typed [`ServeError`].
    pub rejected: u64,
    /// Stream tokens appended across all streams.
    pub stream_appends: u64,
    /// Stream queries answered across all streams.
    pub stream_queries: u64,
    /// KV cache: sealed blocks deduplicated against the prefix index
    /// (zero for the cache-off configuration).
    pub kv_hit_blocks: u64,
    /// KV cache: sealed blocks newly inserted into the index.
    pub kv_alloc_blocks: u64,
    /// KV cache: blocks evicted from the prefix index — under capacity
    /// pressure, as sliding-window drops when no capacity bound is
    /// configured, or as batch-chain releases at request completion
    /// under a window policy.
    pub kv_evicted_blocks: u64,
    /// KV cache: distinct blocks resident at shutdown.
    pub kv_resident_blocks: u64,
    /// KV cache: resident KV bytes at shutdown
    /// ([`KvCache::resident_kv_bytes`] — the one place the block-geometry
    /// byte accounting lives).
    pub kv_resident_bytes: u64,
    /// KV cache: tier demotions performed under capacity pressure, one
    /// per rung descended (zero with `--kv-tiers` unset).
    pub kv_demoted_blocks: u64,
    /// KV cache: entries demoted to the disk-only spilled rung,
    /// including the shutdown [`KvCache::spill_index`] snapshot.
    pub kv_spilled_blocks: u64,
    /// KV cache: seal-time hits served by rehydrating an archived block
    /// from the spill store.
    pub kv_spill_hits: u64,
    /// KV cache: spill reads that failed verification (truncation,
    /// digest mismatch, missing file) and degraded to clean misses.
    pub kv_spill_corrupt: u64,
    /// Mean queueing delay (ms) — time from submit to batch execution.
    pub mean_queue_ms: f64,
    /// Mean executed one-shot batch occupancy (filled slots / max_batch,
    /// over executed batches).
    pub mean_occupancy: f64,
    /// Mean per-step admission occupancy (admitted slots / max_batch,
    /// over all executed steps; one-shots and stream queries each count
    /// one slot).
    pub mean_step_occupancy: f64,
    /// Mean engine time per executed batch (ms).
    pub mean_batch_ms: f64,
}

impl AttentionServerStats {
    /// Merge per-shard stats into one cluster view: counters sum, and
    /// each mean is weighted by the counter it was averaged over —
    /// `mean_queue_ms` by requests, `mean_occupancy` and
    /// `mean_batch_ms` by batches, `mean_step_occupancy` by steps.
    /// The shard coordinator reports this aggregate from its stats
    /// printer.
    pub fn merge_weighted(shards: &[AttentionServerStats]) -> AttentionServerStats {
        let mut out = AttentionServerStats::default();
        let mut queue_w = 0.0;
        let mut batch_occ_w = 0.0;
        let mut batch_ms_w = 0.0;
        let mut step_w = 0.0;
        for s in shards {
            out.requests += s.requests;
            out.batches += s.batches;
            out.steps += s.steps;
            out.rejected += s.rejected;
            out.stream_appends += s.stream_appends;
            out.stream_queries += s.stream_queries;
            out.kv_hit_blocks += s.kv_hit_blocks;
            out.kv_alloc_blocks += s.kv_alloc_blocks;
            out.kv_evicted_blocks += s.kv_evicted_blocks;
            out.kv_resident_blocks += s.kv_resident_blocks;
            out.kv_resident_bytes += s.kv_resident_bytes;
            out.kv_demoted_blocks += s.kv_demoted_blocks;
            out.kv_spilled_blocks += s.kv_spilled_blocks;
            out.kv_spill_hits += s.kv_spill_hits;
            out.kv_spill_corrupt += s.kv_spill_corrupt;
            queue_w += s.mean_queue_ms * s.requests as f64;
            batch_occ_w += s.mean_occupancy * s.batches as f64;
            batch_ms_w += s.mean_batch_ms * s.batches as f64;
            step_w += s.mean_step_occupancy * s.steps as f64;
        }
        if out.requests > 0 {
            out.mean_queue_ms = queue_w / out.requests as f64;
        }
        if out.batches > 0 {
            out.mean_occupancy = batch_occ_w / out.batches as f64;
            out.mean_batch_ms = batch_ms_w / out.batches as f64;
        }
        if out.steps > 0 {
            out.mean_step_occupancy = step_w / out.steps as f64;
        }
        out
    }
}

impl AttentionServerHandle {
    /// The configuration the server was started with (the wire front
    /// end advertises the shape from here).
    pub fn config(&self) -> &AttentionServerConfig {
        &self.shared.cfg
    }

    /// A new client connection: its ops get their own round-robin
    /// admission lane.  The wire front end opens one per TCP socket.
    pub fn connection(&self) -> ServerConnection {
        ServerConnection {
            shared: Arc::clone(&self.shared),
            conn: self.shared.next_conn.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// The handle's implicit connection 0 (in-process convenience).
    fn conn0(&self) -> ServerConnection {
        ServerConnection { shared: Arc::clone(&self.shared), conn: 0 }
    }

    /// Submit a request on the implicit connection; returns the reply
    /// receiver.  Rejections arrive as typed `Err(`[`ServeError`]`)`.
    pub fn submit(&self, req: HeadsRequest) -> ReplyRx {
        self.conn0().submit(req)
    }

    /// Open a streaming decode session set (one [`AttentionSession`] per
    /// configured head, server-side) on the implicit connection and
    /// return its handle.
    pub fn open_stream(&self, repilot_stride: usize) -> StreamHandle {
        self.conn0().open_stream(repilot_stride)
    }

    /// The telemetry bundle the serve thread records into.  [`start`]
    /// wires the disabled (no-op) bundle; [`start_with_telemetry`]
    /// takes an operator-configured one whose registry feeds
    /// `/metrics` and whose flight recorder feeds `--trace-out`.
    pub fn telemetry(&self) -> &Arc<ServeTelemetry> {
        &self.shared.obs
    }

    /// Stop the server and collect stats.  Live [`StreamHandle`]s and
    /// [`ServerConnection`]s do not block shutdown (an explicit sentinel
    /// ends the serve loop); their later ops answer
    /// `Err(ServeError::Shutdown)` client-side.  Ops already queued
    /// ahead of the shutdown are still processed.
    pub fn shutdown(mut self) -> Result<AttentionServerStats> {
        let _ = self.shared.tx.send(ServerMsg::Shutdown);
        self.join
            .take()
            .expect("server already joined")
            .join()
            .map_err(|_| anyhow::anyhow!("attention server thread panicked"))
    }
}

/// Start the engine-backed server; validates the method name up front.
/// [`AttentionServerHandle::shutdown`] stops it even while
/// [`StreamHandle`]s are still alive.
pub fn start(cfg: AttentionServerConfig) -> Result<AttentionServerHandle> {
    start_with_telemetry(cfg, ServeTelemetry::disabled())
}

/// As [`start`] with a live telemetry bundle: every serving stage
/// (admission wait, batch formation, KV ingest/gather, attention
/// compute) closes spans and histogram samples into `obs` (see
/// [`crate::obs`]).  Instrumentation reads clocks only — served bytes
/// are bitwise identical to [`start`]'s (pinned by
/// `rust/tests/telemetry.rs`).
pub fn start_with_telemetry(
    cfg: AttentionServerConfig,
    obs: Arc<ServeTelemetry>,
) -> Result<AttentionServerHandle> {
    anyhow::ensure!(
        attention::by_name(&cfg.method, cfg.d).is_some(),
        "unknown attention method {:?}",
        cfg.method
    );
    anyhow::ensure!(cfg.max_batch > 0, "max_batch must be positive");
    let depth = if cfg.queue_depth == 0 { DEFAULT_QUEUE_DEPTH } else { cfg.queue_depth };
    let (tx, rx) = mpsc::sync_channel::<ServerMsg>(depth);
    let shared = Arc::new(HandleShared {
        tx,
        next_stream: AtomicU64::new(0),
        next_conn: AtomicU64::new(1),
        cfg: cfg.clone(),
        obs: Arc::clone(&obs),
    });
    let join = std::thread::spawn(move || serve_loop(cfg, rx, obs));
    Ok(AttentionServerHandle { shared, join: Some(join) })
}

/// Per-stream server-side state.  At least one of the two KV holders is
/// present:
///
/// * `sessions` — one [`AttentionSession`] per head.  Present when the
///   cache is off, and when the method's session is exact-incremental
///   (`vmean`/`linformer`) on an unwindowed cached stream — their state
///   is O(p)/O(d·p) and duplicates nothing.
/// * `chain` — the stream's block chain in the shared [`KvCache`].
///   Present whenever the cache is on; the sole KV holder for
///   recompute-backed methods (their sessions would duplicate the
///   blocks' storage) and for every method under a sliding window.
struct StreamState {
    sessions: Option<Vec<Box<dyn AttentionSession>>>,
    chain: Option<StreamChain>,
    /// Effective re-pilot stride (clamped ≥ 1) — the epoch basis for
    /// cache-backed queries.
    repilot_stride: usize,
    /// The connection that opened the stream (its admission lane).
    conn: u64,
    /// A query is admitted or executing: later ops wait in `deferred`
    /// so per-stream order holds even under pipelined clients.
    blocked: bool,
    /// Ops that arrived while `blocked`, applied in order on unblock.
    deferred: VecDeque<(StreamOp, Option<ReplyTo>)>,
}

impl StreamState {
    /// Tokens a query computes over (window-clamped for cached streams).
    fn len(&self) -> usize {
        match (&self.sessions, &self.chain) {
            (Some(sessions), _) => sessions.first().map_or(0, |s| s.len()),
            (None, Some(chain)) => chain.visible_len(),
            (None, None) => 0,
        }
    }
}

/// A unit of admitted work: one slot in a scheduler step.
enum Work {
    OneShot(Pending),
    Query(QueryTask),
}

/// A stream query waiting for (or in) a step.
struct QueryTask {
    stream: u64,
    q: Arc<[f32]>,
    rows: usize,
    reply: ReplyTo,
}

/// Round-robin admission across connections: each connection keeps a
/// FIFO lane, and [`admit`](Self::admit) takes one slot per lane in
/// rotation until the step is full.  Per-connection order is preserved;
/// no lane can starve another.
#[derive(Default)]
struct Admission {
    queues: HashMap<u64, VecDeque<Work>>,
    /// Rotation of connections with non-empty lanes.
    rr: VecDeque<u64>,
    ready: usize,
    queries: usize,
}

impl Admission {
    /// Queued slots awaiting admission.
    fn ready(&self) -> usize {
        self.ready
    }

    /// Queued stream queries (each has a client blocked on its reply —
    /// their presence short-circuits batch-formation waits).
    fn queries(&self) -> usize {
        self.queries
    }

    fn push(&mut self, conn: u64, work: Work) {
        if matches!(work, Work::Query(_)) {
            self.queries += 1;
        }
        let lane = self.queues.entry(conn).or_default();
        if lane.is_empty() {
            self.rr.push_back(conn);
        }
        lane.push_back(work);
        self.ready += 1;
    }

    /// Take up to `max` slots round-robin across lanes.
    fn admit(&mut self, max: usize) -> Vec<Work> {
        let mut out = Vec::new();
        while out.len() < max {
            let Some(conn) = self.rr.pop_front() else { break };
            let lane = self.queues.get_mut(&conn).expect("rotated lane exists");
            let work = lane.pop_front().expect("rotated lane is non-empty");
            if matches!(work, Work::Query(_)) {
                self.queries -= 1;
            }
            self.ready -= 1;
            if lane.is_empty() {
                self.queues.remove(&conn);
            } else {
                self.rr.push_back(conn);
            }
            out.push(work);
        }
        out
    }
}

/// Running sums behind the mean stats.
#[derive(Default)]
struct Sums {
    queue_ms: f64,
    occupancy: f64,
    step_occupancy: f64,
    batch_ms: f64,
}

/// The serve thread's state: engine, stream table, admission queue, and
/// stats.  One instance lives for the thread's lifetime.
struct Serve<'a> {
    cfg: &'a AttentionServerConfig,
    method: Box<dyn attention::AttentionMethod>,
    engine: BatchedAttention,
    kv_cache: Option<KvCache>,
    streams: HashMap<u64, StreamState>,
    adm: Admission,
    stats: AttentionServerStats,
    sums: Sums,
    out_cache: Option<BatchTensor>,
    obs: Arc<ServeTelemetry>,
}

fn serve_loop(
    cfg: AttentionServerConfig,
    rx: mpsc::Receiver<ServerMsg>,
    obs: Arc<ServeTelemetry>,
) -> AttentionServerStats {
    let method = attention::by_name(&cfg.method, cfg.d).expect("method validated in start()");
    let mut engine = BatchedAttention::new();
    if let Some(w) = cfg.workers {
        engine = engine.with_workers(w);
    }
    let kv_cache = cfg.kv.clone().map(|kv| KvCache::new(kv, cfg.heads * cfg.head_dim));
    let mut srv = Serve {
        cfg: &cfg,
        method,
        engine,
        kv_cache,
        streams: HashMap::new(),
        adm: Admission::default(),
        stats: AttentionServerStats::default(),
        sums: Sums::default(),
        out_cache: None,
        obs,
    };

    let mut shutting_down = false;
    loop {
        if !shutting_down {
            // nothing admitted and nothing queued: block for traffic
            if srv.adm.ready() == 0 {
                match rx.recv() {
                    Ok(msg) => shutting_down = srv.ingest(msg),
                    Err(_) => shutting_down = true, // all senders gone
                }
            }
            // drain whatever else is already queued without blocking
            while !shutting_down {
                match rx.try_recv() {
                    Ok(msg) => shutting_down = srv.ingest(msg),
                    Err(_) => break,
                }
            }
            // batch formation: wait for extra slots only when no stream
            // query is pending (a decode client is blocked on that
            // reply) and the step is not yet full
            if !shutting_down
                && srv.adm.queries() == 0
                && srv.adm.ready() > 0
                && srv.adm.ready() < cfg.max_batch
            {
                let t_form = srv.obs.now();
                let deadline = Instant::now() + cfg.max_wait;
                while srv.adm.queries() == 0 && srv.adm.ready() < cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(msg) => {
                            if srv.ingest(msg) {
                                shutting_down = true;
                                break;
                            }
                        }
                        Err(_) => break, // timeout or disconnect: run what we have
                    }
                }
                // batch formation: the wait-for-extra-slots window
                srv.obs.span(Span::BatchForm, t_form, 0, 0);
            }
        }
        if srv.adm.ready() > 0 {
            srv.run_step();
            continue;
        }
        if shutting_down {
            break;
        }
    }
    srv.finish()
}

impl Serve<'_> {
    /// Apply one inbox message; returns true on the shutdown sentinel.
    fn ingest(&mut self, msg: ServerMsg) -> bool {
        match msg {
            ServerMsg::Batch(p) => {
                if let Err(e) = validate_request(self.cfg, &p.req, p.route.as_ref()) {
                    self.stats.rejected += 1;
                    p.reply.send(Err(e));
                } else {
                    let conn = p.conn;
                    self.adm.push(conn, Work::OneShot(p));
                }
                false
            }
            ServerMsg::Stream { conn, stream, op, err } => {
                self.ingest_stream_op(conn, stream, op, err);
                false
            }
            ServerMsg::Stats(tx) => {
                let _ = tx.send(self.snapshot());
                false
            }
            ServerMsg::Shutdown => true,
        }
    }

    /// Route one stream op: apply it now, defer it behind an in-flight
    /// query, or reject it typed.
    fn ingest_stream_op(&mut self, conn: u64, stream: u64, op: StreamOp, err: Option<ReplyTo>) {
        // Open applies immediately, even over an existing (possibly
        // blocked) stream — a re-opened id is a misbehaving client, and
        // the displaced state's blocks must not leak
        if let StreamOp::Open { repilot_stride } = op {
            let state = self.open_stream_state(conn, stream, repilot_stride);
            if let Some(old) = self.streams.insert(stream, state) {
                self.discard_stream_state(stream, old);
            }
            return;
        }
        let Some(state) = self.streams.get_mut(&stream) else {
            self.stats.rejected += 1;
            let e = ServeError::UnknownStream(stream);
            if let StreamOp::Query { reply, .. } = op {
                reply.send(Err(e));
            } else if let Some(err) = err {
                err.send(Err(e));
            }
            return;
        };
        if state.blocked {
            state.deferred.push_back((op, err));
            return;
        }
        match op {
            StreamOp::Open { .. } => unreachable!("open handled above"),
            StreamOp::Query { q, rows, reply } => {
                state.blocked = true;
                let lane = state.conn;
                self.adm.push(lane, Work::Query(QueryTask { stream, q, rows, reply }));
            }
            StreamOp::Append { k, v } => {
                if let Err(e) = self.apply_append(stream, &k, &v) {
                    self.stats.rejected += 1;
                    if let Some(err) = err {
                        err.send(Err(e));
                    }
                }
            }
            StreamOp::Prefill { k, v, tokens } => {
                if let Err(e) = self.apply_prefill(stream, &k, &v, tokens) {
                    self.stats.rejected += 1;
                    if let Some(err) = err {
                        err.send(Err(e));
                    }
                }
            }
            StreamOp::Close => {
                if let Some(state) = self.streams.remove(&stream) {
                    self.discard_stream_state(stream, state);
                }
            }
        }
    }

    /// Build a fresh stream's server-side KV state.
    fn open_stream_state(&mut self, conn: u64, stream: u64, repilot_stride: usize) -> StreamState {
        let cfg = self.cfg;
        let chain = self.kv_cache.as_mut().map(|c| c.open_stream());
        // live sessions hold the KV state when the cache is off; with
        // the cache on, only exact-incremental sessions survive (tiny
        // state, no stored K/V) — and only without a window, which
        // incremental accumulators cannot evict from
        let windowed = cfg.kv.as_ref().is_some_and(|kv| kv.window().is_some());
        let use_sessions =
            chain.is_none() || (self.method.session_is_exact_incremental() && !windowed);
        let sessions = use_sessions.then(|| {
            (0..cfg.heads)
                .map(|h| {
                    self.method.begin_session(
                        SessionSpec::new(cfg.head_dim)
                            .with_seed(stream_seed(cfg.seed, stream, h as u64))
                            .with_repilot_stride(repilot_stride)
                            .with_capacity_hint(cfg.seq),
                    )
                })
                .collect()
        });
        StreamState {
            sessions,
            chain,
            repilot_stride: repilot_stride.max(1),
            conn,
            blocked: false,
            deferred: VecDeque::new(),
        }
    }

    /// Release a removed/displaced stream's blocks and answer its
    /// deferred ops with typed rejections.
    fn discard_stream_state(&mut self, stream: u64, mut state: StreamState) {
        while let Some((op, err)) = state.deferred.pop_front() {
            self.stats.rejected += 1;
            let e = ServeError::UnknownStream(stream);
            if let StreamOp::Query { reply, .. } = op {
                reply.send(Err(e));
            } else if let Some(err) = err {
                err.send(Err(e));
            }
        }
        if let (Some(chain), Some(cache)) = (state.chain.take(), self.kv_cache.as_mut()) {
            cache.close_stream(chain);
        }
    }

    /// Append one token to a live stream (shape-checked).
    fn apply_append(&mut self, stream: u64, k: &Arc<[f32]>, v: &Arc<[f32]>) -> Result<(), ServeError> {
        let cfg = self.cfg;
        let token_elems = cfg.heads * cfg.head_dim;
        if k.len() != token_elems || v.len() != token_elems {
            return Err(ServeError::BadShape { what: "append token slab" });
        }
        let t0 = self.obs.now();
        let before = (t0 != 0).then(|| self.kv_cache.as_ref().map(|c| c.stats())).flatten();
        let state = self.streams.get_mut(&stream).expect("caller verified the stream");
        let conn = state.conn;
        if let Some(chain) = &mut state.chain {
            let cache = self.kv_cache.as_mut().expect("stream chain implies a cache");
            cache.append(chain, k, v);
        }
        if let Some(sessions) = &mut state.sessions {
            for (h, session) in sessions.iter_mut().enumerate() {
                let o = h * cfg.head_dim;
                session.append(&k[o..o + cfg.head_dim], &v[o..o + cfg.head_dim]);
            }
        }
        self.stats.stream_appends += 1;
        self.close_ingest_span(t0, before, conn, stream);
        Ok(())
    }

    /// Bulk-append `tokens` tokens to a live stream (shape-checked).
    fn apply_prefill(
        &mut self,
        stream: u64,
        k: &Arc<[f32]>,
        v: &Arc<[f32]>,
        tokens: usize,
    ) -> Result<(), ServeError> {
        let cfg = self.cfg;
        let token_elems = cfg.heads * cfg.head_dim;
        if tokens == 0 || k.len() != tokens * token_elems || v.len() != tokens * token_elems {
            return Err(ServeError::BadShape { what: "prefill chunk slab" });
        }
        let t0 = self.obs.now();
        let before = (t0 != 0).then(|| self.kv_cache.as_ref().map(|c| c.stats())).flatten();
        let state = self.streams.get_mut(&stream).expect("caller verified the stream");
        let conn = state.conn;
        if let Some(chain) = &mut state.chain {
            let cache = self.kv_cache.as_mut().expect("stream chain implies a cache");
            cache.append_chunk(chain, k, v, tokens, cfg.head_dim);
        }
        if let Some(sessions) = &mut state.sessions {
            // head h's rows are contiguous in the [heads, tokens,
            // head_dim] slab; sessions are independent per head, so
            // folding all of one head's tokens before the next head's
            // leaves every per-head state identical to per-token order
            for (h, session) in sessions.iter_mut().enumerate() {
                let base = h * tokens * cfg.head_dim;
                for t in 0..tokens {
                    let o = base + t * cfg.head_dim;
                    session.append(&k[o..o + cfg.head_dim], &v[o..o + cfg.head_dim]);
                }
            }
        }
        self.stats.stream_appends += tokens as u64;
        self.close_ingest_span(t0, before, conn, stream);
        Ok(())
    }

    /// Close a KV-ingest span opened before an append/prefill/dedupe
    /// write, classifying hit vs miss by the cache counter deltas: no
    /// fresh block inserts plus at least one dedupe hit means the
    /// write was absorbed by shared blocks.  Session-only streams (no
    /// cache) always classify as miss — every byte was new state.
    fn close_ingest_span(
        &self,
        t0: u64,
        before: Option<crate::kvcache::KvCacheStats>,
        conn: u64,
        stream: u64,
    ) {
        if t0 == 0 {
            return;
        }
        let hit = match (before, self.kv_cache.as_ref().map(|c| c.stats())) {
            (Some(b), Some(a)) => {
                a.alloc_blocks == b.alloc_blocks && a.hit_blocks > b.hit_blocks
            }
            _ => false,
        };
        let span = if hit { Span::KvIngestHit } else { Span::KvIngestMiss };
        self.obs.span(span, t0, conn, stream);
    }

    /// Re-insert a stream after its query completed, applying deferred
    /// ops in order.  A deferred query re-blocks the stream (joining the
    /// admission queue); a deferred close discards the rest.
    fn unblock_stream(&mut self, stream: u64, mut state: StreamState) {
        state.blocked = false;
        self.streams.insert(stream, state);
        loop {
            let state = self.streams.get_mut(&stream).expect("just inserted");
            let Some((op, err)) = state.deferred.pop_front() else { break };
            match op {
                StreamOp::Open { .. } => unreachable!("open is never deferred"),
                StreamOp::Query { q, rows, reply } => {
                    state.blocked = true;
                    let lane = state.conn;
                    self.adm.push(lane, Work::Query(QueryTask { stream, q, rows, reply }));
                    break; // remaining deferred ops stay behind this query
                }
                StreamOp::Append { k, v } => {
                    if let Err(e) = self.apply_append(stream, &k, &v) {
                        self.stats.rejected += 1;
                        if let Some(err) = err {
                            err.send(Err(e));
                        }
                    }
                }
                StreamOp::Prefill { k, v, tokens } => {
                    if let Err(e) = self.apply_prefill(stream, &k, &v, tokens) {
                        self.stats.rejected += 1;
                        if let Some(err) = err {
                            err.send(Err(e));
                        }
                    }
                }
                StreamOp::Close => {
                    let state = self.streams.remove(&stream).expect("just inserted");
                    self.discard_stream_state(stream, state);
                    break;
                }
            }
        }
    }

    /// Execute one scheduler step: admit up to `max_batch` slots
    /// round-robin, run the one-shot grid and the stream-query grid.
    fn run_step(&mut self) {
        if self.obs.enabled() {
            self.obs.g_queue_depth.set(self.adm.ready() as u64);
        }
        let admitted = self.adm.admit(self.cfg.max_batch);
        debug_assert!(!admitted.is_empty(), "run_step called with an empty queue");
        self.stats.steps += 1;
        self.sums.step_occupancy += admitted.len() as f64 / self.cfg.max_batch as f64;
        let mut oneshots = Vec::new();
        let mut routed: BTreeMap<(u32, u32), Vec<Pending>> = BTreeMap::new();
        let mut qtasks = Vec::new();
        for work in admitted {
            match work {
                Work::OneShot(p) => match p.route {
                    None => oneshots.push(p),
                    Some(r) => routed.entry((r.head_lo, r.head_hi)).or_default().push(p),
                },
                Work::Query(t) => qtasks.push(t),
            }
        }
        if !oneshots.is_empty() {
            self.execute_batch(oneshots);
        }
        for (_, group) in routed {
            self.execute_routed_batch(group);
        }
        if !qtasks.is_empty() {
            self.execute_queries(qtasks);
        }
    }

    /// Run one admitted group of one-shot requests as a `B × H` engine
    /// grid, packing each request's slabs zero-copy.
    fn execute_batch(&mut self, group: Vec<Pending>) {
        let cfg = self.cfg;
        let slab_views = |get: fn(&HeadsRequest) -> &Arc<[f32]>| {
            BatchTensor::from_slabs(
                cfg.heads,
                cfg.seq,
                cfg.head_dim,
                group.iter().map(|p| Arc::clone(get(&p.req))).collect(),
            )
        };
        let q = slab_views(|r| &r.q);
        // batch-slab dedupe: ingest each request's K/V through the
        // shared cache (chunked, per-request chain) so a resubmitted
        // or prompt-shared request materialises its head views from
        // shared blocks; otherwise wrap the client slabs in place
        let obs = Arc::clone(&self.obs);
        let chains: Option<Vec<StreamChain>> = match self.kv_cache.as_mut() {
            Some(cache) if cache.cfg().batch_dedupe => Some(
                group
                    .iter()
                    .map(|p| {
                        let t0 = obs.now();
                        let before = (t0 != 0).then(|| cache.stats());
                        let mut chain = cache.open_batch_stream();
                        cache.append_chunk(&mut chain, &p.req.k, &p.req.v, cfg.seq, cfg.head_dim);
                        if let Some(b) = before {
                            // no fresh inserts and at least one dedupe
                            // hit = the slab was served from shared
                            // blocks
                            let a = cache.stats();
                            let hit = a.alloc_blocks == b.alloc_blocks && a.hit_blocks > b.hit_blocks;
                            let span =
                                if hit { Span::KvIngestHit } else { Span::KvIngestMiss };
                            obs.span(span, t0, p.conn, 0);
                        }
                        chain
                    })
                    .collect(),
            ),
            _ => None,
        };
        let kv = chains.is_none().then(|| (slab_views(|r| &r.k), slab_views(|r| &r.v)));
        let any_mask = group.iter().any(|p| p.req.mask.is_some());
        let mut masks =
            if any_mask { Some(Matrix::full(group.len(), cfg.seq, 1.0)) } else { None };
        let t_adm = self.obs.now();
        for (b, p) in group.iter().enumerate() {
            if let (Some(mm), Some(req_mask)) = (masks.as_mut(), p.req.mask.as_ref()) {
                mm.set_row(b, &req_mask[..]);
            }
            self.sums.queue_ms += p.enqueued.elapsed().as_secs_f64() * 1e3;
            if t_adm != 0 {
                self.obs.span_at(
                    Span::QueueWait,
                    obs::start_ns(t_adm, p.enqueued),
                    t_adm,
                    p.conn,
                    0,
                );
            }
        }

        let t0 = Instant::now();
        let t_compute = self.obs.now();
        let seed = batch_seed(cfg.seed, self.stats.batches);
        // reuse the output tensor across equal-occupancy batches —
        // with the engine's in-place head writes the steady-state
        // request path allocates only the per-request reply copies
        let mut out = match self.out_cache.take() {
            Some(t) if t.batch() == group.len() => t,
            _ => BatchTensor::zeros(group.len(), cfg.heads, cfg.seq, cfg.head_dim),
        };
        match (&chains, &kv) {
            (Some(chains), _) => {
                // cache-backed K/V: the engine gathers each head's
                // rows from the (possibly shared) blocks — bitwise
                // what the slab tensors hold, per the verified-dedupe
                // contract
                let fill = |b: usize, h: usize, km: &mut Matrix, vm: &mut Matrix| {
                    chains[b].gather_head_into(h, cfg.head_dim, km, vm);
                };
                let t_gather = self.obs.now();
                self.engine.run_gather_into(
                    self.method.as_ref(),
                    &q,
                    cfg.seq,
                    &fill,
                    masks.as_ref(),
                    seed,
                    &mut out,
                );
                // the per-head gathers run inside the engine's fan-out
                // (the fill callback), so this span covers the whole
                // cache-backed compute — it nests inside AttnCompute
                // and marks the batch as chain-fed in the trace
                self.obs.span(Span::KvGather, t_gather, 0, 0);
            }
            (None, Some((k, v))) => {
                self.engine
                    .run_into(self.method.as_ref(), &q, k, v, masks.as_ref(), seed, &mut out)
            }
            (None, None) => unreachable!("kv tensors built whenever chains are absent"),
        }
        if let (Some(chains), Some(cache)) = (chains, self.kv_cache.as_mut()) {
            // shared sealed blocks stay index-retained for future
            // replays (until capacity pressure evicts them); under a
            // window policy close_stream also releases the chain's
            // non-shared blocks so a one-shot burst cannot pin the pool
            for chain in chains {
                cache.close_stream(chain);
            }
        }
        self.sums.batch_ms += t0.elapsed().as_secs_f64() * 1e3;
        self.obs.span(Span::AttnCompute, t_compute, 0, 0);

        let n = group.len();
        for (b, p) in group.into_iter().enumerate() {
            p.reply.send(Ok(out.sequence(b).to_vec()));
        }
        self.out_cache = Some(out);
        self.stats.requests += n as u64;
        self.stats.batches += 1;
        self.sums.occupancy += n as f64 / cfg.max_batch as f64;
    }

    /// Run one admitted group of head-range-routed sub-requests that
    /// share a `(head_lo, head_hi)` window.  Seeds come from the route
    /// (one per sub-request, pinned by the coordinator) rather than
    /// this shard's batch counter, and the engine offsets head RNG
    /// derivation by `head_lo` — so the output is bitwise the head
    /// slice of the single-process result no matter how sub-requests
    /// were packed into shard-side batches.  Batch-slab dedupe is
    /// bypassed here: routed slabs are head-range fragments whose
    /// geometry does not match the cache's full-width block layout.
    fn execute_routed_batch(&mut self, group: Vec<Pending>) {
        let cfg = self.cfg;
        let route = group[0].route.expect("routed group");
        let width = route.width();
        let slab_views = |get: fn(&HeadsRequest) -> &Arc<[f32]>| {
            BatchTensor::from_slabs(
                width,
                cfg.seq,
                cfg.head_dim,
                group.iter().map(|p| Arc::clone(get(&p.req))).collect(),
            )
        };
        let q = slab_views(|r| &r.q);
        let k = slab_views(|r| &r.k);
        let v = slab_views(|r| &r.v);
        let any_mask = group.iter().any(|p| p.req.mask.is_some());
        let mut masks =
            if any_mask { Some(Matrix::full(group.len(), cfg.seq, 1.0)) } else { None };
        let mut seeds = Vec::with_capacity(group.len());
        let t_adm = self.obs.now();
        for (b, p) in group.iter().enumerate() {
            if let (Some(mm), Some(req_mask)) = (masks.as_mut(), p.req.mask.as_ref()) {
                mm.set_row(b, &req_mask[..]);
            }
            seeds.push(p.route.expect("routed group").seed);
            self.sums.queue_ms += p.enqueued.elapsed().as_secs_f64() * 1e3;
            if t_adm != 0 {
                self.obs.span_at(
                    Span::QueueWait,
                    obs::start_ns(t_adm, p.enqueued),
                    t_adm,
                    p.conn,
                    0,
                );
            }
        }

        let t0 = Instant::now();
        let t_compute = self.obs.now();
        let mut out = BatchTensor::zeros(group.len(), width, cfg.seq, cfg.head_dim);
        self.engine.run_seeded_into(
            self.method.as_ref(),
            &q,
            &k,
            &v,
            masks.as_ref(),
            &seeds,
            route.head_lo as usize,
            &mut out,
        );
        self.sums.batch_ms += t0.elapsed().as_secs_f64() * 1e3;
        self.obs.span(Span::AttnCompute, t_compute, 0, 0);

        let n = group.len();
        for (b, p) in group.into_iter().enumerate() {
            p.reply.send(Ok(out.sequence(b).to_vec()));
        }
        self.stats.requests += n as u64;
        self.stats.batches += 1;
        self.sums.occupancy += n as f64 / cfg.max_batch as f64;
    }

    /// Answer one admitted group of stream queries: validate each
    /// against its stream's state, fan the survivors out as one
    /// (stream × head) grid, reply, and unblock the streams.
    fn execute_queries(&mut self, tasks: Vec<QueryTask>) {
        let mut jobs: Vec<QueryJob> = Vec::with_capacity(tasks.len());
        for t in tasks {
            let Some(state) = self.streams.remove(&t.stream) else {
                // displaced by a re-open between admission and execution
                // (misbehaving client): reject
                self.stats.rejected += 1;
                t.reply.send(Err(ServeError::UnknownStream(t.stream)));
                continue;
            };
            let len = state.len();
            let want = self.cfg.heads * t.rows * self.cfg.head_dim;
            let fail = if len == 0 {
                Some(ServeError::EmptyStream(t.stream))
            } else if t.rows == 0 || t.q.len() != want {
                Some(ServeError::BadShape { what: "query q slab" })
            } else if !self.method.supports_cross_shape() && t.rows != len {
                // square-only methods can only answer full-state queries
                Some(ServeError::CrossShapeUnsupported { rows: t.rows, len })
            } else {
                None
            };
            if let Some(e) = fail {
                self.stats.rejected += 1;
                t.reply.send(Err(e));
                self.unblock_stream(t.stream, state);
                continue;
            }
            jobs.push(QueryJob {
                stream: t.stream,
                state,
                q: t.q,
                rows: t.rows,
                reply: t.reply,
                out: vec![0.0f32; want],
            });
        }
        if !jobs.is_empty() {
            let t_compute = self.obs.now();
            self.run_query_grid(&mut jobs);
            self.obs.span(Span::AttnCompute, t_compute, 0, 0);
        }
        for job in jobs {
            self.stats.stream_queries += 1;
            job.reply.send(Ok(job.out));
            self.unblock_stream(job.stream, job.state);
        }
    }

    /// Fan a step's stream queries out as one (stream × head) task grid
    /// across the persistent worker pool.  Task (j, h) touches only job
    /// j's head-h session (or its read-only chain view) and writes only
    /// its own span of job j's output slab, so tasks are disjoint; each
    /// head's bytes are a pure function of its inputs and seed, so the
    /// result is bitwise invariant to worker count *and* to which other
    /// streams share the step — the contract that makes continuous
    /// batching transparent.
    fn run_query_grid(&mut self, jobs: &mut [QueryJob]) {
        let cfg = self.cfg;
        let head_dim = cfg.head_dim;
        let method = self.method.as_ref();
        let obs = &self.obs;
        let workers = cfg.workers.unwrap_or_else(pool::pool_size).max(1);
        // mirror the engine's oversubscription policy: when the task
        // grid alone saturates the pool, inner matmuls go single-threaded
        let grid = jobs.len() * cfg.heads;
        let inner_plan = if grid.min(workers) >= pool::pool_size() {
            MatmulPlan::SingleThread
        } else {
            MatmulPlan::Auto
        };

        // decompose each job into a raw-pointer context so the parallel
        // region borrows only the context table (SendPtr is Send + Sync)
        let ctxs: Vec<Ctx> = jobs
            .iter_mut()
            .map(|job| {
                let (kv, epoch) = match (&mut job.state.sessions, &job.state.chain) {
                    (Some(sessions), _) => (KvSrc::Sessions(pool::SendPtr(sessions.as_mut_ptr())), 0),
                    (None, Some(chain)) => {
                        // the seed rule RecomputeSession (and
                        // BoundedSession, under a window) applies: epoch
                        // over the TOTAL appended count
                        let epoch = session_epoch(chain.appended(), job.state.repilot_stride);
                        let chain: *const StreamChain = chain;
                        (KvSrc::Chain(pool::SendPtr(chain.cast_mut())), epoch)
                    }
                    (None, None) => unreachable!("stream holds sessions or a chain"),
                };
                Ctx {
                    stream: job.stream,
                    rows: job.rows,
                    head_elems: job.rows * head_dim,
                    q: pool::SendPtr(job.q.as_ptr().cast_mut()),
                    out: pool::SendPtr(job.out.as_mut_ptr()),
                    kv,
                    epoch,
                }
            })
            .collect();
        let tasks: Vec<(usize, usize)> =
            (0..ctxs.len()).flat_map(|j| (0..cfg.heads).map(move |h| (j, h))).collect();
        pool::parallel_map_workers(&tasks, workers, |&(j, h)| {
            let ctx = &ctxs[j];
            let mut scratch = AttnScratch::new();
            // SAFETY: ctx.q points at job j's live Arc<[f32]> slab of
            // heads * head_elems elements; reads only.
            let q_all =
                unsafe { std::slice::from_raw_parts(ctx.q.0, cfg.heads * ctx.head_elems) };
            let qbuf = scratch.buf_from(&q_all[h * ctx.head_elems..(h + 1) * ctx.head_elems]);
            let q_head = Matrix::from_vec(ctx.rows, head_dim, qbuf);
            let mut out = scratch.matrix(ctx.rows, head_dim);
            match ctx.kv {
                KvSrc::Sessions(sess) => {
                    // SAFETY: each (j, h) pair is claimed by exactly one
                    // task (parallel_map_workers' disjoint-index
                    // contract), task (j, h) touches only job j's
                    // sessions[h], and the call does not return until
                    // every task completed — so the &mut never aliases
                    // and never outlives the jobs borrow.
                    let session = unsafe { &mut *sess.0.add(h) };
                    with_default_plan(inner_plan, || {
                        session.query_into(&q_head, &mut out, &mut scratch)
                    });
                }
                KvSrc::Chain(chain) => {
                    // SAFETY: shared read-only view of job j's chain; no
                    // task mutates any chain during the grid.
                    let chain: &StreamChain = unsafe { &*chain.0 };
                    let n = chain.visible_len();
                    let mut k = scratch.matrix(n, head_dim);
                    let mut v = scratch.matrix(n, head_dim);
                    // per-(stream, head) gather span, recorded from the
                    // worker thread (the flight recorder's rings are
                    // per-thread, so this is contention-free)
                    let t_gather = obs.now();
                    chain.gather_head_into(h, head_dim, &mut k, &mut v);
                    obs.span(Span::KvGather, t_gather, 0, ctx.stream);
                    let seed = session_seed(stream_seed(cfg.seed, ctx.stream, h as u64), ctx.epoch);
                    let inputs = AttnInputs::new(&q_head, &k, &v).with_seed(seed);
                    with_default_plan(inner_plan, || {
                        method.compute_into(&inputs, &mut out, &mut scratch)
                    });
                    scratch.recycle(v);
                    scratch.recycle(k);
                }
            }
            // SAFETY: disjoint output spans — task (j, h) writes only
            // job j's [h * head_elems, (h + 1) * head_elems) span.
            unsafe {
                std::slice::from_raw_parts_mut(ctx.out.0.add(h * ctx.head_elems), ctx.head_elems)
                    .copy_from_slice(out.data());
            }
            scratch.recycle(out);
            scratch.recycle_buf(q_head.into_vec());
        });
    }

    /// Finalize the mean stats and surface the KV cache counters.  With
    /// a spill store configured, the index is snapshotted to it first
    /// ([`KvCache::spill_index`]) so the next server over the same
    /// directory warm-restarts from this one's cached prefixes.
    fn finish(mut self) -> AttentionServerStats {
        if let Some(cache) = self.kv_cache.as_mut() {
            if cache.spill_store().is_some() {
                cache.spill_index();
            }
        }
        self.snapshot()
    }

    /// A point-in-time copy of the stats: the raw counters plus means
    /// computed from the running sums and the current KV cache
    /// counters.  Unlike [`finish`](Self::finish) this does not touch
    /// the spill index — it is what the `Stats` wire frame and the
    /// shard coordinator's aggregation poll observe on a live server.
    fn snapshot(&self) -> AttentionServerStats {
        let mut stats = self.stats;
        if stats.requests > 0 {
            stats.mean_queue_ms = self.sums.queue_ms / stats.requests as f64;
        }
        if stats.batches > 0 {
            stats.mean_occupancy = self.sums.occupancy / stats.batches as f64;
            stats.mean_batch_ms = self.sums.batch_ms / stats.batches as f64;
        }
        if stats.steps > 0 {
            stats.mean_step_occupancy = self.sums.step_occupancy / stats.steps as f64;
        }
        if let Some(cache) = self.kv_cache.as_ref() {
            let kv = cache.stats();
            stats.kv_hit_blocks = kv.hit_blocks;
            stats.kv_alloc_blocks = kv.alloc_blocks;
            stats.kv_evicted_blocks = kv.evicted_blocks;
            stats.kv_resident_blocks = kv.resident_blocks;
            stats.kv_resident_bytes = cache.resident_kv_bytes();
            stats.kv_demoted_blocks = kv.demoted_blocks;
            stats.kv_spilled_blocks = kv.spilled_blocks;
            stats.kv_spill_hits = kv.spill_hits;
            stats.kv_spill_corrupt = kv.spill_corrupt;
        }
        if self.obs.enabled() {
            // refresh the residency gauges on every snapshot — the
            // `/metrics` render polls stats first, so scrapes see
            // current occupancy
            self.obs.g_kv_resident_blocks.set(stats.kv_resident_blocks);
            self.obs.g_kv_resident_bytes.set(stats.kv_resident_bytes);
        }
        stats
    }
}

/// One validated stream query in a step's grid.
struct QueryJob {
    stream: u64,
    state: StreamState,
    q: Arc<[f32]>,
    rows: usize,
    reply: ReplyTo,
    out: Vec<f32>,
}

/// Per-job raw-pointer context for the (stream × head) fan-out; see the
/// SAFETY comments in [`Serve::run_query_grid`].
struct Ctx {
    stream: u64,
    rows: usize,
    head_elems: usize,
    q: pool::SendPtr<f32>,
    out: pool::SendPtr<f32>,
    kv: KvSrc,
    /// Epoch for the chain seed rule (0 for session-backed jobs).
    epoch: u64,
}

/// Where a query job's KV state lives.
enum KvSrc {
    /// Base pointer into the job's per-head session vec; task h takes
    /// `&mut sessions[h]`.
    Sessions(pool::SendPtr<Box<dyn AttentionSession>>),
    /// Shared read-only chain view (all heads gather from it).
    Chain(pool::SendPtr<StreamChain>),
}

/// Render the counter/mean portion of an [`AttentionServerStats`]
/// snapshot as Prometheus text exposition.  The `/metrics` endpoint
/// composes this with [`ServeTelemetry::render`]; it lives here rather
/// than in [`crate::obs`] because the obs layer must not depend on the
/// serving stack.  The KV residency numbers are deliberately omitted —
/// the telemetry gauges `skein_kv_resident_blocks` /
/// `skein_kv_resident_bytes` (refreshed by every stats snapshot) own
/// those, and one exposition must not name a metric twice.
pub fn render_stats_prometheus(s: &AttentionServerStats) -> String {
    let mut out = String::new();
    let counters = [
        ("skein_requests_total", s.requests),
        ("skein_batches_total", s.batches),
        ("skein_steps_total", s.steps),
        ("skein_rejected_total", s.rejected),
        ("skein_stream_appends_total", s.stream_appends),
        ("skein_stream_queries_total", s.stream_queries),
        ("skein_kv_hit_blocks_total", s.kv_hit_blocks),
        ("skein_kv_alloc_blocks_total", s.kv_alloc_blocks),
        ("skein_kv_evicted_blocks_total", s.kv_evicted_blocks),
        ("skein_kv_demoted_blocks_total", s.kv_demoted_blocks),
        ("skein_kv_spilled_blocks_total", s.kv_spilled_blocks),
        ("skein_kv_spill_hits_total", s.kv_spill_hits),
        ("skein_kv_spill_corrupt_total", s.kv_spill_corrupt),
    ];
    for (name, v) in counters {
        out.push_str("# TYPE ");
        out.push_str(name);
        out.push_str(" counter\n");
        out.push_str(name);
        out.push(' ');
        out.push_str(&v.to_string());
        out.push('\n');
    }
    let gauges = [
        ("skein_mean_queue_ms", s.mean_queue_ms),
        ("skein_mean_occupancy", s.mean_occupancy),
        ("skein_mean_step_occupancy", s.mean_step_occupancy),
        ("skein_mean_batch_ms", s.mean_batch_ms),
    ];
    for (name, v) in gauges {
        out.push_str("# TYPE ");
        out.push_str(name);
        out.push_str(" gauge\n");
        out.push_str(name);
        out.push(' ');
        out.push_str(&v.to_string());
        out.push('\n');
    }
    out
}

/// Shape-check one one-shot request against the server shape.  A
/// routed request carries only its head range, so its slabs are
/// `(head_hi - head_lo) * seq * head_dim` elements instead of the full
/// `heads * seq * head_dim`.
pub(crate) fn validate_request(
    cfg: &AttentionServerConfig,
    req: &HeadsRequest,
    route: Option<&SubmitRoute>,
) -> Result<(), ServeError> {
    let elems = match route {
        None => cfg.request_elems(),
        Some(r) => {
            if r.head_lo >= r.head_hi || r.head_hi as usize > cfg.heads {
                return Err(ServeError::BadShape { what: "head range" });
            }
            r.width() * cfg.seq * cfg.head_dim
        }
    };
    if req.q.len() != elems {
        return Err(ServeError::BadShape { what: "q slab" });
    }
    if req.k.len() != elems {
        return Err(ServeError::BadShape { what: "k slab" });
    }
    if req.v.len() != elems {
        return Err(ServeError::BadShape { what: "v slab" });
    }
    if req.mask.as_ref().is_some_and(|m| m.len() != cfg.seq) {
        return Err(ServeError::BadShape { what: "mask" });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{HeadSpec, Standard};
    use crate::rng::Rng;

    fn cfg(method: &str, max_batch: usize) -> AttentionServerConfig {
        AttentionServerConfig {
            method: method.to_string(),
            d: 8,
            heads: 2,
            seq: 16,
            head_dim: 4,
            max_batch,
            max_wait: Duration::from_millis(2),
            seed: 0,
            workers: None,
            queue_depth: 0,
            kv: None,
        }
    }

    #[test]
    fn telemetry_start_records_serving_spans() {
        let c = cfg("standard", 2);
        let obs = ServeTelemetry::new(true);
        let handle = start_with_telemetry(c.clone(), Arc::clone(&obs)).unwrap();
        let r1 = handle.submit(HeadsRequest::random(c.request_elems(), &mut Rng::new(1)));
        assert_eq!(r1.recv().unwrap().len(), c.request_elems());
        handle.shutdown().unwrap();
        assert!(obs.h_queue_wait.snapshot().count() >= 1, "queue-wait histo empty");
        assert!(obs.h_attn_compute.snapshot().count() >= 1, "attn-compute histo empty");
        assert!(obs.recorder().recorded() >= 2, "flight recorder saw no spans");
        let text = obs.render();
        assert!(text.contains("skein_attn_compute_ns_count"));
    }

    #[test]
    fn stats_prometheus_render_is_well_formed() {
        let s = AttentionServerStats { requests: 3, mean_queue_ms: 0.5, ..Default::default() };
        let text = render_stats_prometheus(&s);
        assert!(text.contains("# TYPE skein_requests_total counter\nskein_requests_total 3\n"));
        assert!(text.contains("# TYPE skein_mean_queue_ms gauge\nskein_mean_queue_ms 0.5\n"));
        // every non-comment line is exactly `name value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad sample line {line:?}");
        }
    }

    fn random_request(cfg: &AttentionServerConfig, seed: u64) -> HeadsRequest {
        HeadsRequest::random(cfg.request_elems(), &mut Rng::new(seed))
    }

    #[test]
    fn batch_seeds_do_not_collide_across_nearby_batches() {
        // the engine XORs head indices 0..B*H into the seed; the sets
        // {batch_seed(s,i) ^ g} must be disjoint across batches
        let mut seen = std::collections::HashSet::new();
        for batch in 0..64u64 {
            for g in 0..16u64 {
                assert!(
                    seen.insert(batch_seed(0, batch) ^ g),
                    "stream seed reused at batch {batch}, head {g}"
                );
            }
        }
    }

    #[test]
    fn serves_requests_and_reports_stats() {
        let c = cfg("standard", 4);
        let handle = start(c.clone()).unwrap();
        let rxs: Vec<_> = (0..6).map(|i| handle.submit(random_request(&c, i))).collect();
        for rx in rxs {
            let out = rx.recv().expect("reply");
            assert_eq!(out.len(), c.request_elems());
            assert!(out.iter().all(|x| x.is_finite()));
        }
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.requests, 6);
        assert!(stats.batches >= 2, "6 requests at max_batch 4 need >= 2 batches");
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn scheduler_reports_step_occupancy() {
        let c = cfg("standard", 4);
        let handle = start(c.clone()).unwrap();
        let rxs: Vec<_> = (0..8).map(|i| handle.submit(random_request(&c, i))).collect();
        for rx in rxs {
            rx.recv().expect("reply");
        }
        let stats = handle.shutdown().unwrap();
        assert!(stats.steps >= stats.batches, "every batch runs inside a step");
        assert!(
            stats.mean_step_occupancy > 0.0 && stats.mean_step_occupancy <= 1.0,
            "occupancy must be a (0, 1] fraction, got {}",
            stats.mean_step_occupancy
        );
    }

    #[test]
    fn single_sequence_batch_matches_direct_engine_call() {
        let c = cfg("standard", 1); // batch size 1: deterministic packing
        let handle = start(c.clone()).unwrap();
        let req = random_request(&c, 9);
        let got = handle.submit(req.clone()).recv().unwrap();
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.batches, 1);

        let spec = HeadSpec::new(1, c.heads, c.seq, c.head_dim);
        let q = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.q.to_vec());
        let k = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.k.to_vec());
        let v = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.v.to_vec());
        // the first batch of a server's lifetime computes with batch_seed(seed, 0)
        let want =
            BatchedAttention::new().run(&Standard, &q, &k, &v, None, batch_seed(c.seed, 0));
        assert!(spec.matches(&want));
        assert_eq!(got, want.data().to_vec());
    }

    #[test]
    fn routed_head_ranges_gather_to_the_full_result_bitwise() {
        // split one 4-head request into [0,2) and [2,4) sub-requests
        // with a pinned seed — the gathered halves must be bitwise the
        // single-process result under that same seed, which is the
        // shard coordinator's scatter/gather contract
        let mut c = cfg("skeinformer", 2);
        c.heads = 4;
        let handle = start(c.clone()).unwrap();
        let req = random_request(&c, 31);
        let pinned = batch_seed(0xC0FF_EE00, 0);

        let per_head = c.seq * c.head_dim;
        let slice = |s: &Arc<[f32]>, lo: usize, hi: usize| -> Vec<f32> {
            s[lo * per_head..hi * per_head].to_vec()
        };
        let conn = handle.connection();
        let mut rxs = Vec::new();
        for (lo, hi) in [(0u32, 2u32), (2, 4)] {
            let sub = HeadsRequest::from_vecs(
                slice(&req.q, lo as usize, hi as usize),
                slice(&req.k, lo as usize, hi as usize),
                slice(&req.v, lo as usize, hi as usize),
            );
            let (reply, rx) = ReplyTo::channel();
            conn.submit_routed(
                sub,
                Some(SubmitRoute { head_lo: lo, head_hi: hi, seed: pinned }),
                reply,
            );
            rxs.push((lo, rx));
        }
        let mut got = vec![0.0f32; c.heads * per_head];
        for (lo, rx) in rxs {
            let part = rx.recv().unwrap();
            got[lo as usize * per_head..lo as usize * per_head + part.len()]
                .copy_from_slice(&part);
        }
        handle.shutdown().unwrap();

        let method = crate::attention::by_name(&c.method, c.d).unwrap();
        let q = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.q.to_vec());
        let k = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.k.to_vec());
        let v = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.v.to_vec());
        let want = BatchedAttention::new().run(method.as_ref(), &q, &k, &v, None, pinned);
        assert_eq!(got, want.data().to_vec(), "scatter/gather must be bitwise");
    }

    #[test]
    fn routed_requests_reject_bad_head_ranges() {
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let conn = handle.connection();
        let per_head = c.seq * c.head_dim;
        // empty range, range past the head count, and a slab that does
        // not match the claimed width must all reject typed
        for (lo, hi, elems) in
            [(1u32, 1u32, per_head), (0, 3, 3 * per_head), (0, 1, 2 * per_head)]
        {
            let sub = HeadsRequest::from_vecs(vec![0.0; elems], vec![0.0; elems], vec![0.0; elems]);
            let (reply, rx) = ReplyTo::channel();
            conn.submit_routed(sub, Some(SubmitRoute { head_lo: lo, head_hi: hi, seed: 7 }), reply);
            assert!(matches!(rx.recv(), Err(ServeError::BadShape { .. })));
        }
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.rejected, 3);
    }

    #[test]
    fn live_stats_snapshot_tracks_the_running_server() {
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let conn = handle.connection();
        conn.submit(random_request(&c, 1)).recv().unwrap();
        let snap = conn.stats().expect("server alive");
        assert_eq!(snap.requests, 1);
        assert_eq!(snap.batches, 1);
        assert!(snap.mean_step_occupancy > 0.0, "means are live, not end-only");
        let end = handle.shutdown().unwrap();
        assert_eq!(end.requests, snap.requests);
    }

    #[test]
    fn merged_stats_sum_counters_and_weight_means() {
        let a = AttentionServerStats {
            requests: 2,
            steps: 1,
            mean_queue_ms: 4.0,
            mean_step_occupancy: 1.0,
            ..Default::default()
        };
        let b = AttentionServerStats {
            requests: 6,
            steps: 3,
            mean_queue_ms: 8.0,
            mean_step_occupancy: 0.5,
            ..Default::default()
        };
        let m = AttentionServerStats::merge_weighted(&[a, b]);
        assert_eq!(m.requests, 8);
        assert_eq!(m.steps, 4);
        // queue: (2*4 + 6*8) / 8; step occupancy: (1*1.0 + 3*0.5) / 4
        assert!((m.mean_queue_ms - 7.0).abs() < 1e-12);
        assert!((m.mean_step_occupancy - 0.625).abs() < 1e-12);
    }

    #[test]
    fn explicit_stream_ids_pin_the_seed_derivation() {
        // a coordinator-assigned id must not collide with locally
        // minted ones: after adopting id 7, the next local id is 8
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let conn = handle.connection();
        conn.open_stream_with_id(7, 1);
        let s = conn.open_stream(1);
        assert_eq!(s.id(), 8);
        handle.shutdown().unwrap();
    }

    #[test]
    fn malformed_requests_are_rejected_not_wedged() {
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let bad = HeadsRequest::from_vecs(vec![0.0; 3], vec![0.0; 3], vec![0.0; 3]);
        let bad_rx = handle.submit(bad);
        let good_rx = handle.submit(random_request(&c, 1));
        assert!(good_rx.recv().is_ok());
        assert!(bad_rx.recv().is_err(), "malformed request must not get a reply");
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn typed_rejections_name_the_failure() {
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        // malformed one-shot: BadShape
        let bad = HeadsRequest::from_vecs(vec![0.0; 3], vec![0.0; 3], vec![0.0; 3]);
        assert!(matches!(
            handle.submit(bad).recv(),
            Err(ServeError::BadShape { .. })
        ));
        // query before any append: EmptyStream
        let s = handle.open_stream(1);
        let q: Arc<[f32]> = vec![0.0f32; c.heads * c.head_dim].into();
        let sid = s.id();
        assert_eq!(s.query(q.clone(), 1).recv(), Err(ServeError::EmptyStream(sid)));
        // a query for an id that was never opened: UnknownStream
        let conn = handle.connection();
        let (reply, rx) = ReplyTo::channel();
        conn.stream_op(999, StreamOp::Query { q: q.clone(), rows: 1, reply }, None);
        assert_eq!(rx.recv(), Err(ServeError::UnknownStream(999)));
        // malformed query slab against a live stream: BadShape
        s.append(q.clone(), q.clone());
        let short: Arc<[f32]> = vec![0.0f32; 3].into();
        assert!(matches!(s.query(short, 1).recv(), Err(ServeError::BadShape { .. })));
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.rejected, 4);
        // distinct wire codes per variant
        let codes: std::collections::HashSet<u8> = [
            ServeError::BadShape { what: "q slab" }.code(),
            ServeError::UnknownStream(0).code(),
            ServeError::EmptyStream(0).code(),
            ServeError::CrossShapeUnsupported { rows: 1, len: 2 }.code(),
            ServeError::Shutdown.code(),
            ServeError::Disconnected.code(),
            ServeError::ShardDown { shard: "127.0.0.1:0".into() }.code(),
        ]
        .into();
        assert_eq!(codes.len(), 7);
        assert!(!codes.contains(&0), "0 is reserved for wire-level errors");
    }

    #[test]
    fn unknown_method_is_rejected_up_front() {
        assert!(start(cfg("no-such-method", 2)).is_err());
    }

    #[test]
    fn shared_slab_requests_are_served_in_place() {
        // q, k, and v may all alias ONE client allocation — the zero-copy
        // path must read it in place without tripping over the aliasing,
        // and the client's clone must survive the request untouched.
        let c = cfg("standard", 1);
        let mut buf = vec![0.0f32; c.request_elems()];
        Rng::new(5).fill_normal(&mut buf);
        let slab: Arc<[f32]> = buf.clone().into();
        let req =
            HeadsRequest { q: slab.clone(), k: slab.clone(), v: slab.clone(), mask: None };
        let handle = start(c.clone()).unwrap();
        let got = handle.submit(req).recv().unwrap();
        handle.shutdown().unwrap();
        assert_eq!(got.len(), c.request_elems());
        assert!(got.iter().all(|x| x.is_finite()));
        assert_eq!(&slab[..], &buf[..], "client slab must be untouched");

        // and it matches the owned-Vec construction bitwise
        let handle = start(c.clone()).unwrap();
        let owned = HeadsRequest::from_vecs(buf.clone(), buf.clone(), buf.clone());
        let got_owned = handle.submit(owned).recv().unwrap();
        handle.shutdown().unwrap();
        assert_eq!(got, got_owned);
    }

    #[test]
    fn stream_decode_matches_direct_session_math() {
        // standard-method stream: a one-row query after t appends must
        // equal exact cross attention of that query against the appended
        // keys, per head
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let stream = handle.open_stream(1);
        let mut rng = Rng::new(3);
        let token_elems = c.heads * c.head_dim;
        let mut ks: Vec<Arc<[f32]>> = Vec::new();
        let mut vs: Vec<Arc<[f32]>> = Vec::new();
        for _ in 0..6 {
            let mut k = vec![0.0f32; token_elems];
            let mut v = vec![0.0f32; token_elems];
            rng.fill_normal(&mut k);
            rng.fill_normal(&mut v);
            let (k, v): (Arc<[f32]>, Arc<[f32]>) = (k.into(), v.into());
            stream.append(k.clone(), v.clone());
            ks.push(k);
            vs.push(v);
        }
        let mut q = vec![0.0f32; token_elems]; // one query row per head
        rng.fill_normal(&mut q);
        let got = stream.query(q.clone().into(), 1).recv().expect("stream reply");
        assert_eq!(got.len(), token_elems);

        for h in 0..c.heads {
            let o = h * c.head_dim;
            let k_mat = crate::tensor::Matrix::from_rows(
                &ks.iter().map(|t| t[o..o + c.head_dim].to_vec()).collect::<Vec<_>>(),
            );
            let v_mat = crate::tensor::Matrix::from_rows(
                &vs.iter().map(|t| t[o..o + c.head_dim].to_vec()).collect::<Vec<_>>(),
            );
            let q_mat = crate::tensor::Matrix::from_vec(1, c.head_dim, q[o..o + c.head_dim].to_vec());
            let want = Standard::exact(&q_mat, &k_mat, &v_mat, None);
            for j in 0..c.head_dim {
                assert!(
                    (got[o + j] - want.get(0, j)).abs() < 1e-5,
                    "head {h} col {j}: {} vs {}",
                    got[o + j],
                    want.get(0, j)
                );
            }
        }

        stream.close();
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.stream_appends, 6);
        assert_eq!(stats.stream_queries, 1);
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn pipelined_queries_preserve_per_stream_order() {
        // fire a query, then — without waiting for its reply — append a
        // second token and fire a second query.  Ops behind the in-flight
        // query are deferred and applied in order, so query 1 must see
        // exactly one token and query 2 exactly two.
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let s = handle.open_stream(1);
        let token_elems = c.heads * c.head_dim;
        let mut rng = Rng::new(11);
        let mut mk = |rng: &mut Rng| {
            let mut b = vec![0.0f32; token_elems];
            rng.fill_normal(&mut b);
            let slab: Arc<[f32]> = b.into();
            slab
        };
        let (k0, v0) = (mk(&mut rng), mk(&mut rng));
        let (k1, v1) = (mk(&mut rng), mk(&mut rng));
        let q = mk(&mut rng);
        s.append(k0.clone(), v0.clone());
        let rx1 = s.query(q.clone(), 1);
        s.append(k1.clone(), v1.clone());
        let rx2 = s.query(q.clone(), 1);
        let got1 = rx1.recv().expect("first pipelined reply");
        let got2 = rx2.recv().expect("second pipelined reply");

        for h in 0..c.heads {
            let o = h * c.head_dim;
            let q_mat = crate::tensor::Matrix::from_vec(1, c.head_dim, q[o..o + c.head_dim].to_vec());
            let rows = |ts: &[&Arc<[f32]>]| {
                crate::tensor::Matrix::from_rows(
                    &ts.iter().map(|t| t[o..o + c.head_dim].to_vec()).collect::<Vec<_>>(),
                )
            };
            let want1 = Standard::exact(&q_mat, &rows(&[&k0]), &rows(&[&v0]), None);
            let want2 =
                Standard::exact(&q_mat, &rows(&[&k0, &k1]), &rows(&[&v0, &v1]), None);
            assert_eq!(&got1[o..o + c.head_dim], want1.data(), "query 1 must see 1 token");
            assert_eq!(&got2[o..o + c.head_dim], want2.data(), "query 2 must see 2 tokens");
        }
        s.close();
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.stream_queries, 2);
        assert_eq!(stats.stream_appends, 2);
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn stream_rejections_do_not_wedge_the_server() {
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let stream = handle.open_stream(1);
        // query before any append -> typed EmptyStream rejection
        let early = stream.query(vec![0.0f32; c.heads * c.head_dim].into(), 1);
        assert!(early.recv().is_err());
        // malformed append (wrong slab size) -> rejected
        let bad: Arc<[f32]> = vec![0.0f32; 3].into();
        stream.append(bad.clone(), bad);
        // a good request still flows
        let ok = handle.submit(random_request(&c, 1));
        assert!(ok.recv().is_ok());
        stream.close();
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.rejected, 2);
        assert_eq!(stats.stream_appends, 0);
    }

    #[test]
    fn shutdown_completes_with_a_live_stream_handle() {
        // the stream handle's cloned sender must not wedge shutdown
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let stream = handle.open_stream(1);
        let token_elems = c.heads * c.head_dim;
        stream.append(vec![0.5f32; token_elems].into(), vec![0.5f32; token_elems].into());
        let stats = handle.shutdown().expect("shutdown must not hang");
        assert_eq!(stats.stream_appends, 1);
        // late ops on the dead server answer Err(Shutdown) client-side
        let late = stream.query(vec![0.0f32; token_elems].into(), 1);
        assert_eq!(late.recv(), Err(ServeError::Shutdown));
    }

    #[test]
    fn stream_and_batch_seed_families_are_disjoint_enough() {
        let mut seen = std::collections::HashSet::new();
        for s in 0..32u64 {
            for h in 0..8u64 {
                assert!(seen.insert(stream_seed(0, s, h)), "stream seed reuse at ({s},{h})");
            }
        }
        for b in 0..32u64 {
            for g in 0..8u64 {
                assert!(
                    seen.insert(batch_seed(0, b) ^ g),
                    "stream/batch seed collision at batch {b} head {g}"
                );
            }
        }
    }

    /// Decode `tokens` tokens through one stream (append + 1-row query
    /// per token) and return the concatenated query outputs.
    fn decode_stream(c: &AttentionServerConfig, tokens: usize, data_seed: u64) -> Vec<f32> {
        let handle = start(c.clone()).unwrap();
        let stream = handle.open_stream(1);
        let token_elems = c.heads * c.head_dim;
        let mut rng = Rng::new(data_seed);
        let mut outs = Vec::new();
        for _ in 0..tokens {
            let mut mk = || {
                let mut b = vec![0.0f32; token_elems];
                rng.fill_normal(&mut b);
                let slab: Arc<[f32]> = b.into();
                slab
            };
            let (k, v, q) = (mk(), mk(), mk());
            stream.append(k, v);
            outs.extend(stream.query(q, 1).recv().expect("stream reply"));
        }
        stream.close();
        handle.shutdown().unwrap();
        outs
    }

    #[test]
    fn cached_streams_are_bitwise_identical_to_uncached() {
        // block size 2 so the 7-token stream seals blocks mid-run; the
        // full per-registry-method sweep lives in rust/tests/kv_cache.rs
        for method in ["standard", "skeinformer", "vmean", "linformer"] {
            let base = cfg(method, 2);
            let mut cached = base.clone();
            cached.kv = Some(crate::kvcache::KvCacheConfig::new(2));
            let want = decode_stream(&base, 7, 42);
            let got = decode_stream(&cached, 7, 42);
            assert_eq!(got, want, "{method}: cache changed served bytes");
        }
    }

    #[test]
    fn kv_stats_count_prefix_sharing() {
        let mut c = cfg("standard", 2);
        c.kv = Some(crate::kvcache::KvCacheConfig::new(2));
        let handle = start(c.clone()).unwrap();
        let token_elems = c.heads * c.head_dim;
        let mut rng = Rng::new(9);
        let tokens: Vec<(Arc<[f32]>, Arc<[f32]>)> = (0..6)
            .map(|_| {
                let mut mk = || {
                    let mut b = vec![0.0f32; token_elems];
                    rng.fill_normal(&mut b);
                    let slab: Arc<[f32]> = b.into();
                    slab
                };
                (mk(), mk())
            })
            .collect();
        // two streams replaying the same prompt: the second allocates
        // zero new blocks for the shared region
        let s0 = handle.open_stream(1);
        for (k, v) in &tokens {
            s0.append(k.clone(), v.clone());
        }
        let s1 = handle.open_stream(1);
        for (k, v) in &tokens {
            s1.append(k.clone(), v.clone());
        }
        s0.close();
        s1.close();
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.kv_alloc_blocks, 3, "first stream's sealed blocks only");
        assert_eq!(stats.kv_hit_blocks, 3, "second stream shares every sealed block");
        assert_eq!(stats.kv_evicted_blocks, 0);
        assert_eq!(stats.kv_resident_blocks, 3, "index retains the shared blocks");
    }

    #[test]
    fn sliding_window_stream_matches_bounded_session() {
        let mut c = cfg("skeinformer", 2);
        c.kv = Some(crate::kvcache::KvCacheConfig::new(2).with_window(4));
        let stride = 3usize;
        let handle = start(c.clone()).unwrap();
        let stream = handle.open_stream(stride);
        let token_elems = c.heads * c.head_dim;
        let mut rng = Rng::new(17);
        let mut mk = |rng: &mut Rng| {
            let mut b = vec![0.0f32; token_elems];
            rng.fill_normal(&mut b);
            let slab: Arc<[f32]> = b.into();
            slab
        };
        // reference: one BoundedSession per head at the stream's seeds
        let mut reference: Vec<crate::attention::BoundedSession> = (0..c.heads)
            .map(|h| {
                crate::attention::BoundedSession::new(
                    crate::attention::by_name(&c.method, c.d).unwrap(),
                    SessionSpec::new(c.head_dim)
                        .with_seed(stream_seed(c.seed, 0, h as u64))
                        .with_repilot_stride(stride),
                    4,
                )
            })
            .collect();
        for _ in 0..9 {
            let (k, v, q) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
            stream.append(k.clone(), v.clone());
            let got = stream.query(q.clone(), 1).recv().expect("windowed stream reply");
            for (h, session) in reference.iter_mut().enumerate() {
                let o = h * c.head_dim;
                session.append(&k[o..o + c.head_dim], &v[o..o + c.head_dim]);
                let q_head = Matrix::from_vec(1, c.head_dim, q[o..o + c.head_dim].to_vec());
                let want = session.query(&q_head);
                assert_eq!(
                    &got[o..o + c.head_dim],
                    want.data(),
                    "head {h} diverged from BoundedSession"
                );
            }
        }
        stream.close();
        handle.shutdown().unwrap();
    }

    #[test]
    fn masked_requests_flow_through() {
        let mut c = cfg("skeinformer", 2);
        c.d = 4;
        let handle = start(c.clone()).unwrap();
        let mut req = random_request(&c, 3);
        let mut mask = vec![1.0f32; c.seq];
        for m in mask.iter_mut().skip(12) {
            *m = 0.0;
        }
        req.mask = Some(mask.into());
        let out = handle.submit(req).recv().unwrap();
        assert_eq!(out.len(), c.request_elems());
        assert!(out.iter().all(|x| x.is_finite()));
        handle.shutdown().unwrap();
    }

    #[test]
    fn prefill_matches_per_token_appends_bitwise() {
        // the full per-registry-method sweep lives in rust/tests/kv_cache.rs
        let mut c = cfg("skeinformer", 2);
        c.kv = Some(crate::kvcache::KvCacheConfig::new(2));
        let token_elems = c.heads * c.head_dim;
        let tokens = 7usize;
        let mut rng = Rng::new(31);
        let mut k_rows = Vec::new();
        let mut v_rows = Vec::new();
        for _ in 0..tokens {
            let mut mk = || {
                let mut b = vec![0.0f32; token_elems];
                rng.fill_normal(&mut b);
                b
            };
            k_rows.push(mk());
            v_rows.push(mk());
        }
        let mut q = vec![0.0f32; token_elems];
        rng.fill_normal(&mut q);
        let q: Arc<[f32]> = q.into();

        // reference: per-token appends, one final 1-row query
        let handle = start(c.clone()).unwrap();
        let s = handle.open_stream(2);
        for t in 0..tokens {
            s.append(k_rows[t].clone().into(), v_rows[t].clone().into());
        }
        let want = s.query(q.clone(), 1).recv().expect("per-token reply");
        s.close();
        let want_stats = handle.shutdown().unwrap();

        // chunked: the same tokens through Prefill ops of {4, 3}
        let to_chunk = |rows: &[Vec<f32>], lo: usize, hi: usize| -> Arc<[f32]> {
            let n = hi - lo;
            let mut slab = vec![0.0f32; n * token_elems];
            for (i, row) in rows[lo..hi].iter().enumerate() {
                for h in 0..c.heads {
                    let dst = (h * n + i) * c.head_dim;
                    slab[dst..dst + c.head_dim]
                        .copy_from_slice(&row[h * c.head_dim..(h + 1) * c.head_dim]);
                }
            }
            slab.into()
        };
        let handle = start(c.clone()).unwrap();
        let s = handle.open_stream(2);
        for (lo, hi) in [(0usize, 4usize), (4, 7)] {
            s.prefill(to_chunk(&k_rows, lo, hi), to_chunk(&v_rows, lo, hi), hi - lo);
        }
        let got = s.query(q, 1).recv().expect("prefill reply");
        s.close();
        let got_stats = handle.shutdown().unwrap();

        assert_eq!(got, want, "prefill changed served bytes");
        assert_eq!(got_stats.stream_appends, want_stats.stream_appends);
        assert_eq!(got_stats.kv_alloc_blocks, want_stats.kv_alloc_blocks);
        assert_eq!(got_stats.kv_hit_blocks, want_stats.kv_hit_blocks);
    }

    #[test]
    fn batch_dedupe_replay_hits_every_block() {
        let mut c = cfg("standard", 1); // batch size 1: one batch per submit
        c.kv = Some(crate::kvcache::KvCacheConfig::new(2).with_batch_dedupe(true));
        let handle = start(c.clone()).unwrap();
        let req = random_request(&c, 4);
        let first = handle.submit(req.clone()).recv().expect("first reply");
        let second = handle.submit(req).recv().expect("resubmitted reply");
        // standard attention is seedless: the replay reproduces the bytes
        assert_eq!(first, second);
        let stats = handle.shutdown().unwrap();
        let blocks = (c.seq / 2) as u64; // seq 16 at block size 2
        assert_eq!(stats.kv_alloc_blocks, blocks, "only the first submission allocates");
        assert_eq!(stats.kv_hit_blocks, blocks, "the replay shares every sealed block");
    }

    #[test]
    fn multi_stream_step_matches_solo_streams() {
        // two streams queried back-to-back (sharing steps when the
        // scheduler packs them) must produce exactly what each produces
        // decoding alone — grid placement never leaks into the bytes
        let c = cfg("skeinformer", 4);
        // solo reference for stream i burns i ids first so the measured
        // stream gets the same server-side id (= the same seeds) it gets
        // in the joint run
        let solo: Vec<Vec<f32>> = (0..2usize)
            .map(|i| {
                let handle = start(c.clone()).unwrap();
                let _burned: Vec<StreamHandle> =
                    (0..i).map(|_| handle.open_stream(1)).collect();
                let s = handle.open_stream(1);
                let token_elems = c.heads * c.head_dim;
                let mut rng = Rng::new(100 + i as u64);
                let mut outs = Vec::new();
                for _ in 0..5 {
                    let mut mk = || {
                        let mut b = vec![0.0f32; token_elems];
                        rng.fill_normal(&mut b);
                        let slab: Arc<[f32]> = b.into();
                        slab
                    };
                    let (k, v, q) = (mk(), mk(), mk());
                    s.append(k, v);
                    outs.extend(s.query(q, 1).recv().expect("solo stream reply"));
                }
                s.close();
                handle.shutdown().unwrap();
                outs
            })
            .collect();

        let handle = start(c.clone()).unwrap();
        let streams: Vec<StreamHandle> = (0..2).map(|_| handle.open_stream(1)).collect();
        let token_elems = c.heads * c.head_dim;
        let mut rngs: Vec<Rng> = (0..2).map(|i| Rng::new(100 + i as u64)).collect();
        let mut outs: Vec<Vec<f32>> = vec![Vec::new(); 2];
        for _ in 0..5 {
            let mut rxs = Vec::new();
            for (i, s) in streams.iter().enumerate() {
                let mut mk = || {
                    let mut b = vec![0.0f32; token_elems];
                    rngs[i].fill_normal(&mut b);
                    let slab: Arc<[f32]> = b.into();
                    slab
                };
                let (k, v, q) = (mk(), mk(), mk());
                s.append(k, v);
                rxs.push(s.query(q, 1));
            }
            for (i, rx) in rxs.into_iter().enumerate() {
                outs[i].extend(rx.recv().expect("joint stream reply"));
            }
        }
        for s in streams {
            s.close();
        }
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.stream_queries, 10);
        // stream ids 0 and 1 in both runs -> identical seeds -> identical bytes
        assert_eq!(outs[0], solo[0], "stream 0 diverged when sharing steps");
        assert_eq!(outs[1], solo[1], "stream 1 diverged when sharing steps");
    }
}
