//! Batched attention service over the pure-rust engine: the serving path
//! that needs no AOT artifacts and no PJRT.
//!
//! Clients submit one sequence per request — `Arc<[f32]>` Q/K/V slabs of
//! shape `[heads, seq, head_dim]` (plus an optional padding mask) — and a
//! dedicated engine thread groups pending requests into a `B × H` grid,
//! runs [`BatchedAttention`] across the worker pool, and answers each
//! request with its sequence's output slab.  Dynamic batching policy
//! matches the PJRT server: wait up to `max_wait` for a full batch, then
//! flush whatever is pending.
//!
//! **Zero-copy request path.**  Batch formation wraps the pending
//! requests' slabs in a slab-backed [`BatchTensor`]
//! ([`BatchTensor::from_slabs`]) — `Arc` clones, no element copies — so
//! the engine reads each client's memory in place.  The `Arc` ownership
//! rule: the client keeps its clone (requests are reusable), the server
//! holds one only for the duration of the batch, and the slab is freed
//! when the last clone drops.  Slab contents must stay immutable after
//! submission — `Arc<[f32]>` enforces this in the type.  The one
//! remaining copy on the request path is the reply (the output slab is
//! handed to the client as an owned `Vec<f32>`).
//!
//! **Invariants** (checked per request at batch formation; violators are
//! rejected and their reply channel closed): each of `q`/`k`/`v` holds
//! exactly `heads * seq * head_dim` elements, and `mask`, when present,
//! holds `seq`.
//!
//! Batch `i` of a server's lifetime computes with [`batch_seed`]`(cfg.seed,
//! i)`, and each head inside a batch follows the engine's derivation rule,
//! so a given arrival order reproduces exactly while distinct batches get
//! disjoint per-head streams.
//!
//! # Examples
//!
//! ```
//! use skeinformer::coordinator::attention_server::{self, AttentionServerConfig, HeadsRequest};
//! use skeinformer::rng::Rng;
//! use std::time::Duration;
//!
//! let cfg = AttentionServerConfig {
//!     method: "standard".into(),
//!     d: 8,
//!     heads: 2,
//!     seq: 16,
//!     head_dim: 4,
//!     max_batch: 2,
//!     max_wait: Duration::from_millis(1),
//!     seed: 0,
//!     workers: None,
//! };
//! let handle = attention_server::start(cfg.clone()).unwrap();
//! let reply = handle.submit(HeadsRequest::random(cfg.request_elems(), &mut Rng::new(1)));
//! assert_eq!(reply.recv().unwrap().len(), cfg.request_elems());
//! handle.shutdown().unwrap();
//! ```

use crate::attention::{self, BatchedAttention};
use crate::rng::Rng;
use crate::tensor::{BatchTensor, Matrix};
use anyhow::Result;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Engine seed for batch `i` of a server's lifetime.  The engine XORs
/// small head indices into its seed, so deriving batch seeds by XOR too
/// (`base ^ i`) would collide: with `H` heads, batches `i` and `i ^ 1`
/// would reuse the same stream set.  [`crate::rng::mix`] instead.
pub fn batch_seed(base: u64, batch: u64) -> u64 {
    crate::rng::mix(base, batch)
}

/// Server configuration: workload shape + batching policy.
#[derive(Clone, Debug)]
pub struct AttentionServerConfig {
    /// Registry name of the attention method (see `attention::by_name`).
    pub method: String,
    /// Feature budget `d` for approximate methods.
    pub d: usize,
    /// Heads per sequence.
    pub heads: usize,
    /// Sequence length n.
    pub seq: usize,
    /// Per-head feature dimension p.
    pub head_dim: usize,
    /// Max sequences per executed batch.
    pub max_batch: usize,
    /// Max time to wait for a full batch before flushing.
    pub max_wait: Duration,
    /// Base RNG seed (batch `i` computes with [`batch_seed`]`(seed, i)`).
    pub seed: u64,
    /// Worker cap for head dispatch (None = pool default).
    pub workers: Option<usize>,
}

impl AttentionServerConfig {
    /// The per-request head grid (batch dimension = 1 sequence).
    pub fn request_elems(&self) -> usize {
        self.heads * self.seq * self.head_dim
    }

    /// Build from CLI flags — the one place the flag names and defaults
    /// live (`skein serve --engine cpu` and the serving example share it):
    /// `--method --d --heads --seq --head-dim --batch --max-wait-ms
    /// --seed --workers` (workers 0 = pool default).  The global
    /// `--pool-size` flag sizes the process-wide worker pool itself and
    /// is handled by the binaries via [`crate::pool::set_pool_size`].
    pub fn from_args(args: &crate::cli::Args) -> Result<Self, crate::cli::CliError> {
        let workers = args.get_usize("workers", 0)?;
        Ok(Self {
            method: args.get_or("method", "skeinformer").to_string(),
            d: args.get_usize("d", 64)?,
            heads: args.get_usize("heads", 4)?,
            seq: args.get_usize("seq", 512)?,
            head_dim: args.get_usize("head-dim", 32)?,
            max_batch: args.get_usize("batch", 8)?,
            max_wait: Duration::from_millis(args.get_u64("max-wait-ms", 4)?),
            seed: args.get_u64("seed", 0)?,
            workers: if workers == 0 { None } else { Some(workers) },
        })
    }
}

/// One sequence's attention inputs: shared `[heads, seq, head_dim]`
/// row-major slabs, plus an optional length-`seq` 0/1 padding mask.
///
/// The slabs are `Arc<[f32]>` so batch formation is zero-copy: the server
/// reads the client's memory in place and never copies the payload
/// (`Clone` bumps three reference counts; only the optional `mask`, a
/// plain `Vec`, is deep-copied).  A client that keeps its payload in
/// `Arc<[f32]>` slabs (e.g. resubmitting or fanning one slab into many
/// requests) submits with no element copies at all.
/// [`HeadsRequest::from_vecs`] is the convenience for owned buffers — note
/// `Vec → Arc<[f32]>` allocates and copies once per slab, so hot-path
/// clients should build `Arc` slabs up front and reuse them.
#[derive(Clone, Debug)]
pub struct HeadsRequest {
    pub q: Arc<[f32]>,
    pub k: Arc<[f32]>,
    pub v: Arc<[f32]>,
    pub mask: Option<Vec<f32>>,
}

impl HeadsRequest {
    /// Wrap owned Q/K/V buffers (each `heads * seq * head_dim` elements,
    /// row-major `[heads, seq, head_dim]`).
    pub fn from_vecs(q: Vec<f32>, k: Vec<f32>, v: Vec<f32>) -> Self {
        Self { q: q.into(), k: k.into(), v: v.into(), mask: None }
    }

    /// Dense standard-normal request of `elems = heads * seq * head_dim`
    /// values per slab — the demo/bench payload.
    pub fn random(elems: usize, rng: &mut Rng) -> Self {
        let mut mk = || {
            let mut buf = vec![0.0f32; elems];
            rng.fill_normal(&mut buf);
            buf
        };
        Self::from_vecs(mk(), mk(), mk())
    }
}

struct Pending {
    req: HeadsRequest,
    reply: mpsc::Sender<Vec<f32>>,
    enqueued: Instant,
}

/// Client handle to a running attention server.
pub struct AttentionServerHandle {
    tx: mpsc::Sender<Pending>,
    join: Option<std::thread::JoinHandle<AttentionServerStats>>,
}

/// Aggregate serving statistics, reported on shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct AttentionServerStats {
    pub requests: u64,
    pub batches: u64,
    /// Requests dropped for malformed payloads (wrong slab/mask length).
    pub rejected: u64,
    /// Mean queueing delay (ms) — time from submit to batch formation.
    pub mean_queue_ms: f64,
    /// Mean executed batch occupancy (filled slots / max_batch).
    pub mean_occupancy: f64,
    /// Mean engine time per executed batch (ms).
    pub mean_batch_ms: f64,
}

impl AttentionServerHandle {
    /// Submit a request; returns a receiver for the output slab.  The
    /// receiver errors if the request is rejected (malformed payload).
    pub fn submit(&self, req: HeadsRequest) -> mpsc::Receiver<Vec<f32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let _ = self.tx.send(Pending { req, reply: reply_tx, enqueued: Instant::now() });
        reply_rx
    }

    /// Stop the server and collect stats.
    pub fn shutdown(mut self) -> Result<AttentionServerStats> {
        drop(self.tx);
        self.join
            .take()
            .expect("server already joined")
            .join()
            .map_err(|_| anyhow::anyhow!("attention server thread panicked"))
    }
}

/// Start the engine-backed server; validates the method name up front.
pub fn start(cfg: AttentionServerConfig) -> Result<AttentionServerHandle> {
    anyhow::ensure!(
        attention::by_name(&cfg.method, cfg.d).is_some(),
        "unknown attention method {:?}",
        cfg.method
    );
    anyhow::ensure!(cfg.max_batch > 0, "max_batch must be positive");
    let (tx, rx) = mpsc::channel::<Pending>();
    let join = std::thread::spawn(move || serve_loop(cfg, rx));
    Ok(AttentionServerHandle { tx, join: Some(join) })
}

fn serve_loop(cfg: AttentionServerConfig, rx: mpsc::Receiver<Pending>) -> AttentionServerStats {
    let method = attention::by_name(&cfg.method, cfg.d).expect("method validated in start()");
    let mut engine = BatchedAttention::new();
    if let Some(w) = cfg.workers {
        engine = engine.with_workers(w);
    }
    let elems = cfg.request_elems();

    let mut stats = AttentionServerStats::default();
    let mut queue_ms_sum = 0.0f64;
    let mut occupancy_sum = 0.0f64;
    let mut batch_ms_sum = 0.0f64;

    loop {
        let Some(mut pending) = super::collect_batch(&rx, cfg.max_batch, cfg.max_wait) else {
            break; // all senders dropped -> shutdown
        };

        // drop malformed payloads (their reply sender closes -> client
        // recv errors); keep the rest
        pending.retain(|p| {
            let r = &p.req;
            let ok = r.q.len() == elems
                && r.k.len() == elems
                && r.v.len() == elems
                && r.mask.as_ref().is_none_or(|m| m.len() == cfg.seq);
            if !ok {
                stats.rejected += 1;
            }
            ok
        });
        if pending.is_empty() {
            continue;
        }

        // pack the grid zero-copy: batch = sequences in this flush, each
        // request's slabs wrapped in place (Arc clones, no element copies)
        let slab_views = |get: fn(&HeadsRequest) -> &Arc<[f32]>| {
            BatchTensor::from_slabs(
                cfg.heads,
                cfg.seq,
                cfg.head_dim,
                pending.iter().map(|p| Arc::clone(get(&p.req))).collect(),
            )
        };
        let q = slab_views(|r| &r.q);
        let k = slab_views(|r| &r.k);
        let v = slab_views(|r| &r.v);
        let any_mask = pending.iter().any(|p| p.req.mask.is_some());
        let mut masks = if any_mask {
            Some(Matrix::full(pending.len(), cfg.seq, 1.0))
        } else {
            None
        };
        for (b, p) in pending.iter().enumerate() {
            if let (Some(mm), Some(req_mask)) = (masks.as_mut(), p.req.mask.as_ref()) {
                mm.set_row(b, req_mask);
            }
            queue_ms_sum += p.enqueued.elapsed().as_secs_f64() * 1e3;
        }

        let t0 = Instant::now();
        let seed = batch_seed(cfg.seed, stats.batches);
        let out = engine.run(method.as_ref(), &q, &k, &v, masks.as_ref(), seed);
        batch_ms_sum += t0.elapsed().as_secs_f64() * 1e3;

        for (b, p) in pending.iter().enumerate() {
            let _ = p.reply.send(out.sequence(b).to_vec());
        }
        stats.requests += pending.len() as u64;
        stats.batches += 1;
        occupancy_sum += pending.len() as f64 / cfg.max_batch as f64;
    }

    if stats.requests > 0 {
        stats.mean_queue_ms = queue_ms_sum / stats.requests as f64;
    }
    if stats.batches > 0 {
        stats.mean_occupancy = occupancy_sum / stats.batches as f64;
        stats.mean_batch_ms = batch_ms_sum / stats.batches as f64;
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{HeadSpec, Standard};
    use crate::rng::Rng;

    fn cfg(method: &str, max_batch: usize) -> AttentionServerConfig {
        AttentionServerConfig {
            method: method.to_string(),
            d: 8,
            heads: 2,
            seq: 16,
            head_dim: 4,
            max_batch,
            max_wait: Duration::from_millis(2),
            seed: 0,
            workers: None,
        }
    }

    fn random_request(cfg: &AttentionServerConfig, seed: u64) -> HeadsRequest {
        HeadsRequest::random(cfg.request_elems(), &mut Rng::new(seed))
    }

    #[test]
    fn batch_seeds_do_not_collide_across_nearby_batches() {
        // the engine XORs head indices 0..B*H into the seed; the sets
        // {batch_seed(s,i) ^ g} must be disjoint across batches
        let mut seen = std::collections::HashSet::new();
        for batch in 0..64u64 {
            for g in 0..16u64 {
                assert!(
                    seen.insert(batch_seed(0, batch) ^ g),
                    "stream seed reused at batch {batch}, head {g}"
                );
            }
        }
    }

    #[test]
    fn serves_requests_and_reports_stats() {
        let c = cfg("standard", 4);
        let handle = start(c.clone()).unwrap();
        let rxs: Vec<_> = (0..6).map(|i| handle.submit(random_request(&c, i))).collect();
        for rx in rxs {
            let out = rx.recv().expect("reply");
            assert_eq!(out.len(), c.request_elems());
            assert!(out.iter().all(|x| x.is_finite()));
        }
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.requests, 6);
        assert!(stats.batches >= 2, "6 requests at max_batch 4 need >= 2 batches");
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn single_sequence_batch_matches_direct_engine_call() {
        let c = cfg("standard", 1); // batch size 1: deterministic packing
        let handle = start(c.clone()).unwrap();
        let req = random_request(&c, 9);
        let got = handle.submit(req.clone()).recv().unwrap();
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.batches, 1);

        let spec = HeadSpec::new(1, c.heads, c.seq, c.head_dim);
        let q = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.q.to_vec());
        let k = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.k.to_vec());
        let v = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.v.to_vec());
        // the first batch of a server's lifetime computes with batch_seed(seed, 0)
        let want =
            BatchedAttention::new().run(&Standard, &q, &k, &v, None, batch_seed(c.seed, 0));
        assert!(spec.matches(&want));
        assert_eq!(got, want.data().to_vec());
    }

    #[test]
    fn malformed_requests_are_rejected_not_wedged() {
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let bad = HeadsRequest::from_vecs(vec![0.0; 3], vec![0.0; 3], vec![0.0; 3]);
        let bad_rx = handle.submit(bad);
        let good_rx = handle.submit(random_request(&c, 1));
        assert!(good_rx.recv().is_ok());
        assert!(bad_rx.recv().is_err(), "malformed request must not get a reply");
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn unknown_method_is_rejected_up_front() {
        assert!(start(cfg("no-such-method", 2)).is_err());
    }

    #[test]
    fn shared_slab_requests_are_served_in_place() {
        // q, k, and v may all alias ONE client allocation — the zero-copy
        // path must read it in place without tripping over the aliasing,
        // and the client's clone must survive the request untouched.
        let c = cfg("standard", 1);
        let mut buf = vec![0.0f32; c.request_elems()];
        Rng::new(5).fill_normal(&mut buf);
        let slab: Arc<[f32]> = buf.clone().into();
        let req =
            HeadsRequest { q: slab.clone(), k: slab.clone(), v: slab.clone(), mask: None };
        let handle = start(c.clone()).unwrap();
        let got = handle.submit(req).recv().unwrap();
        handle.shutdown().unwrap();
        assert_eq!(got.len(), c.request_elems());
        assert!(got.iter().all(|x| x.is_finite()));
        assert_eq!(&slab[..], &buf[..], "client slab must be untouched");

        // and it matches the owned-Vec construction bitwise
        let handle = start(c.clone()).unwrap();
        let owned = HeadsRequest::from_vecs(buf.clone(), buf.clone(), buf.clone());
        let got_owned = handle.submit(owned).recv().unwrap();
        handle.shutdown().unwrap();
        assert_eq!(got, got_owned);
    }

    #[test]
    fn masked_requests_flow_through() {
        let mut c = cfg("skeinformer", 2);
        c.d = 4;
        let handle = start(c.clone()).unwrap();
        let mut req = random_request(&c, 3);
        let mut mask = vec![1.0f32; c.seq];
        for m in mask.iter_mut().skip(12) {
            *m = 0.0;
        }
        req.mask = Some(mask);
        let out = handle.submit(req).recv().unwrap();
        assert_eq!(out.len(), c.request_elems());
        assert!(out.iter().all(|x| x.is_finite()));
        handle.shutdown().unwrap();
    }
}
