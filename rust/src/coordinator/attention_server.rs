//! Batched attention service over the pure-rust engine: the serving path
//! that needs no AOT artifacts and no PJRT.
//!
//! Clients submit one sequence per request — `Arc<[f32]>` Q/K/V slabs of
//! shape `[heads, seq, head_dim]` (plus an optional padding mask) — and a
//! dedicated engine thread groups pending requests into a `B × H` grid,
//! runs [`BatchedAttention`] across the worker pool, and answers each
//! request with its sequence's output slab.  Dynamic batching policy
//! matches the PJRT server: wait up to `max_wait` for a full batch, then
//! flush whatever is pending.
//!
//! **Zero-copy request path.**  Batch formation wraps the pending
//! requests' slabs in a slab-backed [`BatchTensor`]
//! ([`BatchTensor::from_slabs`]) — `Arc` clones, no element copies — so
//! the engine reads each client's memory in place.  The `Arc` ownership
//! rule: the client keeps its clone (requests are reusable), the server
//! holds one only for the duration of the batch, and the slab is freed
//! when the last clone drops.  Slab contents must stay immutable after
//! submission — `Arc<[f32]>` enforces this in the type.  The one
//! remaining copy on the request path is the reply (the output slab is
//! handed to the client as an owned `Vec<f32>`).
//!
//! **Invariants** (checked per request at batch formation; violators are
//! rejected and their reply channel closed): each of `q`/`k`/`v` holds
//! exactly `heads * seq * head_dim` elements, and `mask`, when present,
//! holds `seq`.
//!
//! Batch `i` of a server's lifetime computes with [`batch_seed`]`(cfg.seed,
//! i)`, and each head inside a batch follows the engine's derivation rule,
//! so a given arrival order reproduces exactly while distinct batches get
//! disjoint per-head streams.
//!
//! **Streaming decode.**  Alongside the batched one-shot path, a client
//! can [`open_stream`](AttentionServerHandle::open_stream) a stateful
//! decode stream: the server keeps one
//! [`AttentionSession`](crate::attention::AttentionSession) per head
//! (seeded [`stream_seed`]`(cfg.seed, stream, head)`), and the stream's
//! [`append`](StreamHandle::append) / [`query`](StreamHandle::query) ops
//! ride the same channel — and the same zero-copy `Arc<[f32]>` slab
//! convention — as batched requests, preserving per-stream op order.
//! Appends are O(heads · head_dim) bookkeeping; queries run on the serve
//! thread against the per-stream session state (per-token cost is the
//! session's — exact-incremental for standard/vmean/linformer, the
//! method's own linear cost otherwise), instead of re-uploading and
//! recomputing the whole prefix each token.
//!
//! # Examples
//!
//! ```
//! use skeinformer::coordinator::attention_server::{self, AttentionServerConfig, HeadsRequest};
//! use skeinformer::rng::Rng;
//! use std::time::Duration;
//!
//! let cfg = AttentionServerConfig {
//!     method: "standard".into(),
//!     d: 8,
//!     heads: 2,
//!     seq: 16,
//!     head_dim: 4,
//!     max_batch: 2,
//!     max_wait: Duration::from_millis(1),
//!     seed: 0,
//!     workers: None,
//! };
//! let handle = attention_server::start(cfg.clone()).unwrap();
//! let reply = handle.submit(HeadsRequest::random(cfg.request_elems(), &mut Rng::new(1)));
//! assert_eq!(reply.recv().unwrap().len(), cfg.request_elems());
//! handle.shutdown().unwrap();
//! ```

use crate::attention::{self, AttentionSession, AttnScratch, BatchedAttention, SessionSpec};
use crate::rng::Rng;
use crate::tensor::{BatchTensor, Matrix};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Engine seed for batch `i` of a server's lifetime.  The engine XORs
/// small head indices into its seed, so deriving batch seeds by XOR too
/// (`base ^ i`) would collide: with `H` heads, batches `i` and `i ^ 1`
/// would reuse the same stream set.  [`crate::rng::mix`] instead.
pub fn batch_seed(base: u64, batch: u64) -> u64 {
    crate::rng::mix(base, batch)
}

/// Session seed for head `h` of stream `s`: a double
/// [`mix`](crate::rng::mix) so streams are decorrelated from each other
/// and from the batch path's `batch_seed(base, i) ^ g` family.
pub fn stream_seed(base: u64, stream: u64, head: u64) -> u64 {
    crate::rng::mix(crate::rng::mix(base, stream), head)
}

/// Server configuration: workload shape + batching policy.
#[derive(Clone, Debug)]
pub struct AttentionServerConfig {
    /// Registry name of the attention method (see `attention::by_name`).
    pub method: String,
    /// Feature budget `d` for approximate methods.
    pub d: usize,
    /// Heads per sequence.
    pub heads: usize,
    /// Sequence length n.
    pub seq: usize,
    /// Per-head feature dimension p.
    pub head_dim: usize,
    /// Max sequences per executed batch.
    pub max_batch: usize,
    /// Max time to wait for a full batch before flushing.
    pub max_wait: Duration,
    /// Base RNG seed (batch `i` computes with [`batch_seed`]`(seed, i)`).
    pub seed: u64,
    /// Worker cap for head dispatch (None = pool default).
    pub workers: Option<usize>,
}

impl AttentionServerConfig {
    /// The per-request head grid (batch dimension = 1 sequence).
    pub fn request_elems(&self) -> usize {
        self.heads * self.seq * self.head_dim
    }

    /// Build from CLI flags — the one place the flag names and defaults
    /// live (`skein serve --engine cpu` and the serving example share it):
    /// `--method --d --heads --seq --head-dim --batch --max-wait-ms
    /// --seed --workers` (workers 0 = pool default).  The global
    /// `--pool-size` flag sizes the process-wide worker pool itself and
    /// is handled by the binaries via [`crate::pool::set_pool_size`].
    pub fn from_args(args: &crate::cli::Args) -> Result<Self, crate::cli::CliError> {
        let workers = args.get_usize("workers", 0)?;
        Ok(Self {
            method: args.get_or("method", "skeinformer").to_string(),
            d: args.get_usize("d", 64)?,
            heads: args.get_usize("heads", 4)?,
            seq: args.get_usize("seq", 512)?,
            head_dim: args.get_usize("head-dim", 32)?,
            max_batch: args.get_usize("batch", 8)?,
            max_wait: Duration::from_millis(args.get_u64("max-wait-ms", 4)?),
            seed: args.get_u64("seed", 0)?,
            workers: if workers == 0 { None } else { Some(workers) },
        })
    }
}

/// One sequence's attention inputs: shared `[heads, seq, head_dim]`
/// row-major slabs, plus an optional length-`seq` 0/1 padding mask.
///
/// The slabs are `Arc<[f32]>` so batch formation is zero-copy: the server
/// reads the client's memory in place and never copies the payload
/// (`Clone` bumps three reference counts; only the optional `mask`, a
/// plain `Vec`, is deep-copied).  A client that keeps its payload in
/// `Arc<[f32]>` slabs (e.g. resubmitting or fanning one slab into many
/// requests) submits with no element copies at all.
/// [`HeadsRequest::from_vecs`] is the convenience for owned buffers — note
/// `Vec → Arc<[f32]>` allocates and copies once per slab, so hot-path
/// clients should build `Arc` slabs up front and reuse them.
#[derive(Clone, Debug)]
pub struct HeadsRequest {
    pub q: Arc<[f32]>,
    pub k: Arc<[f32]>,
    pub v: Arc<[f32]>,
    pub mask: Option<Vec<f32>>,
}

impl HeadsRequest {
    /// Wrap owned Q/K/V buffers (each `heads * seq * head_dim` elements,
    /// row-major `[heads, seq, head_dim]`).
    pub fn from_vecs(q: Vec<f32>, k: Vec<f32>, v: Vec<f32>) -> Self {
        Self { q: q.into(), k: k.into(), v: v.into(), mask: None }
    }

    /// Dense standard-normal request of `elems = heads * seq * head_dim`
    /// values per slab — the demo/bench payload.
    pub fn random(elems: usize, rng: &mut Rng) -> Self {
        let mut mk = || {
            let mut buf = vec![0.0f32; elems];
            rng.fill_normal(&mut buf);
            buf
        };
        Self::from_vecs(mk(), mk(), mk())
    }
}

struct Pending {
    req: HeadsRequest,
    reply: mpsc::Sender<Vec<f32>>,
    enqueued: Instant,
}

/// One operation on a decode stream.  Payloads ride the same zero-copy
/// `Arc<[f32]>` slab path as [`HeadsRequest`]: the server reads them in
/// place and only the reply is an owned copy.
pub enum StreamOp {
    /// Create the stream's per-head sessions (one per configured head).
    Open {
        /// Re-pilot stride for approximating methods (see
        /// [`SessionSpec::repilot_stride`]).
        repilot_stride: usize,
    },
    /// Append one token: `k`/`v` are `[heads, head_dim]` row-major slabs.
    Append { k: Arc<[f32]>, v: Arc<[f32]> },
    /// Query `rows` query rows per head: `q` is `[heads, rows, head_dim]`;
    /// the reply is the `[heads, rows, head_dim]` output slab.
    Query { q: Arc<[f32]>, rows: usize, reply: mpsc::Sender<Vec<f32>> },
    /// Drop the stream's state.
    Close,
}

/// A message to the serve loop: a batched request, a stream operation,
/// or the explicit shutdown sentinel (needed because cloned stream
/// senders may outlive the handle — channel disconnect alone can no
/// longer signal shutdown).
enum ServerMsg {
    Batch(Pending),
    Stream { stream: u64, op: StreamOp },
    Shutdown,
}

/// Client handle to a running attention server.
pub struct AttentionServerHandle {
    tx: mpsc::Sender<ServerMsg>,
    next_stream: AtomicU64,
    heads: usize,
    head_dim: usize,
    join: Option<std::thread::JoinHandle<AttentionServerStats>>,
}

/// Client handle to one decode stream on a running server.  Ops sent
/// through one handle arrive in order (the channel preserves per-sender
/// order), so `append` → `query` sequences behave like local sessions.
pub struct StreamHandle {
    id: u64,
    heads: usize,
    head_dim: usize,
    tx: mpsc::Sender<ServerMsg>,
}

impl StreamHandle {
    /// Elements per `[heads, head_dim]` token slab.
    pub fn token_elems(&self) -> usize {
        self.heads * self.head_dim
    }

    /// Append one token (each slab `[heads, head_dim]`, read in place).
    pub fn append(&self, k: Arc<[f32]>, v: Arc<[f32]>) {
        let _ = self.tx.send(ServerMsg::Stream {
            stream: self.id,
            op: StreamOp::Append { k, v },
        });
    }

    /// Query `rows` query rows per head (`q` is `[heads, rows, head_dim]`,
    /// read in place); returns a receiver for the output slab.  The
    /// receiver errors if the op is rejected (bad shape, unknown stream,
    /// empty stream, or a cross-shape query against a square-only method).
    pub fn query(&self, q: Arc<[f32]>, rows: usize) -> mpsc::Receiver<Vec<f32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let _ = self.tx.send(ServerMsg::Stream {
            stream: self.id,
            op: StreamOp::Query { q, rows, reply: reply_tx },
        });
        reply_rx
    }

    /// Drop the stream's server-side state.
    pub fn close(self) {
        let _ = self.tx.send(ServerMsg::Stream { stream: self.id, op: StreamOp::Close });
    }
}

/// Aggregate serving statistics, reported on shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct AttentionServerStats {
    pub requests: u64,
    pub batches: u64,
    /// Requests or stream ops dropped for malformed payloads (wrong
    /// slab/mask length, unknown stream, invalid query shape).
    pub rejected: u64,
    /// Stream tokens appended across all streams.
    pub stream_appends: u64,
    /// Stream queries answered across all streams.
    pub stream_queries: u64,
    /// Mean queueing delay (ms) — time from submit to batch formation.
    pub mean_queue_ms: f64,
    /// Mean executed batch occupancy (filled slots / max_batch).
    pub mean_occupancy: f64,
    /// Mean engine time per executed batch (ms).
    pub mean_batch_ms: f64,
}

impl AttentionServerHandle {
    /// Submit a request; returns a receiver for the output slab.  The
    /// receiver errors if the request is rejected (malformed payload).
    pub fn submit(&self, req: HeadsRequest) -> mpsc::Receiver<Vec<f32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let _ = self.tx.send(ServerMsg::Batch(Pending {
            req,
            reply: reply_tx,
            enqueued: Instant::now(),
        }));
        reply_rx
    }

    /// Open a streaming decode session set (one [`AttentionSession`] per
    /// configured head, server-side) and return its handle.
    pub fn open_stream(&self, repilot_stride: usize) -> StreamHandle {
        let id = self.next_stream.fetch_add(1, Ordering::Relaxed);
        let _ = self.tx.send(ServerMsg::Stream { stream: id, op: StreamOp::Open { repilot_stride } });
        StreamHandle { id, heads: self.heads, head_dim: self.head_dim, tx: self.tx.clone() }
    }

    /// Stop the server and collect stats.  Live [`StreamHandle`]s do not
    /// block shutdown (an explicit sentinel ends the serve loop); their
    /// later ops simply error out client-side.  Ops already queued ahead
    /// of the shutdown are still processed.
    pub fn shutdown(mut self) -> Result<AttentionServerStats> {
        let _ = self.tx.send(ServerMsg::Shutdown);
        drop(self.tx);
        self.join
            .take()
            .expect("server already joined")
            .join()
            .map_err(|_| anyhow::anyhow!("attention server thread panicked"))
    }
}

/// Start the engine-backed server; validates the method name up front.
/// [`AttentionServerHandle::shutdown`] stops it even while
/// [`StreamHandle`]s are still alive.
pub fn start(cfg: AttentionServerConfig) -> Result<AttentionServerHandle> {
    anyhow::ensure!(
        attention::by_name(&cfg.method, cfg.d).is_some(),
        "unknown attention method {:?}",
        cfg.method
    );
    anyhow::ensure!(cfg.max_batch > 0, "max_batch must be positive");
    let (tx, rx) = mpsc::channel::<ServerMsg>();
    let heads = cfg.heads;
    let head_dim = cfg.head_dim;
    let join = std::thread::spawn(move || serve_loop(cfg, rx));
    Ok(AttentionServerHandle {
        tx,
        next_stream: AtomicU64::new(0),
        heads,
        head_dim,
        join: Some(join),
    })
}

/// Per-stream server-side state: one session per head plus the recycled
/// scratch their queries draw temporaries from.
struct StreamState {
    sessions: Vec<Box<dyn AttentionSession>>,
    scratch: AttnScratch,
}

fn serve_loop(cfg: AttentionServerConfig, rx: mpsc::Receiver<ServerMsg>) -> AttentionServerStats {
    let method = attention::by_name(&cfg.method, cfg.d).expect("method validated in start()");
    let mut engine = BatchedAttention::new();
    if let Some(w) = cfg.workers {
        engine = engine.with_workers(w);
    }
    let elems = cfg.request_elems();

    let mut stats = AttentionServerStats::default();
    let mut queue_ms_sum = 0.0f64;
    let mut occupancy_sum = 0.0f64;
    let mut batch_ms_sum = 0.0f64;
    let mut streams: std::collections::HashMap<u64, StreamState> = Default::default();
    let mut out_cache: Option<BatchTensor> = None;

    loop {
        let Some(msgs) = collect_msgs(&rx, cfg.max_batch, cfg.max_wait) else {
            break; // all senders dropped -> shutdown
        };
        // stream ops apply immediately, in arrival order; batched
        // requests accumulate and flush as engine grids below
        let mut shutting_down = false;
        let mut pending = Vec::new();
        for msg in msgs {
            match msg {
                ServerMsg::Batch(p) => pending.push(p),
                ServerMsg::Stream { stream, op } => {
                    handle_stream_op(&cfg, method.as_ref(), &mut streams, stream, op, &mut stats)
                }
                ServerMsg::Shutdown => shutting_down = true,
            }
        }
        if pending.is_empty() {
            if shutting_down {
                break;
            }
            continue;
        }

        // drop malformed payloads (their reply sender closes -> client
        // recv errors); keep the rest
        pending.retain(|p| {
            let r = &p.req;
            let ok = r.q.len() == elems
                && r.k.len() == elems
                && r.v.len() == elems
                && r.mask.as_ref().is_none_or(|m| m.len() == cfg.seq);
            if !ok {
                stats.rejected += 1;
            }
            ok
        });
        if pending.is_empty() {
            // the sentinel must survive an all-malformed drain too
            if shutting_down {
                break;
            }
            continue;
        }

        // execute in max_batch-sized chunks (the urgent stream-query
        // drain in collect_msgs may have pulled in more than one batch's
        // worth), packing each grid zero-copy: the requests' slabs are
        // wrapped in place (Arc clones, no element copies)
        for chunk in pending.chunks(cfg.max_batch) {
            let slab_views = |get: fn(&HeadsRequest) -> &Arc<[f32]>| {
                BatchTensor::from_slabs(
                    cfg.heads,
                    cfg.seq,
                    cfg.head_dim,
                    chunk.iter().map(|p| Arc::clone(get(&p.req))).collect(),
                )
            };
            let q = slab_views(|r| &r.q);
            let k = slab_views(|r| &r.k);
            let v = slab_views(|r| &r.v);
            let any_mask = chunk.iter().any(|p| p.req.mask.is_some());
            let mut masks = if any_mask {
                Some(Matrix::full(chunk.len(), cfg.seq, 1.0))
            } else {
                None
            };
            for (b, p) in chunk.iter().enumerate() {
                if let (Some(mm), Some(req_mask)) = (masks.as_mut(), p.req.mask.as_ref()) {
                    mm.set_row(b, req_mask);
                }
                queue_ms_sum += p.enqueued.elapsed().as_secs_f64() * 1e3;
            }

            let t0 = Instant::now();
            let seed = batch_seed(cfg.seed, stats.batches);
            // reuse the output tensor across equal-occupancy batches —
            // with the engine's in-place head writes the steady-state
            // request path allocates only the per-request reply copies
            let mut out = match out_cache.take() {
                Some(t) if t.batch() == chunk.len() => t,
                _ => BatchTensor::zeros(chunk.len(), cfg.heads, cfg.seq, cfg.head_dim),
            };
            engine.run_into(method.as_ref(), &q, &k, &v, masks.as_ref(), seed, &mut out);
            batch_ms_sum += t0.elapsed().as_secs_f64() * 1e3;

            for (b, p) in chunk.iter().enumerate() {
                let _ = p.reply.send(out.sequence(b).to_vec());
            }
            out_cache = Some(out);
            stats.requests += chunk.len() as u64;
            stats.batches += 1;
            occupancy_sum += chunk.len() as f64 / cfg.max_batch as f64;
        }
        if shutting_down {
            break;
        }
    }

    if stats.requests > 0 {
        stats.mean_queue_ms = queue_ms_sum / stats.requests as f64;
    }
    if stats.batches > 0 {
        stats.mean_occupancy = occupancy_sum / stats.batches as f64;
        stats.mean_batch_ms = batch_ms_sum / stats.batches as f64;
    }
    stats
}

/// Stream-aware dynamic batching: like
/// [`collect_batch`](super::collect_batch), but only *batched* requests
/// count toward `max`, and a pending stream **query** short-circuits the
/// wait — a decode client is blocked on that reply, so making it sit out
/// the `max_wait` batch-formation deadline would put a ~`max_wait` floor
/// under every decoded token.  When a query is seen, whatever is already
/// queued is drained without blocking and the flush happens immediately.
/// Appends and opens carry no reply and batch freely.
fn collect_msgs(
    rx: &mpsc::Receiver<ServerMsg>,
    max_batch: usize,
    max_wait: Duration,
) -> Option<Vec<ServerMsg>> {
    // queries (a client is blocked on the reply) and the shutdown
    // sentinel both short-circuit the batching wait
    let is_query = |m: &ServerMsg| {
        matches!(
            m,
            ServerMsg::Stream { op: StreamOp::Query { .. }, .. } | ServerMsg::Shutdown
        )
    };
    let first = rx.recv().ok()?;
    let mut urgent = is_query(&first);
    let mut batch_count = usize::from(matches!(first, ServerMsg::Batch(_)));
    let mut pending = vec![first];
    let deadline = Instant::now() + max_wait;
    while batch_count < max_batch && !urgent {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(m) => {
                urgent = is_query(&m);
                batch_count += usize::from(matches!(m, ServerMsg::Batch(_)));
                pending.push(m);
            }
            Err(_) => break, // timeout or disconnect: flush what we have
        }
    }
    if urgent {
        // drain only what is already queued (no blocking), then flush so
        // the query's reply is not delayed behind batch formation
        while let Ok(m) = rx.try_recv() {
            pending.push(m);
        }
    }
    Some(pending)
}

/// Apply one stream op to the server's stream table.  Malformed ops are
/// rejected (counted, reply channel dropped) rather than allowed to panic
/// the serve thread: shape checks here mirror the capability checks the
/// attention layer enforces.
fn handle_stream_op(
    cfg: &AttentionServerConfig,
    method: &dyn attention::AttentionMethod,
    streams: &mut std::collections::HashMap<u64, StreamState>,
    stream: u64,
    op: StreamOp,
    stats: &mut AttentionServerStats,
) {
    let token_elems = cfg.heads * cfg.head_dim;
    match op {
        StreamOp::Open { repilot_stride } => {
            let sessions = (0..cfg.heads)
                .map(|h| {
                    method.begin_session(
                        SessionSpec::new(cfg.head_dim)
                            .with_seed(stream_seed(cfg.seed, stream, h as u64))
                            .with_repilot_stride(repilot_stride)
                            .with_capacity_hint(cfg.seq),
                    )
                })
                .collect();
            streams.insert(stream, StreamState { sessions, scratch: AttnScratch::new() });
        }
        StreamOp::Append { k, v } => {
            let Some(state) = streams.get_mut(&stream) else {
                stats.rejected += 1;
                return;
            };
            if k.len() != token_elems || v.len() != token_elems {
                stats.rejected += 1;
                return;
            }
            for (h, session) in state.sessions.iter_mut().enumerate() {
                let o = h * cfg.head_dim;
                session.append(&k[o..o + cfg.head_dim], &v[o..o + cfg.head_dim]);
            }
            stats.stream_appends += 1;
        }
        StreamOp::Query { q, rows, reply } => {
            let Some(state) = streams.get_mut(&stream) else {
                stats.rejected += 1;
                return;
            };
            let StreamState { sessions, scratch } = state;
            let len = sessions.first().map_or(0, |s| s.len());
            let shape_ok = rows > 0 && q.len() == cfg.heads * rows * cfg.head_dim;
            // square-only methods can only answer full-state queries
            let cross_ok = method.supports_cross_shape() || rows == len;
            if len == 0 || !shape_ok || !cross_ok {
                stats.rejected += 1;
                return; // dropping `reply` signals the rejection
            }
            let head_elems = rows * cfg.head_dim;
            let mut out_slab = vec![0.0f32; cfg.heads * head_elems];
            for (h, session) in sessions.iter_mut().enumerate() {
                let qbuf = scratch.buf_from(&q[h * head_elems..(h + 1) * head_elems]);
                let q_head = Matrix::from_vec(rows, cfg.head_dim, qbuf);
                let mut out = scratch.matrix(rows, cfg.head_dim);
                session.query_into(&q_head, &mut out, scratch);
                out_slab[h * head_elems..(h + 1) * head_elems].copy_from_slice(out.data());
                scratch.recycle(out);
                scratch.recycle_buf(q_head.into_vec());
            }
            let _ = reply.send(out_slab);
            stats.stream_queries += 1;
        }
        StreamOp::Close => {
            streams.remove(&stream);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{HeadSpec, Standard};
    use crate::rng::Rng;

    fn cfg(method: &str, max_batch: usize) -> AttentionServerConfig {
        AttentionServerConfig {
            method: method.to_string(),
            d: 8,
            heads: 2,
            seq: 16,
            head_dim: 4,
            max_batch,
            max_wait: Duration::from_millis(2),
            seed: 0,
            workers: None,
        }
    }

    fn random_request(cfg: &AttentionServerConfig, seed: u64) -> HeadsRequest {
        HeadsRequest::random(cfg.request_elems(), &mut Rng::new(seed))
    }

    #[test]
    fn batch_seeds_do_not_collide_across_nearby_batches() {
        // the engine XORs head indices 0..B*H into the seed; the sets
        // {batch_seed(s,i) ^ g} must be disjoint across batches
        let mut seen = std::collections::HashSet::new();
        for batch in 0..64u64 {
            for g in 0..16u64 {
                assert!(
                    seen.insert(batch_seed(0, batch) ^ g),
                    "stream seed reused at batch {batch}, head {g}"
                );
            }
        }
    }

    #[test]
    fn serves_requests_and_reports_stats() {
        let c = cfg("standard", 4);
        let handle = start(c.clone()).unwrap();
        let rxs: Vec<_> = (0..6).map(|i| handle.submit(random_request(&c, i))).collect();
        for rx in rxs {
            let out = rx.recv().expect("reply");
            assert_eq!(out.len(), c.request_elems());
            assert!(out.iter().all(|x| x.is_finite()));
        }
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.requests, 6);
        assert!(stats.batches >= 2, "6 requests at max_batch 4 need >= 2 batches");
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn single_sequence_batch_matches_direct_engine_call() {
        let c = cfg("standard", 1); // batch size 1: deterministic packing
        let handle = start(c.clone()).unwrap();
        let req = random_request(&c, 9);
        let got = handle.submit(req.clone()).recv().unwrap();
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.batches, 1);

        let spec = HeadSpec::new(1, c.heads, c.seq, c.head_dim);
        let q = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.q.to_vec());
        let k = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.k.to_vec());
        let v = crate::tensor::BatchTensor::from_vec(1, c.heads, c.seq, c.head_dim, req.v.to_vec());
        // the first batch of a server's lifetime computes with batch_seed(seed, 0)
        let want =
            BatchedAttention::new().run(&Standard, &q, &k, &v, None, batch_seed(c.seed, 0));
        assert!(spec.matches(&want));
        assert_eq!(got, want.data().to_vec());
    }

    #[test]
    fn malformed_requests_are_rejected_not_wedged() {
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let bad = HeadsRequest::from_vecs(vec![0.0; 3], vec![0.0; 3], vec![0.0; 3]);
        let bad_rx = handle.submit(bad);
        let good_rx = handle.submit(random_request(&c, 1));
        assert!(good_rx.recv().is_ok());
        assert!(bad_rx.recv().is_err(), "malformed request must not get a reply");
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn unknown_method_is_rejected_up_front() {
        assert!(start(cfg("no-such-method", 2)).is_err());
    }

    #[test]
    fn shared_slab_requests_are_served_in_place() {
        // q, k, and v may all alias ONE client allocation — the zero-copy
        // path must read it in place without tripping over the aliasing,
        // and the client's clone must survive the request untouched.
        let c = cfg("standard", 1);
        let mut buf = vec![0.0f32; c.request_elems()];
        Rng::new(5).fill_normal(&mut buf);
        let slab: Arc<[f32]> = buf.clone().into();
        let req =
            HeadsRequest { q: slab.clone(), k: slab.clone(), v: slab.clone(), mask: None };
        let handle = start(c.clone()).unwrap();
        let got = handle.submit(req).recv().unwrap();
        handle.shutdown().unwrap();
        assert_eq!(got.len(), c.request_elems());
        assert!(got.iter().all(|x| x.is_finite()));
        assert_eq!(&slab[..], &buf[..], "client slab must be untouched");

        // and it matches the owned-Vec construction bitwise
        let handle = start(c.clone()).unwrap();
        let owned = HeadsRequest::from_vecs(buf.clone(), buf.clone(), buf.clone());
        let got_owned = handle.submit(owned).recv().unwrap();
        handle.shutdown().unwrap();
        assert_eq!(got, got_owned);
    }

    #[test]
    fn stream_decode_matches_direct_session_math() {
        // standard-method stream: a one-row query after t appends must
        // equal exact cross attention of that query against the appended
        // keys, per head
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let stream = handle.open_stream(1);
        let mut rng = Rng::new(3);
        let token_elems = c.heads * c.head_dim;
        let mut ks: Vec<Arc<[f32]>> = Vec::new();
        let mut vs: Vec<Arc<[f32]>> = Vec::new();
        for _ in 0..6 {
            let mut k = vec![0.0f32; token_elems];
            let mut v = vec![0.0f32; token_elems];
            rng.fill_normal(&mut k);
            rng.fill_normal(&mut v);
            let (k, v): (Arc<[f32]>, Arc<[f32]>) = (k.into(), v.into());
            stream.append(k.clone(), v.clone());
            ks.push(k);
            vs.push(v);
        }
        let mut q = vec![0.0f32; token_elems]; // one query row per head
        rng.fill_normal(&mut q);
        let got = stream.query(q.clone().into(), 1).recv().expect("stream reply");
        assert_eq!(got.len(), token_elems);

        for h in 0..c.heads {
            let o = h * c.head_dim;
            let k_mat = crate::tensor::Matrix::from_rows(
                &ks.iter().map(|t| t[o..o + c.head_dim].to_vec()).collect::<Vec<_>>(),
            );
            let v_mat = crate::tensor::Matrix::from_rows(
                &vs.iter().map(|t| t[o..o + c.head_dim].to_vec()).collect::<Vec<_>>(),
            );
            let q_mat = crate::tensor::Matrix::from_vec(1, c.head_dim, q[o..o + c.head_dim].to_vec());
            let want = Standard::exact(&q_mat, &k_mat, &v_mat, None);
            for j in 0..c.head_dim {
                assert!(
                    (got[o + j] - want.get(0, j)).abs() < 1e-5,
                    "head {h} col {j}: {} vs {}",
                    got[o + j],
                    want.get(0, j)
                );
            }
        }

        stream.close();
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.stream_appends, 6);
        assert_eq!(stats.stream_queries, 1);
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn stream_rejections_do_not_wedge_the_server() {
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let stream = handle.open_stream(1);
        // query before any append -> rejected, reply channel closes
        let early = stream.query(vec![0.0f32; c.heads * c.head_dim].into(), 1);
        assert!(early.recv().is_err());
        // malformed append (wrong slab size) -> rejected
        let bad: Arc<[f32]> = vec![0.0f32; 3].into();
        stream.append(bad.clone(), bad);
        // a good request still flows
        let ok = handle.submit(random_request(&c, 1));
        assert!(ok.recv().is_ok());
        stream.close();
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.rejected, 2);
        assert_eq!(stats.stream_appends, 0);
    }

    #[test]
    fn shutdown_completes_with_a_live_stream_handle() {
        // the stream handle's cloned sender must not wedge shutdown
        let c = cfg("standard", 2);
        let handle = start(c.clone()).unwrap();
        let stream = handle.open_stream(1);
        let token_elems = c.heads * c.head_dim;
        stream.append(vec![0.5f32; token_elems].into(), vec![0.5f32; token_elems].into());
        let stats = handle.shutdown().expect("shutdown must not hang");
        assert_eq!(stats.stream_appends, 1);
        // late ops on the dead server are silently dropped client-side
        let late = stream.query(vec![0.0f32; token_elems].into(), 1);
        assert!(late.recv().is_err());
    }

    #[test]
    fn stream_and_batch_seed_families_are_disjoint_enough() {
        let mut seen = std::collections::HashSet::new();
        for s in 0..32u64 {
            for h in 0..8u64 {
                assert!(seen.insert(stream_seed(0, s, h)), "stream seed reuse at ({s},{h})");
            }
        }
        for b in 0..32u64 {
            for g in 0..8u64 {
                assert!(
                    seen.insert(batch_seed(0, b) ^ g),
                    "stream/batch seed collision at batch {b} head {g}"
                );
            }
        }
    }

    #[test]
    fn masked_requests_flow_through() {
        let mut c = cfg("skeinformer", 2);
        c.d = 4;
        let handle = start(c.clone()).unwrap();
        let mut req = random_request(&c, 3);
        let mut mask = vec![1.0f32; c.seq];
        for m in mask.iter_mut().skip(12) {
            *m = 0.0;
        }
        req.mask = Some(mask);
        let out = handle.submit(req).recv().unwrap();
        assert_eq!(out.len(), c.request_elems());
        assert!(out.iter().all(|x| x.is_finite()));
        handle.shutdown().unwrap();
    }
}
