//! # Skeinformer: sketching-based efficient self-attention
//!
//! A full-system reproduction of *"Sketching as a Tool for Understanding and
//! Accelerating Self-attention for Long Sequences"* (NAACL 2022) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — coordinator: experiment sweeps, the training
//!   loop driving AOT-compiled XLA artifacts, synthetic LRA data
//!   generators, batched inference services (artifact-backed and the
//!   pure-rust [`attention::BatchedAttention`] engine), and a pure-rust
//!   attention substrate used by the approximation study (Figure 1) and
//!   the property-test suites.
//! * **L2 (`python/compile/`)** — the jax transformer + per-method
//!   attention, lowered once to HLO text artifacts (`make artifacts`).
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the
//!   column-sampled attention hot spot, validated against a pure-jnp
//!   oracle.
//!
//! Python never runs on the request path: the rust binary loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate) and executes
//! them directly.  Offline builds use the vendored stub `xla` crate
//! (`rust/vendor/xla`), so the L3 layer builds and tests without
//! artifacts.  See `DESIGN.md` for the layer map and experiment index.

pub mod attention;
pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod flops;
pub mod json;
pub mod kvcache;
pub mod metrics;
pub mod obs;
pub mod pool;
pub mod prop;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod sketch;
pub mod synth_qkv;
pub mod tensor;
pub mod train;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
