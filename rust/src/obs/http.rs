//! Minimal dependency-free HTTP GET handler for `/metrics`.
//!
//! One accept loop thread, one short-lived connection per scrape — the
//! Prometheus text exposition is rendered by a caller-supplied closure
//! at request time, written with `Connection: close`, and the socket
//! dropped.  This is deliberately not a web server: it answers
//! `GET /metrics` (200, `text/plain; version=0.0.4`) and 404s
//! everything else, reusing the same std-only `TcpListener` plumbing
//! style as [`coordinator::net`](crate::coordinator::net).  Stop is
//! the NetServer idiom: set the flag, self-connect to unblock
//! `accept`, join.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Largest request head we will buffer before giving up on a client.
const MAX_REQUEST_BYTES: usize = 8 * 1024;

/// Per-connection socket timeout: a stalled scraper cannot wedge the
/// accept loop for long.
const SCRAPE_TIMEOUT: Duration = Duration::from_secs(5);

/// Render callback invoked per scrape.
pub type RenderFn = Arc<dyn Fn() -> String + Send + Sync>;

/// Handle to a running metrics endpoint; dropping without
/// [`stop`](MetricsServer::stop) leaves the thread serving until
/// process exit.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the serving thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock accept() the same way NetServer does
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Bind `addr` and serve `GET /metrics` with the text `render`
/// produces, until [`MetricsServer::stop`].
pub fn serve_metrics(addr: impl ToSocketAddrs, render: RenderFn) -> std::io::Result<MetricsServer> {
    let listener = TcpListener::bind(addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop_flag = Arc::clone(&stop);
    let join = std::thread::Builder::new()
        .name("skein-metrics".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop_flag.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(sock) = conn else { continue };
                // scrapes are cheap: handle inline, one at a time
                let _ = handle_scrape(sock, &render);
            }
        })
        .expect("spawn metrics thread");
    Ok(MetricsServer { addr, stop, join: Some(join) })
}

fn handle_scrape(mut sock: TcpStream, render: &RenderFn) -> std::io::Result<()> {
    sock.set_read_timeout(Some(SCRAPE_TIMEOUT))?;
    sock.set_write_timeout(Some(SCRAPE_TIMEOUT))?;
    let mut head = Vec::new();
    let mut buf = [0u8; 512];
    // read until the blank line ending the request head (we ignore
    // bodies: GET has none worth honoring)
    while !head.windows(4).any(|w| w == b"\r\n\r\n") {
        if head.len() > MAX_REQUEST_BYTES {
            return Ok(()); // hostile head: drop the connection
        }
        let n = sock.read(&mut buf)?;
        if n == 0 {
            break;
        }
        head.extend_from_slice(&buf[..n]);
    }
    let line = head.split(|&b| b == b'\r').next().unwrap_or(&[]);
    let line = String::from_utf8_lossy(line);
    let mut parts = line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if method == "GET" && (path == "/metrics" || path == "/") {
        let body = render();
        let resp = format!(
            "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        );
        sock.write_all(resp.as_bytes())?;
    } else {
        let body = "not found\n";
        let resp = format!(
            "HTTP/1.1 404 Not Found\r\nContent-Type: text/plain\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{}",
            body.len(),
            body
        );
        sock.write_all(resp.as_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_404s_the_rest() {
        let render: RenderFn = Arc::new(|| "# TYPE t counter\nt 1\n".to_string());
        let srv = serve_metrics("127.0.0.1:0", render).unwrap();
        let addr = srv.local_addr();
        let ok = get(addr, "/metrics");
        assert!(ok.starts_with("HTTP/1.1 200 OK"), "{ok}");
        assert!(ok.contains("# TYPE t counter"));
        assert!(ok.contains("text/plain"));
        let miss = get(addr, "/nope");
        assert!(miss.starts_with("HTTP/1.1 404"), "{miss}");
        srv.stop();
    }

    #[test]
    fn render_runs_per_scrape() {
        let hits = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let h = Arc::clone(&hits);
        let render: RenderFn = Arc::new(move || {
            let n = h.fetch_add(1, Ordering::SeqCst) + 1;
            format!("scrapes {n}\n")
        });
        let srv = serve_metrics("127.0.0.1:0", render).unwrap();
        let addr = srv.local_addr();
        assert!(get(addr, "/metrics").contains("scrapes 1"));
        assert!(get(addr, "/metrics").contains("scrapes 2"));
        srv.stop();
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }
}
