//! Per-stage span tracing: a flight recorder of recent serving spans.
//!
//! Each writer thread owns a private ring of [`TRACE`-capacity] slots
//! registered lazily through a thread-local, so the record path is
//! **lock-free**: one relaxed counter bump plus a per-slot seqlock
//! (odd/even sequence) that lets the drain side detect and skip slots
//! being overwritten mid-read.  The ring is bounded — when a shard
//! wraps, its oldest events are overwritten and counted as dropped
//! ([`FlightRecorder::dropped`]), never blocking the writer.
//!
//! Spans only ever carry clock readings and routing ids (`conn`,
//! `stream`) — never request data and never RNG state — which is the
//! invariant that keeps tracing zero-cost on served bytes.
//!
//! Drains render as a Chrome-trace-event-compatible JSON array
//! ([`FlightRecorder::to_chrome_trace`]), one complete `"ph": "X"`
//! event object per line, loadable in `chrome://tracing` / Perfetto.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default per-thread ring capacity (slots).
pub const DEFAULT_TRACE_CAP: usize = 4096;

/// What a span measured.  Names are the Chrome-trace event names.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Span {
    /// Admission-queue wait: request enqueue to step admission.
    QueueWait,
    /// Batch formation: first ready work to step execution.
    BatchForm,
    /// KV ingest where every sealed block was shared (prefix hit).
    KvIngestHit,
    /// KV ingest that allocated at least one fresh block.
    KvIngestMiss,
    /// Cache-backed K/V gather feeding the engine.
    KvGather,
    /// Per-step attention compute (the engine grid).
    AttnCompute,
    /// Reply frame write on the connection writer thread.
    ReplyWrite,
    /// Coordinator: encoding + sending one request's scatter frames.
    ScatterEncode,
    /// Coordinator: one shard's submit→reply round trip.
    ShardRtt,
    /// Coordinator: scatter start to last sub-reply (gather countdown).
    GatherWait,
}

impl Span {
    pub fn name(self) -> &'static str {
        match self {
            Span::QueueWait => "queue_wait",
            Span::BatchForm => "batch_form",
            Span::KvIngestHit => "kv_ingest_hit",
            Span::KvIngestMiss => "kv_ingest_miss",
            Span::KvGather => "kv_gather",
            Span::AttnCompute => "attn_compute",
            Span::ReplyWrite => "reply_write",
            Span::ScatterEncode => "scatter_encode",
            Span::ShardRtt => "shard_rtt",
            Span::GatherWait => "gather_wait",
        }
    }

    fn to_u64(self) -> u64 {
        match self {
            Span::QueueWait => 0,
            Span::BatchForm => 1,
            Span::KvIngestHit => 2,
            Span::KvIngestMiss => 3,
            Span::KvGather => 4,
            Span::AttnCompute => 5,
            Span::ReplyWrite => 6,
            Span::ScatterEncode => 7,
            Span::ShardRtt => 8,
            Span::GatherWait => 9,
        }
    }

    fn from_u64(v: u64) -> Option<Span> {
        Some(match v {
            0 => Span::QueueWait,
            1 => Span::BatchForm,
            2 => Span::KvIngestHit,
            3 => Span::KvIngestMiss,
            4 => Span::KvGather,
            5 => Span::AttnCompute,
            6 => Span::ReplyWrite,
            7 => Span::ScatterEncode,
            8 => Span::ShardRtt,
            9 => Span::GatherWait,
            _ => return None,
        })
    }
}

/// One drained span event.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub span: Span,
    pub t_start_ns: u64,
    pub t_end_ns: u64,
    pub conn: u64,
    pub stream: u64,
    /// Writer-thread ring id (the Chrome-trace `tid`).
    pub tid: u64,
}

/// One ring slot: a seqlock sequence plus the event fields.  Fields
/// are atomics so concurrent drain reads are race-free; the sequence
/// (odd while a write is in flight) filters torn combinations.
struct Slot {
    seq: AtomicU64,
    span: AtomicU64,
    t0: AtomicU64,
    t1: AtomicU64,
    conn: AtomicU64,
    stream: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            span: AtomicU64::new(0),
            t0: AtomicU64::new(0),
            t1: AtomicU64::new(0),
            conn: AtomicU64::new(0),
            stream: AtomicU64::new(0),
        }
    }
}

/// One writer thread's private ring.  `push` is called only by the
/// owning thread; drains may run concurrently from any thread.
struct RingShard {
    tid: u64,
    /// Total events ever pushed (monotone); `written - cap` of them
    /// have been overwritten once `written > cap`.
    written: AtomicU64,
    slots: Box<[Slot]>,
}

impl RingShard {
    fn push(&self, span: Span, t0: u64, t1: u64, conn: u64, stream: u64) {
        let n = self.written.load(Ordering::Relaxed);
        let slot = &self.slots[(n % self.slots.len() as u64) as usize];
        // odd sequence marks the slot mid-write so drains skip it
        slot.seq.fetch_add(1, Ordering::Release);
        slot.span.store(span.to_u64(), Ordering::Relaxed);
        slot.t0.store(t0, Ordering::Relaxed);
        slot.t1.store(t1, Ordering::Relaxed);
        slot.conn.store(conn, Ordering::Relaxed);
        slot.stream.store(stream, Ordering::Relaxed);
        slot.seq.fetch_add(1, Ordering::Release);
        self.written.store(n + 1, Ordering::Release);
    }

    fn read(&self, idx: u64) -> Option<TraceEvent> {
        let slot = &self.slots[(idx % self.slots.len() as u64) as usize];
        let s1 = slot.seq.load(Ordering::Acquire);
        if s1 % 2 == 1 {
            return None; // write in flight
        }
        let ev = TraceEvent {
            span: Span::from_u64(slot.span.load(Ordering::Relaxed))?,
            t_start_ns: slot.t0.load(Ordering::Relaxed),
            t_end_ns: slot.t1.load(Ordering::Relaxed),
            conn: slot.conn.load(Ordering::Relaxed),
            stream: slot.stream.load(Ordering::Relaxed),
            tid: self.tid,
        };
        if slot.seq.load(Ordering::Acquire) != s1 {
            return None; // overwritten under us
        }
        Some(ev)
    }
}

static NEXT_RECORDER: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// This thread's shard per recorder it has written to, keyed by
    /// recorder id (tests may run several recorders in one process).
    static MY_SHARDS: std::cell::RefCell<Vec<(u64, Arc<RingShard>)>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Bounded multi-shard flight recorder; see the module doc.
pub struct FlightRecorder {
    id: u64,
    cap: usize,
    shards: Mutex<Vec<Arc<RingShard>>>,
    next_tid: AtomicU64,
}

impl FlightRecorder {
    pub fn new(cap: usize) -> Arc<FlightRecorder> {
        Arc::new(FlightRecorder {
            id: NEXT_RECORDER.fetch_add(1, Ordering::Relaxed),
            cap: cap.max(1),
            shards: Mutex::new(Vec::new()),
            next_tid: AtomicU64::new(0),
        })
    }

    /// Record one completed span from the calling thread (lock-free
    /// after this thread's first record).
    pub fn record(self: &Arc<Self>, span: Span, t0: u64, t1: u64, conn: u64, stream: u64) {
        MY_SHARDS.with(|cell| {
            let mut mine = cell.borrow_mut();
            let shard = match mine.iter().find(|(id, _)| *id == self.id) {
                Some((_, s)) => Arc::clone(s),
                None => {
                    let shard = Arc::new(RingShard {
                        tid: self.next_tid.fetch_add(1, Ordering::Relaxed),
                        written: AtomicU64::new(0),
                        slots: (0..self.cap).map(|_| Slot::new()).collect(),
                    });
                    self.shards.lock().expect("recorder poisoned").push(Arc::clone(&shard));
                    mine.push((self.id, Arc::clone(&shard)));
                    shard
                }
            };
            shard.push(span, t0, t1, conn, stream);
        });
    }

    /// Total events overwritten before they could be drained, summed
    /// over all writer shards.
    pub fn dropped(&self) -> u64 {
        let shards = self.shards.lock().expect("recorder poisoned");
        shards
            .iter()
            .map(|s| s.written.load(Ordering::Acquire).saturating_sub(s.slots.len() as u64))
            .sum()
    }

    /// Total events ever recorded, summed over all writer shards.
    pub fn recorded(&self) -> u64 {
        let shards = self.shards.lock().expect("recorder poisoned");
        shards.iter().map(|s| s.written.load(Ordering::Acquire)).sum()
    }

    /// Drain a snapshot of every shard's retained events, sorted by
    /// start time.  Slots being overwritten mid-drain are skipped
    /// (seqlock), so the result is always well-formed.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let shards = self.shards.lock().expect("recorder poisoned");
        let mut out = Vec::new();
        for shard in shards.iter() {
            let w = shard.written.load(Ordering::Acquire);
            let lo = w.saturating_sub(shard.slots.len() as u64);
            for idx in lo..w {
                if let Some(ev) = shard.read(idx) {
                    out.push(ev);
                }
            }
        }
        out.sort_by_key(|e| (e.t_start_ns, e.tid));
        out
    }

    /// Render the retained events as a Chrome-trace-event JSON array,
    /// one complete event object per line (`chrome://tracing` /
    /// Perfetto compatible).  Timestamps are microseconds per the
    /// trace-event spec.
    pub fn to_chrome_trace(&self, method: &str) -> String {
        let mut out = String::from("[\n");
        let events = self.snapshot();
        for (i, ev) in events.iter().enumerate() {
            let ts = ev.t_start_ns as f64 / 1e3;
            let dur = ev.t_end_ns.saturating_sub(ev.t_start_ns) as f64 / 1e3;
            let sep = if i + 1 == events.len() { "" } else { "," };
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"cat\":\"serve\",\"ph\":\"X\",\"ts\":{ts:.3},\
                 \"dur\":{dur:.3},\"pid\":0,\"tid\":{},\"args\":{{\"conn\":{},\
                 \"stream\":{},\"method\":\"{}\"}}}}{sep}\n",
                ev.span.name(),
                ev.tid,
                ev.conn,
                ev.stream,
                method,
            ));
        }
        out.push_str("]\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_drains_in_start_order() {
        let r = FlightRecorder::new(16);
        r.record(Span::QueueWait, 100, 200, 1, 0);
        r.record(Span::AttnCompute, 150, 400, 1, 0);
        let evs = r.snapshot();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].span, Span::QueueWait);
        assert_eq!(evs[1].span, Span::AttnCompute);
        assert_eq!(r.dropped(), 0);
        assert_eq!(r.recorded(), 2);
    }

    #[test]
    fn wrap_drops_oldest_and_counts() {
        let r = FlightRecorder::new(4);
        for i in 0..10u64 {
            r.record(Span::ReplyWrite, i, i + 1, 0, 0);
        }
        let evs = r.snapshot();
        assert_eq!(evs.len(), 4, "ring retains cap events");
        assert_eq!(r.dropped(), 6);
        // the retained events are the newest ones
        assert_eq!(evs[0].t_start_ns, 6);
        assert_eq!(evs[3].t_start_ns, 9);
    }

    #[test]
    fn shards_are_per_thread() {
        let r = FlightRecorder::new(8);
        r.record(Span::BatchForm, 1, 2, 0, 0);
        let r2 = Arc::clone(&r);
        std::thread::spawn(move || {
            r2.record(Span::BatchForm, 3, 4, 0, 0);
        })
        .join()
        .unwrap();
        let evs = r.snapshot();
        assert_eq!(evs.len(), 2);
        let tids: std::collections::HashSet<u64> = evs.iter().map(|e| e.tid).collect();
        assert_eq!(tids.len(), 2, "each writer thread gets its own shard");
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let r = FlightRecorder::new(8);
        r.record(Span::QueueWait, 1_000, 2_500, 3, 7);
        let text = r.to_chrome_trace("skeinformer");
        let doc = crate::json::parse(&text).expect("chrome trace parses");
        let arr = doc.as_arr().expect("array");
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].req_str("name").unwrap(), "queue_wait");
        assert_eq!(arr[0].req_str("ph").unwrap(), "X");
        assert_eq!(arr[0].path(&["args", "conn"]).unwrap().as_usize().unwrap(), 3);
    }
}
