//! Serving telemetry: metrics registry, span tracing, and exposition.
//!
//! Three parts (see DESIGN.md §8):
//!
//! * [`registry`] — named counters / gauges / fixed-bucket log2
//!   latency histograms (constant memory, mergeable bucket-wise for
//!   cluster aggregation) with Prometheus text exposition.
//! * [`trace`] — a lock-free per-thread flight recorder of
//!   `{span, t_start, t_end, conn, stream}` events, drained as a
//!   Chrome-trace-compatible JSON array.
//! * [`http`] — the minimal dependency-free `GET /metrics` endpoint
//!   (`skein serve --metrics-addr H:P`).
//!
//! [`ServeTelemetry`] bundles them for the serving layers with the
//! hot-path metric handles prebound.  The **overhead contract**: every
//! record site is gated on one `enabled` bool; instrumentation reads
//! *clocks only* — never RNG state, never request data — so served
//! bytes are bitwise identical with telemetry on, off, or tracing
//! (pinned by `rust/tests/telemetry.rs`; measured by
//! `make obs-bench`).  `--no-telemetry` is the kill switch.
//!
//! Timestamps are nanoseconds since a lazily-pinned process epoch
//! ([`now_ns`]), so all spans in one process share a timeline.

pub mod http;
pub mod registry;
pub mod trace;

pub use http::{serve_metrics, MetricsServer, RenderFn};
pub use registry::{
    bucket_index, bucket_le, render_histogram, Counter, Gauge, Histo, HistoSnapshot, Registry,
    HISTO_BUCKETS,
};
pub use trace::{FlightRecorder, Span, TraceEvent, DEFAULT_TRACE_CAP};

use std::sync::{Arc, OnceLock};
use std::time::Instant;

/// Nanoseconds since the process telemetry epoch (the first call pins
/// it).  Monotone within a process; meaningless across processes.
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Epoch-relative start timestamp for a span whose duration was
/// measured with an [`Instant`]: `end_ns - elapsed`, saturating.
pub fn start_ns(end_ns: u64, since: Instant) -> u64 {
    end_ns.saturating_sub(since.elapsed().as_nanos() as u64)
}

/// The telemetry bundle threaded through the serving layers: one
/// registry, one flight recorder, and prebound handles for every
/// hot-path metric so recording never touches the registry maps.
///
/// Constructed once per server / coordinator process
/// ([`ServeTelemetry::new`]); `enabled == false` (the `--no-telemetry`
/// kill switch, or [`ServeTelemetry::disabled`] — what plain
/// `attention_server::start` uses) turns every record site into a
/// single branch that reads no clock.
pub struct ServeTelemetry {
    enabled: bool,
    registry: Arc<Registry>,
    recorder: Arc<FlightRecorder>,
    g_trace_dropped: Arc<Gauge>,
    /// Engine: admission-queue wait per request.
    pub h_queue_wait: Arc<Histo>,
    /// Engine: first-ready-work to step execution.
    pub h_batch_form: Arc<Histo>,
    /// Engine: per-step attention compute.
    pub h_attn_compute: Arc<Histo>,
    /// Engine: KV append/prefill/dedupe ingest.
    pub h_kv_ingest: Arc<Histo>,
    /// Engine: cache-backed K/V gather.
    pub h_kv_gather: Arc<Histo>,
    /// Front end: reply frame write on the writer thread.
    pub h_reply_write: Arc<Histo>,
    /// Coordinator: scatter frame encode + send per request.
    pub h_scatter_encode: Arc<Histo>,
    /// Coordinator: per-shard submit→reply round trip.
    pub h_shard_rtt: Arc<Histo>,
    /// Coordinator: scatter start to gather completion.
    pub h_gather_wait: Arc<Histo>,
    /// Engine: ready admission-queue slots at the last step.
    pub g_queue_depth: Arc<Gauge>,
    /// Engine: resident KV blocks at the last snapshot.
    pub g_kv_resident_blocks: Arc<Gauge>,
    /// Engine: resident KV bytes at the last snapshot.
    pub g_kv_resident_bytes: Arc<Gauge>,
}

impl ServeTelemetry {
    pub fn new(enabled: bool) -> Arc<ServeTelemetry> {
        Self::with_trace_cap(enabled, DEFAULT_TRACE_CAP)
    }

    /// As [`new`](Self::new) with an explicit per-thread ring
    /// capacity (tests pin wrap behavior with tiny rings).
    pub fn with_trace_cap(enabled: bool, trace_cap: usize) -> Arc<ServeTelemetry> {
        let registry = Registry::new();
        // One-hot ISA gauge family: every known ISA gets a labelled
        // sample, the active one reads 1.  Summing a label across a
        // fleet scrape (or the coordinator's gauge aggregation) counts
        // shards running that kernel tier.
        let active = crate::tensor::kernels::active_isa();
        for isa in crate::tensor::kernels::KernelIsa::ALL {
            registry
                .gauge(&format!("skein_kernel_isa{{isa=\"{}\"}}", isa.name()))
                .set((isa == active) as u64);
        }
        Arc::new(ServeTelemetry {
            enabled,
            recorder: FlightRecorder::new(trace_cap),
            g_trace_dropped: registry.gauge("skein_trace_dropped_total"),
            h_queue_wait: registry.histo("skein_queue_wait_ns"),
            h_batch_form: registry.histo("skein_batch_form_ns"),
            h_attn_compute: registry.histo("skein_attn_compute_ns"),
            h_kv_ingest: registry.histo("skein_kv_ingest_ns"),
            h_kv_gather: registry.histo("skein_kv_gather_ns"),
            h_reply_write: registry.histo("skein_reply_write_ns"),
            h_scatter_encode: registry.histo("skein_scatter_encode_ns"),
            h_shard_rtt: registry.histo("skein_shard_rtt_ns"),
            h_gather_wait: registry.histo("skein_gather_wait_ns"),
            g_queue_depth: registry.gauge("skein_queue_depth"),
            g_kv_resident_blocks: registry.gauge("skein_kv_resident_blocks"),
            g_kv_resident_bytes: registry.gauge("skein_kv_resident_bytes"),
            registry,
        })
    }

    /// The no-op bundle: what in-process `start` wires by default.
    pub fn disabled() -> Arc<ServeTelemetry> {
        Self::new(false)
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.recorder
    }

    /// Epoch timestamp for an about-to-open span, or 0 when disabled
    /// (record sites pass it straight back to [`span`](Self::span),
    /// which ignores 0).
    #[inline]
    pub fn now(&self) -> u64 {
        if self.enabled {
            now_ns()
        } else {
            0
        }
    }

    /// Close a span opened at `t0` (a [`now`](Self::now) reading):
    /// records the flight-recorder event and the span's histogram
    /// sample.  No-op when disabled or `t0 == 0`.
    #[inline]
    pub fn span(self: &Arc<Self>, span: Span, t0: u64, conn: u64, stream: u64) {
        if !self.enabled || t0 == 0 {
            return;
        }
        self.span_at(span, t0, now_ns(), conn, stream);
    }

    /// As [`span`](Self::span) with an explicit end timestamp (for
    /// sites that already read the clock).
    pub fn span_at(self: &Arc<Self>, span: Span, t0: u64, t1: u64, conn: u64, stream: u64) {
        if !self.enabled || t0 == 0 {
            return;
        }
        self.recorder.record(span, t0, t1, conn, stream);
        self.histo_for(span).record(t1.saturating_sub(t0));
    }

    fn histo_for(&self, span: Span) -> &Histo {
        match span {
            Span::QueueWait => &self.h_queue_wait,
            Span::BatchForm => &self.h_batch_form,
            Span::KvIngestHit | Span::KvIngestMiss => &self.h_kv_ingest,
            Span::KvGather => &self.h_kv_gather,
            Span::AttnCompute => &self.h_attn_compute,
            Span::ReplyWrite => &self.h_reply_write,
            Span::ScatterEncode => &self.h_scatter_encode,
            Span::ShardRtt => &self.h_shard_rtt,
            Span::GatherWait => &self.h_gather_wait,
        }
    }

    /// Render the registry's Prometheus exposition (refreshes the
    /// trace drop counter first).
    pub fn render(&self) -> String {
        self.g_trace_dropped.set(self.recorder.dropped());
        self.registry.render_prometheus()
    }

    /// Gauge and histogram snapshots for the wire `Stats` reply
    /// (refreshes the trace drop counter first).  Empty when disabled,
    /// so a kill-switched server sends the same frame bytes it always
    /// did.
    pub fn wire_snapshots(&self) -> (Vec<(String, u64)>, Vec<(String, HistoSnapshot)>) {
        if !self.enabled {
            return (Vec::new(), Vec::new());
        }
        self.g_trace_dropped.set(self.recorder.dropped());
        (self.registry.gauge_snapshots(), self.registry.histo_snapshots())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_bundle_records_nothing() {
        let t = ServeTelemetry::disabled();
        let t0 = t.now();
        assert_eq!(t0, 0, "disabled now() must not read the clock path");
        t.span(Span::QueueWait, t0, 1, 0);
        assert_eq!(t.recorder().recorded(), 0);
        assert_eq!(t.h_queue_wait.snapshot().count(), 0);
    }

    #[test]
    fn span_records_both_ring_and_histogram() {
        let t = ServeTelemetry::new(true);
        let t0 = t.now();
        assert!(t0 > 0);
        t.span(Span::AttnCompute, t0, 2, 5);
        assert_eq!(t.recorder().recorded(), 1);
        assert_eq!(t.h_attn_compute.snapshot().count(), 1);
        let ev = &t.recorder().snapshot()[0];
        assert_eq!((ev.conn, ev.stream), (2, 5));
        assert!(ev.t_end_ns >= ev.t_start_ns);
        let text = t.render();
        assert!(text.contains("skein_attn_compute_ns_count 1"));
        assert!(text.contains("skein_trace_dropped_total 0"));
    }

    #[test]
    fn epoch_is_monotone() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
        let i = Instant::now();
        let end = now_ns();
        assert!(start_ns(end, i) <= end);
    }
}
