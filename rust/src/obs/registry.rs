//! Metrics registry: named counters, gauges, and fixed-bucket log2
//! latency histograms with Prometheus text exposition.
//!
//! The histogram is the load-bearing type: unlike
//! [`metrics::Percentiles`](crate::metrics::Percentiles), which retains
//! every sample, a [`Histo`] is **constant memory** ([`HISTO_BUCKETS`]
//! atomic buckets over nanoseconds) and **mergeable by bucket-wise
//! sum** — which is what lets the shard coordinator aggregate
//! per-shard latency distributions over the wire without shipping
//! samples.  Bucket `i` covers the duration range
//! `(2^(i-1), 2^i]` ns (bucket 0 covers `0..=1`; the last bucket is
//! the `+Inf` overflow), so quantiles come back as power-of-two upper
//! bounds — coarse, but bounded and exact to the bucket contract.
//!
//! All mutation is relaxed atomics: recording a sample is a couple of
//! `fetch_add`s, safe from any thread, and never allocates.  Snapshots
//! ([`HistoSnapshot`]) are plain `Copy` data used for wire export and
//! merging.
//!
//! ```
//! use skeinformer::obs::{Histo, HistoSnapshot};
//! let h = Histo::default();
//! for v in [100u64, 200, 3_000, 50_000] {
//!     h.record(v);
//! }
//! let s = h.snapshot();
//! assert_eq!(s.count(), 4);
//! assert!(s.percentile(50.0) >= 200);
//! let merged = HistoSnapshot::merge_all(&[s, s]);
//! assert_eq!(merged.count(), 8);
//! ```

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of log2 buckets per histogram.  Bucket 38's upper bound is
/// `2^38` ns ≈ 275 s; anything slower lands in the final `+Inf`
/// bucket.
pub const HISTO_BUCKETS: usize = 40;

/// Bucket index for a nanosecond value: 0 for `v <= 1`, else
/// `ceil(log2(v))`, clamped into the `+Inf` bucket.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    let bits = 64 - v.saturating_sub(1).leading_zeros() as usize;
    bits.min(HISTO_BUCKETS - 1)
}

/// Inclusive upper bound of bucket `i`, or `None` for the `+Inf`
/// overflow bucket.
#[inline]
pub fn bucket_le(i: usize) -> Option<u64> {
    if i + 1 < HISTO_BUCKETS {
        Some(1u64 << i)
    } else {
        None
    }
}

/// Monotone counter (relaxed atomics; safe from any thread).
#[derive(Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (relaxed atomics).
#[derive(Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn set(&self, n: u64) {
        self.v.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket log2 histogram over u64 nanoseconds: constant memory,
/// lock-free recording, mergeable snapshots.
pub struct Histo {
    buckets: [AtomicU64; HISTO_BUCKETS],
    sum: AtomicU64,
}

impl Default for Histo {
    fn default() -> Self {
        Histo { buckets: std::array::from_fn(|_| AtomicU64::new(0)), sum: AtomicU64::new(0) }
    }
}

impl Histo {
    /// Record one nanosecond sample.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Point-in-time copy of the buckets (plain data, `Copy`).
    pub fn snapshot(&self) -> HistoSnapshot {
        HistoSnapshot {
            sum: self.sum.load(Ordering::Relaxed),
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
        }
    }
}

/// Plain-data copy of a [`Histo`]: what goes over the wire and what
/// the coordinator merges bucket-wise.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistoSnapshot {
    pub sum: u64,
    pub buckets: [u64; HISTO_BUCKETS],
}

impl Default for HistoSnapshot {
    fn default() -> Self {
        HistoSnapshot { sum: 0, buckets: [0; HISTO_BUCKETS] }
    }
}

impl HistoSnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Bucket-wise sum: the merge is associative and commutative, so
    /// any aggregation tree over any shard order yields the same
    /// result (pinned by `rust/tests/telemetry.rs`).
    pub fn merge(&mut self, other: &HistoSnapshot) {
        self.sum = self.sum.wrapping_add(other.sum);
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }

    pub fn merge_all(parts: &[HistoSnapshot]) -> HistoSnapshot {
        let mut out = HistoSnapshot::default();
        for p in parts {
            out.merge(p);
        }
        out
    }

    /// Upper bound (ns) of the bucket containing the `p`-th percentile
    /// sample, or 0 for an empty histogram.  The `+Inf` bucket reports
    /// the largest finite bound.
    pub fn percentile(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_le(i).unwrap_or(1u64 << (HISTO_BUCKETS - 1));
            }
        }
        1u64 << (HISTO_BUCKETS - 1)
    }

    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum as f64 / n as f64
        }
    }
}

/// Named-metric registry: idempotent registration by name, sorted
/// Prometheus text exposition.  `Arc`-shareable; handles returned by
/// the getters are prebound `Arc`s so hot paths never touch the maps.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histos: Mutex<BTreeMap<String, Arc<Histo>>>,
}

impl Registry {
    pub fn new() -> Arc<Registry> {
        Arc::new(Registry::default())
    }

    /// Get-or-create the named counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.counters.lock().expect("registry poisoned");
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Get-or-create the named gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.gauges.lock().expect("registry poisoned");
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Get-or-create the named histogram.
    pub fn histo(&self, name: &str) -> Arc<Histo> {
        let mut m = self.histos.lock().expect("registry poisoned");
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Snapshot every gauge as `(name, value)` (wire export).
    pub fn gauge_snapshots(&self) -> Vec<(String, u64)> {
        let m = self.gauges.lock().expect("registry poisoned");
        m.iter().map(|(k, v)| (k.clone(), v.get())).collect()
    }

    /// Snapshot every counter as `(name, value)`.
    pub fn counter_snapshots(&self) -> Vec<(String, u64)> {
        let m = self.counters.lock().expect("registry poisoned");
        m.iter().map(|(k, v)| (k.clone(), v.get())).collect()
    }

    /// Snapshot every histogram as `(name, snapshot)` (wire export).
    pub fn histo_snapshots(&self) -> Vec<(String, HistoSnapshot)> {
        let m = self.histos.lock().expect("registry poisoned");
        m.iter().map(|(k, v)| (k.clone(), v.snapshot())).collect()
    }

    /// Prometheus text exposition (format version 0.0.4): `# TYPE`
    /// line per metric family, `_bucket{le=...}` / `_sum` / `_count`
    /// series per histogram, everything name-sorted so output is
    /// stable.  Labelled samples (`name{k="v"}`) share one family: the
    /// `# TYPE` line carries the base name (everything before `{`) and
    /// is emitted once per family — snapshots are name-sorted, so a
    /// family's labelled variants are always adjacent.
    pub fn render_prometheus(&self) -> String {
        fn base(name: &str) -> &str {
            name.split('{').next().unwrap_or(name)
        }
        let mut out = String::new();
        let mut last_family = String::new();
        for (name, c) in self.counter_snapshots() {
            let fam = base(&name);
            if fam != last_family {
                let _ = writeln!(out, "# TYPE {fam} counter");
                last_family = fam.to_string();
            }
            let _ = writeln!(out, "{name} {}", c);
        }
        last_family.clear();
        for (name, g) in self.gauge_snapshots() {
            let fam = base(&name);
            if fam != last_family {
                let _ = writeln!(out, "# TYPE {fam} gauge");
                last_family = fam.to_string();
            }
            let _ = writeln!(out, "{name} {}", g);
        }
        for (name, h) in self.histo_snapshots() {
            render_histogram(&mut out, &name, &h);
        }
        out
    }
}

/// Render one histogram in Prometheus text format (cumulative
/// buckets).  Public so aggregated snapshots that never lived in a
/// local [`Registry`] (the coordinator's merged view) render the same
/// way.
pub fn render_histogram(out: &mut String, name: &str, h: &HistoSnapshot) {
    let _ = writeln!(out, "# TYPE {name} histogram");
    let mut cum = 0u64;
    for (i, &c) in h.buckets.iter().enumerate() {
        cum += c;
        // empty interior buckets are skipped to keep the exposition
        // small; cumulative semantics make that lossless
        if c == 0 && i + 1 < HISTO_BUCKETS {
            continue;
        }
        match bucket_le(i) {
            Some(le) => {
                let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cum}");
            }
            None => {
                let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
            }
        }
    }
    let _ = writeln!(out, "{name}_sum {}", h.sum);
    let _ = writeln!(out, "{name}_count {}", cum);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_covers_the_log2_ranges() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(1 << 20), 20);
        assert_eq!(bucket_index((1 << 20) + 1), 21);
        assert_eq!(bucket_index(u64::MAX), HISTO_BUCKETS - 1);
        // every value lands in a bucket whose le bound contains it
        for v in [0u64, 1, 2, 7, 1000, 123_456_789] {
            let i = bucket_index(v);
            if let Some(le) = bucket_le(i) {
                assert!(v <= le, "value {v} above its bucket bound {le}");
            }
            if i > 0 {
                let below = bucket_le(i - 1).expect("interior bucket");
                assert!(v > below, "value {v} should be in bucket {}", i - 1);
            }
        }
    }

    #[test]
    fn percentile_returns_bucket_upper_bounds() {
        let h = Histo::default();
        for _ in 0..99 {
            h.record(100); // bucket le=128
        }
        h.record(1_000_000); // bucket le=2^20
        let s = h.snapshot();
        assert_eq!(s.count(), 100);
        assert_eq!(s.percentile(50.0), 128);
        assert_eq!(s.percentile(99.0), 128);
        assert_eq!(s.percentile(100.0), 1 << 20);
        assert_eq!(HistoSnapshot::default().percentile(50.0), 0);
    }

    #[test]
    fn merge_is_bucket_wise_sum() {
        let a = Histo::default();
        let b = Histo::default();
        a.record(10);
        a.record(10_000);
        b.record(10);
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.count(), 3);
        assert_eq!(m.sum, 20_010);
        assert_eq!(m.buckets[bucket_index(10)], 2);
    }

    #[test]
    fn registry_is_idempotent_and_renders_sorted() {
        let r = Registry::new();
        let c = r.counter("skein_requests_total");
        c.add(3);
        r.counter("skein_requests_total").inc(); // same handle
        assert_eq!(c.get(), 4);
        r.gauge("skein_queue_depth").set(7);
        r.histo("skein_queue_wait_ns").record(100);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE skein_requests_total counter"));
        assert!(text.contains("skein_requests_total 4"));
        assert!(text.contains("skein_queue_depth 7"));
        assert!(text.contains("skein_queue_wait_ns_bucket{le=\"128\"} 1"));
        assert!(text.contains("skein_queue_wait_ns_count 1"));
    }

    #[test]
    fn labelled_samples_share_one_type_line() {
        let r = Registry::new();
        r.gauge("skein_kernel_isa{isa=\"avx2\"}").set(0);
        r.gauge("skein_kernel_isa{isa=\"scalar\"}").set(1);
        r.gauge("skein_queue_depth").set(3);
        let text = r.render_prometheus();
        assert_eq!(text.matches("# TYPE skein_kernel_isa gauge").count(), 1);
        assert!(!text.contains("# TYPE skein_kernel_isa{"), "TYPE must use the base name");
        assert!(text.contains("skein_kernel_isa{isa=\"scalar\"} 1"));
        assert!(text.contains("skein_kernel_isa{isa=\"avx2\"} 0"));
        assert!(text.contains("# TYPE skein_queue_depth gauge"));
    }
}
