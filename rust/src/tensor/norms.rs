//! Matrix norms: Frobenius and spectral (power iteration).
//!
//! The paper's Figure 1 metric is the spectral norm of the approximation
//! error, `‖BV − R‖₂`.  The error matrices are (n, p) with p ≤ 64, so power
//! iteration on the p×p Gram matrix `EᵀE` converges in a handful of sweeps
//! and costs O(n·p²) — negligible next to the attention compute.

use super::ops::{dot, normalize, sub};
use super::{matmul_tn, Matrix};

/// Dense p×p mat-vec used inside the power iteration (p is small);
/// per-row dots on the shared dispatched kernel.
fn gram_matvec(g: &[f32], p: usize, x: &[f32], y: &mut [f32]) {
    for i in 0..p {
        y[i] = dot(&g[i * p..(i + 1) * p], x);
    }
}

/// Frobenius norm `‖M‖_F` (f64 accumulation for large matrices).
pub fn frobenius_norm(m: &Matrix) -> f32 {
    m.data().iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
}

/// Largest singular value via power iteration on `MᵀM`.
///
/// `iters` sweeps of `v ← normalize(MᵀM v)`; σ ≈ sqrt(λ_max). For the error
/// matrices in this codebase 40 iterations give ≥3 significant digits; the
/// tests verify against analytically-known singular values.
pub fn power_iteration(m: &Matrix, iters: usize, seed: u64) -> f32 {
    let p = m.cols();
    if p == 0 || m.rows() == 0 {
        return 0.0;
    }
    let g = matmul_tn(m, m); // Gram matrix (p×p)
    let gd = g.data();
    // deterministic xorshift start vector
    let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
    let mut v: Vec<f32> = (0..p)
        .map(|_| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 11) as f32 / (1u64 << 53) as f32) - 0.5
        })
        .collect();
    normalize(&mut v);
    let mut w = vec![0.0f32; p];
    let mut lambda = 0.0f32;
    for _ in 0..iters {
        gram_matvec(gd, p, &v, &mut w);
        lambda = normalize(&mut w);
        std::mem::swap(&mut v, &mut w);
    }
    lambda.max(0.0).sqrt()
}

/// Spectral norm `‖M‖₂` with the default iteration budget.
pub fn spectral_norm(m: &Matrix) -> f32 {
    power_iteration(m, 40, 0xC0FFEE)
}

/// `‖A − B‖₂`.
pub fn spectral_norm_diff(a: &Matrix, b: &Matrix) -> f32 {
    assert_eq!(a.shape(), b.shape());
    spectral_norm(&sub(a, b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    #[test]
    fn frobenius_of_ones() {
        let m = Matrix::full(3, 4, 1.0);
        assert!((frobenius_norm(&m) - (12.0f32).sqrt()).abs() < 1e-5);
    }

    #[test]
    fn spectral_of_diagonal() {
        let mut m = Matrix::zeros(6, 3);
        m.set(0, 0, 1.0);
        m.set(1, 1, -5.0);
        m.set(2, 2, 3.0);
        assert!((spectral_norm(&m) - 5.0).abs() < 1e-3);
    }

    #[test]
    fn spectral_of_rank_one() {
        // ‖u vᵀ‖₂ = ‖u‖‖v‖
        let u: Vec<f32> = (0..8).map(|i| i as f32 * 0.5 - 1.0).collect();
        let v: Vec<f32> = (0..5).map(|i| (i as f32).cos()).collect();
        let m = Matrix::from_fn(8, 5, |i, j| u[i] * v[j]);
        let expect = u.iter().map(|x| x * x).sum::<f32>().sqrt()
            * v.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((spectral_norm(&m) - expect).abs() / expect < 1e-3);
    }

    #[test]
    fn spectral_le_frobenius() {
        let m = Matrix::from_fn(20, 10, |i, j| ((i * 7 + j * 13) % 23) as f32 * 0.1 - 1.0);
        assert!(spectral_norm(&m) <= frobenius_norm(&m) + 1e-4);
    }

    #[test]
    fn diff_norm_is_zero_for_identical() {
        let m = Matrix::from_fn(5, 5, |i, j| (i + j) as f32);
        assert!(spectral_norm_diff(&m, &m) < 1e-6);
    }
}
