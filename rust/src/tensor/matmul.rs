//! Blocked, threaded matrix multiplication kernels.
//!
//! Three layouts cover every product the attention algorithms need without
//! materialising transposes:
//!
//! * [`matmul`]     — `C = A · B`        (ikj loop order, row-major streams)
//! * [`matmul_nt`]  — `C = A · Bᵀ`       (dot-product of rows; the `QKᵀ` shape)
//! * [`matmul_tn`]  — `C = Aᵀ · B`       (outer-product accumulate; `SᵀV`)
//!
//! The inner loops are the dispatched SIMD microkernels of
//! [`super::kernels`]: [`matmul_nt`]'s row dot runs on the shared
//! 8-lane `dot` kernel (fixed lane-reduction tree — see the kernels
//! module docs; this replaced an older 4-way unrolled accumulator),
//! while [`matmul`] and [`matmul_tn`] stream `saxpy` row updates,
//! which are element-wise and therefore bitwise identical at any lane
//! width.  Every ISA variant of those kernels produces identical
//! bytes, so kernel dispatch — like threading — never changes results.
//!
//! [`matmul`] probes each A row for zeros once: rows without any (the
//! common dense case) take a branch-free saxpy stream; rows with real
//! zeros (masked attention) keep the skip, which both saves the work
//! and preserves the historical semantics that a zero coefficient
//! contributes nothing even against non-finite B rows.  Output is
//! bitwise identical either way.
//!
//! All kernels parallelise over row blocks with
//! [`crate::pool::parallel_row_blocks`] when the output is large enough to
//! amortise the queue round-trip on the persistent worker pool.  Results
//! are independent of the thread count *and* of the chosen
//! [`MatmulPlan`]: every output row is computed by the same per-row
//! arithmetic regardless of which block it lands in (the batched
//! attention engine's bitwise worker-invariance rests on this).
//!
//! Callers that already occupy the whole pool — the batched engine when
//! its `B × H` head grid saturates the workers — scope-override the
//! `Auto` decision with [`with_default_plan`], forcing the inner kernels
//! single-threaded instead of oversubscribing (~10–20% loss at 16×8
//! before this existed).

use super::kernels;
use super::Matrix;
use crate::pool;
use std::cell::Cell;

/// Work threshold (output elements × inner dim) below which the
/// single-threaded kernel is used.
const PAR_FLOP_THRESHOLD: usize = 1 << 22;

/// Execution plan — lets benches force single/multi-thread variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatmulPlan {
    Auto,
    SingleThread,
    MultiThread,
}

thread_local! {
    /// What `MatmulPlan::Auto` resolves to on this thread (see
    /// [`with_default_plan`]).  `Auto` means "use the FLOP threshold".
    static DEFAULT_PLAN: Cell<MatmulPlan> = const { Cell::new(MatmulPlan::Auto) };
}

/// Run `f` with `MatmulPlan::Auto` resolving to `plan` on this thread —
/// restores the previous default afterwards, panic or not.
///
/// This is how an outer parallel layer keeps inner kernels from
/// oversubscribing: the batched attention engine wraps each per-head
/// `compute` in `with_default_plan(MatmulPlan::SingleThread, ..)` once
/// its head grid alone saturates the pool.  Kernels invoked with an
/// explicit non-`Auto` plan are unaffected; `Auto` — whether implicit
/// ([`matmul`] etc.) or passed to [`matmul_plan`]/[`matmul_nt_plan`]
/// directly — consults the default.  The plan never changes results,
/// only the threading (see the module docs).
pub fn with_default_plan<R>(plan: MatmulPlan, f: impl FnOnce() -> R) -> R {
    struct Restore(MatmulPlan);
    impl Drop for Restore {
        fn drop(&mut self) {
            DEFAULT_PLAN.with(|p| p.set(self.0));
        }
    }
    let _restore = Restore(DEFAULT_PLAN.with(|p| p.replace(plan)));
    f()
}

fn should_par(m: usize, n: usize, k: usize, plan: MatmulPlan) -> bool {
    let plan = match plan {
        MatmulPlan::Auto => DEFAULT_PLAN.with(|p| p.get()),
        explicit => explicit,
    };
    match plan {
        MatmulPlan::SingleThread => false,
        MatmulPlan::MultiThread => true,
        MatmulPlan::Auto => m * n * k >= PAR_FLOP_THRESHOLD,
    }
}

/// `C = A · B` with `A: (m,k)`, `B: (k,n)`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_plan(a, b, MatmulPlan::Auto)
}

pub fn matmul_plan(a: &Matrix, b: &Matrix, plan: MatmulPlan) -> Matrix {
    let mut out = Matrix::zeros(a.rows(), b.cols());
    matmul_into_plan(a, b, &mut out, plan);
    out
}

/// `matmul` writing into a caller-provided output (overwrites `out`
/// completely) — the zero-allocation variant the v2 attention API uses.
/// Bitwise identical to [`matmul`] for every input.
///
/// # Panics
///
/// Panics if `out.shape() != (a.rows(), b.cols())` or the inner dims
/// mismatch.
pub fn matmul_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    matmul_into_plan(a, b, out, MatmulPlan::Auto);
}

fn matmul_into_plan(a: &Matrix, b: &Matrix, out: &mut Matrix, plan: MatmulPlan) {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "matmul inner-dim mismatch: {ka} vs {kb}");
    assert_eq!(out.shape(), (m, n), "matmul_into output shape mismatch");
    // the kernel accumulates, so start from zero exactly like the
    // allocating path does
    out.data_mut().iter_mut().for_each(|x| *x = 0.0);
    let bd = b.data();
    let kt = kernels::active();
    let run = |rows: std::ops::Range<usize>, out_rows: &mut [f32]| {
        // ikj order: C[i,:] += A[i,k] * B[k,:] — unit-stride saxpy on
        // both C and B.  One zero-probe per row picks the path: dense
        // rows (the common case) stream branch-free; rows with real
        // zeros (masked attention) keep the per-coefficient skip.
        // Bitwise identical either way — the dense path performs the
        // exact add sequence the skip path would, because there is
        // nothing to skip.
        for (ri, i) in rows.enumerate() {
            let arow = a.row(i);
            let crow = &mut out_rows[ri * n..(ri + 1) * n];
            if arow.iter().any(|&x| x == 0.0) {
                for (k, &aik) in arow.iter().enumerate() {
                    if aik == 0.0 {
                        continue; // sparse-ish rows skip work
                    }
                    (kt.saxpy)(aik, &bd[k * n..(k + 1) * n], crow);
                }
            } else {
                for (k, &aik) in arow.iter().enumerate() {
                    (kt.saxpy)(aik, &bd[k * n..(k + 1) * n], crow);
                }
            }
        }
    };
    if should_par(m, n, ka, plan) {
        pool::parallel_row_blocks(out.data_mut(), m, n, |r, buf| run(r, buf));
    } else {
        run(0..m, out.data_mut());
    }
}

/// `C = A · Bᵀ` with `A: (m,k)`, `B: (n,k)` — the `Q Kᵀ` shape.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_nt_plan(a, b, MatmulPlan::Auto)
}

pub fn matmul_nt_plan(a: &Matrix, b: &Matrix, plan: MatmulPlan) -> Matrix {
    let mut out = Matrix::zeros(a.rows(), b.rows());
    matmul_nt_into_plan(a, b, &mut out, plan);
    out
}

/// `matmul_nt` writing into a caller-provided output (overwrites `out`
/// completely).  Bitwise identical to [`matmul_nt`] for every input.
///
/// # Panics
///
/// Panics if `out.shape() != (a.rows(), b.rows())` or the inner dims
/// mismatch.
pub fn matmul_nt_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    matmul_nt_into_plan(a, b, out, MatmulPlan::Auto);
}

fn matmul_nt_into_plan(a: &Matrix, b: &Matrix, out: &mut Matrix, plan: MatmulPlan) {
    let (m, ka) = a.shape();
    let (n, kb) = b.shape();
    assert_eq!(ka, kb, "matmul_nt inner-dim mismatch: {ka} vs {kb}");
    assert_eq!(out.shape(), (m, n), "matmul_nt_into output shape mismatch");
    let k = ka;
    let kt = kernels::active();
    let run = |rows: std::ops::Range<usize>, out_rows: &mut [f32]| {
        for (ri, i) in rows.enumerate() {
            let arow = a.row(i);
            let crow = &mut out_rows[ri * n..(ri + 1) * n];
            for j in 0..n {
                // the shared dispatched dot kernel: 8-lane fixed
                // accumulation order on every ISA (slices are
                // unit-stride rows of both operands)
                crow[j] = (kt.dot)(arow, b.row(j));
            }
        }
    };
    if should_par(m, n, k, plan) {
        pool::parallel_row_blocks(out.data_mut(), m, n, |r, buf| run(r, buf));
    } else {
        run(0..m, out.data_mut());
    }
}

/// `C = Aᵀ · B` with `A: (k,m)`, `B: (k,n)` — the `Sᵀ V` / pilot-norm shape.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(a.cols(), b.cols());
    matmul_tn_into(a, b, &mut out);
    out
}

/// `matmul_tn` writing into a caller-provided output (overwrites `out`
/// completely).  Bitwise identical to [`matmul_tn`] for every input.
///
/// # Panics
///
/// Panics if `out.shape() != (a.cols(), b.cols())` or the inner dims
/// mismatch.
pub fn matmul_tn_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    let (ka, m) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "matmul_tn inner-dim mismatch: {ka} vs {kb}");
    assert_eq!(out.shape(), (m, n), "matmul_tn_into output shape mismatch");
    out.data_mut().iter_mut().for_each(|x| *x = 0.0);
    // Accumulate rank-1 updates: C += A[k,:]ᵀ ⊗ B[k,:]. Single-threaded —
    // every k touches the whole output, and the m×n outputs here are small
    // (d×p) in all call sites.  The zero-coefficient skip is part of the
    // accumulation order contract: the streaming sketch sessions replay
    // it token by token (see `attention/session.rs`), so both sides now
    // route the row update through the same dispatched saxpy kernel.
    let kt = kernels::active();
    for kk in 0..ka {
        let arow = a.row(kk);
        let brow = b.row(kk);
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            (kt.saxpy)(av, brow, &mut out.data_mut()[i * n..(i + 1) * n]);
        }
    }
}

/// `y = A · x` with `A: (m,k)`, `x: (k,)` — per-row dots on the shared
/// dispatched kernel, so matvec agrees bitwise with a 1-column
/// [`matmul_nt`] of the same operands.
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    let (m, k) = a.shape();
    assert_eq!(k, x.len(), "matvec dim mismatch");
    let kt = kernels::active();
    let mut y = vec![0.0f32; m];
    for i in 0..m {
        y[i] = (kt.dot)(a.row(i), x);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let (_, n) = b.shape();
        Matrix::from_fn(m, n, |i, j| (0..k).map(|kk| a.get(i, kk) * b.get(kk, j)).sum())
    }

    #[test]
    fn matmul_matches_naive() {
        let a = Matrix::from_fn(7, 5, |i, j| (i as f32 - j as f32) * 0.5);
        let b = Matrix::from_fn(5, 9, |i, j| (i * j) as f32 * 0.1 - 1.0);
        let got = matmul(&a, &b);
        let want = naive(&a, &b);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn matmul_nt_matches_transpose() {
        let a = Matrix::from_fn(6, 8, |i, j| ((i + j) as f32).sin());
        let b = Matrix::from_fn(10, 8, |i, j| ((i * 2 + j) as f32).cos());
        let got = matmul_nt(&a, &b);
        let want = matmul(&a, &b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn matmul_tn_matches_transpose() {
        let a = Matrix::from_fn(8, 6, |i, j| (i as f32 * 0.3 - j as f32 * 0.7).tanh());
        let b = Matrix::from_fn(8, 4, |i, j| (i + 3 * j) as f32 * 0.05);
        let got = matmul_tn(&a, &b);
        let want = matmul(&a.transpose(), &b);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn threaded_matches_single() {
        let a = Matrix::from_fn(257, 130, |i, j| ((i * 31 + j * 17) % 13) as f32 - 6.0);
        let b = Matrix::from_fn(130, 129, |i, j| ((i * 7 + j * 3) % 11) as f32 * 0.25);
        let st = matmul_plan(&a, &b, MatmulPlan::SingleThread);
        let mt = matmul_plan(&a, &b, MatmulPlan::MultiThread);
        assert!(st.max_abs_diff(&mt) < 1e-4);
        let st2 = matmul_nt_plan(&a, &b.transpose(), MatmulPlan::SingleThread);
        let mt2 = matmul_nt_plan(&a, &b.transpose(), MatmulPlan::MultiThread);
        assert!(st2.max_abs_diff(&mt2) < 1e-4);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_fn(5, 4, |i, j| (i * 4 + j) as f32);
        let x = vec![1.0, -1.0, 2.0, 0.5];
        let y = matvec(&a, &x);
        let xm = Matrix::from_vec(4, 1, x);
        let want = matmul(&a, &xm);
        for i in 0..5 {
            assert!((y[i] - want.get(i, 0)).abs() < 1e-5);
        }
    }

    #[test]
    fn default_plan_override_is_scoped_and_bitwise_neutral() {
        let a = Matrix::from_fn(200, 160, |i, j| ((i * 13 + j * 7) % 17) as f32 - 8.0);
        let b = Matrix::from_fn(160, 190, |i, j| ((i * 5 + j * 11) % 19) as f32 * 0.125);
        let auto = matmul(&a, &b);
        let forced = with_default_plan(MatmulPlan::SingleThread, || matmul(&a, &b));
        // plan changes threading only — outputs are bitwise identical
        assert_eq!(forced.max_abs_diff(&auto), 0.0);
        // the override is scoped: Auto behaviour is restored afterwards
        let again = matmul(&a, &b);
        assert_eq!(again.max_abs_diff(&auto), 0.0);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        let _ = matmul(&a, &b);
    }

    #[test]
    fn into_variants_overwrite_dirty_outputs_bitwise() {
        // the _into kernels must fully overwrite whatever the reused
        // buffer held — stale values from a previous call must not leak
        let a = Matrix::from_fn(9, 6, |i, j| ((i * 3 + j) as f32 * 0.2).sin());
        let b = Matrix::from_fn(6, 7, |i, j| ((i + j * 5) as f32 * 0.1).cos());
        let mut dirty = Matrix::full(9, 7, f32::NAN);
        matmul_into(&a, &b, &mut dirty);
        assert_eq!(dirty.max_abs_diff(&matmul(&a, &b)), 0.0);

        let bt = b.transpose(); // (7, 6)
        let mut dirty = Matrix::full(9, 7, -1e30);
        matmul_nt_into(&a, &bt, &mut dirty);
        assert_eq!(dirty.max_abs_diff(&matmul_nt(&a, &bt)), 0.0);

        let ab = matmul(&a, &b); // (9, 7)
        let mut dirty = Matrix::full(6, 7, 42.0);
        matmul_tn_into(&a, &ab, &mut dirty);
        assert_eq!(dirty.max_abs_diff(&matmul_tn(&a, &ab)), 0.0);
    }

    #[test]
    #[should_panic]
    fn into_variant_rejects_wrong_output_shape() {
        let a = Matrix::zeros(3, 4);
        let b = Matrix::zeros(4, 5);
        let mut out = Matrix::zeros(3, 4);
        matmul_into(&a, &b, &mut out);
    }
}
