//! Element-wise and row-wise operations on [`Matrix`] / `&[f32]`.
//!
//! Everything the attention algorithms (and the softmax structure of the
//! paper) need: stable row softmax, exp, row sums/means, scaling, the
//! geometric-mean fill of Eq. (6), and small vector helpers.
//!
//! The dense row-contiguous loops (softmax passes, exp, scaling, dot,
//! axpy, row norms) dispatch through [`kernels`] so every ISA variant
//! is bitwise identical; the strided column reductions stay as plain
//! element-order loops, which is itself a determinism pin (column
//! accumulation order is row-by-row, unchanged from the seed).

use super::{kernels, Matrix};

/// Numerically-stable softmax applied to every row in place.
///
/// Four dispatched passes per row: row max, shifted exp, row sum,
/// scale by the reciprocal.  `exp(-inf) == 0` exactly in the exp
/// kernel, so masked columns contribute nothing to the sum.
pub fn softmax_rows(m: &mut Matrix) {
    let kt = kernels::active();
    let cols = m.cols();
    for i in 0..m.rows() {
        let row = m.row_mut(i);
        let max = (kt.row_max)(row);
        if !max.is_finite() {
            // fully-masked row: fall back to uniform so downstream stays finite
            let u = 1.0 / cols as f32;
            row.iter_mut().for_each(|x| *x = u);
            continue;
        }
        (kt.exp_shifted)(row, max);
        let sum = (kt.row_sum)(row);
        (kt.scale)(row, 1.0 / sum);
    }
}

/// `exp` applied element-wise in place (dispatched kernel; `x - 0.0`
/// is bitwise `x`, so the shift-by-zero path is exact).
pub fn exp_inplace(m: &mut Matrix) {
    (kernels::active().exp_shifted)(m.data_mut(), 0.0);
}

/// Multiply every element by a scalar in place.
pub fn scale_inplace(m: &mut Matrix, s: f32) {
    (kernels::active().scale)(m.data_mut(), s);
}

/// `a - b`, allocating.
pub fn sub(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x - y).collect();
    Matrix::from_vec(a.rows(), a.cols(), data)
}

/// `a + b`, allocating.
pub fn add(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x + y).collect();
    Matrix::from_vec(a.rows(), a.cols(), data)
}

/// Sum of each row.
pub fn row_sums(m: &Matrix) -> Vec<f32> {
    let kt = kernels::active();
    (0..m.rows()).map(|i| (kt.row_sum)(m.row(i))).collect()
}

/// Mean of each row.
pub fn row_means(m: &Matrix) -> Vec<f32> {
    row_sums(m).iter().map(|s| s / m.cols() as f32).collect()
}

/// ℓ2 norm of each row — the paper's `‖V_(i)‖`.
pub fn row_norms(m: &Matrix) -> Vec<f32> {
    let mut out = vec![0.0f32; m.rows()];
    row_norms_into(m, &mut out);
    out
}

/// [`row_norms`] into a reused buffer (fully overwritten).
pub fn row_norms_into(m: &Matrix, out: &mut [f32]) {
    assert_eq!(out.len(), m.rows(), "row_norms_into length mismatch");
    let kt = kernels::active();
    for (i, o) in out.iter_mut().enumerate() {
        *o = (kt.sum_sq)(m.row(i)).sqrt();
    }
}

/// ℓ2 norm of each column — the paper's `‖B^(i)‖` (strided; used on small
/// pilot strips only, where the strip fits cache).
pub fn col_norms(m: &Matrix) -> Vec<f32> {
    let mut out = vec![0.0f32; m.cols()];
    col_norms_into(m, &mut out);
    out
}

/// [`col_norms`] into a reused buffer (fully overwritten).
pub fn col_norms_into(m: &Matrix, out: &mut [f32]) {
    assert_eq!(out.len(), m.cols(), "col_norms_into length mismatch");
    out.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..m.rows() {
        for (o, &x) in out.iter_mut().zip(m.row(i)) {
            *o += x * x;
        }
    }
    out.iter_mut().for_each(|x| *x = x.sqrt());
}

/// Column sums: `1ᵀ M`.
pub fn col_sums(m: &Matrix) -> Vec<f32> {
    let mut out = vec![0.0f32; m.cols()];
    col_sums_into(m, &mut out);
    out
}

/// [`col_sums`] into a reused buffer (fully overwritten).
pub fn col_sums_into(m: &Matrix, out: &mut [f32]) {
    assert_eq!(out.len(), m.cols(), "col_sums_into length mismatch");
    out.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..m.rows() {
        for (o, &x) in out.iter_mut().zip(m.row(i)) {
            *o += x;
        }
    }
}

/// Row-wise geometric mean computed in log space (Eq. 6's `g`); every
/// element must be > 0 (exp scores are).
pub fn row_geometric_means(m: &Matrix) -> Vec<f32> {
    let mut out = vec![0.0f32; m.rows()];
    row_geometric_means_into(m, &mut out);
    out
}

/// [`row_geometric_means`] into a reused buffer (fully overwritten).
pub fn row_geometric_means_into(m: &Matrix, out: &mut [f32]) {
    assert_eq!(out.len(), m.rows(), "row_geometric_means_into length mismatch");
    for (i, o) in out.iter_mut().enumerate() {
        let row = m.row(i);
        let mean_log: f32 = row.iter().map(|x| x.max(1e-30).ln()).sum::<f32>() / row.len() as f32;
        *o = mean_log.exp();
    }
}

/// Divide each row by the matching scalar (`diag(d)⁻¹ M`).
pub fn scale_rows_inplace(m: &mut Matrix, scales: &[f32]) {
    assert_eq!(scales.len(), m.rows());
    for (i, &s) in scales.iter().enumerate() {
        m.row_mut(i).iter_mut().for_each(|x| *x *= s);
    }
}

/// Dot product on the dispatched kernel — the one accumulation order
/// every dot in the crate shares (matmul_nt rows, matvec, power
/// iteration, the sketch sessions).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    (kernels::active().dot)(a, b)
}

/// ℓ2 norm of a vector.
pub fn norm2(v: &[f32]) -> f32 {
    dot(v, v).sqrt()
}

/// Normalize a vector to unit ℓ2 norm in place; returns the original norm.
pub fn normalize(v: &mut [f32]) -> f32 {
    let n = norm2(v);
    if n > 0.0 {
        let inv = 1.0 / n;
        v.iter_mut().for_each(|x| *x *= inv);
    }
    n
}

/// axpy: `y += a * x` (dispatched kernel; element-wise, so every ISA
/// performs the identical per-element mul-then-add — no FMA).
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    (kernels::active().saxpy)(a, x, y);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_are_stochastic() {
        let mut m = Matrix::from_fn(4, 8, |i, j| (i * j) as f32 * 0.3 - 1.0);
        softmax_rows(&mut m);
        for s in row_sums(&m) {
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!(m.data().iter().all(|&x| (0.0..=1.0).contains(&x)));
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let mut a = Matrix::from_fn(2, 5, |_, j| j as f32);
        let mut b = Matrix::from_fn(2, 5, |_, j| j as f32 + 1000.0);
        softmax_rows(&mut a);
        softmax_rows(&mut b);
        assert!(a.max_abs_diff(&b) < 1e-5);
    }

    #[test]
    fn softmax_handles_fully_masked_row() {
        let mut m = Matrix::full(1, 4, f32::NEG_INFINITY);
        softmax_rows(&mut m);
        for &x in m.data() {
            assert!((x - 0.25).abs() < 1e-6);
        }
    }

    #[test]
    fn norms_match_manual() {
        let m = Matrix::from_rows(&[vec![3.0, 4.0], vec![0.0, 0.0]]);
        assert_eq!(row_norms(&m), vec![5.0, 0.0]);
        let c = col_norms(&m);
        assert!((c[0] - 3.0).abs() < 1e-6 && (c[1] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn geometric_mean_of_constants() {
        let m = Matrix::full(2, 10, 3.0);
        for g in row_geometric_means(&m) {
            assert!((g - 3.0).abs() < 1e-4);
        }
    }

    #[test]
    fn geometric_le_arithmetic() {
        // AM-GM inequality, the heart of Informer's sparsity measurement.
        let m = Matrix::from_fn(5, 16, |i, j| ((i * 37 + j * 11) % 17) as f32 * 0.2 + 0.1);
        let gm = row_geometric_means(&m);
        let am = row_means(&m);
        for (g, a) in gm.iter().zip(&am) {
            assert!(g <= &(a + 1e-5));
        }
    }

    #[test]
    fn scale_rows_matches_diag_product() {
        let mut m = Matrix::from_fn(3, 4, |i, j| (i + j) as f32 + 1.0);
        let orig = m.clone();
        scale_rows_inplace(&mut m, &[2.0, 0.5, -1.0]);
        for j in 0..4 {
            assert_eq!(m.get(0, j), orig.get(0, j) * 2.0);
            assert_eq!(m.get(1, j), orig.get(1, j) * 0.5);
            assert_eq!(m.get(2, j), -orig.get(2, j));
        }
    }

    #[test]
    fn vector_helpers() {
        let mut v = vec![3.0, 4.0];
        assert_eq!(norm2(&v), 5.0);
        let n = normalize(&mut v);
        assert_eq!(n, 5.0);
        assert!((norm2(&v) - 1.0).abs() < 1e-6);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &v, &mut y);
        assert!((y[0] - (1.0 + 2.0 * 0.6)).abs() < 1e-6);
    }
}
