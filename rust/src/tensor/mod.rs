//! Dense f32 linear algebra substrate.
//!
//! The offline environment has no BLAS/ndarray, so the numeric kernels the
//! coordinator-side experiments need (the Figure-1 approximation study, the
//! pure-rust attention implementations, property tests) are built here:
//! a row-major [`Matrix`], blocked/threaded matmul, stable softmax, and the
//! norms the paper's metrics use (Frobenius, spectral via power iteration).
//!
//! Conventions: all matrices are row-major `Vec<f32>`, shape `(rows, cols)`.
//! Methods that allocate return new matrices; `_into` / `*_assign` variants
//! reuse buffers on hot paths.  Batched multi-head inputs live in
//! [`BatchTensor`] (`[batch, heads, seq, head_dim]`, contiguous per head).
//! The dense inner loops (dot, saxpy, softmax passes, dequantise) run on
//! the runtime-dispatched SIMD microkernels in [`kernels`] — every ISA
//! variant is bitwise identical by construction, so dispatch never
//! perturbs the determinism contract.

mod batch;
pub mod kernels;
mod matmul;
mod norms;
mod ops;

pub use batch::BatchTensor;
pub use kernels::KernelIsa;
pub use matmul::{
    matmul, matmul_into, matmul_nt, matmul_nt_into, matmul_nt_plan, matmul_plan, matmul_tn,
    matmul_tn_into, matvec, with_default_plan, MatmulPlan,
};
pub use norms::{frobenius_norm, power_iteration, spectral_norm, spectral_norm_diff};
pub use ops::*;

/// A dense, row-major f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl std::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with a constant.
    pub fn full(rows: usize, cols: usize, value: f32) -> Self {
        Self { rows, cols, data: vec![value; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a generator `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Self { rows, cols, data }
    }

    /// Stack a slice of equal-length rows.
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Self { rows: rows.len(), cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrowed iterator over column `j` — columns are strided, so there
    /// is no slice to hand out, but iterating allocates nothing.  Hot
    /// paths use this; [`col`](Self::col) is the allocating convenience.
    #[inline]
    pub fn col_iter(&self, j: usize) -> impl Iterator<Item = f32> + '_ {
        debug_assert!(j < self.cols);
        self.data.iter().skip(j).step_by(self.cols.max(1)).copied()
    }

    /// Copy column `j` out (columns are strided; this allocates — prefer
    /// [`col_iter`](Self::col_iter) on hot paths).
    pub fn col(&self, j: usize) -> Vec<f32> {
        self.col_iter(j).collect()
    }

    /// New matrix containing the given rows, in order (the paper's
    /// "forming a view" gather — `Q_J`, `K_{J'}`, `V_{J'}`).
    pub fn gather_rows(&self, idx: &[usize]) -> Self {
        let mut data = Vec::with_capacity(idx.len() * self.cols);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        Self { rows: idx.len(), cols: self.cols, data }
    }

    /// [`gather_rows`](Self::gather_rows) into a caller-provided matrix —
    /// the scratch-friendly variant the v2 attention hot paths use.
    ///
    /// # Panics
    ///
    /// Panics unless `out.shape() == (idx.len(), self.cols())`.
    pub fn gather_rows_into(&self, idx: &[usize], out: &mut Self) {
        assert_eq!(out.shape(), (idx.len(), self.cols), "gather_rows_into shape mismatch");
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
    }

    /// Overwrite row `i` from a slice.
    pub fn set_row(&mut self, i: usize, src: &[f32]) {
        assert_eq!(src.len(), self.cols);
        self.row_mut(i).copy_from_slice(src);
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Self {
        let mut out = Self::zeros(self.cols, self.rows);
        // block the transpose for cache friendliness at large sizes
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// True if every element is finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Max absolute element-wise difference to another matrix.
    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_fn(3, 2, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.shape(), (3, 2));
        assert_eq!(m.get(2, 1), 21.0);
        assert_eq!(m.row(1), &[10.0, 11.0]);
        assert_eq!(m.col(0), vec![0.0, 10.0, 20.0]);
    }

    #[test]
    fn eye_is_identity_under_matmul() {
        let a = Matrix::from_fn(4, 4, |i, j| (i + 2 * j) as f32);
        let i4 = Matrix::eye(4);
        let prod = matmul(&a, &i4);
        assert_eq!(prod, a);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Matrix::from_fn(5, 7, |i, j| (i * 7 + j) as f32);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().get(3, 2), a.get(2, 3));
    }

    #[test]
    fn col_iter_matches_col() {
        let m = Matrix::from_fn(5, 3, |i, j| (i * 3 + j) as f32);
        for j in 0..3 {
            let it: Vec<f32> = m.col_iter(j).collect();
            assert_eq!(it, m.col(j));
            assert_eq!(it.len(), 5);
        }
    }

    #[test]
    fn gather_rows_into_matches_allocating() {
        let a = Matrix::from_fn(6, 3, |i, j| (i * 3 + j) as f32);
        let idx = [4, 0, 4, 2];
        let mut out = Matrix::full(4, 3, f32::NAN);
        a.gather_rows_into(&idx, &mut out);
        assert_eq!(out, a.gather_rows(&idx));
    }

    #[test]
    fn gather_rows_matches_manual() {
        let a = Matrix::from_fn(6, 3, |i, j| (i * 3 + j) as f32);
        let g = a.gather_rows(&[4, 0, 4]);
        assert_eq!(g.rows(), 3);
        assert_eq!(g.row(0), a.row(4));
        assert_eq!(g.row(1), a.row(0));
        assert_eq!(g.row(2), a.row(4));
    }

    #[test]
    #[should_panic]
    fn from_vec_size_mismatch_panics() {
        let _ = Matrix::from_vec(2, 2, vec![1.0; 5]);
    }

    #[test]
    fn max_abs_diff_basic() {
        let a = Matrix::full(2, 2, 1.0);
        let mut b = a.clone();
        b.set(1, 0, 3.5);
        assert_eq!(a.max_abs_diff(&b), 2.5);
    }
}
