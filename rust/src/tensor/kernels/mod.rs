//! Explicit-SIMD microkernels behind a process-wide dispatch table.
//!
//! The sketching methods make attention linear in `n`, so serving cost
//! is dominated by the *constant factor* of the remaining dense inner
//! loops: the `QKᵀ`-shaped row dots, `SᵀV` rank-1 accumulates, the
//! softmax max/exp/sum passes, and the f16/int8 dequantise-on-gather
//! read path of the tiered KV cache.  This module provides those inner
//! loops as per-ISA variants (scalar always; SSE2 and AVX2 via
//! `core::arch` intrinsics when the `simd` cargo feature is on and the
//! CPU supports them), selected once at startup into a [`KernelTable`]
//! of plain function pointers.
//!
//! # The lane-order determinism rule
//!
//! The repo's determinism contract (DESIGN.md §2) requires served bytes
//! to be identical across machines, worker counts, and builds.  SIMD
//! normally breaks that by changing *accumulation order*.  Here every
//! variant — including the scalar fallback — commits to one fixed
//! order, so scalar, SSE2, and AVX2 are **bitwise identical by
//! construction**:
//!
//! * **Reductions** (`dot`, `row_sum`, `sum_sq`, `row_max`) accumulate
//!   into 8 lanes (`lane[l] ⊕= x[8c + l]`), reduce the lanes with the
//!   fixed tree `s_i = lane_i ⊕ lane_{i+4}` → `t_i = s_i ⊕ s_{i+2}` →
//!   `t_0 ⊕ t_1` — exactly the AVX2 `vextractf128`/`movhlps`/`shufps`
//!   horizontal reduction — then fold the `len % 8` tail in
//!   sequentially.  SSE2 keeps two 4-lane registers (lanes 0–3 / 4–7)
//!   so its first tree level is one `addps`/`maxps`.
//! * **Element-wise** kernels (`saxpy`, `scale`, `exp_shifted`,
//!   `dequant_*`) perform the same per-element operation sequence at
//!   any lane width, so they are bitwise-safe at every ISA trivially.
//! * **No FMA.** Fused multiply-add rounds once where `mul`+`add`
//!   rounds twice, which would split scalar from AVX2.  The AVX2 tier
//!   is *gated* on `avx2 && fma && f16c` (the ISA class it targets) but
//!   the kernels emit only separate `_mm256_mul_ps`/`_mm256_add_ps`.
//!   Rust never contracts scalar `a * b + c`, so the mirror holds.
//! * `exp_shifted` uses a Cephes-style polynomial (`sse_mathfun`
//!   lineage) built from exactly-rounded IEEE ops, with the scalar
//!   reference mirroring the *vector* semantics (`minps`/`maxps` NaN
//!   behaviour, emulated floor, ordered-compare blends) lane for lane.
//!
//! All loads are unaligned (`loadu`); nothing here requires aligned
//! buffers.  [`crate::pool::take_scratch`] still rounds capacities to
//! whole lanes so recycled buffers bucket coarsely.
//!
//! # Dispatch
//!
//! [`active`] returns the process-wide table: on first use the `simd`
//! feature gate, `is_x86_feature_detected!`, and the `SKEIN_KERNEL`
//! env override (`avx2|sse2|scalar`) pick the ISA; the CLI's global
//! `--kernel` flag calls [`select`].  The selection is a relaxed
//! atomic — benign to race, because every table produces identical
//! bytes (the property `rust/tests/kernels.rs` pins).  Tests and
//! benches that compare ISAs directly use [`table_for`] instead of
//! flipping the global.

mod scalar;
#[cfg(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64")))]
mod x86;

pub use scalar::f16_bits_to_f32;

use std::sync::atomic::{AtomicU8, Ordering};

/// Accumulator lanes every reduction kernel commits to (one AVX2
/// register of f32s; scalar and SSE2 emulate the same eight).
pub const LANES: usize = 8;

/// The instruction sets a [`KernelTable`] can be built for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum KernelIsa {
    /// Portable fallback — same 8-lane accumulation order as the
    /// vector variants, compiled for any target.
    Scalar = 0,
    /// 128-bit SSE2 (x86-64 baseline); reductions keep two 4-lane
    /// registers to match the 8-lane order.
    Sse2 = 1,
    /// 256-bit AVX2; requires `avx2`, `fma`, and `f16c` at runtime
    /// (the dequant path converts halfs with `vcvtph2ps`; FMA is
    /// detected as part of the ISA class but never emitted — see the
    /// module docs).
    Avx2 = 2,
}

impl KernelIsa {
    pub const ALL: [KernelIsa; 3] = [KernelIsa::Scalar, KernelIsa::Sse2, KernelIsa::Avx2];

    pub fn name(self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Sse2 => "sse2",
            KernelIsa::Avx2 => "avx2",
        }
    }

    /// Parse the `SKEIN_KERNEL` / `--kernel` spelling.
    pub fn parse(s: &str) -> Option<KernelIsa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelIsa::Scalar),
            "sse2" => Some(KernelIsa::Sse2),
            "avx2" => Some(KernelIsa::Avx2),
            _ => None,
        }
    }

    fn from_index(i: u8) -> KernelIsa {
        match i {
            0 => KernelIsa::Scalar,
            1 => KernelIsa::Sse2,
            2 => KernelIsa::Avx2,
            _ => unreachable!("invalid kernel ISA index {i}"),
        }
    }
}

impl std::fmt::Display for KernelIsa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One ISA's set of inner kernels.  Plain `fn` pointers: `Sync`, no
/// indirection beyond one load, and trivially shareable across the
/// worker pool.
pub struct KernelTable {
    pub isa: KernelIsa,
    /// `Σ a[i]·b[i]` in the fixed 8-lane order (`a.len() == b.len()`).
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// `y[i] += a·x[i]` (element-wise; `x.len() == y.len()`).
    pub saxpy: fn(f32, &[f32], &mut [f32]),
    /// Max element with `maxps` semantics (`if acc > x { acc } else
    /// { x }` — an accumulated NaN is *dropped* by the next ordered
    /// compare); `-inf` for an empty slice.
    pub row_max: fn(&[f32]) -> f32,
    /// `Σ x[i]` in the fixed 8-lane order.
    pub row_sum: fn(&[f32]) -> f32,
    /// `Σ x[i]²` in the fixed 8-lane order.
    pub sum_sq: fn(&[f32]) -> f32,
    /// `x[i] *= s` (element-wise).
    pub scale: fn(&mut [f32], f32),
    /// `x[i] = exp(x[i] - shift)` via the shared Cephes-style
    /// polynomial; `exp(-inf) == 0` exactly (mask semantics), `+inf`
    /// stays `+inf`, NaN propagates as the canonical quiet NaN.
    pub exp_shifted: fn(&mut [f32], f32),
    /// Decode IEEE binary16 bits to f32 (exact conversion).
    pub dequant_f16: fn(&[u16], &mut [f32]),
    /// Decode int8 `q` to `q as f32 * scale` (both steps exact for the
    /// tier ladder's power-of-two scales).
    pub dequant_i8: fn(&[i8], f32, &mut [f32]),
}

static SCALAR_TABLE: KernelTable = KernelTable {
    isa: KernelIsa::Scalar,
    dot: scalar::dot,
    saxpy: scalar::saxpy,
    row_max: scalar::row_max,
    row_sum: scalar::row_sum,
    sum_sq: scalar::sum_sq,
    scale: scalar::scale,
    exp_shifted: scalar::exp_shifted,
    dequant_f16: scalar::dequant_f16,
    dequant_i8: scalar::dequant_i8,
};

/// Is `isa` usable in this build on this CPU?
pub fn supported(isa: KernelIsa) -> bool {
    match isa {
        KernelIsa::Scalar => true,
        KernelIsa::Sse2 => have_sse2(),
        KernelIsa::Avx2 => have_avx2(),
    }
}

#[cfg(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64")))]
fn have_sse2() -> bool {
    is_x86_feature_detected!("sse2")
}

#[cfg(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64")))]
fn have_avx2() -> bool {
    // fma rides along as the tier gate (AVX2+FMA class hardware) even
    // though no fmadd is ever emitted; f16c is load-bearing for the
    // dequant path's vcvtph2ps.
    is_x86_feature_detected!("avx2")
        && is_x86_feature_detected!("fma")
        && is_x86_feature_detected!("f16c")
}

#[cfg(not(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64"))))]
fn have_sse2() -> bool {
    false
}

#[cfg(not(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64"))))]
fn have_avx2() -> bool {
    false
}

/// The table for a specific ISA, or `None` when this build/CPU cannot
/// run it.  This is how tests and benches compare ISAs head-to-head
/// without touching the process-wide selection.
pub fn table_for(isa: KernelIsa) -> Option<&'static KernelTable> {
    if !supported(isa) {
        return None;
    }
    match isa {
        KernelIsa::Scalar => Some(&SCALAR_TABLE),
        #[cfg(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64")))]
        KernelIsa::Sse2 => Some(&x86::SSE2_TABLE),
        #[cfg(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64")))]
        KernelIsa::Avx2 => Some(&x86::AVX2_TABLE),
        #[cfg(not(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64"))))]
        KernelIsa::Sse2 | KernelIsa::Avx2 => None,
    }
}

/// Widest ISA this build/CPU supports.
pub fn best_supported() -> KernelIsa {
    if have_avx2() {
        KernelIsa::Avx2
    } else if have_sse2() {
        KernelIsa::Sse2
    } else {
        KernelIsa::Scalar
    }
}

const UNSELECTED: u8 = u8::MAX;
static ACTIVE: AtomicU8 = AtomicU8::new(UNSELECTED);

fn default_isa() -> KernelIsa {
    match std::env::var("SKEIN_KERNEL") {
        Ok(v) => match KernelIsa::parse(&v) {
            Some(isa) if supported(isa) => isa,
            Some(isa) => {
                eprintln!(
                    "skein: SKEIN_KERNEL={isa} unsupported by this build/CPU; using {}",
                    best_supported()
                );
                best_supported()
            }
            None => {
                eprintln!(
                    "skein: SKEIN_KERNEL={v:?} unrecognised (want avx2|sse2|scalar); using {}",
                    best_supported()
                );
                best_supported()
            }
        },
        Err(_) => best_supported(),
    }
}

/// The process-wide kernel table.  First call resolves the default
/// (env override, else widest supported ISA).  Relaxed atomics
/// throughout: a racing [`select`] is benign because every table is
/// bitwise identical.
pub fn active() -> &'static KernelTable {
    let idx = ACTIVE.load(Ordering::Relaxed);
    let isa = if idx == UNSELECTED {
        let isa = default_isa();
        ACTIVE.store(isa as u8, Ordering::Relaxed);
        isa
    } else {
        KernelIsa::from_index(idx)
    };
    table_for(isa).expect("active kernel ISA is always a supported one")
}

/// The ISA [`active`] dispatches to (startup lines, the obs gauge).
pub fn active_isa() -> KernelIsa {
    active().isa
}

/// Pin the process-wide selection (the CLI's global `--kernel` flag).
/// Errors when the ISA is compiled out (`simd` feature off, non-x86)
/// or the CPU lacks it — a pin that silently degraded would defeat its
/// use in the bitwise cross-ISA tests.
pub fn select(isa: KernelIsa) -> Result<(), String> {
    if !supported(isa) {
        return Err(format!(
            "kernel ISA {isa} not available (feature \"simd\" {}; best supported: {})",
            if cfg!(feature = "simd") { "on" } else { "off" },
            best_supported()
        ));
    }
    ACTIVE.store(isa as u8, Ordering::Relaxed);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_names() {
        for isa in KernelIsa::ALL {
            assert_eq!(KernelIsa::parse(isa.name()), Some(isa));
        }
        assert_eq!(KernelIsa::parse("AVX2"), Some(KernelIsa::Avx2));
        assert_eq!(KernelIsa::parse(" scalar "), Some(KernelIsa::Scalar));
        assert_eq!(KernelIsa::parse("avx512"), None);
    }

    #[test]
    fn scalar_is_always_supported_and_active_resolves() {
        assert!(supported(KernelIsa::Scalar));
        assert!(table_for(KernelIsa::Scalar).is_some());
        let t = active();
        assert!(supported(t.isa));
        // best_supported is at least scalar and is what select falls
        // back to rejecting: selecting the active ISA again is a no-op
        select(t.isa).expect("re-selecting the active ISA succeeds");
    }

    #[test]
    fn unsupported_isas_have_no_table() {
        for isa in KernelIsa::ALL {
            assert_eq!(table_for(isa).is_some(), supported(isa));
            if !supported(isa) {
                assert!(select(isa).is_err());
            }
        }
    }
}
