//! Portable reference kernels in the fixed 8-lane accumulation order.
//!
//! These are the semantics definition: the SSE2/AVX2 variants in
//! `x86.rs` must produce bitwise-identical results, and the tails of
//! those vector loops call straight into the per-element helpers here
//! ([`exp_core`], [`f16_bits_to_f32`]).  Scalar mirrors of *vector*
//! instruction semantics are deliberate and load-bearing:
//!
//! * `sel_max(a, x) = if a > x { a } else { x }` is `maxps` — it
//!   returns the second operand on an unordered compare, unlike the
//!   NaN-ignoring `f32::max`.
//! * [`exp_core`]'s clamps mirror `minps`/`maxps` operand order and
//!   its final inf/zero/NaN selects mirror ordered-compare blends.

use super::LANES;

/// The fixed lane-reduction tree (see the module docs of
/// [`super`]): fold lanes 4..8 onto 0..4, then quarters, then the
/// final pair — the exact shape of the AVX2 horizontal reduction.
#[inline]
fn reduce_add(l: &[f32; LANES]) -> f32 {
    let s0 = l[0] + l[4];
    let s1 = l[1] + l[5];
    let s2 = l[2] + l[6];
    let s3 = l[3] + l[7];
    let t0 = s0 + s2;
    let t1 = s1 + s3;
    t0 + t1
}

/// `maxps` semantics: NaN in the accumulator is dropped by the next
/// ordered compare; NaN in the input propagates one step.  The vector
/// kernels' scalar tails use this too.
#[inline]
pub(super) fn sel_max(acc: f32, x: f32) -> f32 {
    if acc > x {
        acc
    } else {
        x
    }
}

pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let k = a.len();
    let chunks = k / LANES;
    let mut lanes = [0.0f32; LANES];
    for c in 0..chunks {
        let o = c * LANES;
        for l in 0..LANES {
            lanes[l] += a[o + l] * b[o + l];
        }
    }
    let mut acc = reduce_add(&lanes);
    for o in chunks * LANES..k {
        acc += a[o] * b[o];
    }
    acc
}

pub(super) fn saxpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

pub(super) fn row_max(xs: &[f32]) -> f32 {
    let k = xs.len();
    let chunks = k / LANES;
    let mut lanes = [f32::NEG_INFINITY; LANES];
    for c in 0..chunks {
        let o = c * LANES;
        for l in 0..LANES {
            lanes[l] = sel_max(lanes[l], xs[o + l]);
        }
    }
    let s0 = sel_max(lanes[0], lanes[4]);
    let s1 = sel_max(lanes[1], lanes[5]);
    let s2 = sel_max(lanes[2], lanes[6]);
    let s3 = sel_max(lanes[3], lanes[7]);
    let t0 = sel_max(s0, s2);
    let t1 = sel_max(s1, s3);
    let mut m = sel_max(t0, t1);
    for o in chunks * LANES..k {
        m = sel_max(m, xs[o]);
    }
    m
}

pub(super) fn row_sum(xs: &[f32]) -> f32 {
    let k = xs.len();
    let chunks = k / LANES;
    let mut lanes = [0.0f32; LANES];
    for c in 0..chunks {
        let o = c * LANES;
        for l in 0..LANES {
            lanes[l] += xs[o + l];
        }
    }
    let mut acc = reduce_add(&lanes);
    for o in chunks * LANES..k {
        acc += xs[o];
    }
    acc
}

pub(super) fn sum_sq(xs: &[f32]) -> f32 {
    let k = xs.len();
    let chunks = k / LANES;
    let mut lanes = [0.0f32; LANES];
    for c in 0..chunks {
        let o = c * LANES;
        for l in 0..LANES {
            lanes[l] += xs[o + l] * xs[o + l];
        }
    }
    let mut acc = reduce_add(&lanes);
    for o in chunks * LANES..k {
        acc += xs[o] * xs[o];
    }
    acc
}

pub(super) fn scale(xs: &mut [f32], s: f32) {
    for x in xs.iter_mut() {
        *x *= s;
    }
}

pub(super) fn exp_shifted(xs: &mut [f32], shift: f32) {
    for x in xs.iter_mut() {
        // x - 0.0 is bitwise x for every x (incl. -0.0, inf, NaN), so
        // exp_inplace reuses this kernel with shift = 0.0
        *x = exp_core(*x - shift);
    }
}

pub(super) fn dequant_f16(src: &[u16], out: &mut [f32]) {
    debug_assert_eq!(src.len(), out.len());
    for (dst, &h) in out.iter_mut().zip(src) {
        *dst = f16_bits_to_f32(h);
    }
}

pub(super) fn dequant_i8(src: &[i8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(src.len(), out.len());
    for (dst, &q) in out.iter_mut().zip(src) {
        // i8 → f32 is exact; the tier ladder's scales are powers of
        // two so the multiply is exact too
        *dst = q as f32 * scale;
    }
}

// ---------------------------------------------------------------------------
// Shared per-element exp (Cephes / sse_mathfun lineage)
// ---------------------------------------------------------------------------

pub(super) const EXP_HI: f32 = 88.3762626647949;
pub(super) const EXP_LO: f32 = -88.3762626647949;
pub(super) const LOG2EF: f32 = 1.44269504088896341;
/// High part of ln(2) — an exactly-representable short binary fraction
/// (0.693359375 = 710/1024) so `fx · C1` loses no low bits.
pub(super) const EXP_C1: f32 = 0.693359375;
pub(super) const EXP_C2: f32 = -2.12194440e-4;
pub(super) const EXP_P0: f32 = 1.9875691500e-4;
pub(super) const EXP_P1: f32 = 1.3981999507e-3;
pub(super) const EXP_P2: f32 = 8.3334519073e-3;
pub(super) const EXP_P3: f32 = 4.1665795894e-2;
pub(super) const EXP_P4: f32 = 1.6666665459e-1;
pub(super) const EXP_P5: f32 = 5.0000001201e-1;

/// `exp(x)` via the classic single-precision Cephes polynomial —
/// every step an exactly-rounded IEEE op, so the SSE2/AVX2 ports in
/// `x86.rs` reproduce it bit for bit.  Relative error vs `f32::exp`
/// is a few ulps over the clamp range; the end selects pin the mask
/// semantics softmax relies on: `exp(-inf) == 0` exactly, overflow
/// saturates to `+inf`, NaN yields the canonical quiet NaN.
pub(super) fn exp_core(x0: f32) -> f32 {
    // minps then maxps, operand order as the vector code issues them:
    // min(x, HI) returns HI when x is NaN, max(t, LO) then keeps NaN
    // out of the pipeline until the final select re-injects it
    let x = if x0 < EXP_HI { x0 } else { EXP_HI };
    let x = if x > EXP_LO { x } else { EXP_LO };
    // fx = floor(x·log2(e) + ½) — round-half-up nearest integer.
    // f32::floor is exact, matching both vroundps and the SSE2
    // truncate-and-adjust emulation for every in-range value.
    let fx = (x * LOG2EF + 0.5).floor();
    // extended-precision ln(2) split keeps x - fx·ln2 accurate
    let x = x - fx * EXP_C1;
    let x = x - fx * EXP_C2;
    let z = x * x;
    let mut y = EXP_P0;
    y = y * x + EXP_P1;
    y = y * x + EXP_P2;
    y = y * x + EXP_P3;
    y = y * x + EXP_P4;
    y = y * x + EXP_P5;
    y = y * z + x;
    y += 1.0;
    // 2^fx by exponent-field construction (fx ∈ [-127, 127] after the
    // clamp; -127 builds +0.0, flushing the bottom edge to zero the
    // same way on every ISA)
    let pow2n = f32::from_bits((((fx as i32) + 127) as u32) << 23);
    let mut r = y * pow2n;
    // ordered-compare selects, same order as the vector blends; NaN
    // input fails both ordered compares and takes only the last
    if x0 > EXP_HI {
        r = f32::INFINITY;
    }
    if x0 < EXP_LO {
        r = 0.0;
    }
    if x0.is_nan() {
        r = f32::NAN;
    }
    r
}

/// Convert IEEE binary16 bits to f32 (exact — every f16 value is
/// representable, and the mapping matches `vcvtph2ps` including sign,
/// subnormal normalisation, and NaN payload placement, which is what
/// lets the AVX2 dequant kernel use the hardware converter).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13) // inf / NaN
    } else if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // subnormal: normalise into an f32 normal
            let mut e = 113u32; // would-be exponent field of 2^-14 * 1.x
            let mut m = man;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x03ff) << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_core_tracks_libm_exp() {
        // a few ulps of relative error across the useful range
        for i in -3000..=3000 {
            let x = i as f32 * 0.0293;
            let got = exp_core(x);
            let want = x.exp();
            let rel = ((got - want) / want).abs();
            assert!(rel < 3e-7, "exp_core({x}) = {got}, libm {want}, rel {rel}");
        }
    }

    #[test]
    fn exp_core_edge_semantics() {
        assert_eq!(exp_core(f32::NEG_INFINITY), 0.0, "mask semantics: exp(-inf) must be 0");
        assert_eq!(exp_core(-1.0e4), 0.0);
        assert_eq!(exp_core(f32::INFINITY), f32::INFINITY);
        assert_eq!(exp_core(1.0e4), f32::INFINITY);
        assert!(exp_core(f32::NAN).is_nan());
        assert_eq!(exp_core(0.0), 1.0);
        assert_eq!(exp_core(-0.0), 1.0);
    }

    #[test]
    fn row_max_uses_maxps_semantics() {
        // NaN first: dropped by the next ordered compare
        assert_eq!(row_max(&[f32::NAN, 2.0]), 2.0);
        // NaN last: propagates
        assert!(row_max(&[2.0, f32::NAN]).is_nan());
        assert_eq!(row_max(&[]), f32::NEG_INFINITY);
        assert_eq!(row_max(&[-3.0]), -3.0);
    }

    #[test]
    fn reductions_match_naive_within_tolerance() {
        let xs: Vec<f32> = (0..37).map(|i| (i as f32 * 0.7).sin()).collect();
        let ys: Vec<f32> = (0..37).map(|i| (i as f32 * 0.3).cos()).collect();
        let naive_dot: f32 = xs.iter().zip(&ys).map(|(a, b)| a * b).sum();
        assert!((dot(&xs, &ys) - naive_dot).abs() < 1e-4);
        let naive_sum: f32 = xs.iter().sum();
        assert!((row_sum(&xs) - naive_sum).abs() < 1e-4);
        let naive_sq: f32 = xs.iter().map(|x| x * x).sum();
        assert!((sum_sq(&xs) - naive_sq).abs() < 1e-4);
    }
}
