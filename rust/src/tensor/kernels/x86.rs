//! SSE2 and AVX2 kernel variants (`core::arch` intrinsics).
//!
//! Compiled only with `--features simd` on x86/x86_64; installed into
//! the dispatch only after `is_x86_feature_detected!` confirms the CPU
//! (see [`super::table_for`]).  Every kernel reproduces the scalar
//! reference in `scalar.rs` bit for bit:
//!
//! * reductions keep the fixed 8-lane accumulation — AVX2 holds one
//!   `__m256`, SSE2 holds two `__m128`s (lanes 0–3 / 4–7) whose first
//!   `addps`/`maxps` *is* level one of the shared reduction tree;
//! * multiplies and adds are issued separately (`mul_ps` + `add_ps`,
//!   never `fmadd`) because FMA's single rounding would split the
//!   variants;
//! * `exp` ports [`scalar::exp_core`] lane-parallel with
//!   ordered-compare blends for the inf/zero/NaN end selects (built
//!   from `and`/`andnot`/`or` — no SSE4.1 `blendvps`);
//! * tails (`len % lanes`) fall through to the scalar per-element
//!   helpers, which are the same arithmetic by construction.
//!
//! All memory access is `loadu`/`storeu` — no alignment requirement.
//!
//! SSE2 lacks `vcvtph2ps`/`pmovsxbd`, so its table points the dequant
//! entries at the scalar decoders (identical results; the conversions
//! are exact either way).

#![allow(unsafe_code)]

#[cfg(target_arch = "x86")]
use core::arch::x86::*;
#[cfg(target_arch = "x86_64")]
use core::arch::x86_64::*;

use super::scalar;
use super::{KernelIsa, KernelTable};

pub(super) static SSE2_TABLE: KernelTable = KernelTable {
    isa: KernelIsa::Sse2,
    dot: dot_sse2,
    saxpy: saxpy_sse2,
    row_max: row_max_sse2,
    row_sum: row_sum_sse2,
    sum_sq: sum_sq_sse2,
    scale: scale_sse2,
    exp_shifted: exp_shifted_sse2,
    // no f16c / pmovsxbd at this tier: the scalar decoders are already
    // exact, so pointing at them keeps the table total without a port
    dequant_f16: scalar::dequant_f16,
    dequant_i8: scalar::dequant_i8,
};

pub(super) static AVX2_TABLE: KernelTable = KernelTable {
    isa: KernelIsa::Avx2,
    dot: dot_avx2,
    saxpy: saxpy_avx2,
    row_max: row_max_avx2,
    row_sum: row_sum_avx2,
    sum_sq: sum_sq_avx2,
    scale: scale_avx2,
    exp_shifted: exp_shifted_avx2,
    dequant_f16: dequant_f16_avx2,
    dequant_i8: dequant_i8_avx2,
};

// ---------------------------------------------------------------------------
// Shared horizontal reductions (the fixed tree)
// ---------------------------------------------------------------------------

/// Levels 2–3 of the reduction tree on a 4-lane register holding
/// `s0..s3`: `t_i = s_i ⊕ s_{i+2}`, then `t_0 ⊕ t_1`.
#[target_feature(enable = "sse2")]
unsafe fn hadd_tree128(s4: __m128) -> f32 {
    let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
    let s1 = _mm_add_ss(s2, _mm_shuffle_ps(s2, s2, 1));
    _mm_cvtss_f32(s1)
}

#[target_feature(enable = "sse2")]
unsafe fn hmax_tree128(s4: __m128) -> f32 {
    let s2 = _mm_max_ps(s4, _mm_movehl_ps(s4, s4));
    let s1 = _mm_max_ss(s2, _mm_shuffle_ps(s2, s2, 1));
    _mm_cvtss_f32(s1)
}

// ---------------------------------------------------------------------------
// SSE2
// ---------------------------------------------------------------------------

fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: reachable only through SSE2_TABLE, which table_for hands
    // out only when sse2 is detected at runtime
    unsafe { dot_sse2_impl(a, b) }
}

#[target_feature(enable = "sse2")]
unsafe fn dot_sse2_impl(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let k = a.len();
    let chunks = k / 8;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc_lo = _mm_setzero_ps(); // lanes 0..4
    let mut acc_hi = _mm_setzero_ps(); // lanes 4..8
    for c in 0..chunks {
        let o = c * 8;
        let p0 = _mm_mul_ps(_mm_loadu_ps(ap.add(o)), _mm_loadu_ps(bp.add(o)));
        let p1 = _mm_mul_ps(_mm_loadu_ps(ap.add(o + 4)), _mm_loadu_ps(bp.add(o + 4)));
        acc_lo = _mm_add_ps(acc_lo, p0);
        acc_hi = _mm_add_ps(acc_hi, p1);
    }
    // level 1 of the tree: lane_i + lane_{i+4}
    let mut s = hadd_tree128(_mm_add_ps(acc_lo, acc_hi));
    for o in chunks * 8..k {
        s += a[o] * b[o];
    }
    s
}

fn saxpy_sse2(a: f32, x: &[f32], y: &mut [f32]) {
    // SAFETY: see dot_sse2
    unsafe { saxpy_sse2_impl(a, x, y) }
}

#[target_feature(enable = "sse2")]
unsafe fn saxpy_sse2_impl(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let va = _mm_set1_ps(a);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    for c in 0..chunks {
        let o = c * 4;
        let prod = _mm_mul_ps(va, _mm_loadu_ps(xp.add(o)));
        _mm_storeu_ps(yp.add(o), _mm_add_ps(_mm_loadu_ps(yp.add(o)), prod));
    }
    for o in chunks * 4..n {
        y[o] += a * x[o];
    }
}

fn row_max_sse2(xs: &[f32]) -> f32 {
    // SAFETY: see dot_sse2
    unsafe { row_max_sse2_impl(xs) }
}

#[target_feature(enable = "sse2")]
unsafe fn row_max_sse2_impl(xs: &[f32]) -> f32 {
    let k = xs.len();
    let chunks = k / 8;
    let p = xs.as_ptr();
    let mut acc_lo = _mm_set1_ps(f32::NEG_INFINITY);
    let mut acc_hi = _mm_set1_ps(f32::NEG_INFINITY);
    for c in 0..chunks {
        let o = c * 8;
        acc_lo = _mm_max_ps(acc_lo, _mm_loadu_ps(p.add(o)));
        acc_hi = _mm_max_ps(acc_hi, _mm_loadu_ps(p.add(o + 4)));
    }
    let mut m = hmax_tree128(_mm_max_ps(acc_lo, acc_hi));
    for o in chunks * 8..k {
        m = scalar::sel_max(m, xs[o]);
    }
    m
}

fn row_sum_sse2(xs: &[f32]) -> f32 {
    // SAFETY: see dot_sse2
    unsafe { row_sum_sse2_impl(xs) }
}

#[target_feature(enable = "sse2")]
unsafe fn row_sum_sse2_impl(xs: &[f32]) -> f32 {
    let k = xs.len();
    let chunks = k / 8;
    let p = xs.as_ptr();
    let mut acc_lo = _mm_setzero_ps();
    let mut acc_hi = _mm_setzero_ps();
    for c in 0..chunks {
        let o = c * 8;
        acc_lo = _mm_add_ps(acc_lo, _mm_loadu_ps(p.add(o)));
        acc_hi = _mm_add_ps(acc_hi, _mm_loadu_ps(p.add(o + 4)));
    }
    let mut s = hadd_tree128(_mm_add_ps(acc_lo, acc_hi));
    for o in chunks * 8..k {
        s += xs[o];
    }
    s
}

fn sum_sq_sse2(xs: &[f32]) -> f32 {
    // SAFETY: see dot_sse2
    unsafe { sum_sq_sse2_impl(xs) }
}

#[target_feature(enable = "sse2")]
unsafe fn sum_sq_sse2_impl(xs: &[f32]) -> f32 {
    let k = xs.len();
    let chunks = k / 8;
    let p = xs.as_ptr();
    let mut acc_lo = _mm_setzero_ps();
    let mut acc_hi = _mm_setzero_ps();
    for c in 0..chunks {
        let o = c * 8;
        let v0 = _mm_loadu_ps(p.add(o));
        let v1 = _mm_loadu_ps(p.add(o + 4));
        acc_lo = _mm_add_ps(acc_lo, _mm_mul_ps(v0, v0));
        acc_hi = _mm_add_ps(acc_hi, _mm_mul_ps(v1, v1));
    }
    let mut s = hadd_tree128(_mm_add_ps(acc_lo, acc_hi));
    for o in chunks * 8..k {
        s += xs[o] * xs[o];
    }
    s
}

fn scale_sse2(xs: &mut [f32], s: f32) {
    // SAFETY: see dot_sse2
    unsafe { scale_sse2_impl(xs, s) }
}

#[target_feature(enable = "sse2")]
unsafe fn scale_sse2_impl(xs: &mut [f32], s: f32) {
    let n = xs.len();
    let chunks = n / 4;
    let vs = _mm_set1_ps(s);
    let p = xs.as_mut_ptr();
    for c in 0..chunks {
        let o = c * 4;
        _mm_storeu_ps(p.add(o), _mm_mul_ps(_mm_loadu_ps(p.add(o)), vs));
    }
    for x in xs[chunks * 4..].iter_mut() {
        *x *= s;
    }
}

fn exp_shifted_sse2(xs: &mut [f32], shift: f32) {
    // SAFETY: see dot_sse2
    unsafe { exp_shifted_sse2_impl(xs, shift) }
}

#[target_feature(enable = "sse2")]
unsafe fn exp_shifted_sse2_impl(xs: &mut [f32], shift: f32) {
    let n = xs.len();
    let chunks = n / 4;
    let p = xs.as_mut_ptr();
    let vshift = _mm_set1_ps(shift);
    for c in 0..chunks {
        let o = c * 4;
        let x0 = _mm_sub_ps(_mm_loadu_ps(p.add(o)), vshift);
        _mm_storeu_ps(p.add(o), exp128(x0));
    }
    for x in xs[chunks * 4..].iter_mut() {
        *x = scalar::exp_core(*x - shift);
    }
}

/// 4-lane port of [`scalar::exp_core`] — same clamps, same polynomial,
/// same end selects, per lane.  `floor` is emulated (no `roundps` in
/// SSE2) by truncate-and-adjust, which is exact over the clamped range
/// and therefore equal to `f32::floor`.
#[target_feature(enable = "sse2")]
unsafe fn exp128(x0: __m128) -> __m128 {
    let hi = _mm_set1_ps(scalar::EXP_HI);
    let lo = _mm_set1_ps(scalar::EXP_LO);
    let mut x = _mm_min_ps(x0, hi);
    x = _mm_max_ps(x, lo);
    let fx0 = _mm_add_ps(_mm_mul_ps(x, _mm_set1_ps(scalar::LOG2EF)), _mm_set1_ps(0.5));
    // floor: truncate toward zero, then subtract 1 where truncation
    // rounded up (negative non-integers)
    let trunc = _mm_cvtepi32_ps(_mm_cvttps_epi32(fx0));
    let adj = _mm_and_ps(_mm_cmpgt_ps(trunc, fx0), _mm_set1_ps(1.0));
    let fx = _mm_sub_ps(trunc, adj);
    x = _mm_sub_ps(x, _mm_mul_ps(fx, _mm_set1_ps(scalar::EXP_C1)));
    x = _mm_sub_ps(x, _mm_mul_ps(fx, _mm_set1_ps(scalar::EXP_C2)));
    let z = _mm_mul_ps(x, x);
    let mut y = _mm_set1_ps(scalar::EXP_P0);
    y = _mm_add_ps(_mm_mul_ps(y, x), _mm_set1_ps(scalar::EXP_P1));
    y = _mm_add_ps(_mm_mul_ps(y, x), _mm_set1_ps(scalar::EXP_P2));
    y = _mm_add_ps(_mm_mul_ps(y, x), _mm_set1_ps(scalar::EXP_P3));
    y = _mm_add_ps(_mm_mul_ps(y, x), _mm_set1_ps(scalar::EXP_P4));
    y = _mm_add_ps(_mm_mul_ps(y, x), _mm_set1_ps(scalar::EXP_P5));
    y = _mm_add_ps(_mm_mul_ps(y, z), x);
    y = _mm_add_ps(y, _mm_set1_ps(1.0));
    let emm = _mm_add_epi32(_mm_cvttps_epi32(fx), _mm_set1_epi32(127));
    let pow2n = _mm_castsi128_ps(_mm_slli_epi32(emm, 23));
    let mut r = _mm_mul_ps(y, pow2n);
    // end selects in the scalar order: overflow → +inf, underflow /
    // -inf → 0, NaN → canonical quiet NaN (ordered compares are false
    // on NaN, so only the last mask fires for it)
    let m_hi = _mm_cmpgt_ps(x0, hi);
    let m_lo = _mm_cmplt_ps(x0, lo);
    let m_nan = _mm_cmpunord_ps(x0, x0);
    r = _mm_or_ps(_mm_andnot_ps(m_hi, r), _mm_and_ps(m_hi, _mm_set1_ps(f32::INFINITY)));
    r = _mm_andnot_ps(m_lo, r);
    r = _mm_or_ps(_mm_andnot_ps(m_nan, r), _mm_and_ps(m_nan, _mm_set1_ps(f32::NAN)));
    r
}

// ---------------------------------------------------------------------------
// AVX2
// ---------------------------------------------------------------------------

fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: reachable only through AVX2_TABLE, which table_for hands
    // out only when avx2+fma+f16c are detected at runtime
    unsafe { dot_avx2_impl(a, b) }
}

#[target_feature(enable = "avx2")]
unsafe fn dot_avx2_impl(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let k = a.len();
    let chunks = k / 8;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let o = c * 8;
        // mul then add — not fmadd — to keep the scalar mirror bitwise
        let prod = _mm256_mul_ps(_mm256_loadu_ps(ap.add(o)), _mm256_loadu_ps(bp.add(o)));
        acc = _mm256_add_ps(acc, prod);
    }
    // level 1 of the tree: low half + high half
    let s4 = _mm_add_ps(_mm256_castps256_ps128(acc), _mm256_extractf128_ps(acc, 1));
    let mut s = hadd_tree128(s4);
    for o in chunks * 8..k {
        s += a[o] * b[o];
    }
    s
}

fn saxpy_avx2(a: f32, x: &[f32], y: &mut [f32]) {
    // SAFETY: see dot_avx2
    unsafe { saxpy_avx2_impl(a, x, y) }
}

#[target_feature(enable = "avx2")]
unsafe fn saxpy_avx2_impl(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    let va = _mm256_set1_ps(a);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    for c in 0..chunks {
        let o = c * 8;
        let prod = _mm256_mul_ps(va, _mm256_loadu_ps(xp.add(o)));
        _mm256_storeu_ps(yp.add(o), _mm256_add_ps(_mm256_loadu_ps(yp.add(o)), prod));
    }
    for o in chunks * 8..n {
        y[o] += a * x[o];
    }
}

fn row_max_avx2(xs: &[f32]) -> f32 {
    // SAFETY: see dot_avx2
    unsafe { row_max_avx2_impl(xs) }
}

#[target_feature(enable = "avx2")]
unsafe fn row_max_avx2_impl(xs: &[f32]) -> f32 {
    let k = xs.len();
    let chunks = k / 8;
    let p = xs.as_ptr();
    let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
    for c in 0..chunks {
        acc = _mm256_max_ps(acc, _mm256_loadu_ps(p.add(c * 8)));
    }
    let s4 = _mm_max_ps(_mm256_castps256_ps128(acc), _mm256_extractf128_ps(acc, 1));
    let mut m = hmax_tree128(s4);
    for o in chunks * 8..k {
        m = scalar::sel_max(m, xs[o]);
    }
    m
}

fn row_sum_avx2(xs: &[f32]) -> f32 {
    // SAFETY: see dot_avx2
    unsafe { row_sum_avx2_impl(xs) }
}

#[target_feature(enable = "avx2")]
unsafe fn row_sum_avx2_impl(xs: &[f32]) -> f32 {
    let k = xs.len();
    let chunks = k / 8;
    let p = xs.as_ptr();
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        acc = _mm256_add_ps(acc, _mm256_loadu_ps(p.add(c * 8)));
    }
    let s4 = _mm_add_ps(_mm256_castps256_ps128(acc), _mm256_extractf128_ps(acc, 1));
    let mut s = hadd_tree128(s4);
    for o in chunks * 8..k {
        s += xs[o];
    }
    s
}

fn sum_sq_avx2(xs: &[f32]) -> f32 {
    // SAFETY: see dot_avx2
    unsafe { sum_sq_avx2_impl(xs) }
}

#[target_feature(enable = "avx2")]
unsafe fn sum_sq_avx2_impl(xs: &[f32]) -> f32 {
    let k = xs.len();
    let chunks = k / 8;
    let p = xs.as_ptr();
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let v = _mm256_loadu_ps(p.add(c * 8));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(v, v));
    }
    let s4 = _mm_add_ps(_mm256_castps256_ps128(acc), _mm256_extractf128_ps(acc, 1));
    let mut s = hadd_tree128(s4);
    for o in chunks * 8..k {
        s += xs[o] * xs[o];
    }
    s
}

fn scale_avx2(xs: &mut [f32], s: f32) {
    // SAFETY: see dot_avx2
    unsafe { scale_avx2_impl(xs, s) }
}

#[target_feature(enable = "avx2")]
unsafe fn scale_avx2_impl(xs: &mut [f32], s: f32) {
    let n = xs.len();
    let chunks = n / 8;
    let vs = _mm256_set1_ps(s);
    let p = xs.as_mut_ptr();
    for c in 0..chunks {
        let o = c * 8;
        _mm256_storeu_ps(p.add(o), _mm256_mul_ps(_mm256_loadu_ps(p.add(o)), vs));
    }
    for x in xs[chunks * 8..].iter_mut() {
        *x *= s;
    }
}

fn exp_shifted_avx2(xs: &mut [f32], shift: f32) {
    // SAFETY: see dot_avx2
    unsafe { exp_shifted_avx2_impl(xs, shift) }
}

#[target_feature(enable = "avx2")]
unsafe fn exp_shifted_avx2_impl(xs: &mut [f32], shift: f32) {
    let n = xs.len();
    let chunks = n / 8;
    let p = xs.as_mut_ptr();
    let vshift = _mm256_set1_ps(shift);
    for c in 0..chunks {
        let o = c * 8;
        let x0 = _mm256_sub_ps(_mm256_loadu_ps(p.add(o)), vshift);
        _mm256_storeu_ps(p.add(o), exp256(x0));
    }
    for x in xs[chunks * 8..].iter_mut() {
        *x = scalar::exp_core(*x - shift);
    }
}

/// 8-lane port of [`scalar::exp_core`]; `vroundps` floor is exact, so
/// it equals both `f32::floor` and the SSE2 emulation.
#[target_feature(enable = "avx2")]
unsafe fn exp256(x0: __m256) -> __m256 {
    let hi = _mm256_set1_ps(scalar::EXP_HI);
    let lo = _mm256_set1_ps(scalar::EXP_LO);
    let mut x = _mm256_min_ps(x0, hi);
    x = _mm256_max_ps(x, lo);
    let fx0 = _mm256_add_ps(_mm256_mul_ps(x, _mm256_set1_ps(scalar::LOG2EF)), _mm256_set1_ps(0.5));
    let fx = _mm256_floor_ps(fx0);
    x = _mm256_sub_ps(x, _mm256_mul_ps(fx, _mm256_set1_ps(scalar::EXP_C1)));
    x = _mm256_sub_ps(x, _mm256_mul_ps(fx, _mm256_set1_ps(scalar::EXP_C2)));
    let z = _mm256_mul_ps(x, x);
    let mut y = _mm256_set1_ps(scalar::EXP_P0);
    y = _mm256_add_ps(_mm256_mul_ps(y, x), _mm256_set1_ps(scalar::EXP_P1));
    y = _mm256_add_ps(_mm256_mul_ps(y, x), _mm256_set1_ps(scalar::EXP_P2));
    y = _mm256_add_ps(_mm256_mul_ps(y, x), _mm256_set1_ps(scalar::EXP_P3));
    y = _mm256_add_ps(_mm256_mul_ps(y, x), _mm256_set1_ps(scalar::EXP_P4));
    y = _mm256_add_ps(_mm256_mul_ps(y, x), _mm256_set1_ps(scalar::EXP_P5));
    y = _mm256_add_ps(_mm256_mul_ps(y, z), x);
    y = _mm256_add_ps(y, _mm256_set1_ps(1.0));
    let emm = _mm256_add_epi32(_mm256_cvttps_epi32(fx), _mm256_set1_epi32(127));
    let pow2n = _mm256_castsi256_ps(_mm256_slli_epi32(emm, 23));
    let mut r = _mm256_mul_ps(y, pow2n);
    let m_hi = _mm256_cmp_ps(x0, hi, _CMP_GT_OQ);
    let m_lo = _mm256_cmp_ps(x0, lo, _CMP_LT_OQ);
    let m_nan = _mm256_cmp_ps(x0, x0, _CMP_UNORD_Q);
    r = _mm256_or_ps(
        _mm256_andnot_ps(m_hi, r),
        _mm256_and_ps(m_hi, _mm256_set1_ps(f32::INFINITY)),
    );
    r = _mm256_andnot_ps(m_lo, r);
    r = _mm256_or_ps(
        _mm256_andnot_ps(m_nan, r),
        _mm256_and_ps(m_nan, _mm256_set1_ps(f32::NAN)),
    );
    r
}

fn dequant_f16_avx2(src: &[u16], out: &mut [f32]) {
    // SAFETY: see dot_avx2 (the table gate includes f16c)
    unsafe { dequant_f16_avx2_impl(src, out) }
}

#[target_feature(enable = "avx2,f16c")]
unsafe fn dequant_f16_avx2_impl(src: &[u16], out: &mut [f32]) {
    debug_assert_eq!(src.len(), out.len());
    let n = src.len();
    let chunks = n / 8;
    let sp = src.as_ptr();
    let op = out.as_mut_ptr();
    for c in 0..chunks {
        let o = c * 8;
        // 8 halfs = 16 bytes; vcvtph2ps is the exact same mapping as
        // the bit-twiddling scalar decoder (f16 → f32 is exact)
        let halfs = _mm_loadu_si128(sp.add(o) as *const __m128i);
        _mm256_storeu_ps(op.add(o), _mm256_cvtph_ps(halfs));
    }
    for o in chunks * 8..n {
        out[o] = scalar::f16_bits_to_f32(src[o]);
    }
}

fn dequant_i8_avx2(src: &[i8], scale: f32, out: &mut [f32]) {
    // SAFETY: see dot_avx2
    unsafe { dequant_i8_avx2_impl(src, scale, out) }
}

#[target_feature(enable = "avx2")]
unsafe fn dequant_i8_avx2_impl(src: &[i8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(src.len(), out.len());
    let n = src.len();
    let chunks = n / 8;
    let sp = src.as_ptr();
    let op = out.as_mut_ptr();
    let vs = _mm256_set1_ps(scale);
    for c in 0..chunks {
        let o = c * 8;
        // 8 bytes sign-extended to i32 (exact), converted to f32
        // (exact), scaled by one IEEE multiply — same three steps as
        // the scalar decoder
        let bytes = _mm_loadl_epi64(sp.add(o) as *const __m128i);
        let ints = _mm256_cvtepi8_epi32(bytes);
        _mm256_storeu_ps(op.add(o), _mm256_mul_ps(_mm256_cvtepi32_ps(ints), vs));
    }
    for o in chunks * 8..n {
        out[o] = src[o] as f32 * scale;
    }
}
