//! Batched multi-head tensor storage: `[batch, heads, seq, head_dim]`.
//!
//! The batched attention engine works over a `B × H` grid of `(seq,
//! head_dim)` head slices.  With `seq` and `head_dim` innermost, every head
//! slice is a *contiguous* run of its backing memory, so per-head access is
//! a zero-copy borrow ([`BatchTensor::head`]) and materialising a head as a
//! [`Matrix`] ([`BatchTensor::head_matrix`]) is a single `memcpy` — no
//! strided gather, no per-element work.
//!
//! # Two storage modes
//!
//! * **Owned** — one contiguous `Vec<f32>` covering the whole grid.  This
//!   is what [`zeros`](BatchTensor::zeros) / [`from_vec`](BatchTensor::from_vec)
//!   build and what the engine writes its outputs into.  Mutable access
//!   ([`data_mut`](BatchTensor::data_mut), [`set_head`](BatchTensor::set_head))
//!   requires owned storage.
//! * **Slab-backed** — [`from_slabs`](BatchTensor::from_slabs) wraps one
//!   `Arc<[f32]>` slab of shape `[heads, seq, head_dim]` *per batch index*,
//!   without copying.  This is the serving path's zero-copy request
//!   packing: each client's Q/K/V slab is read in place by the engine, and
//!   the `Arc` keeps it alive for exactly as long as any tensor view
//!   does.  Slab-backed tensors are **read-only views**: the mutating and
//!   whole-buffer accessors panic (see each method's *Panics* section),
//!   and [`into_vec`](BatchTensor::into_vec) materialises a contiguous
//!   copy on demand.
//!
//! The invariant either way: every slab holds exactly
//! `heads * seq * head_dim` elements and the grid holds
//! `batch * heads * seq * head_dim` total.  Constructors assert this.
//!
//! # Examples
//!
//! ```
//! use std::sync::Arc;
//! use skeinformer::tensor::BatchTensor;
//!
//! // Two clients submit [heads=2, seq=4, head_dim=8] slabs; the batcher
//! // packs them into a B=2 grid without copying either slab.
//! let client_a: Arc<[f32]> = vec![1.0f32; 2 * 4 * 8].into();
//! let client_b: Arc<[f32]> = vec![2.0f32; 2 * 4 * 8].into();
//! let grid = BatchTensor::from_slabs(2, 4, 8, vec![client_a.clone(), client_b]);
//! assert_eq!(grid.shape(), (2, 2, 4, 8));
//! assert_eq!(grid.head(0, 1)[0], 1.0); // reads client_a's memory in place
//! assert_eq!(grid.sequence(1)[0], 2.0);
//! ```

use super::Matrix;
use std::sync::Arc;

/// Backing memory: one contiguous owned buffer, or one shared slab per
/// batch index (the zero-copy serving path).
#[derive(Clone)]
enum Storage {
    Owned(Vec<f32>),
    Slabs(Vec<Arc<[f32]>>),
}

/// A dense, row-major f32 tensor of shape `(batch, heads, seq, dim)`.
#[derive(Clone)]
pub struct BatchTensor {
    batch: usize,
    heads: usize,
    seq: usize,
    dim: usize,
    storage: Storage,
}

impl std::fmt::Debug for BatchTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "BatchTensor({}x{}x{}x{}{})",
            self.batch,
            self.heads,
            self.seq,
            self.dim,
            if self.is_slab_backed() { ", slab-backed" } else { "" }
        )
    }
}

/// Element-wise equality across storage modes: an owned tensor and a
/// slab-backed view with the same shape and values compare equal.
impl PartialEq for BatchTensor {
    fn eq(&self, other: &Self) -> bool {
        self.shape() == other.shape()
            && (0..self.batch).all(|b| self.sequence(b) == other.sequence(b))
    }
}

impl BatchTensor {
    /// All-zeros tensor (owned storage).
    pub fn zeros(batch: usize, heads: usize, seq: usize, dim: usize) -> Self {
        Self {
            batch,
            heads,
            seq,
            dim,
            storage: Storage::Owned(vec![0.0; batch * heads * seq * dim]),
        }
    }

    /// Wrap an existing `[b][h][n][d]` row-major buffer (owned storage).
    ///
    /// # Panics
    ///
    /// Panics unless `data.len() == batch * heads * seq * dim`.
    pub fn from_vec(batch: usize, heads: usize, seq: usize, dim: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), batch * heads * seq * dim, "buffer size mismatch");
        Self { batch, heads, seq, dim, storage: Storage::Owned(data) }
    }

    /// Zero-copy view over one shared `[heads, seq, dim]` slab per batch
    /// index — the serving path's request packing (`batch = slabs.len()`).
    /// The tensor holds an `Arc` clone of each slab; no element is copied
    /// and the client memory stays alive while any view does.  The
    /// resulting tensor is read-only (see the module docs).
    ///
    /// # Panics
    ///
    /// Panics unless every slab holds exactly `heads * seq * dim`
    /// elements.
    pub fn from_slabs(heads: usize, seq: usize, dim: usize, slabs: Vec<Arc<[f32]>>) -> Self {
        let elems = heads * seq * dim;
        for (b, slab) in slabs.iter().enumerate() {
            assert_eq!(
                slab.len(),
                elems,
                "slab {b}: expected heads*seq*dim = {elems} elements, got {}",
                slab.len()
            );
        }
        Self { batch: slabs.len(), heads, seq, dim, storage: Storage::Slabs(slabs) }
    }

    /// Build from a generator `f(b, h, i, j)` (owned storage).
    pub fn from_fn(
        batch: usize,
        heads: usize,
        seq: usize,
        dim: usize,
        mut f: impl FnMut(usize, usize, usize, usize) -> f32,
    ) -> Self {
        let mut data = Vec::with_capacity(batch * heads * seq * dim);
        for b in 0..batch {
            for h in 0..heads {
                for i in 0..seq {
                    for j in 0..dim {
                        data.push(f(b, h, i, j));
                    }
                }
            }
        }
        Self { batch, heads, seq, dim, storage: Storage::Owned(data) }
    }

    /// Stack `batch * heads` equal-shape head matrices (grid order: head
    /// varies fastest; owned storage).
    ///
    /// # Panics
    ///
    /// Panics if `mats.len() != batch * heads`, `mats` is empty, or the
    /// head shapes are ragged.
    pub fn from_heads(batch: usize, heads: usize, mats: &[Matrix]) -> Self {
        assert_eq!(mats.len(), batch * heads, "expected batch*heads matrices");
        assert!(!mats.is_empty(), "from_heads needs at least one head");
        let (seq, dim) = mats[0].shape();
        let mut data = Vec::with_capacity(batch * heads * seq * dim);
        for m in mats {
            assert_eq!(m.shape(), (seq, dim), "ragged head shapes");
            data.extend_from_slice(m.data());
        }
        Self { batch, heads, seq, dim, storage: Storage::Owned(data) }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// `(batch, heads, seq, dim)`.
    pub fn shape(&self) -> (usize, usize, usize, usize) {
        (self.batch, self.heads, self.seq, self.dim)
    }

    /// Number of head slices in the grid (`batch * heads`).
    pub fn head_count(&self) -> usize {
        self.batch * self.heads
    }

    /// Total element count (`batch * heads * seq * dim`).
    pub fn elems(&self) -> usize {
        self.batch * self.heads * self.seq * self.dim
    }

    /// True for zero-copy views built with [`from_slabs`](Self::from_slabs)
    /// (read-only; no single contiguous buffer).
    pub fn is_slab_backed(&self) -> bool {
        matches!(self.storage, Storage::Slabs(_))
    }

    /// Zero-copy borrow of head `(b, h)` as a `seq * dim` row-major slice.
    /// Works for both storage modes — this is the accessor the engine's
    /// per-head dispatch reads through.
    #[inline]
    pub fn head(&self, b: usize, h: usize) -> &[f32] {
        debug_assert!(b < self.batch && h < self.heads);
        let len = self.seq * self.dim;
        match &self.storage {
            Storage::Owned(data) => {
                let o = (b * self.heads + h) * len;
                &data[o..o + len]
            }
            Storage::Slabs(slabs) => {
                let o = h * len;
                &slabs[b][o..o + len]
            }
        }
    }

    /// Mutable zero-copy borrow of head `(b, h)`.
    ///
    /// # Panics
    ///
    /// Panics on slab-backed tensors — they are read-only views of shared
    /// client memory.
    #[inline]
    pub fn head_mut(&mut self, b: usize, h: usize) -> &mut [f32] {
        debug_assert!(b < self.batch && h < self.heads);
        let len = self.seq * self.dim;
        let o = (b * self.heads + h) * len;
        match &mut self.storage {
            Storage::Owned(data) => &mut data[o..o + len],
            Storage::Slabs(_) => panic!("head_mut on a slab-backed (read-only) BatchTensor"),
        }
    }

    /// Head `(b, h)` as a `(seq, dim)` [`Matrix`] — one contiguous memcpy.
    pub fn head_matrix(&self, b: usize, h: usize) -> Matrix {
        Matrix::from_vec(self.seq, self.dim, self.head(b, h).to_vec())
    }

    /// Overwrite head `(b, h)` from a `(seq, dim)` matrix.
    ///
    /// # Panics
    ///
    /// Panics if the shape differs, or on slab-backed tensors (read-only).
    pub fn set_head(&mut self, b: usize, h: usize, m: &Matrix) {
        assert_eq!(m.shape(), (self.seq, self.dim), "head shape mismatch");
        self.head_mut(b, h).copy_from_slice(m.data());
    }

    /// Zero-copy borrow of sequence `b`'s full `[heads, seq, dim]` slab —
    /// the per-request payload the serving path returns.  Works for both
    /// storage modes.
    pub fn sequence(&self, b: usize) -> &[f32] {
        let len = self.heads * self.seq * self.dim;
        match &self.storage {
            Storage::Owned(data) => &data[b * len..(b + 1) * len],
            Storage::Slabs(slabs) => &slabs[b],
        }
    }

    /// The whole grid as one contiguous slice.
    ///
    /// # Panics
    ///
    /// Panics on slab-backed tensors: their batch entries live in separate
    /// client allocations, so no single contiguous borrow exists.  Iterate
    /// [`sequence`](Self::sequence) / [`head`](Self::head), or materialise
    /// with [`into_vec`](Self::into_vec).
    pub fn data(&self) -> &[f32] {
        match &self.storage {
            Storage::Owned(data) => data,
            Storage::Slabs(_) => {
                panic!("data() on a slab-backed BatchTensor — no contiguous buffer; \
                        use sequence()/head() or into_vec()")
            }
        }
    }

    /// Mutable access to the whole grid.
    ///
    /// # Panics
    ///
    /// Panics on slab-backed tensors (read-only views; see [`data`](Self::data)).
    pub fn data_mut(&mut self) -> &mut [f32] {
        match &mut self.storage {
            Storage::Owned(data) => data,
            Storage::Slabs(_) => {
                panic!("data_mut() on a slab-backed (read-only) BatchTensor")
            }
        }
    }

    /// Consume into one contiguous `[b][h][n][d]` buffer.  Free for owned
    /// storage; slab-backed views pay one concatenating copy here (the
    /// only place a slab-backed tensor ever copies).
    pub fn into_vec(self) -> Vec<f32> {
        let elems = self.elems();
        match self.storage {
            Storage::Owned(data) => data,
            Storage::Slabs(slabs) => {
                let mut data = Vec::with_capacity(elems);
                for slab in &slabs {
                    data.extend_from_slice(slab);
                }
                data
            }
        }
    }

    /// True if every element is finite.
    pub fn all_finite(&self) -> bool {
        (0..self.batch).all(|b| self.sequence(b).iter().all(|x| x.is_finite()))
    }

    /// Max absolute element-wise difference to another tensor (any mix of
    /// storage modes).
    ///
    /// # Panics
    ///
    /// Panics if the shapes differ.
    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        assert_eq!(self.shape(), other.shape());
        (0..self.batch)
            .map(|b| {
                self.sequence(b)
                    .iter()
                    .zip(other.sequence(b))
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max)
            })
            .fold(0.0f32, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_slices_are_contiguous_and_correct() {
        let t = BatchTensor::from_fn(2, 3, 4, 5, |b, h, i, j| {
            (b * 1000 + h * 100 + i * 10 + j) as f32
        });
        assert_eq!(t.shape(), (2, 3, 4, 5));
        assert_eq!(t.head_count(), 6);
        let s = t.head(1, 2);
        assert_eq!(s.len(), 20);
        assert_eq!(s[0], 1200.0);
        assert_eq!(s[19], 1234.0);
        let m = t.head_matrix(1, 2);
        assert_eq!(m.shape(), (4, 5));
        assert_eq!(m.get(3, 4), 1234.0);
    }

    #[test]
    fn set_head_roundtrips() {
        let mut t = BatchTensor::zeros(2, 2, 3, 3);
        let m = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        t.set_head(1, 0, &m);
        assert_eq!(t.head_matrix(1, 0), m);
        assert!(t.head(0, 0).iter().all(|&x| x == 0.0));
        assert!(t.head(1, 1).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn from_heads_matches_grid_order() {
        let mats: Vec<Matrix> = (0..4).map(|g| Matrix::full(2, 2, g as f32)).collect();
        let t = BatchTensor::from_heads(2, 2, &mats);
        assert_eq!(t.head(0, 0)[0], 0.0);
        assert_eq!(t.head(0, 1)[0], 1.0);
        assert_eq!(t.head(1, 0)[0], 2.0);
        assert_eq!(t.head(1, 1)[0], 3.0);
    }

    #[test]
    fn sequence_slab_covers_all_heads() {
        let t = BatchTensor::from_fn(2, 2, 2, 2, |b, _, _, _| b as f32);
        let s = t.sequence(1);
        assert_eq!(s.len(), 8);
        assert!(s.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn max_abs_diff_basic() {
        let a = BatchTensor::zeros(1, 2, 2, 2);
        let mut b = a.clone();
        b.data_mut()[5] = -2.5;
        assert_eq!(a.max_abs_diff(&b), 2.5);
    }

    #[test]
    #[should_panic]
    fn from_vec_size_mismatch_panics() {
        let _ = BatchTensor::from_vec(2, 2, 2, 2, vec![0.0; 15]);
    }

    #[test]
    fn slab_view_aliases_client_memory() {
        let owned = BatchTensor::from_fn(3, 2, 4, 5, |b, h, i, j| {
            (b * 1000 + h * 100 + i * 10 + j) as f32
        });
        let slabs: Vec<Arc<[f32]>> =
            (0..3).map(|b| Arc::from(owned.sequence(b).to_vec())).collect();
        let view = BatchTensor::from_slabs(2, 4, 5, slabs.clone());
        assert!(view.is_slab_backed());
        assert_eq!(view.shape(), owned.shape());
        // same bytes, read in place (no copy on construction)
        assert_eq!(view, owned);
        assert_eq!(view.max_abs_diff(&owned), 0.0);
        for b in 0..3 {
            assert!(std::ptr::eq(view.sequence(b).as_ptr(), slabs[b].as_ptr()));
            for h in 0..2 {
                assert_eq!(view.head(b, h), owned.head(b, h));
            }
        }
        // materialising pays the one copy and matches the owned layout
        assert_eq!(view.clone().into_vec(), owned.data().to_vec());
    }

    #[test]
    #[should_panic]
    fn slab_view_rejects_wrong_slab_length() {
        let slab: Arc<[f32]> = vec![0.0f32; 7].into();
        let _ = BatchTensor::from_slabs(2, 4, 5, vec![slab]);
    }

    #[test]
    #[should_panic]
    fn slab_view_is_read_only() {
        let slab: Arc<[f32]> = vec![0.0f32; 2 * 4 * 5].into();
        let mut view = BatchTensor::from_slabs(2, 4, 5, vec![slab]);
        let _ = view.data_mut();
    }

    #[test]
    #[should_panic]
    fn slab_view_has_no_contiguous_data() {
        let slab: Arc<[f32]> = vec![0.0f32; 2 * 4 * 5].into();
        let view = BatchTensor::from_slabs(2, 4, 5, vec![slab.clone(), slab]);
        let _ = view.data();
    }
}
