//! Batched multi-head tensor storage: `[batch, heads, seq, head_dim]`.
//!
//! The batched attention engine works over a `B × H` grid of `(seq,
//! head_dim)` head slices.  With `seq` and `head_dim` innermost, every head
//! slice is a *contiguous* run of the backing buffer, so per-head access is
//! a zero-copy borrow ([`BatchTensor::head`]) and materialising a head as a
//! [`Matrix`] ([`BatchTensor::head_matrix`]) is a single `memcpy` — no
//! strided gather, no per-element work.  Per-sequence output slabs
//! (`[heads, seq, head_dim]` for one batch index) are contiguous too, which
//! is what the serving path hands back to clients.

use super::Matrix;

/// A dense, row-major f32 tensor of shape `(batch, heads, seq, dim)`.
#[derive(Clone, PartialEq)]
pub struct BatchTensor {
    batch: usize,
    heads: usize,
    seq: usize,
    dim: usize,
    data: Vec<f32>,
}

impl std::fmt::Debug for BatchTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "BatchTensor({}x{}x{}x{})",
            self.batch, self.heads, self.seq, self.dim
        )
    }
}

impl BatchTensor {
    /// All-zeros tensor.
    pub fn zeros(batch: usize, heads: usize, seq: usize, dim: usize) -> Self {
        Self { batch, heads, seq, dim, data: vec![0.0; batch * heads * seq * dim] }
    }

    /// Wrap an existing `[b][h][n][d]` row-major buffer.
    pub fn from_vec(batch: usize, heads: usize, seq: usize, dim: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), batch * heads * seq * dim, "buffer size mismatch");
        Self { batch, heads, seq, dim, data }
    }

    /// Build from a generator `f(b, h, i, j)`.
    pub fn from_fn(
        batch: usize,
        heads: usize,
        seq: usize,
        dim: usize,
        mut f: impl FnMut(usize, usize, usize, usize) -> f32,
    ) -> Self {
        let mut data = Vec::with_capacity(batch * heads * seq * dim);
        for b in 0..batch {
            for h in 0..heads {
                for i in 0..seq {
                    for j in 0..dim {
                        data.push(f(b, h, i, j));
                    }
                }
            }
        }
        Self { batch, heads, seq, dim, data }
    }

    /// Stack `batch * heads` equal-shape head matrices (grid order: head
    /// varies fastest).
    pub fn from_heads(batch: usize, heads: usize, mats: &[Matrix]) -> Self {
        assert_eq!(mats.len(), batch * heads, "expected batch*heads matrices");
        assert!(!mats.is_empty(), "from_heads needs at least one head");
        let (seq, dim) = mats[0].shape();
        let mut data = Vec::with_capacity(batch * heads * seq * dim);
        for m in mats {
            assert_eq!(m.shape(), (seq, dim), "ragged head shapes");
            data.extend_from_slice(m.data());
        }
        Self { batch, heads, seq, dim, data }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// `(batch, heads, seq, dim)`.
    pub fn shape(&self) -> (usize, usize, usize, usize) {
        (self.batch, self.heads, self.seq, self.dim)
    }

    /// Number of head slices in the grid (`batch * heads`).
    pub fn head_count(&self) -> usize {
        self.batch * self.heads
    }

    #[inline]
    fn head_offset(&self, b: usize, h: usize) -> usize {
        debug_assert!(b < self.batch && h < self.heads);
        (b * self.heads + h) * self.seq * self.dim
    }

    /// Zero-copy borrow of head `(b, h)` as a `seq * dim` row-major slice.
    #[inline]
    pub fn head(&self, b: usize, h: usize) -> &[f32] {
        let o = self.head_offset(b, h);
        &self.data[o..o + self.seq * self.dim]
    }

    /// Mutable zero-copy borrow of head `(b, h)`.
    #[inline]
    pub fn head_mut(&mut self, b: usize, h: usize) -> &mut [f32] {
        let o = self.head_offset(b, h);
        let len = self.seq * self.dim;
        &mut self.data[o..o + len]
    }

    /// Head `(b, h)` as a `(seq, dim)` [`Matrix`] — one contiguous memcpy.
    pub fn head_matrix(&self, b: usize, h: usize) -> Matrix {
        Matrix::from_vec(self.seq, self.dim, self.head(b, h).to_vec())
    }

    /// Overwrite head `(b, h)` from a `(seq, dim)` matrix.
    pub fn set_head(&mut self, b: usize, h: usize, m: &Matrix) {
        assert_eq!(m.shape(), (self.seq, self.dim), "head shape mismatch");
        self.head_mut(b, h).copy_from_slice(m.data());
    }

    /// Zero-copy borrow of sequence `b`'s full `[heads, seq, dim]` slab —
    /// the per-request payload the serving path returns.
    pub fn sequence(&self, b: usize) -> &[f32] {
        let len = self.heads * self.seq * self.dim;
        &self.data[b * len..(b + 1) * len]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// True if every element is finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Max absolute element-wise difference to another tensor.
    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_slices_are_contiguous_and_correct() {
        let t = BatchTensor::from_fn(2, 3, 4, 5, |b, h, i, j| {
            (b * 1000 + h * 100 + i * 10 + j) as f32
        });
        assert_eq!(t.shape(), (2, 3, 4, 5));
        assert_eq!(t.head_count(), 6);
        let s = t.head(1, 2);
        assert_eq!(s.len(), 20);
        assert_eq!(s[0], 1200.0);
        assert_eq!(s[19], 1234.0);
        let m = t.head_matrix(1, 2);
        assert_eq!(m.shape(), (4, 5));
        assert_eq!(m.get(3, 4), 1234.0);
    }

    #[test]
    fn set_head_roundtrips() {
        let mut t = BatchTensor::zeros(2, 2, 3, 3);
        let m = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        t.set_head(1, 0, &m);
        assert_eq!(t.head_matrix(1, 0), m);
        assert!(t.head(0, 0).iter().all(|&x| x == 0.0));
        assert!(t.head(1, 1).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn from_heads_matches_grid_order() {
        let mats: Vec<Matrix> = (0..4).map(|g| Matrix::full(2, 2, g as f32)).collect();
        let t = BatchTensor::from_heads(2, 2, &mats);
        assert_eq!(t.head(0, 0)[0], 0.0);
        assert_eq!(t.head(0, 1)[0], 1.0);
        assert_eq!(t.head(1, 0)[0], 2.0);
        assert_eq!(t.head(1, 1)[0], 3.0);
    }

    #[test]
    fn sequence_slab_covers_all_heads() {
        let t = BatchTensor::from_fn(2, 2, 2, 2, |b, _, _, _| b as f32);
        let s = t.sequence(1);
        assert_eq!(s.len(), 8);
        assert!(s.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn max_abs_diff_basic() {
        let a = BatchTensor::zeros(1, 2, 2, 2);
        let mut b = a.clone();
        b.data_mut()[5] = -2.5;
        assert_eq!(a.max_abs_diff(&b), 2.5);
    }

    #[test]
    #[should_panic]
    fn from_vec_size_mismatch_panics() {
        let _ = BatchTensor::from_vec(2, 2, 2, 2, vec![0.0; 15]);
    }
}
