//! Typed experiment configuration, loadable from JSON files with CLI
//! overrides — the coordinator's single source of truth for a run.
//!
//! ```
//! use skeinformer::config::ExperimentConfig;
//! let cfg = ExperimentConfig::default();
//! assert_eq!(cfg.model.seq_len, 128);
//! cfg.validate().unwrap();
//! ```

use crate::json::{parse, Json};
use anyhow::{bail, Context, Result};

/// Model hyper-parameters — must mirror `python/compile/model.py`'s
/// `ModelConfig` (the artifact manifests carry the authoritative copy; this
/// struct is checked against the manifest at load time).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub seq_len: usize,
    pub embed: usize,
    pub heads: usize,
    pub layers: usize,
    pub ffn: usize,
    pub classes: usize,
    pub features: usize,
    pub batch: usize,
    pub lr: f64,
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self {
            vocab: 64,
            seq_len: 128,
            embed: 64,
            heads: 2,
            layers: 2,
            ffn: 128,
            classes: 10,
            features: 64,
            batch: 32,
            lr: 1e-4,
        }
    }
}

/// Training-loop parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    /// Hard cap on optimizer steps.
    pub max_steps: usize,
    /// Validation cadence (steps).
    pub eval_every: usize,
    /// Early stopping: halt after this many evals without improvement
    /// (the paper's "10 checking steps" strategy).
    pub patience: usize,
    /// Gradient-accumulation steps (Table 4's `accu`).
    pub grad_accum: usize,
    /// Examples in each validation slice.
    pub eval_examples: usize,
    /// Base RNG seed.
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            max_steps: 400,
            eval_every: 20,
            patience: 10,
            grad_accum: 1,
            eval_examples: 256,
            seed: 42,
        }
    }
}

/// A full experiment: which method, which task, model + training params.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub method: String,
    pub task: String,
    pub artifacts_dir: String,
    pub model: ModelConfig,
    pub train: TrainConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            method: "skeinformer".into(),
            task: "listops".into(),
            artifacts_dir: "artifacts".into(),
            model: ModelConfig::default(),
            train: TrainConfig::default(),
        }
    }
}

pub const KNOWN_TASKS: &[&str] = &["text", "listops", "retrieval", "pathfinder", "image"];

pub const KNOWN_METHODS: &[&str] = &[
    "standard",
    "standard_nodrop",
    "vmean",
    "skeinformer",
    "skein_uniform",
    "skein_no_norm",
    "skein_simple_norm",
    "skein_no_psr",
    "informer",
    "informer_mask",
    "linformer",
    "linformer_jlt",
    "performer",
    "nystromformer",
    "bigbird",
    "reformer",
];

impl ExperimentConfig {
    /// Load from a JSON file; missing fields fall back to defaults.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let j = parse(&text).with_context(|| format!("parsing config {path}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(m) = j.get("method").and_then(Json::as_str) {
            cfg.method = m.to_string();
        }
        if let Some(t) = j.get("task").and_then(Json::as_str) {
            cfg.task = t.to_string();
        }
        if let Some(a) = j.get("artifacts_dir").and_then(Json::as_str) {
            cfg.artifacts_dir = a.to_string();
        }
        if let Some(model) = j.get("model") {
            let m = &mut cfg.model;
            read_usize(model, "vocab", &mut m.vocab);
            read_usize(model, "seq_len", &mut m.seq_len);
            read_usize(model, "embed", &mut m.embed);
            read_usize(model, "heads", &mut m.heads);
            read_usize(model, "layers", &mut m.layers);
            read_usize(model, "ffn", &mut m.ffn);
            read_usize(model, "classes", &mut m.classes);
            read_usize(model, "features", &mut m.features);
            read_usize(model, "batch", &mut m.batch);
            if let Some(x) = model.get("lr").and_then(Json::as_f64) {
                m.lr = x;
            }
        }
        if let Some(train) = j.get("train") {
            let t = &mut cfg.train;
            read_usize(train, "max_steps", &mut t.max_steps);
            read_usize(train, "eval_every", &mut t.eval_every);
            read_usize(train, "patience", &mut t.patience);
            read_usize(train, "grad_accum", &mut t.grad_accum);
            read_usize(train, "eval_examples", &mut t.eval_examples);
            if let Some(x) = train.get("seed").and_then(Json::as_i64) {
                t.seed = x as u64;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize (for experiment provenance next to results).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(self.method.clone())),
            ("task", Json::str(self.task.clone())),
            ("artifacts_dir", Json::str(self.artifacts_dir.clone())),
            (
                "model",
                Json::obj(vec![
                    ("vocab", Json::num(self.model.vocab as f64)),
                    ("seq_len", Json::num(self.model.seq_len as f64)),
                    ("embed", Json::num(self.model.embed as f64)),
                    ("heads", Json::num(self.model.heads as f64)),
                    ("layers", Json::num(self.model.layers as f64)),
                    ("ffn", Json::num(self.model.ffn as f64)),
                    ("classes", Json::num(self.model.classes as f64)),
                    ("features", Json::num(self.model.features as f64)),
                    ("batch", Json::num(self.model.batch as f64)),
                    ("lr", Json::num(self.model.lr)),
                ]),
            ),
            (
                "train",
                Json::obj(vec![
                    ("max_steps", Json::num(self.train.max_steps as f64)),
                    ("eval_every", Json::num(self.train.eval_every as f64)),
                    ("patience", Json::num(self.train.patience as f64)),
                    ("grad_accum", Json::num(self.train.grad_accum as f64)),
                    ("eval_examples", Json::num(self.train.eval_examples as f64)),
                    ("seed", Json::num(self.train.seed as f64)),
                ]),
            ),
        ])
    }

    /// Sanity checks before a run.
    pub fn validate(&self) -> Result<()> {
        if !KNOWN_METHODS.contains(&self.method.as_str()) {
            bail!("unknown method {:?}; known: {KNOWN_METHODS:?}", self.method);
        }
        if !KNOWN_TASKS.contains(&self.task.as_str()) {
            bail!("unknown task {:?}; known: {KNOWN_TASKS:?}", self.task);
        }
        if self.model.embed % self.model.heads != 0 {
            bail!("embed {} not divisible by heads {}", self.model.embed, self.model.heads);
        }
        if self.model.features > self.model.seq_len {
            bail!(
                "feature budget {} exceeds sequence length {}",
                self.model.features,
                self.model.seq_len
            );
        }
        if self.train.eval_every == 0 || self.train.max_steps == 0 {
            bail!("eval_every and max_steps must be positive");
        }
        Ok(())
    }
}

fn read_usize(j: &Json, key: &str, out: &mut usize) {
    if let Some(x) = j.get(key).and_then(Json::as_usize) {
        *out = x;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = ExperimentConfig::default();
        cfg.method = "linformer".into();
        cfg.task = "image".into();
        cfg.model.batch = 8;
        cfg.train.seed = 7;
        let j = cfg.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = parse(r#"{"method": "informer"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.method, "informer");
        assert_eq!(cfg.task, "listops");
        assert_eq!(cfg.model.seq_len, 128);
    }

    #[test]
    fn rejects_unknown_method_and_task() {
        let j = parse(r#"{"method": "magic"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j2 = parse(r#"{"task": "sudoku"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j2).is_err());
    }

    #[test]
    fn rejects_inconsistent_model() {
        let j = parse(r#"{"model": {"embed": 65}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j2 = parse(r#"{"model": {"features": 512}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j2).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("skein_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        let cfg = ExperimentConfig::default();
        std::fs::write(&path, cfg.to_json().to_pretty()).unwrap();
        let back = ExperimentConfig::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg, back);
        let _ = std::fs::remove_dir_all(dir);
    }
}
