//! Synthetic LRA-style tasks (DESIGN.md §10 documents each substitution).
//!
//! Every task implements [`Task`]: an infinite, seeded stream of
//! `(tokens, label)` examples over a shared vocabulary budget.  The
//! [`Batcher`] pads/truncates to the model's sequence length and packs the
//! `(tokens, mask, labels)` arrays the AOT train-step artifact consumes.
//!
//! Task vocabulary convention (shared `vocab = 64` budget):
//! * id 0 — PAD (always masked)
//! * id 1 — CLS/BOS
//! * id 2 — SEP
//! * ids 3.. — task-specific symbols

mod image;
mod listops;
mod pathfinder;
mod retrieval;
mod text;

pub use image::ImageTask;
pub use listops::ListOpsTask;
pub use pathfinder::PathfinderTask;
pub use retrieval::RetrievalTask;
pub use text::TextTask;

use crate::rng::Rng;

pub const PAD: i32 = 0;
pub const CLS: i32 = 1;
pub const SEP: i32 = 2;

/// One labelled example: token ids (un-padded) and a class label.
#[derive(Clone, Debug)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub label: i32,
}

/// A synthetic classification task over token sequences.
pub trait Task: Sync {
    /// Registry name (matches config and the paper's task columns).
    fn name(&self) -> &'static str;
    /// Number of classes.
    fn classes(&self) -> usize;
    /// Upper bound on token ids + 1 (must fit the model vocab).
    fn vocab(&self) -> usize;
    /// Draw one example.
    fn sample(&self, rng: &mut Rng) -> Example;
}

/// Build a task by name.
pub fn by_name(name: &str, seq_len: usize) -> Option<Box<dyn Task>> {
    Some(match name {
        "listops" => Box::new(ListOpsTask::new(seq_len)),
        "text" => Box::new(TextTask::new(seq_len)),
        "retrieval" => Box::new(RetrievalTask::new(seq_len)),
        "pathfinder" => Box::new(PathfinderTask::new(seq_len)),
        "image" => Box::new(ImageTask::new(seq_len)),
        _ => return None,
    })
}

/// All task names, in the paper's Table-1 column order.
pub const TASK_NAMES: &[&str] = &["text", "listops", "retrieval", "pathfinder", "image"];

/// A packed batch in the exact layout the train artifact expects.
#[derive(Clone, Debug)]
pub struct Batch {
    /// (batch × seq_len) row-major token ids.
    pub tokens: Vec<i32>,
    /// (batch × seq_len) 0/1 validity mask.
    pub mask: Vec<f32>,
    /// (batch,) labels.
    pub labels: Vec<i32>,
    pub batch: usize,
    pub seq_len: usize,
}

/// Pads/truncates examples into fixed-shape batches.
pub struct Batcher<'a> {
    task: &'a dyn Task,
    pub batch: usize,
    pub seq_len: usize,
}

impl<'a> Batcher<'a> {
    pub fn new(task: &'a dyn Task, batch: usize, seq_len: usize) -> Self {
        Self { task, batch, seq_len }
    }

    /// Draw one batch from the stream.
    pub fn next_batch(&self, rng: &mut Rng) -> Batch {
        let mut tokens = vec![PAD; self.batch * self.seq_len];
        let mut mask = vec![0.0f32; self.batch * self.seq_len];
        let mut labels = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            let ex = self.task.sample(rng);
            let len = ex.tokens.len().min(self.seq_len);
            let row = b * self.seq_len;
            tokens[row..row + len].copy_from_slice(&ex.tokens[..len]);
            for m in &mut mask[row..row + len] {
                *m = 1.0;
            }
            labels.push(ex.label);
        }
        Batch { tokens, mask, labels, batch: self.batch, seq_len: self.seq_len }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_tasks(seq_len: usize) -> Vec<Box<dyn Task>> {
        TASK_NAMES.iter().map(|n| by_name(n, seq_len).unwrap()).collect()
    }

    #[test]
    fn registry_covers_table1_columns() {
        for name in TASK_NAMES {
            assert!(by_name(name, 128).is_some(), "{name}");
        }
        assert!(by_name("sudoku", 128).is_none());
    }

    #[test]
    fn examples_respect_vocab_and_classes() {
        for task in all_tasks(128) {
            let mut rng = Rng::new(1);
            for _ in 0..50 {
                let ex = task.sample(&mut rng);
                assert!(!ex.tokens.is_empty(), "{}", task.name());
                assert!(
                    ex.tokens.iter().all(|&t| (t as usize) < task.vocab()),
                    "{} token out of vocab",
                    task.name()
                );
                assert!(
                    (ex.label as usize) < task.classes(),
                    "{} label {} out of range",
                    task.name(),
                    ex.label
                );
                assert!(task.vocab() <= 64, "{} vocab too large", task.name());
            }
        }
    }

    #[test]
    fn sampling_is_deterministic_given_seed() {
        for task in all_tasks(128) {
            let a = task.sample(&mut Rng::new(9));
            let b = task.sample(&mut Rng::new(9));
            assert_eq!(a.tokens, b.tokens, "{}", task.name());
            assert_eq!(a.label, b.label);
        }
    }

    #[test]
    fn labels_are_reasonably_balanced() {
        for task in all_tasks(128) {
            let mut rng = Rng::new(3);
            let mut counts = vec![0usize; task.classes()];
            let n = 600;
            for _ in 0..n {
                counts[task.sample(&mut rng).label as usize] += 1;
            }
            let max = *counts.iter().max().unwrap();
            assert!(
                max < n * 9 / 10,
                "{}: dominant class {max}/{n} ({counts:?})",
                task.name()
            );
        }
    }

    #[test]
    fn batcher_pads_and_masks() {
        let task = by_name("text", 64).unwrap();
        let batcher = Batcher::new(task.as_ref(), 4, 64);
        let batch = batcher.next_batch(&mut Rng::new(5));
        assert_eq!(batch.tokens.len(), 4 * 64);
        assert_eq!(batch.mask.len(), 4 * 64);
        assert_eq!(batch.labels.len(), 4);
        for b in 0..4 {
            for i in 0..64 {
                let t = batch.tokens[b * 64 + i];
                let m = batch.mask[b * 64 + i];
                if m == 0.0 {
                    assert_eq!(t, PAD, "padded position has non-PAD token");
                }
            }
            // mask is a prefix of ones
            let row = &batch.mask[b * 64..(b + 1) * 64];
            let ones = row.iter().take_while(|&&m| m == 1.0).count();
            assert!(row[ones..].iter().all(|&m| m == 0.0));
            assert!(ones > 0);
        }
    }
}
