//! Image classification — the CIFAR-10 substitute (DESIGN.md §10):
//! procedurally rendered grayscale glyphs on a small grid, flattened
//! row-major into intensity-bucket tokens.  Ten classes = five shape
//! families × two sizes, with pixel noise and random placement, so the
//! classifier must integrate 2-D structure from a 1-D pixel sequence —
//! the property the LRA Image task tests.

use super::{Example, Task, CLS};
use crate::rng::Rng;

const INTENSITY0: i32 = 3; // 8 intensity buckets: ids 3..10
const N_BUCKETS: i32 = 8;

#[derive(Clone, Copy, Debug)]
enum Shape {
    Square,
    Cross,
    DiagTL, // main diagonal
    HBar,
    VBar,
}

const SHAPES: [Shape; 5] = [Shape::Square, Shape::Cross, Shape::DiagTL, Shape::HBar, Shape::VBar];

pub struct ImageTask {
    grid: usize,
    seq_len: usize,
}

impl ImageTask {
    pub fn new(seq_len: usize) -> Self {
        let mut grid = 2;
        while (grid + 1) * (grid + 1) + 1 <= seq_len {
            grid += 1;
        }
        Self { grid, seq_len }
    }

    pub fn grid(&self) -> usize {
        self.grid
    }

    fn render(&self, shape: Shape, big: bool, rng: &mut Rng) -> Vec<f32> {
        let g = self.grid;
        let size = if big { g * 3 / 4 } else { g * 2 / 5 };
        let size = size.max(2);
        let r0 = rng.below(g - size + 1);
        let c0 = rng.below(g - size + 1);
        let mut img = vec![0.0f32; g * g];
        let put = |r: usize, c: usize, img: &mut Vec<f32>| {
            if r < g && c < g {
                img[r * g + c] = 1.0;
            }
        };
        match shape {
            Shape::Square => {
                for i in 0..size {
                    put(r0, c0 + i, &mut img);
                    put(r0 + size - 1, c0 + i, &mut img);
                    put(r0 + i, c0, &mut img);
                    put(r0 + i, c0 + size - 1, &mut img);
                }
            }
            Shape::Cross => {
                let mid = size / 2;
                for i in 0..size {
                    put(r0 + mid, c0 + i, &mut img);
                    put(r0 + i, c0 + mid, &mut img);
                }
            }
            Shape::DiagTL => {
                for i in 0..size {
                    put(r0 + i, c0 + i, &mut img);
                }
            }
            Shape::HBar => {
                let mid = size / 2;
                for i in 0..size {
                    put(r0 + mid, c0 + i, &mut img);
                }
            }
            Shape::VBar => {
                let mid = size / 2;
                for i in 0..size {
                    put(r0 + i, c0 + mid, &mut img);
                }
            }
        }
        // pixel noise + intensity jitter
        for px in img.iter_mut() {
            if *px > 0.0 {
                *px = (0.7 + 0.3 * rng.uniform()).min(1.0);
            } else if rng.bernoulli(0.04) {
                *px = 0.3 * rng.uniform();
            }
        }
        img
    }

    fn bucketize(img: &[f32]) -> Vec<i32> {
        img.iter()
            .map(|&x| {
                let b = (x * (N_BUCKETS - 1) as f32).round() as i32;
                INTENSITY0 + b.clamp(0, N_BUCKETS - 1)
            })
            .collect()
    }
}

impl Task for ImageTask {
    fn name(&self) -> &'static str {
        "image"
    }

    fn classes(&self) -> usize {
        10 // 5 shapes × 2 sizes
    }

    fn vocab(&self) -> usize {
        (INTENSITY0 + N_BUCKETS) as usize
    }

    fn sample(&self, rng: &mut Rng) -> Example {
        let class = rng.below(10);
        let shape = SHAPES[class % 5];
        let big = class >= 5;
        let img = self.render(shape, big, rng);
        let mut tokens = Vec::with_capacity(self.grid * self.grid + 1);
        tokens.push(CLS);
        tokens.extend(Self::bucketize(&img));
        debug_assert!(tokens.len() <= self.seq_len);
        Example { tokens, label: class as i32 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ten_classes_all_produced() {
        let task = ImageTask::new(128);
        let mut rng = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..500 {
            seen[task.sample(&mut rng).label as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "missing classes: {seen:?}");
    }

    #[test]
    fn images_have_shape_pixels() {
        let task = ImageTask::new(128);
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let ex = task.sample(&mut rng);
            // bright pixels (upper intensity buckets) must exist
            let bright = ex.tokens[1..]
                .iter()
                .filter(|&&t| t >= INTENSITY0 + N_BUCKETS / 2)
                .count();
            assert!(bright >= 2, "almost-empty image");
        }
    }

    #[test]
    fn big_and_small_variants_differ_in_extent() {
        let task = ImageTask::new(128);
        let g = task.grid();
        let mut rng = Rng::new(3);
        // average bright-pixel count: big classes (5..10) > small (0..5)
        let mut bright_small = 0usize;
        let mut bright_big = 0usize;
        let mut n_small = 0usize;
        let mut n_big = 0usize;
        for _ in 0..600 {
            let ex = task.sample(&mut rng);
            let bright = ex.tokens[1..]
                .iter()
                .filter(|&&t| t >= INTENSITY0 + N_BUCKETS / 2)
                .count();
            if ex.label >= 5 {
                bright_big += bright;
                n_big += 1;
            } else {
                bright_small += bright;
                n_small += 1;
            }
        }
        let avg_small = bright_small as f64 / n_small as f64;
        let avg_big = bright_big as f64 / n_big as f64;
        assert!(avg_big > avg_small, "big {avg_big} !> small {avg_small} (grid {g})");
    }

    #[test]
    fn bucketize_range() {
        let img = vec![0.0, 0.5, 1.0];
        let toks = ImageTask::bucketize(&img);
        assert_eq!(toks[0], INTENSITY0);
        assert_eq!(toks[2], INTENSITY0 + N_BUCKETS - 1);
        assert!(toks[1] > toks[0] && toks[1] < toks[2]);
    }
}
