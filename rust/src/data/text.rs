//! Text classification — the IMDb substitute (DESIGN.md §10): a synthetic
//! "sentiment grammar" over a small word-id vocabulary.  Documents are a
//! sequence of clauses; each clause contributes polarity (positive /
//! negative word ids), optionally flipped by a preceding negation token,
//! diluted by neutral filler.  The label is the sign of the summed
//! polarity — token-level evidence spread over a long, variable-length
//! sequence, which is the property the LRA Text task exercises.

use super::{Example, Task, CLS, SEP};
use crate::rng::Rng;

const NEG_WORD0: i32 = 3; // 8 negative word ids: 3..10
const POS_WORD0: i32 = 11; // 8 positive word ids: 11..18
const NEUTRAL0: i32 = 19; // 24 neutral filler ids: 19..42
const NOT: i32 = 43; // negation token
const INTENSIFIER: i32 = 44; // doubles the next clause's weight

pub struct TextTask {
    seq_len: usize,
}

impl TextTask {
    pub fn new(seq_len: usize) -> Self {
        Self { seq_len }
    }

    /// Ground-truth polarity score of a token sequence (the label oracle,
    /// also used by tests).
    pub fn polarity(tokens: &[i32]) -> i32 {
        let mut score = 0i32;
        let mut negate = false;
        let mut weight = 1i32;
        for &t in tokens {
            match t {
                NOT => negate = !negate,
                INTENSIFIER => weight = 2,
                t if (NEG_WORD0..NEG_WORD0 + 8).contains(&t) => {
                    score += if negate { weight } else { -weight };
                    negate = false;
                    weight = 1;
                }
                t if (POS_WORD0..POS_WORD0 + 8).contains(&t) => {
                    score += if negate { -weight } else { weight };
                    negate = false;
                    weight = 1;
                }
                _ => {}
            }
        }
        score
    }
}

impl Task for TextTask {
    fn name(&self) -> &'static str {
        "text"
    }

    fn classes(&self) -> usize {
        2
    }

    fn vocab(&self) -> usize {
        (INTENSIFIER + 1) as usize
    }

    fn sample(&self, rng: &mut Rng) -> Example {
        // choose a target sentiment, then generate until the polarity is
        // clearly on that side (|score| >= 2) so labels are unambiguous.
        loop {
            let min_len = self.seq_len / 2;
            let len = min_len + rng.below(self.seq_len - min_len);
            let mut tokens = Vec::with_capacity(len);
            tokens.push(CLS);
            while tokens.len() < len - 1 {
                let roll = rng.uniform();
                if roll < 0.62 {
                    tokens.push(NEUTRAL0 + rng.below(24) as i32);
                } else if roll < 0.70 {
                    tokens.push(NOT);
                } else if roll < 0.74 {
                    tokens.push(INTENSIFIER);
                } else if roll < 0.87 {
                    tokens.push(POS_WORD0 + rng.below(8) as i32);
                } else {
                    tokens.push(NEG_WORD0 + rng.below(8) as i32);
                }
                // occasional clause boundary
                if rng.bernoulli(0.05) && tokens.len() < len - 1 {
                    tokens.push(SEP);
                }
            }
            let score = Self::polarity(&tokens);
            if score.abs() >= 2 {
                return Example { tokens, label: i32::from(score > 0) };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn polarity_oracle_basics() {
        assert_eq!(TextTask::polarity(&[POS_WORD0, POS_WORD0 + 3]), 2);
        assert_eq!(TextTask::polarity(&[NEG_WORD0]), -1);
        assert_eq!(TextTask::polarity(&[NOT, POS_WORD0]), -1);
        assert_eq!(TextTask::polarity(&[NOT, NOT, POS_WORD0]), 1);
        assert_eq!(TextTask::polarity(&[INTENSIFIER, NEG_WORD0]), -2);
        assert_eq!(TextTask::polarity(&[NEUTRAL0, NEUTRAL0 + 5]), 0);
    }

    #[test]
    fn labels_match_oracle() {
        let task = TextTask::new(128);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let ex = task.sample(&mut rng);
            let score = TextTask::polarity(&ex.tokens);
            assert!(score.abs() >= 2);
            assert_eq!(ex.label, i32::from(score > 0));
        }
    }

    #[test]
    fn lengths_are_variable_and_bounded() {
        let task = TextTask::new(128);
        let mut rng = Rng::new(2);
        let lens: Vec<usize> = (0..100).map(|_| task.sample(&mut rng).tokens.len()).collect();
        assert!(lens.iter().all(|&l| l <= 128 && l >= 32));
        let distinct: std::collections::HashSet<_> = lens.iter().collect();
        assert!(distinct.len() > 10, "lengths not variable");
    }

    #[test]
    fn negation_actually_flips_labels_sometimes() {
        // ensure NOT tokens appear and matter — remove them and the
        // polarity should change for some documents.
        let task = TextTask::new(128);
        let mut rng = Rng::new(3);
        let mut flipped = false;
        for _ in 0..200 {
            let ex = task.sample(&mut rng);
            let without: Vec<i32> =
                ex.tokens.iter().copied().filter(|&t| t != NOT).collect();
            if TextTask::polarity(&without).signum() != TextTask::polarity(&ex.tokens).signum() {
                flipped = true;
                break;
            }
        }
        assert!(flipped, "negation never mattered");
    }
}
