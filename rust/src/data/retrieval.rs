//! Document retrieval — the AAN substitute (DESIGN.md §10): decide whether
//! two documents are "related".  Each document is generated from a topic
//! template (a topic-specific token distribution plus shared noise);
//! related pairs share a topic, unrelated pairs use two distinct topics.
//! The pair is packed as `[CLS] doc1 [SEP] doc2` — matching how the
//! encoder-with-mean-pooling baseline consumes LRA's two-sequence task.

use super::{Example, Task, CLS, SEP};
use crate::rng::Rng;

const TOPIC_WORD0: i32 = 3; // topic vocabulary: 8 topics × 5 signature ids
const N_TOPICS: usize = 8;
const SIG_PER_TOPIC: usize = 5;
const COMMON0: i32 = TOPIC_WORD0 + (N_TOPICS * SIG_PER_TOPIC) as i32; // 43..58 shared words
const N_COMMON: usize = 16;

pub struct RetrievalTask {
    seq_len: usize,
}

impl RetrievalTask {
    pub fn new(seq_len: usize) -> Self {
        Self { seq_len }
    }

    fn gen_doc(&self, topic: usize, len: usize, rng: &mut Rng, out: &mut Vec<i32>) {
        for _ in 0..len {
            if rng.bernoulli(0.35) {
                // signature word from the topic
                out.push(TOPIC_WORD0 + (topic * SIG_PER_TOPIC + rng.below(SIG_PER_TOPIC)) as i32);
            } else {
                out.push(COMMON0 + rng.below(N_COMMON) as i32);
            }
        }
    }

    /// Oracle: dominant topic of a token slice (tests use this to confirm
    /// the signal survives packing).
    pub fn dominant_topic(tokens: &[i32]) -> Option<usize> {
        let mut counts = [0usize; N_TOPICS];
        for &t in tokens {
            if (TOPIC_WORD0..COMMON0).contains(&t) {
                counts[(t - TOPIC_WORD0) as usize / SIG_PER_TOPIC] += 1;
            }
        }
        let (best, &cnt) = counts.iter().enumerate().max_by_key(|(_, c)| **c)?;
        if cnt == 0 {
            None
        } else {
            Some(best)
        }
    }
}

impl Task for RetrievalTask {
    fn name(&self) -> &'static str {
        "retrieval"
    }

    fn classes(&self) -> usize {
        2
    }

    fn vocab(&self) -> usize {
        (COMMON0 as usize) + N_COMMON
    }

    fn sample(&self, rng: &mut Rng) -> Example {
        let related = rng.bernoulli(0.5);
        let t1 = rng.below(N_TOPICS);
        let t2 = if related {
            t1
        } else {
            // pick a different topic
            let mut t = rng.below(N_TOPICS - 1);
            if t >= t1 {
                t += 1;
            }
            t
        };
        // budget: CLS + doc1 + SEP + doc2
        let body = self.seq_len - 2;
        let len1 = body / 3 + rng.below(body / 6 + 1);
        let len2 = body - len1 - 1;
        let mut tokens = Vec::with_capacity(self.seq_len);
        tokens.push(CLS);
        self.gen_doc(t1, len1, rng, &mut tokens);
        tokens.push(SEP);
        self.gen_doc(t2, len2.min(body - len1), rng, &mut tokens);
        Example { tokens, label: i32::from(related) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn related_pairs_share_dominant_topic() {
        let task = RetrievalTask::new(128);
        let mut rng = Rng::new(1);
        let mut checked = 0;
        for _ in 0..200 {
            let ex = task.sample(&mut rng);
            let sep_pos = ex.tokens.iter().position(|&t| t == SEP).unwrap();
            let d1 = RetrievalTask::dominant_topic(&ex.tokens[..sep_pos]);
            let d2 = RetrievalTask::dominant_topic(&ex.tokens[sep_pos..]);
            let (Some(d1), Some(d2)) = (d1, d2) else { continue };
            checked += 1;
            if ex.label == 1 {
                assert_eq!(d1, d2, "related pair with different topics");
            } else {
                // unrelated docs *usually* differ; sampling noise can
                // occasionally align the noisy estimate, so just count.
            }
        }
        assert!(checked > 150);
    }

    #[test]
    fn unrelated_pairs_mostly_differ() {
        let task = RetrievalTask::new(128);
        let mut rng = Rng::new(2);
        let mut diff = 0;
        let mut total = 0;
        for _ in 0..300 {
            let ex = task.sample(&mut rng);
            if ex.label == 1 {
                continue;
            }
            let sep_pos = ex.tokens.iter().position(|&t| t == SEP).unwrap();
            let d1 = RetrievalTask::dominant_topic(&ex.tokens[..sep_pos]);
            let d2 = RetrievalTask::dominant_topic(&ex.tokens[sep_pos..]);
            if let (Some(d1), Some(d2)) = (d1, d2) {
                total += 1;
                if d1 != d2 {
                    diff += 1;
                }
            }
        }
        assert!(diff as f64 > total as f64 * 0.9, "{diff}/{total}");
    }

    #[test]
    fn packing_layout() {
        let task = RetrievalTask::new(96);
        let mut rng = Rng::new(3);
        let ex = task.sample(&mut rng);
        assert_eq!(ex.tokens[0], CLS);
        assert_eq!(ex.tokens.iter().filter(|&&t| t == SEP).count(), 1);
        assert!(ex.tokens.len() <= 96);
    }
}
