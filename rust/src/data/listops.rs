//! ListOps — an *exact* reproduction of the LRA task family: ListOps is a
//! synthetic dataset by construction (Nangia & Bowman 2018), so no
//! substitution is needed, only a generator with bounded length.
//!
//! Expressions are nested prefix operations over digits:
//! `[MAX 2 9 [MIN 4 7 ] 0 ]` → 9.  Operators: MIN, MAX, MED (median),
//! SM (sum mod 10).  The label is the evaluated result (10 classes).

use super::{Example, Task, CLS};
use crate::rng::Rng;

// token ids (see data/mod.rs convention; 3.. task symbols)
const DIGIT0: i32 = 3; // digits 0..9 -> ids 3..12
const OPEN_MIN: i32 = 13;
const OPEN_MAX: i32 = 14;
const OPEN_MED: i32 = 15;
const OPEN_SM: i32 = 16;
const CLOSE: i32 = 17;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Op {
    Min,
    Max,
    Med,
    SumMod,
}

impl Op {
    fn token(self) -> i32 {
        match self {
            Op::Min => OPEN_MIN,
            Op::Max => OPEN_MAX,
            Op::Med => OPEN_MED,
            Op::SumMod => OPEN_SM,
        }
    }

    fn apply(self, args: &[i64]) -> i64 {
        match self {
            Op::Min => *args.iter().min().unwrap(),
            Op::Max => *args.iter().max().unwrap(),
            Op::Med => {
                let mut v = args.to_vec();
                v.sort_unstable();
                v[v.len() / 2]
            }
            Op::SumMod => args.iter().sum::<i64>() % 10,
        }
    }
}

pub struct ListOpsTask {
    seq_len: usize,
    max_depth: usize,
    max_args: usize,
}

impl ListOpsTask {
    pub fn new(seq_len: usize) -> Self {
        Self { seq_len, max_depth: 4, max_args: 5 }
    }

    /// Generate one expression tree, emitting tokens; returns its value.
    fn gen_expr(&self, rng: &mut Rng, depth: usize, budget: &mut usize, out: &mut Vec<i32>) -> i64 {
        // each node costs at least 2 tokens (open+close) or 1 (digit)
        let want_leaf = depth >= self.max_depth || *budget < 6 || rng.bernoulli(0.35);
        if want_leaf {
            let v = rng.below(10) as i64;
            out.push(DIGIT0 + v as i32);
            *budget = budget.saturating_sub(1);
            return v;
        }
        let op = *[Op::Min, Op::Max, Op::Med, Op::SumMod]
            .get(rng.below(4))
            .unwrap();
        out.push(op.token());
        *budget = budget.saturating_sub(2); // open + close
        let n_args = 2 + rng.below(self.max_args - 1);
        let mut args = Vec::with_capacity(n_args);
        for _ in 0..n_args {
            if *budget == 0 {
                break;
            }
            args.push(self.gen_expr(rng, depth + 1, budget, out));
        }
        if args.is_empty() {
            // degenerate budget case: force one digit argument
            let v = rng.below(10) as i64;
            out.push(DIGIT0 + v as i32);
            args.push(v);
        }
        out.push(CLOSE);
        op.apply(&args)
    }

    /// Evaluate a token sequence back to its value (used by tests to verify
    /// generator/evaluator agreement — the generator's label must equal an
    /// independent parse).
    pub fn evaluate(tokens: &[i32]) -> Option<i64> {
        let mut pos = 0usize;
        let toks: Vec<i32> = tokens.iter().copied().filter(|&t| t != CLS).collect();
        let v = Self::eval_at(&toks, &mut pos)?;
        if pos == toks.len() {
            Some(v)
        } else {
            None
        }
    }

    fn eval_at(tokens: &[i32], pos: &mut usize) -> Option<i64> {
        let t = *tokens.get(*pos)?;
        *pos += 1;
        if (DIGIT0..DIGIT0 + 10).contains(&t) {
            return Some((t - DIGIT0) as i64);
        }
        let op = match t {
            OPEN_MIN => Op::Min,
            OPEN_MAX => Op::Max,
            OPEN_MED => Op::Med,
            OPEN_SM => Op::SumMod,
            _ => return None,
        };
        let mut args = Vec::new();
        loop {
            let nt = *tokens.get(*pos)?;
            if nt == CLOSE {
                *pos += 1;
                break;
            }
            args.push(Self::eval_at(tokens, pos)?);
        }
        if args.is_empty() {
            None
        } else {
            Some(op.apply(&args))
        }
    }
}

impl Task for ListOpsTask {
    fn name(&self) -> &'static str {
        "listops"
    }

    fn classes(&self) -> usize {
        10
    }

    fn vocab(&self) -> usize {
        (CLOSE + 1) as usize
    }

    fn sample(&self, rng: &mut Rng) -> Example {
        let mut tokens = vec![CLS];
        let mut budget = self.seq_len - 2;
        let value = self.gen_expr(rng, 0, &mut budget, &mut tokens);
        debug_assert!(tokens.len() <= self.seq_len);
        Example { tokens, label: value as i32 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_label_matches_independent_evaluator() {
        let task = ListOpsTask::new(128);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let ex = task.sample(&mut rng);
            let val = ListOpsTask::evaluate(&ex.tokens)
                .unwrap_or_else(|| panic!("unparseable: {:?}", ex.tokens));
            assert_eq!(val as i32, ex.label);
        }
    }

    #[test]
    fn respects_sequence_budget() {
        let task = ListOpsTask::new(64);
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let ex = task.sample(&mut rng);
            assert!(ex.tokens.len() <= 64, "len {}", ex.tokens.len());
        }
    }

    #[test]
    fn operators_apply_correctly() {
        assert_eq!(Op::Min.apply(&[3, 1, 4]), 1);
        assert_eq!(Op::Max.apply(&[3, 1, 4]), 4);
        assert_eq!(Op::Med.apply(&[3, 1, 4]), 3);
        assert_eq!(Op::SumMod.apply(&[7, 8]), 5);
    }

    #[test]
    fn evaluator_handles_nesting() {
        // [MAX 2 [MIN 9 4] 0] = max(2, 4, 0) = 4
        let toks = vec![
            OPEN_MAX,
            DIGIT0 + 2,
            OPEN_MIN,
            DIGIT0 + 9,
            DIGIT0 + 4,
            CLOSE,
            DIGIT0,
            CLOSE,
        ];
        assert_eq!(ListOpsTask::evaluate(&toks), Some(4));
    }

    #[test]
    fn evaluator_rejects_malformed() {
        assert_eq!(ListOpsTask::evaluate(&[OPEN_MIN, DIGIT0]), None); // no close
        assert_eq!(ListOpsTask::evaluate(&[CLOSE]), None);
        assert_eq!(ListOpsTask::evaluate(&[OPEN_SM, CLOSE]), None); // no args
    }

    #[test]
    fn expressions_are_actually_nested_sometimes() {
        let task = ListOpsTask::new(128);
        let mut rng = Rng::new(5);
        let mut saw_nested = false;
        for _ in 0..100 {
            let ex = task.sample(&mut rng);
            let opens =
                ex.tokens.iter().filter(|&&t| (OPEN_MIN..=OPEN_SM).contains(&t)).count();
            if opens >= 2 {
                saw_nested = true;
                break;
            }
        }
        assert!(saw_nested, "never generated a nested expression");
    }
}
