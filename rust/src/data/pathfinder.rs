//! Pathfinder — the LRA Pathfinder substitute (DESIGN.md §10): decide
//! whether two endpoint markers on a small grid are connected by a drawn
//! path.  Positive examples draw one self-avoiding lattice path between
//! the endpoints plus distractor fragments; negatives draw two *disjoint*
//! fragments starting at the endpoints plus distractors.  The image is
//! flattened row-major into a pixel-token sequence, so solving it requires
//! integrating spatial evidence across the whole sequence — the
//! long-range-dependency property the LRA task tests.

use super::{Example, Task, CLS};
use crate::rng::Rng;

const EMPTY: i32 = 3;
const PATH: i32 = 4;
const ENDPOINT: i32 = 5;

pub struct PathfinderTask {
    grid: usize,
    seq_len: usize,
}

impl PathfinderTask {
    pub fn new(seq_len: usize) -> Self {
        // grid² + CLS must fit the sequence budget
        let mut grid = 2;
        while (grid + 1) * (grid + 1) + 1 <= seq_len {
            grid += 1;
        }
        Self { grid, seq_len }
    }

    pub fn grid(&self) -> usize {
        self.grid
    }

    fn idx(&self, r: usize, c: usize) -> usize {
        r * self.grid + c
    }

    /// Random walk from `start` biased toward `goal`; marks cells PATH.
    /// Returns true if the goal was reached.
    fn walk(
        &self,
        cells: &mut [i32],
        start: (usize, usize),
        goal: (usize, usize),
        max_steps: usize,
        rng: &mut Rng,
    ) -> bool {
        let (mut r, mut c) = start;
        for _ in 0..max_steps {
            if (r, c) == goal {
                return true;
            }
            // biased step: 70% toward the goal, else random
            let toward = rng.bernoulli(0.7);
            let dr = goal.0 as i64 - r as i64;
            let dc = goal.1 as i64 - c as i64;
            let (nr, nc) = if toward && dr.abs() >= dc.abs() && dr != 0 {
                ((r as i64 + dr.signum()) as usize, c)
            } else if toward && dc != 0 {
                (r, (c as i64 + dc.signum()) as usize)
            } else {
                match rng.below(4) {
                    0 if r + 1 < self.grid => (r + 1, c),
                    1 if r > 0 => (r - 1, c),
                    2 if c + 1 < self.grid => (r, c + 1),
                    _ if c > 0 => (r, c - 1),
                    _ => (r, c),
                }
            };
            r = nr;
            c = nc;
            if cells[self.idx(r, c)] == EMPTY {
                cells[self.idx(r, c)] = PATH;
            }
        }
        (r, c) == goal
    }

    /// Connectivity oracle: BFS over PATH/ENDPOINT cells (tests verify the
    /// generated label against this).
    pub fn connected(cells: &[i32], grid: usize) -> bool {
        let endpoints: Vec<usize> = cells
            .iter()
            .enumerate()
            .filter(|(_, &v)| v == ENDPOINT)
            .map(|(i, _)| i)
            .collect();
        if endpoints.len() != 2 {
            return false;
        }
        let mut seen = vec![false; cells.len()];
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(endpoints[0]);
        seen[endpoints[0]] = true;
        while let Some(i) = queue.pop_front() {
            if i == endpoints[1] {
                return true;
            }
            let (r, c) = (i / grid, i % grid);
            let mut push = |nr: usize, nc: usize, queue: &mut std::collections::VecDeque<usize>| {
                let j = nr * grid + nc;
                if !seen[j] && (cells[j] == PATH || cells[j] == ENDPOINT) {
                    seen[j] = true;
                    queue.push_back(j);
                }
            };
            if r + 1 < grid {
                push(r + 1, c, &mut queue);
            }
            if r > 0 {
                push(r - 1, c, &mut queue);
            }
            if c + 1 < grid {
                push(r, c + 1, &mut queue);
            }
            if c > 0 {
                push(r, c - 1, &mut queue);
            }
        }
        false
    }

    fn random_cell(&self, rng: &mut Rng) -> (usize, usize) {
        (rng.below(self.grid), rng.below(self.grid))
    }
}

impl Task for PathfinderTask {
    fn name(&self) -> &'static str {
        "pathfinder"
    }

    fn classes(&self) -> usize {
        2
    }

    fn vocab(&self) -> usize {
        (ENDPOINT + 1) as usize
    }

    fn sample(&self, rng: &mut Rng) -> Example {
        let g = self.grid;
        loop {
            let mut cells = vec![EMPTY; g * g];
            let want_connected = rng.bernoulli(0.5);
            // two endpoints, far apart
            let a = (rng.below(g / 2), rng.below(g / 2));
            let b = (g / 2 + rng.below(g - g / 2), g / 2 + rng.below(g - g / 2));
            if a == b {
                continue;
            }
            if want_connected {
                let ok = self.walk(&mut cells, a, b, g * g * 3, rng);
                if !ok {
                    continue;
                }
            } else {
                // two short disjoint fragments from each endpoint
                let mid1 = (a.0, (a.1 + 1).min(g - 1));
                let mid2 = (b.0, b.1.saturating_sub(1));
                self.walk(&mut cells, a, mid1, g / 2, rng);
                self.walk(&mut cells, b, mid2, g / 2, rng);
            }
            // distractor fragments
            for _ in 0..2 {
                let s = self.random_cell(rng);
                let t = self.random_cell(rng);
                self.walk(&mut cells, s, t, g, rng);
            }
            cells[self.idx(a.0, a.1)] = ENDPOINT;
            cells[self.idx(b.0, b.1)] = ENDPOINT;

            // verify label with the BFS oracle; regenerate on mismatch
            // (distractors can accidentally bridge the fragments)
            let label = Self::connected(&cells, g);
            if label != want_connected {
                continue;
            }
            let mut tokens = Vec::with_capacity(g * g + 1);
            tokens.push(CLS);
            tokens.extend_from_slice(&cells);
            debug_assert!(tokens.len() <= self.seq_len);
            return Example { tokens, label: i32::from(label) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_fits_budget() {
        for seq in [64, 128, 256] {
            let t = PathfinderTask::new(seq);
            assert!(t.grid() * t.grid() + 1 <= seq);
            assert!((t.grid() + 1) * (t.grid() + 1) + 1 > seq);
        }
    }

    #[test]
    fn labels_verified_by_bfs_oracle() {
        let task = PathfinderTask::new(128);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let ex = task.sample(&mut rng);
            let cells = &ex.tokens[1..];
            let got = PathfinderTask::connected(cells, task.grid());
            assert_eq!(i32::from(got), ex.label);
        }
    }

    #[test]
    fn exactly_two_endpoints() {
        let task = PathfinderTask::new(128);
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let ex = task.sample(&mut rng);
            let n_end = ex.tokens.iter().filter(|&&t| t == ENDPOINT).count();
            assert_eq!(n_end, 2);
        }
    }

    #[test]
    fn bfs_oracle_on_handcrafted_grids() {
        // 3×3: path across the top row
        let g = 3;
        let mut cells = vec![EMPTY; 9];
        cells[0] = ENDPOINT;
        cells[1] = PATH;
        cells[2] = ENDPOINT;
        assert!(PathfinderTask::connected(&cells, g));
        cells[1] = EMPTY;
        assert!(!PathfinderTask::connected(&cells, g));
        // diagonal adjacency does NOT connect
        cells[4] = PATH;
        assert!(!PathfinderTask::connected(&cells, g));
    }
}
