//! Table renderers: turn sweep outcomes into the paper's Tables 1-4 and
//! the Figure-2 CSV series, plus a side-by-side paper-vs-measured view for
//! EXPERIMENTS.md.

use crate::bench_util::ascii_table;
use crate::data::TASK_NAMES;
use crate::train::TrainOutcome;

/// Paper Table-1 accuracies (%), used for the paper-vs-measured report.
pub fn paper_table1(method: &str, task: &str) -> Option<f64> {
    let col = TASK_NAMES.iter().position(|t| *t == task)?;
    // columns: text, listops, retrieval, pathfinder, image
    let row: [f64; 5] = match method {
        "standard" => [57.69, 38.15, 80.10, 73.59, 37.97],
        "standard_nodrop" => [59.44, 38.17, 79.35, 72.35, 37.58],
        "vmean" => [65.29, 28.78, 80.49, 61.01, 34.33],
        "bigbird" => [61.91, 38.86, 79.73, 71.75, 35.00],
        "performer" => [57.67, 37.70, 75.69, 56.50, 37.40],
        "nystromformer" => [60.91, 37.76, 79.87, 72.53, 31.93],
        "reformer" => [62.69, 37.94, 78.85, 69.21, 36.42],
        "linformer" => [58.52, 37.97, 77.40, 55.57, 37.48],
        "linformer_jlt" => [59.12, 37.48, 79.39, 68.45, 35.96],
        "informer" => [61.55, 38.43, 80.88, 59.34, 36.55],
        "informer_mask" => [60.98, 37.26, 79.92, 62.51, 37.19],
        "skeinformer" => [62.47, 38.73, 80.42, 71.51, 37.27],
        "skein_uniform" => [64.48, 30.02, 80.57, 64.35, 36.97],
        "skein_no_norm" => [60.67, 37.69, 78.67, 66.35, 37.06],
        "skein_simple_norm" => [60.26, 38.35, 78.97, 65.41, 39.72],
        "skein_no_psr" => [62.39, 38.12, 79.88, 71.53, 37.20],
        _ => return None,
    };
    Some(row[col])
}

/// Render a Table-1-shaped accuracy table from outcomes.
pub fn table1(outcomes: &[TrainOutcome]) -> String {
    let idx = crate::coordinator::index_outcomes(outcomes);
    let methods = method_order(outcomes);
    let mut headers = vec!["Model"];
    headers.extend(TASK_NAMES.iter().copied());
    headers.push("Average");
    let mut rows = Vec::new();
    for m in &methods {
        let mut row = vec![m.to_string()];
        let mut sum = 0.0;
        let mut count = 0;
        for t in TASK_NAMES {
            match idx.get(t).and_then(|by| by.get(m.as_str())) {
                Some(o) => {
                    row.push(format!("{:.2}", o.best_accuracy * 100.0));
                    sum += o.best_accuracy * 100.0;
                    count += 1;
                }
                None => row.push("-".into()),
            }
        }
        row.push(if count > 0 { format!("{:.2}", sum / count as f64) } else { "-".into() });
        rows.push(row);
    }
    ascii_table(&headers, &rows)
}

/// Render Table-2 (steps (k), min per 1k steps, grad-accum steps).
pub fn table2(outcomes: &[TrainOutcome]) -> String {
    let idx = crate::coordinator::index_outcomes(outcomes);
    let methods = method_order(outcomes);
    let mut headers = vec!["Model".to_string()];
    for t in TASK_NAMES {
        headers.push(format!("{t}:steps"));
        headers.push(format!("{t}:ms/step"));
        headers.push(format!("{t}:accu"));
    }
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut rows = Vec::new();
    for m in &methods {
        let mut row = vec![m.to_string()];
        for t in TASK_NAMES {
            match idx.get(t).and_then(|by| by.get(m.as_str())) {
                Some(o) => {
                    row.push(format!("{}", o.steps));
                    row.push(format!("{:.1}", o.ms_per_step));
                    row.push(format!("{}", o.grad_accum));
                }
                None => {
                    row.extend(["-".to_string(), "-".into(), "-".into()]);
                }
            }
        }
        rows.push(row);
    }
    ascii_table(&header_refs, &rows)
}

/// Render Table-3 (total steps + total time).
pub fn table3(outcomes: &[TrainOutcome]) -> String {
    let idx = crate::coordinator::index_outcomes(outcomes);
    let methods = method_order(outcomes);
    let mut headers = vec!["Model".to_string()];
    for t in TASK_NAMES {
        headers.push(format!("{t}:steps"));
        headers.push(format!("{t}:secs"));
    }
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut rows = Vec::new();
    for m in &methods {
        let mut row = vec![m.to_string()];
        for t in TASK_NAMES {
            match idx.get(t).and_then(|by| by.get(m.as_str())) {
                Some(o) => {
                    row.push(format!("{}", o.steps));
                    row.push(format!("{:.1}", o.seconds));
                }
                None => row.extend(["-".to_string(), "-".into()]),
            }
        }
        rows.push(row);
    }
    ascii_table(&header_refs, &rows)
}

/// Paper-vs-measured accuracy comparison (EXPERIMENTS.md body).
pub fn paper_vs_measured(outcomes: &[TrainOutcome]) -> String {
    let idx = crate::coordinator::index_outcomes(outcomes);
    let methods = method_order(outcomes);
    let mut rows = Vec::new();
    for m in &methods {
        for t in TASK_NAMES {
            if let Some(o) = idx.get(t).and_then(|by| by.get(m.as_str())) {
                let paper = paper_table1(m, t)
                    .map(|x| format!("{x:.2}"))
                    .unwrap_or_else(|| "-".into());
                rows.push(vec![
                    m.to_string(),
                    t.to_string(),
                    paper,
                    format!("{:.2}", o.best_accuracy * 100.0),
                ]);
            }
        }
    }
    ascii_table(&["Model", "Task", "Paper acc%", "Ours acc% (synthetic)"], &rows)
}

/// Figure-2 CSV (all methods' loss curves concatenated).
pub fn figure2_csv(outcomes: &[TrainOutcome]) -> (String, Vec<String>) {
    let mut rows = Vec::new();
    for o in outcomes {
        let label = format!("{}:{}", o.method, o.task);
        rows.extend(o.history.csv_rows(&label));
    }
    (crate::train::History::CSV_HEADER.to_string(), rows)
}

/// Preserve first-seen method order (Table 1 ordering comes from sweep
/// construction, which mirrors the paper's row order).
fn method_order(outcomes: &[TrainOutcome]) -> Vec<String> {
    let mut seen = std::collections::HashSet::new();
    let mut order = Vec::new();
    for o in outcomes {
        if seen.insert(o.method.clone()) {
            order.push(o.method.clone());
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::History;

    fn outcome(method: &str, task: &str, acc: f64) -> TrainOutcome {
        TrainOutcome {
            method: method.into(),
            task: task.into(),
            steps: 100,
            best_accuracy: acc,
            final_accuracy: acc,
            seconds: 12.5,
            ms_per_step: 42.0,
            grad_accum: 2,
            history: History::new(),
        }
    }

    #[test]
    fn paper_numbers_spot_check() {
        assert_eq!(paper_table1("skeinformer", "text"), Some(62.47));
        assert_eq!(paper_table1("standard", "pathfinder"), Some(73.59));
        assert_eq!(paper_table1("vmean", "listops"), Some(28.78));
        assert_eq!(paper_table1("nope", "text"), None);
    }

    #[test]
    fn table1_renders_all_methods() {
        let outcomes = vec![
            outcome("standard", "listops", 0.38),
            outcome("skeinformer", "listops", 0.39),
            outcome("skeinformer", "text", 0.62),
        ];
        let t = table1(&outcomes);
        assert!(t.contains("skeinformer"));
        assert!(t.contains("39.00"));
        assert!(t.contains("Average"));
        // missing cells render as '-'
        assert!(t.contains('-'));
    }

    #[test]
    fn table2_and_3_render() {
        let outcomes = vec![outcome("skeinformer", "image", 0.3)];
        assert!(table2(&outcomes).contains("image:ms/step"));
        assert!(table3(&outcomes).contains("12.5"));
    }

    #[test]
    fn figure2_csv_has_labels() {
        let mut o = outcome("skeinformer", "listops", 0.4);
        o.history.push(crate::train::HistoryPoint {
            step: 10,
            seconds: 1.0,
            train_loss: 2.0,
            val_loss: 2.1,
            val_accuracy: 0.2,
        });
        let (header, rows) = figure2_csv(&[o]);
        assert!(header.starts_with("method,"));
        assert_eq!(rows.len(), 1);
        assert!(rows[0].starts_with("skeinformer:listops,10,"));
    }
}
